"""CI benchmark-regression gate for the wide-aggregation suites.

    PYTHONPATH=src python -m benchmarks.check_regression \
        BENCH_wide_ops.json BENCH_candidate.json --max-slowdown 1.5

Compares the candidate run against the committed baseline on every
(bench, dist, k) key present in BOTH files (so a ``--quick`` candidate
gates against a full baseline) and fails when any op slows down by more
than ``--max-slowdown`` on the gate metric, or when any correctness flag
is False.  The gate metric is best-of-N wall clock by default (one-sided
scheduler noise never deflates it; medians stay in the JSON for
inspection) -- pass ``--metric median`` on quiet machines.

``--calibrate`` divides every key's ratio by the median ratio across all
keys before gating: the committed baseline was recorded on a different
machine than the CI runner, and a uniform hardware-speed factor must not
fail the gate -- only ops that regressed RELATIVE to the rest of the
suite do.  Speedups and new keys are reported but never fail the gate.
"""

from __future__ import annotations

import argparse
import json
import sys


def _key(rec: dict) -> tuple:
    # n_devices is part of the identity: sharded records from a 1-device
    # fallback run must never be compared against true multi-device runs
    # (the gate fails loudly on zero overlap instead)
    return (rec["bench"], rec["dist"], rec["k"], rec.get("n_devices", 1))


def _metrics(a: dict, b: dict, metric: str) -> tuple[float, float]:
    """Pick the SAME metric on both sides -- never mix a best-of baseline
    with a median candidate.

    ``best`` (default) gates on best-of-N wall clock: one-sided noise
    (scheduler bursts on shared runners only ever inflate a sample) makes
    it far more stable than a 3-sample median.  ``median`` is available
    for quiet machines and is always recorded in the JSON either way."""
    if metric == "median" and a.get("median_us") and b.get("median_us"):
        return a["median_us"], b["median_us"]
    return a["wide_us"], b["wide_us"]


def compare(baseline: list[dict], candidate: list[dict],
            max_slowdown: float, min_us: float = 0.0,
            metric: str = "best",
            calibrate: bool = False) -> tuple[list[str], list[str]]:
    """Returns (failures, notes).

    Pairs whose gate metrics both sit under ``min_us`` are scheduler-
    noise-dominated and only reported, never failed (CI passes an explicit
    floor; default 0 keeps the strict contract for local runs).  With
    ``calibrate``, each ratio is divided by the median ratio over all
    compared keys, cancelling uniform machine-speed differences between
    the baseline recorder and the CI runner."""
    import statistics

    base = {_key(r): r for r in baseline}
    failures, notes = [], []
    pairs = []
    for rec in candidate:
        k = _key(rec)
        if not rec.get("correct", True):
            failures.append(f"{k}: correctness check failed")
            continue
        b = base.get(k)
        if b is None:
            notes.append(f"{k}: new bench (no baseline), "
                         f"{rec.get('median_us') or rec['wide_us']:.1f}us")
            continue
        mb, mc = _metrics(b, rec, metric)
        pairs.append((k, mb, mc))
    scale = statistics.median(mc / mb for _, mb, mc in pairs) \
        if calibrate and pairs else 1.0
    if calibrate and pairs:
        notes.append(f"machine calibration factor: {scale:.2f}x "
                     f"(median ratio across {len(pairs)} keys)")
    for k, mb, mc in pairs:
        ratio = mc / mb / scale
        line = f"{k}: {mb:.1f}us -> {mc:.1f}us ({ratio:.2f}x)"
        if ratio > max_slowdown and max(mb, mc) >= min_us:
            failures.append(line + f"  EXCEEDS {max_slowdown}x")
        else:
            notes.append(line)
    if not pairs:
        failures.append("no candidate key overlaps the baseline -- "
                        "wrong file or empty run?")
    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_wide_ops.json")
    ap.add_argument("candidate", nargs="+",
                    help="freshly produced record files; pass ALL suites "
                         "in one invocation so --calibrate's median ratio "
                         "draws on every key (calibrating a single-suite "
                         "subset whose keys share one code path would "
                         "cancel exactly the regressions being gated)")
    ap.add_argument("--max-slowdown", type=float, default=1.5,
                    help="fail when the candidate/baseline ratio of the "
                         "gate metric exceeds this (default 1.5)")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="never fail pairs whose metrics both sit under "
                         "this many microseconds (noise floor; CI uses 500)")
    ap.add_argument("--metric", choices=("best", "median"), default="best",
                    help="gate metric: best-of-N (default; robust to "
                         "one-sided scheduler bursts) or median (falls "
                         "back to best when either record lacks median_us)")
    ap.add_argument("--calibrate", action="store_true",
                    help="divide each ratio by the median ratio across "
                         "keys, cancelling uniform machine-speed "
                         "differences vs the baseline recorder (CI on)")
    ap.add_argument("--report", default="",
                    help="also write the full comparison (every note and "
                         "failure line plus the gate parameters) to this "
                         "JSON file, pass or fail -- CI uploads it as an "
                         "artifact so gate failures are debuggable "
                         "without rerunning locally")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    candidate = []
    for path in args.candidate:
        with open(path) as f:
            candidate += json.load(f)
    failures, notes = compare(baseline, candidate, args.max_slowdown,
                              args.min_us, args.metric, args.calibrate)
    if args.report:
        with open(args.report, "w") as f:
            json.dump({"baseline": args.baseline,
                       "candidates": args.candidate,
                       "metric": args.metric,
                       "max_slowdown": args.max_slowdown,
                       "min_us": args.min_us,
                       "calibrate": args.calibrate,
                       "passed": not failures,
                       "failures": failures,
                       "notes": notes}, f, indent=1)
    for n in notes:
        print(f"ok   {n}")
    for x in failures:
        print(f"FAIL {x}", file=sys.stderr)
    if failures:
        print(f"{len(failures)} regression(s) beyond "
              f"{args.max_slowdown}x", file=sys.stderr)
        return 1
    print(f"gate passed: {len(notes)} compared, none beyond "
          f"{args.max_slowdown}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
