"""One benchmark function per paper table (sections 5.4-5.10, App. B).

All report `name,us_per_call,derived` rows via common.emit; `derived` holds
the paper's own metric (bits/value, cycles/value at 3.4 GHz) so results are
directly comparable to the published tables.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.baselines import STRUCTURES, RoaringSet


def table3_datasets(rows, n_sets=50):
    """Dataset characteristics (paper Table 3)."""
    for name, (sets, universe) in common.datasets(n_sets).items():
        card = float(np.mean([len(s) for s in sets]))
        common.emit(rows, "table3", "stats", "-", name, 0.0,
                    f"universe={universe};avg_card={card:.1f};"
                    f"density={card / universe:.5f}")


def table4_memory(rows, n_sets=50):
    """Memory usage in bits per value (paper Table 4)."""
    for name, (sets, universe) in common.datasets(n_sets).items():
        total_vals = sum(len(s) for s in sets)
        for cls in STRUCTURES:
            built = [cls(v, universe) for v in sets]
            bits = 8.0 * sum(b.memory_bytes() for b in built) / total_vals
            common.emit(rows, "table4", "memory", cls.name, name, 0.0,
                        f"bits_per_value={bits:.2f}")


def table5_sequential(rows, n_sets=30):
    """Iterate all values, checking total cardinality (paper Table 5)."""
    for name, (sets, universe) in common.datasets(n_sets).items():
        total_vals = sum(len(s) for s in sets)
        for cls in STRUCTURES:
            built = [cls(v, universe) for v in sets]

            def run():
                n = 0
                for b in built:
                    n += int(b.to_array().size)
                assert n == total_vals
            sec = common.best_of(run)
            common.emit(rows, "table5", "sequential", cls.name, name,
                        sec * 1e6 / n_sets,
                        f"cycles_per_value={common.cycles_per_value(sec, total_vals):.2f}")


def table6_membership(rows, n_sets=30, n_probe_batches=16):
    """Random-access membership (paper Table 6: n/4, n/2, 3n/4 probes)."""
    for name, (sets, universe) in common.datasets(n_sets).items():
        probes = np.asarray([universe // 4, universe // 2,
                             3 * universe // 4], np.uint32)
        for cls in STRUCTURES:
            built = [cls(v, universe) for v in sets]

            def run():
                for b in built:
                    b.contains_many(probes)
            sec = common.best_of(run)
            n_queries = 3 * n_sets
            common.emit(rows, "table6", "membership", cls.name, name,
                        sec * 1e6 / n_queries,
                        f"cycles_per_query={common.cycles_per_value(sec, n_queries):.1f}")


def _pairwise(rows, table, opname, opfn, n_sets=30):
    for name, (sets, universe) in common.datasets(n_sets).items():
        for cls in STRUCTURES:
            built = [cls(v, universe) for v in sets]
            input_vals = sum(len(sets[i]) + len(sets[i + 1])
                             for i in range(n_sets - 1))
            cards = []

            def run():
                cards.clear()
                for i in range(n_sets - 1):
                    cards.append(opfn(built[i], built[i + 1]))
            sec = common.best_of(run)
            common.emit(rows, table, opname, cls.name, name,
                        sec * 1e6 / (n_sets - 1),
                        f"cycles_per_value={common.cycles_per_value(sec, input_vals):.3f}")


def table7_pairwise_ops(rows, n_sets=30):
    """Two-by-two AND/OR/XOR/ANDNOT with materialization + cardinality
    check (paper Table 7a-d)."""
    _pairwise(rows, "table7a", "intersection",
              lambda a, b: (a & b).cardinality(), n_sets)
    _pairwise(rows, "table7b", "union",
              lambda a, b: (a | b).cardinality(), n_sets)
    _pairwise(rows, "table7c", "difference",
              lambda a, b: a.andnot(b).cardinality(), n_sets)
    _pairwise(rows, "table7d", "symmetric_difference",
              lambda a, b: (a ^ b).cardinality(), n_sets)


def table8_wide_union(rows, n_sets=30):
    """Union of all sets in the dataset (paper Table 8)."""
    from repro.core import RoaringBitmap
    for name, (sets, universe) in common.datasets(n_sets).items():
        input_vals = sum(len(s) for s in sets)
        for cls in STRUCTURES:
            built = [cls(v, universe) for v in sets]
            if cls is RoaringSet:
                def run():
                    RoaringBitmap.or_many([b.bm for b in built])
            else:
                def run():
                    acc = built[0]
                    for b in built[1:]:
                        acc = acc | b
            sec = common.best_of(run)
            common.emit(rows, "table8", "wide_union", cls.name, name,
                        sec * 1e6,
                        f"cycles_per_value={common.cycles_per_value(sec, input_vals):.3f}")


def table9_fast_counts(rows, n_sets=30):
    """Count-only intersections (paper Table 9a; 9b-d derive from 9a by
    inclusion-exclusion, which is how Roaring computes them)."""
    _pairwise(rows, "table9a", "intersection_count",
              lambda a, b: a.and_card(b), n_sets)


def table12_clusterdata(rows, scale=0.002, n_sets=20):
    """Appendix B: ClusterData 10^9-universe workload (scaled for CI;
    --full uses scale=1)."""
    from repro.data.synth import clusterdata_sets
    sets = clusterdata_sets(n_sets=n_sets, seed=3, scale=scale)
    universe = int(1_000_000_000 * scale)
    total = sum(len(s) for s in sets)
    for cls in STRUCTURES:
        built = [cls(v, universe) for v in sets]
        bits = 8.0 * sum(b.memory_bytes() for b in built) / total
        def inter():
            for i in range(n_sets - 1):
                built[i].and_card(built[i + 1])
        sec = common.best_of(inter)
        common.emit(rows, "table12", "clusterdata", cls.name,
                    f"scale={scale}", sec * 1e6 / (n_sets - 1),
                    f"bits_per_value={bits:.2f};"
                    f"cycles_per_value={common.cycles_per_value(sec, total):.3f}")
