"""Competitor set representations (paper section 5.1).

  * BitsetSet     -- uncompressed bitset (the paper's cbitset)
  * SortedArraySet -- std::vector analogue (sorted uint32 + binary search)
  * HashSet       -- std::unordered_set analogue (python set; memory uses
                     the paper's 195-bit/value node model, sec 5.4)
  * EWAH32 / WAH31 -- word-aligned RLE formats.  Ops and membership are
                     implemented *vectorized but linear-pass*, matching the
                     formats' algorithmic profile (no random access, no
                     skipping); Concise is WAH-compatible here (the paper
                     treats them as one code template within ~20 %).

BitMagic is a closed-source C++ competitor and is discussed, not
implemented (DESIGN.md sec 7).
"""

from __future__ import annotations

import numpy as np


# ---------------------------------------------------------------- bitset
class BitsetSet:
    name = "bitset"

    def __init__(self, values: np.ndarray, universe: int):
        self.universe = universe
        self.words = np.zeros((universe + 63) // 64, np.uint64)
        np.bitwise_or.at(self.words, values >> 6,
                         np.uint64(1) << (values.astype(np.uint64)
                                          & np.uint64(63)))

    @classmethod
    def _wrap(cls, words, universe):
        out = cls.__new__(cls)
        out.words = words
        out.universe = universe
        return out

    def __and__(self, o):
        return self._wrap(self.words & o.words, self.universe)

    def __or__(self, o):
        return self._wrap(self.words | o.words, self.universe)

    def __xor__(self, o):
        return self._wrap(self.words ^ o.words, self.universe)

    def andnot(self, o):
        return self._wrap(self.words & ~o.words, self.universe)

    def and_card(self, o):
        return int(np.bitwise_count(self.words & o.words).sum())

    def cardinality(self):
        return int(np.bitwise_count(self.words).sum())

    def contains_many(self, q):
        return ((self.words[q >> 6] >> (q.astype(np.uint64) & np.uint64(63)))
                & np.uint64(1)).astype(bool)

    def to_array(self):
        return np.flatnonzero(
            np.unpackbits(self.words.view(np.uint8), bitorder="little"))

    def memory_bytes(self):
        return self.words.nbytes


# ----------------------------------------------------------- sorted array
class SortedArraySet:
    name = "vector"

    def __init__(self, values: np.ndarray, universe: int = 0):
        self.values = np.unique(values).astype(np.uint32)

    @classmethod
    def _wrap(cls, v):
        out = cls.__new__(cls)
        out.values = v
        return out

    def __and__(self, o):
        return self._wrap(np.intersect1d(self.values, o.values,
                                         assume_unique=True))

    def __or__(self, o):
        return self._wrap(np.union1d(self.values, o.values))

    def __xor__(self, o):
        return self._wrap(np.setxor1d(self.values, o.values,
                                      assume_unique=True))

    def andnot(self, o):
        return self._wrap(np.setdiff1d(self.values, o.values,
                                       assume_unique=True))

    def and_card(self, o):
        return int(np.intersect1d(self.values, o.values,
                                  assume_unique=True).size)

    def cardinality(self):
        return int(self.values.size)

    def contains_many(self, q):
        idx = np.searchsorted(self.values, q)
        idx[idx == self.values.size] = max(self.values.size - 1, 0)
        return self.values[idx] == q if self.values.size else \
            np.zeros(q.size, bool)

    def to_array(self):
        return self.values

    def memory_bytes(self):
        return self.values.nbytes


# ---------------------------------------------------------------- hashset
class HashSet:
    name = "hashset"

    def __init__(self, values: np.ndarray, universe: int = 0):
        self.s = set(values.tolist())

    @classmethod
    def _wrap(cls, s):
        out = cls.__new__(cls)
        out.s = s
        return out

    def __and__(self, o):
        return self._wrap(self.s & o.s)

    def __or__(self, o):
        return self._wrap(self.s | o.s)

    def __xor__(self, o):
        return self._wrap(self.s ^ o.s)

    def andnot(self, o):
        return self._wrap(self.s - o.s)

    def and_card(self, o):
        small, big = (self.s, o.s) if len(self.s) < len(o.s) else (o.s, self.s)
        return sum(1 for v in small if v in big)

    def cardinality(self):
        return len(self.s)

    def contains_many(self, q):
        return np.fromiter((int(v) in self.s for v in q), bool, q.size)

    def to_array(self):
        return np.fromiter(self.s, np.uint32, len(self.s))

    def memory_bytes(self):
        # paper sec 5.4: 195 bits/value measured for std::unordered_set
        return int(len(self.s) * 195 / 8)


# --------------------------------------------------- word-aligned RLE base
class _RLEBase:
    """Run-length encoded bitmap over W-bit words.  Storage: two arrays,
    `kinds` (0 = fill-zero run, 1 = fill-one run, 2 = literal) and `payload`
    (run length in words, or the literal word).  Linear-pass semantics."""
    W = 32

    def __init__(self, values: np.ndarray, universe: int):
        self.universe = universe
        w = self.W
        n_words = (universe + w - 1) // w
        bits = np.zeros(n_words * w, np.uint8)
        bits[values] = 1
        words = (bits.reshape(n_words, w)
                 << np.arange(w, dtype=np.uint64)).sum(axis=1,
                                                       dtype=np.uint64)
        full = np.uint64((1 << w) - 1)
        is_fill0 = words == 0
        is_fill1 = words == full
        kind = np.where(is_fill0, 0, np.where(is_fill1, 1, 2)).astype(np.int8)
        # group consecutive identical fills
        change = np.flatnonzero(np.concatenate((
            [True], (kind[1:] != kind[:-1]) | (kind[1:] == 2))))
        counts = np.diff(np.concatenate((change, [n_words])))
        self.kinds = kind[change]
        self.payload = np.where(self.kinds == 2, words[change],
                                counts.astype(np.uint64))
        self.n_words = n_words

    @classmethod
    def _from_words(cls, words, universe):
        out = cls.__new__(cls)
        out.universe = universe
        w = cls.W
        full = np.uint64((1 << w) - 1)
        n_words = words.size
        kind = np.where(words == 0, 0,
                        np.where(words == full, 1, 2)).astype(np.int8)
        change = np.flatnonzero(np.concatenate((
            [True], (kind[1:] != kind[:-1]) | (kind[1:] == 2))))
        counts = np.diff(np.concatenate((change, [n_words])))
        out.kinds = kind[change]
        out.payload = np.where(out.kinds == 2, words[change],
                               counts.astype(np.uint64))
        out.n_words = n_words
        return out

    # linear decompression -- the fundamental cost of RLE formats
    def _words(self):
        reps = np.where(self.kinds == 2, 1, self.payload).astype(np.int64)
        vals = np.where(self.kinds == 1,
                        np.uint64((1 << self.W) - 1),
                        np.where(self.kinds == 0, np.uint64(0),
                                 self.payload))
        return np.repeat(vals, reps)

    def _binop(self, o, f):
        return type(self)._from_words(f(self._words(), o._words()),
                                      self.universe)

    def __and__(self, o):
        return self._binop(o, np.bitwise_and)

    def __or__(self, o):
        return self._binop(o, np.bitwise_or)

    def __xor__(self, o):
        return self._binop(o, np.bitwise_xor)

    def andnot(self, o):
        return self._binop(o, lambda a, b: a & ~b)

    def and_card(self, o):
        return int(np.bitwise_count(self._words() & o._words()).sum())

    def cardinality(self):
        lit = np.bitwise_count(self.payload[self.kinds == 2]).sum()
        fill = (self.payload[self.kinds == 1]).sum() * self.W
        return int(lit + fill)

    def contains_many(self, q):
        # linear pass: rebuild word extents each query batch (no index!)
        reps = np.where(self.kinds == 2, 1, self.payload).astype(np.int64)
        starts = np.concatenate(([0], np.cumsum(reps)))[:-1]
        word_idx = (q // self.W).astype(np.int64)
        seg = np.searchsorted(starts, word_idx, side="right") - 1
        k = self.kinds[seg]
        out = k == 1
        lit = k == 2
        if lit.any():
            w = self.payload[seg[lit]]
            out = out.copy()
            out[lit] = ((w >> (q[lit].astype(np.uint64)
                               % np.uint64(self.W)))
                        & np.uint64(1)).astype(bool)
        return out

    def to_array(self):
        words = self._words()
        bits = (words[:, None] >> np.arange(self.W, dtype=np.uint64)) \
            & np.uint64(1)
        return np.flatnonzero(bits.reshape(-1))

    def memory_bytes(self):
        # marker word + payload per segment, W-bit words
        return int(self.kinds.size * (self.W // 8)
                   + np.count_nonzero(self.kinds == 2) * 0)


class EWAH32(_RLEBase):
    name = "ewah32"
    W = 32


class WAH31(_RLEBase):
    name = "wah31(concise-compat)"
    W = 31

    def memory_bytes(self):
        return int(self.kinds.size * 4)


# ------------------------------------------------------------- roaring
class RoaringSet:
    name = "roaring"

    def __init__(self, values: np.ndarray, universe: int = 0):
        from repro.core import RoaringBitmap
        self.bm = RoaringBitmap.from_values(values).run_optimize()

    @classmethod
    def _wrap(cls, bm):
        out = cls.__new__(cls)
        out.bm = bm
        return out

    def __and__(self, o):
        return self._wrap(self.bm & o.bm)

    def __or__(self, o):
        return self._wrap(self.bm | o.bm)

    def __xor__(self, o):
        return self._wrap(self.bm ^ o.bm)

    def andnot(self, o):
        return self._wrap(self.bm - o.bm)

    def and_card(self, o):
        return self.bm.and_card(o.bm)

    def cardinality(self):
        return self.bm.cardinality

    def contains_many(self, q):
        return self.bm.contains_many(q)

    def to_array(self):
        return self.bm.to_array()

    def memory_bytes(self):
        return self.bm.memory_bytes()


STRUCTURES = [BitsetSet, SortedArraySet, HashSet, RoaringSet, EWAH32, WAH31]
