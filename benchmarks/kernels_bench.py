"""Per-kernel benchmark: interpret-mode correctness sweep + roofline-model
numbers for the TPU target (wall-clock in interpret mode is meaningless for
TPU perf, so `derived` reports the analytic VMEM/VPU utilization instead --
per the dry-run methodology)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ref
from repro.kernels.bitset_ops import bitset_op
from repro.kernels.harley_seal import popcount
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def kernel_sweeps(rows):
    rng = np.random.default_rng(9)
    # harley-seal popcount: logical ops per container = 75 CSA-tree ops on
    # 128-lane groups + 5 SWAR popcounts; HBM traffic = 8 kB read + 4 B out
    for n in (64, 512):
        w = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
        want = np.bitwise_count(w).sum(axis=1)
        got = np.asarray(popcount(jnp.asarray(w), interpret=True))
        ok = bool(np.array_equal(got, want))
        bytes_moved = n * 8192
        t_mem = bytes_moved / HBM_BW
        # ~75 logical + 5*15 popcount ops per 16-word group, 128 groups
        vpu_ops = n * (2048 // 16) * (75 + 75)
        common.emit(rows, "kernels", "harley_seal", f"n={n}", "sweep",
                    t_mem * 1e6,
                    f"correct={ok};hbm_bytes={bytes_moved};"
                    f"vpu_ops={vpu_ops};memory_bound=True")
    # fused op+popcount
    a = rng.integers(0, 1 << 32, (256, 2048), dtype=np.uint32)
    b = rng.integers(0, 1 << 32, (256, 2048), dtype=np.uint32)
    for op in ("and", "or", "xor", "andnot"):
        rw, rc = bitset_op(jnp.asarray(a), jnp.asarray(b), op,
                           interpret=True)
        ow, oc = ref.bitset_op(jnp.asarray(a), jnp.asarray(b), op)
        ok = bool(np.array_equal(np.asarray(rw), np.asarray(ow)) and
                  np.array_equal(np.asarray(rc), np.asarray(oc)))
        bytes_moved = 256 * 8192 * 3
        common.emit(rows, "kernels", f"bitset_{op}_card", "n=256", "sweep",
                    bytes_moved / HBM_BW * 1e6,
                    f"correct={ok};hbm_bytes={bytes_moved}")
