"""Per-kernel benchmark: interpret-mode correctness sweep + roofline-model
numbers for the TPU target (wall-clock in interpret mode is meaningless for
TPU perf, so `derived` reports the analytic VMEM/VPU utilization instead --
per the dry-run methodology)."""

from __future__ import annotations

import functools
import operator

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import RoaringBitmap
from repro.core import aggregate
from repro.core import containers as C
from repro.core.containers import ArrayContainer, BitsetContainer
from repro.kernels import ref
from repro.kernels.bitset_ops import bitset_op
from repro.kernels.harley_seal import popcount
from repro.launch.mesh import HBM_BW


def kernel_sweeps(rows):
    rng = np.random.default_rng(9)
    # harley-seal popcount: logical ops per container = 75 CSA-tree ops on
    # 128-lane groups + 5 SWAR popcounts; HBM traffic = 8 kB read + 4 B out
    for n in (64, 512):
        w = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
        want = np.bitwise_count(w).sum(axis=1)
        got = np.asarray(popcount(jnp.asarray(w), interpret=True))
        ok = bool(np.array_equal(got, want))
        bytes_moved = n * 8192
        t_mem = bytes_moved / HBM_BW
        # ~75 logical + 5*15 popcount ops per 16-word group, 128 groups
        vpu_ops = n * (2048 // 16) * (75 + 75)
        common.emit(rows, "kernels", "harley_seal", f"n={n}", "sweep",
                    t_mem * 1e6,
                    f"correct={ok};hbm_bytes={bytes_moved};"
                    f"vpu_ops={vpu_ops};memory_bound=True")
    # fused op+popcount
    a = rng.integers(0, 1 << 32, (256, 2048), dtype=np.uint32)
    b = rng.integers(0, 1 << 32, (256, 2048), dtype=np.uint32)
    for op in ("and", "or", "xor", "andnot"):
        rw, rc = bitset_op(jnp.asarray(a), jnp.asarray(b), op,
                           interpret=True)
        ow, oc = ref.bitset_op(jnp.asarray(a), jnp.asarray(b), op)
        ok = bool(np.array_equal(np.asarray(rw), np.asarray(ow)) and
                  np.array_equal(np.asarray(rc), np.asarray(oc)))
        bytes_moved = 256 * 8192 * 3
        common.emit(rows, "kernels", f"bitset_{op}_card", "n=256", "sweep",
                    bytes_moved / HBM_BW * 1e6,
                    f"correct={ok};hbm_bytes={bytes_moved}")


# ---------------------------------------------------------------------------
# wide_ops suite: K-way aggregates, planner + segmented kernel vs the seed
# container-at-a-time implementation (frozen copy below), K in {4, 16, 64}
# over uniform / clustered / run-heavy distributions.
# ---------------------------------------------------------------------------

def _seed_or_many(bitmaps):
    """Frozen copy of the pre-planner RoaringBitmap.or_many (container-at-a-
    time accumulation) -- the benchmark baseline this PR replaces."""
    if not bitmaps:
        return RoaringBitmap()
    acc = {}
    for bm in bitmaps:
        for k, c in zip(bm.keys, bm.containers):
            cur = acc.get(k)
            if cur is None:
                acc[k] = c
                continue
            if not isinstance(cur, np.ndarray):
                cur = cur.to_bitset().words.copy()
                acc[k] = cur
            if isinstance(c, ArrayContainer):
                idx = (c.values >> np.uint16(6)).astype(np.int64)
                bit = np.left_shift(
                    np.uint64(1), c.values.astype(np.uint64) & np.uint64(63))
                np.bitwise_or.at(cur, idx, bit)
            elif isinstance(c, BitsetContainer):
                np.bitwise_or(cur, c.words, out=cur)
            else:
                np.bitwise_or(cur, c.to_bitset().words, out=cur)
    keys = sorted(acc)
    conts = []
    for k in keys:
        v = acc[k]
        conts.append(C._result_from_bitset(v) if isinstance(v, np.ndarray)
                     else v)
    return RoaringBitmap(keys, conts)


def _seed_and_many(bitmaps):
    """Frozen copy of the pre-planner RoaringBitmap.and_many (pairwise)."""
    if not bitmaps:
        return RoaringBitmap()
    out = bitmaps[0]
    for bm in sorted(bitmaps[1:], key=lambda b: b.cardinality):
        out = out & bm
        if not out:
            break
    return out


def _wide_dataset(dist: str, k: int, seed: int = 11):
    """K bitmaps over a 2^20 universe in the named distribution."""
    rng = np.random.default_rng(seed)
    universe = 1 << 20
    out = []
    for _ in range(k):
        if dist == "uniform":
            vals = rng.integers(0, universe, 20_000, dtype=np.uint32)
        elif dist == "clustered":
            centers = rng.integers(0, universe, 6)
            vals = np.concatenate([
                c + rng.integers(0, 1 << 14, 4_000).astype(np.uint32)
                for c in centers]) % universe
        elif dist == "run_heavy":
            spans = []
            for _ in range(int(rng.integers(2, 6))):
                lo = int(rng.integers(0, universe - (1 << 16)))
                spans.append(np.arange(lo, lo + int(rng.integers(1 << 12,
                                                                 1 << 16)),
                                       dtype=np.uint32))
            vals = np.concatenate(spans)
        else:
            raise ValueError(dist)
        out.append(RoaringBitmap.from_values(vals).run_optimize())
    return out


def wide_ops(rows, quick: bool = False) -> list[dict]:
    """K-way aggregate timings; returns JSON-able records (BENCH_wide_ops).

    ``quick`` shrinks the sweep for the CI regression gate: the surviving
    (bench, dist, k) keys are a strict subset of the full sweep's, so the
    gate can compare a quick candidate run against the committed full
    baseline key-by-key."""
    records = []
    dists = ("uniform", "run_heavy") if quick else \
        ("uniform", "clustered", "run_heavy")
    ks = (4, 16) if quick else (4, 16, 64)
    repeats = 5                  # best-of-5 keeps the gate noise-robust
    for dist in dists:
        for k in ks:
            bms = _wide_dataset(dist, k)
            weights = [1 + i % 3 for i in range(k)]
            benches = [
                ("or_many", functools.partial(_seed_or_many, bms),
                 functools.partial(RoaringBitmap.or_many, bms)),
                # the slab/kernel path forced on (what a TPU backend runs);
                # the default row above may resolve dense groups on host
                ("or_many_kernel", functools.partial(_seed_or_many, bms),
                 functools.partial(aggregate.or_many, bms, backend="ref")),
                ("and_many", functools.partial(_seed_and_many, bms),
                 functools.partial(RoaringBitmap.and_many, bms)),
                ("xor_many",
                 functools.partial(functools.reduce, operator.xor, bms),
                 functools.partial(RoaringBitmap.xor_many, bms)),
                ("threshold_many", None,
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k // 2))),
                # difference chain: planner vs the pairwise a-b1-b2-... fold
                ("andnot_many",
                 functools.partial(functools.reduce, operator.sub, bms),
                 functools.partial(RoaringBitmap.andnot_many, bms[0],
                                   bms[1:])),
                # weighted T-occurrence through the shift-and-add counters
                ("threshold_weighted", None,
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k), weights=weights)),
            ]
            records += _run_benches(rows, "wide_ops", benches, dist, k,
                                    repeats)
    return records


def _run_benches(rows, table, benches, dist, k, repeats) -> list[dict]:
    records = []
    for name, seed_fn, new_fn in benches:
        got = new_fn()               # warm-up: jit/kernel compilation
        t_new, med_new = common.time_stats(new_fn, repeats=repeats)
        t_new, med_new = t_new * 1e6, med_new * 1e6
        if seed_fn is not None:
            want = seed_fn()
            ok = bool(want == got)
            t_seed = common.best_of(seed_fn, repeats=repeats) * 1e6
            speedup = t_seed / t_new if t_new else float("inf")
        else:
            ok, t_seed, speedup = True, None, None
        rec = {"bench": name, "dist": dist, "k": k,
               "seed_us": t_seed, "wide_us": t_new, "median_us": med_new,
               "speedup": speedup, "correct": ok}
        records.append(rec)
        common.emit(
            rows, table, name, f"k={k}", dist, t_new,
            f"correct={ok};median_us={round(med_new, 1)};seed_us="
            f"{'-' if t_seed is None else round(t_seed, 1)};"
            f"speedup="
            f"{'-' if speedup is None else round(speedup, 2)}")
    return records


def wide_ops_sharded(rows, quick: bool = False) -> list[dict]:
    """Sharded K-way aggregates over a ``wide`` mesh of every visible
    device, checked bit-identical against the single-device plans.

    On one device the mesh path falls back to the single dispatch, so this
    suite is only a real shard test under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI bench
    job sets N=4) or on real multi-device hardware; ``n_devices`` is
    recorded so readers can tell which regime produced a record."""
    from repro.launch.mesh import make_wide_mesh

    mesh = make_wide_mesh()
    n_dev = int(mesh.devices.size)
    records = []
    dists = ("uniform",) if quick else ("uniform", "run_heavy")
    ks = (16,) if quick else (16, 64)
    repeats = 3 if quick else 5
    for dist in dists:
        for k in ks:
            bms = _wide_dataset(dist, k)
            weights = [1 + i % 3 for i in range(k)]
            t = max(2, k // 2)
            benches = [
                ("or_many_sharded",
                 functools.partial(RoaringBitmap.or_many, bms),
                 functools.partial(RoaringBitmap.or_many, bms, mesh=mesh)),
                ("xor_many_sharded",
                 functools.partial(RoaringBitmap.xor_many, bms),
                 functools.partial(RoaringBitmap.xor_many, bms, mesh=mesh)),
                ("threshold_many_sharded",
                 functools.partial(RoaringBitmap.threshold_many, bms, t),
                 functools.partial(RoaringBitmap.threshold_many, bms, t,
                                   mesh=mesh)),
                ("threshold_weighted_sharded",
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k), weights=weights),
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k), weights=weights, mesh=mesh)),
                ("andnot_many_sharded",
                 functools.partial(RoaringBitmap.andnot_many, bms[0],
                                   bms[1:]),
                 functools.partial(RoaringBitmap.andnot_many, bms[0],
                                   bms[1:], mesh=mesh)),
            ]
            recs = _run_benches(rows, "wide_ops_sharded", benches, dist, k,
                                repeats)
            for r in recs:
                r["n_devices"] = n_dev
            records += recs
    return records
