"""Per-kernel benchmark: interpret-mode correctness sweep + roofline-model
numbers for the TPU target (wall-clock in interpret mode is meaningless for
TPU perf, so `derived` reports the analytic VMEM/VPU utilization instead --
per the dry-run methodology)."""

from __future__ import annotations

import functools
import operator

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import RoaringBitmap
from repro.core import aggregate
from repro.core import containers as C
from repro.core.containers import ArrayContainer, BitsetContainer
from repro.kernels import ref
from repro.kernels.bitset_ops import bitset_op
from repro.kernels.harley_seal import popcount
from repro.launch.mesh import HBM_BW


def kernel_sweeps(rows):
    rng = np.random.default_rng(9)
    # harley-seal popcount: logical ops per container = 75 CSA-tree ops on
    # 128-lane groups + 5 SWAR popcounts; HBM traffic = 8 kB read + 4 B out
    for n in (64, 512):
        w = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
        want = np.bitwise_count(w).sum(axis=1)
        got = np.asarray(popcount(jnp.asarray(w), interpret=True))
        ok = bool(np.array_equal(got, want))
        bytes_moved = n * 8192
        t_mem = bytes_moved / HBM_BW
        # ~75 logical + 5*15 popcount ops per 16-word group, 128 groups
        vpu_ops = n * (2048 // 16) * (75 + 75)
        common.emit(rows, "kernels", "harley_seal", f"n={n}", "sweep",
                    t_mem * 1e6,
                    f"correct={ok};hbm_bytes={bytes_moved};"
                    f"vpu_ops={vpu_ops};memory_bound=True")
    # fused op+popcount
    a = rng.integers(0, 1 << 32, (256, 2048), dtype=np.uint32)
    b = rng.integers(0, 1 << 32, (256, 2048), dtype=np.uint32)
    for op in ("and", "or", "xor", "andnot"):
        rw, rc = bitset_op(jnp.asarray(a), jnp.asarray(b), op,
                           interpret=True)
        ow, oc = ref.bitset_op(jnp.asarray(a), jnp.asarray(b), op)
        ok = bool(np.array_equal(np.asarray(rw), np.asarray(ow)) and
                  np.array_equal(np.asarray(rc), np.asarray(oc)))
        bytes_moved = 256 * 8192 * 3
        common.emit(rows, "kernels", f"bitset_{op}_card", "n=256", "sweep",
                    bytes_moved / HBM_BW * 1e6,
                    f"correct={ok};hbm_bytes={bytes_moved}")


# ---------------------------------------------------------------------------
# wide_ops suite: K-way aggregates, planner + segmented kernel vs the seed
# container-at-a-time implementation (frozen copy below), K in {4, 16, 64}
# over uniform / clustered / run-heavy distributions.
# ---------------------------------------------------------------------------

def _seed_or_many(bitmaps):
    """Frozen copy of the pre-planner RoaringBitmap.or_many (container-at-a-
    time accumulation) -- the benchmark baseline this PR replaces."""
    if not bitmaps:
        return RoaringBitmap()
    acc = {}
    for bm in bitmaps:
        for k, c in zip(bm.keys, bm.containers):
            cur = acc.get(k)
            if cur is None:
                acc[k] = c
                continue
            if not isinstance(cur, np.ndarray):
                cur = cur.to_bitset().words.copy()
                acc[k] = cur
            if isinstance(c, ArrayContainer):
                idx = (c.values >> np.uint16(6)).astype(np.int64)
                bit = np.left_shift(
                    np.uint64(1), c.values.astype(np.uint64) & np.uint64(63))
                np.bitwise_or.at(cur, idx, bit)
            elif isinstance(c, BitsetContainer):
                np.bitwise_or(cur, c.words, out=cur)
            else:
                np.bitwise_or(cur, c.to_bitset().words, out=cur)
    keys = sorted(acc)
    conts = []
    for k in keys:
        v = acc[k]
        conts.append(C._result_from_bitset(v) if isinstance(v, np.ndarray)
                     else v)
    return RoaringBitmap(keys, conts)


def _seed_and_many(bitmaps):
    """Frozen copy of the pre-planner RoaringBitmap.and_many (pairwise)."""
    if not bitmaps:
        return RoaringBitmap()
    out = bitmaps[0]
    for bm in sorted(bitmaps[1:], key=lambda b: b.cardinality):
        out = out & bm
        if not out:
            break
    return out


def _wide_dataset(dist: str, k: int, seed: int = 11):
    """K bitmaps over a 2^20 universe in the named distribution."""
    rng = np.random.default_rng(seed)
    universe = 1 << 20
    out = []
    for _ in range(k):
        if dist == "uniform":
            vals = rng.integers(0, universe, 20_000, dtype=np.uint32)
        elif dist == "clustered":
            centers = rng.integers(0, universe, 6)
            vals = np.concatenate([
                c + rng.integers(0, 1 << 14, 4_000).astype(np.uint32)
                for c in centers]) % universe
        elif dist == "run_heavy":
            spans = []
            for _ in range(int(rng.integers(2, 6))):
                lo = int(rng.integers(0, universe - (1 << 16)))
                spans.append(np.arange(lo, lo + int(rng.integers(1 << 12,
                                                                 1 << 16)),
                                       dtype=np.uint32))
            vals = np.concatenate(spans)
        else:
            raise ValueError(dist)
        out.append(RoaringBitmap.from_values(vals).run_optimize())
    return out


def wide_ops(rows, quick: bool = False) -> list[dict]:
    """K-way aggregate timings; returns JSON-able records (BENCH_wide_ops).

    ``quick`` shrinks the sweep for the CI regression gate: the surviving
    (bench, dist, k) keys are a strict subset of the full sweep's, so the
    gate can compare a quick candidate run against the committed full
    baseline key-by-key."""
    records = []
    dists = ("uniform", "run_heavy") if quick else \
        ("uniform", "clustered", "run_heavy")
    ks = (4, 16) if quick else (4, 16, 64)
    repeats = 5                  # best-of-5 keeps the gate noise-robust
    for dist in dists:
        for k in ks:
            bms = _wide_dataset(dist, k)
            weights = [1 + i % 3 for i in range(k)]
            benches = [
                ("or_many", functools.partial(_seed_or_many, bms),
                 functools.partial(RoaringBitmap.or_many, bms)),
                # the slab/kernel path forced on (what a TPU backend runs);
                # the default row above may resolve dense groups on host
                ("or_many_kernel", functools.partial(_seed_or_many, bms),
                 functools.partial(aggregate.or_many, bms, backend="ref")),
                ("and_many", functools.partial(_seed_and_many, bms),
                 functools.partial(RoaringBitmap.and_many, bms)),
                ("xor_many",
                 functools.partial(functools.reduce, operator.xor, bms),
                 functools.partial(RoaringBitmap.xor_many, bms)),
                ("threshold_many", None,
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k // 2))),
                # difference chain: planner vs the pairwise a-b1-b2-... fold
                ("andnot_many",
                 functools.partial(functools.reduce, operator.sub, bms),
                 functools.partial(RoaringBitmap.andnot_many, bms[0],
                                   bms[1:])),
                # weighted T-occurrence through the shift-and-add counters
                ("threshold_weighted", None,
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k), weights=weights)),
            ]
            records += _run_benches(rows, "wide_ops", benches, dist, k,
                                    repeats)
    return records


def _run_benches(rows, table, benches, dist, k, repeats) -> list[dict]:
    records = []
    for name, seed_fn, new_fn in benches:
        got = new_fn()               # warm-up: jit/kernel compilation
        t_new, med_new = common.time_stats(new_fn, repeats=repeats)
        t_new, med_new = t_new * 1e6, med_new * 1e6
        if seed_fn is not None:
            want = seed_fn()
            ok = bool(want == got)
            t_seed = common.best_of(seed_fn, repeats=repeats) * 1e6
            speedup = t_seed / t_new if t_new else float("inf")
        else:
            ok, t_seed, speedup = True, None, None
        rec = {"bench": name, "dist": dist, "k": k,
               "seed_us": t_seed, "wide_us": t_new, "median_us": med_new,
               "speedup": speedup, "correct": ok}
        records.append(rec)
        common.emit(
            rows, table, name, f"k={k}", dist, t_new,
            f"correct={ok};median_us={round(med_new, 1)};seed_us="
            f"{'-' if t_seed is None else round(t_seed, 1)};"
            f"speedup="
            f"{'-' if speedup is None else round(speedup, 2)}")
    return records


# ---------------------------------------------------------------------------
# pairwise suite: the batched pair engine (core.pairwise) vs the seed
# scalar two-by-two path, over Zipfian posting-list shapes -- the
# similarity-join workload ("beyond unions and intersections").
# ---------------------------------------------------------------------------

def _seed_and_card(a, b):
    """Frozen copy of the seed RoaringBitmap.and_card (scalar key-merge;
    the live method now routes through the pairwise planner)."""
    cnt = 0
    i = j = 0
    while i < len(a.keys) and j < len(b.keys):
        ka, kb = a.keys[i], b.keys[j]
        if ka == kb:
            cnt += C.container_and_card(a.containers[i], b.containers[j])
            i += 1
            j += 1
        elif ka < kb:
            i += 1
        else:
            j += 1
    return cnt


def _seed_pair_merge(a, b, op):
    """Frozen copy of the seed RoaringBitmap._merge (one container op per
    matched key)."""
    fn = C.OPS[op][0]
    keys, conts = [], []
    i = j = 0
    na, nb = len(a.keys), len(b.keys)
    while i < na and j < nb:
        ka, kb = a.keys[i], b.keys[j]
        if ka == kb:
            c = fn(a.containers[i], b.containers[j])
            if c.card:
                keys.append(ka)
                conts.append(c)
            i += 1
            j += 1
        elif ka < kb:
            if op in ("or", "xor", "andnot"):
                keys.append(ka)
                conts.append(a.containers[i])
            i += 1
        else:
            if op in ("or", "xor"):
                keys.append(kb)
                conts.append(b.containers[j])
            j += 1
    if op in ("or", "xor", "andnot"):
        while i < na:
            keys.append(a.keys[i])
            conts.append(a.containers[i])
            i += 1
    if op in ("or", "xor"):
        while j < nb:
            keys.append(b.keys[j])
            conts.append(b.containers[j])
            j += 1
    return RoaringBitmap(keys, conts)


def _zipf_postings(n_terms: int, n_docs: int = 1 << 20, seed: int = 17):
    """Zipfian posting lists over a document universe: term r matches
    ~300k/(r+1)^1.1 docs, half clustered around a hot range (dense bitset
    and run containers for head terms) and half uniform (array containers
    for the tail) -- the shape of a real inverted index."""
    rng = np.random.default_rng(seed)
    out = []
    for r in range(n_terms):
        size = max(50, int(300_000 / (r + 1) ** 1.1))
        n_hot = size // 2
        center = int(rng.integers(0, n_docs - (1 << 16)))
        hot = center + rng.integers(0, 1 << 16, n_hot)
        cold = rng.integers(0, n_docs, size - n_hot)
        vals = np.unique(np.concatenate([hot, cold]).astype(np.uint32))
        out.append(RoaringBitmap.from_values(vals).run_optimize())
    return out


def _pr4_similar_topk(bms, q: int, top_k: int):
    """Frozen PR 4 host-select similarity path: batched AND counts over
    every (query, candidate) pair rebuilt per call, float32 scoring, then
    a full host stable argsort -- the baseline the device-resident
    ``SimilarityEngine`` (cached slab + bound pruning + fused top-k)
    replaces."""
    others = [i for i in range(len(bms)) if i != q]
    pairs = [(bms[q], bms[i]) for i in others]
    inter = RoaringBitmap.pairwise_card("and", pairs).astype(np.float32)
    qc = np.float32(bms[q].cardinality)
    oc = np.array([bms[i].cardinality for i in others], np.float32)
    denom = qc + oc - inter
    score = np.divide(inter, denom, out=np.ones_like(inter),
                      where=denom > 0)
    order = np.argsort(-score, kind="stable")[:top_k]
    return tuple(others[i] for i in order.tolist())


def pairwise_suite(rows, quick: bool = False) -> list[dict]:
    """Batched pairwise engine vs looped seed two-by-two (JSON records
    gate-compatible with BENCH_wide_ops.json).

    ``k`` is the number of posting lists; the all-pairs benches cover
    k*(k-1)/2 pairs.  The acceptance contract lives in the k=64 rows:
    batched ``pairwise_card`` / ``jaccard_matrix`` must beat the looped
    seed ``and_card`` by >= 3x with bit-identical results, and the
    ``similar_topk`` record must beat the PR 4 host-select path by
    >= 2x (warm engine: the slab cache is the serving contract, so the
    one-off build happens in the warm-up call outside the timed runs)."""
    records = []
    ks = (16,) if quick else (16, 64)
    repeats = 5
    for k in ks:
        bms = _zipf_postings(k)
        pairs = [(bms[i], bms[j]) for i in range(k)
                 for j in range(i + 1, k)]
        cards = [bm.cardinality for bm in bms]

        def looped_and_card(pairs=pairs):
            return tuple(_seed_and_card(a, b) for a, b in pairs)

        def batched_and_card(pairs=pairs):
            return tuple(RoaringBitmap.pairwise_card("and", pairs)
                         .tolist())

        def looped_jaccard(bms=bms, cards=cards):
            n = len(bms)
            out = np.ones((n, n))
            for i in range(n):
                for j in range(i + 1, n):
                    inter = _seed_and_card(bms[i], bms[j])
                    union = cards[i] + cards[j] - inter
                    out[i, j] = out[j, i] = \
                        inter / union if union else 1.0
            return tuple(out.ravel().tolist())

        def batched_jaccard(bms=bms):
            return tuple(RoaringBitmap.jaccard_matrix(bms)
                         .ravel().tolist())

        from repro.core.pairwise import SimilarityEngine
        q = k // 2                               # mid-rank query term
        eng_box = {}

        def engine_topk(q=q, bms=bms):
            eng = eng_box.get("eng")
            if eng is None:                      # built once, in warm-up
                eng = eng_box["eng"] = SimilarityEngine(bms)
            idx, _, _ = eng.topk(q, 10)
            return tuple(idx.tolist())

        a, b = bms[k // 2], bms[k // 2 + 1]      # array-heavy tail pair
        da, db = bms[0], bms[1]                  # densest (bitset) pair
        benches = [
            ("similar_topk",
             functools.partial(_pr4_similar_topk, bms, q, 10),
             engine_topk),
            ("pairwise_and_card", looped_and_card, batched_and_card),
            ("jaccard_matrix", looped_jaccard, batched_jaccard),
            ("pair_merge_or", functools.partial(_seed_pair_merge,
                                                a, b, "or"),
             functools.partial(operator.or_, a, b)),
            ("pair_merge_and", functools.partial(_seed_pair_merge,
                                                 a, b, "and"),
             functools.partial(operator.and_, a, b)),
            ("pair_merge_xor", functools.partial(_seed_pair_merge,
                                                 a, b, "xor"),
             functools.partial(operator.xor, a, b)),
            ("pair_merge_and_dense", functools.partial(_seed_pair_merge,
                                                       da, db, "and"),
             functools.partial(operator.and_, da, db)),
        ]
        records += _run_benches(rows, "pairwise", benches, "zipf", k,
                                repeats)
    return records


def wide_ops_sharded(rows, quick: bool = False) -> list[dict]:
    """Sharded K-way aggregates over a ``wide`` mesh of every visible
    device, checked bit-identical against the single-device plans.

    On one device the mesh path falls back to the single dispatch, so this
    suite is only a real shard test under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI bench
    job sets N=4) or on real multi-device hardware; ``n_devices`` is
    recorded so readers can tell which regime produced a record."""
    from repro.launch.mesh import make_wide_mesh

    mesh = make_wide_mesh()
    n_dev = int(mesh.devices.size)
    records = []
    dists = ("uniform",) if quick else ("uniform", "run_heavy")
    ks = (16,) if quick else (16, 64)
    repeats = 3 if quick else 5
    for dist in dists:
        for k in ks:
            bms = _wide_dataset(dist, k)
            weights = [1 + i % 3 for i in range(k)]
            t = max(2, k // 2)
            benches = [
                ("or_many_sharded",
                 functools.partial(RoaringBitmap.or_many, bms),
                 functools.partial(RoaringBitmap.or_many, bms, mesh=mesh)),
                ("xor_many_sharded",
                 functools.partial(RoaringBitmap.xor_many, bms),
                 functools.partial(RoaringBitmap.xor_many, bms, mesh=mesh)),
                ("threshold_many_sharded",
                 functools.partial(RoaringBitmap.threshold_many, bms, t),
                 functools.partial(RoaringBitmap.threshold_many, bms, t,
                                   mesh=mesh)),
                ("threshold_weighted_sharded",
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k), weights=weights),
                 functools.partial(RoaringBitmap.threshold_many, bms,
                                   max(2, k), weights=weights, mesh=mesh)),
                ("andnot_many_sharded",
                 functools.partial(RoaringBitmap.andnot_many, bms[0],
                                   bms[1:]),
                 functools.partial(RoaringBitmap.andnot_many, bms[0],
                                   bms[1:], mesh=mesh)),
            ]
            recs = _run_benches(rows, "wide_ops_sharded", benches, dist, k,
                                repeats)
            for r in recs:
                r["n_devices"] = n_dev
            records += recs
    return records


# ---------------------------------------------------------------------------
# query_throughput suite: the continuous query server's coalesced
# multi-query dispatch vs a sequential per-query loop on the same kernel
# backend -- the PR 6 serving contract (>= 3x at 1024 concurrent).
# ---------------------------------------------------------------------------

def _serving_postings(n_terms: int = 64, seed: int = 29):
    """Dense single-chunk bitset postings: every boolean plan carries
    kernel segments (no host fast-path short circuits), so the bench
    isolates dispatch amortization -- the thing coalescing buys."""
    rng = np.random.default_rng(seed)
    out = {}
    for r in range(n_terms):
        size = min(50_000, int(6000 + 40_000 / (r + 1) ** 0.7))
        vals = rng.choice(1 << 16, size, replace=False).astype(np.uint32)
        out[f"t{r}"] = RoaringBitmap.from_values(vals)
    return out


def _serving_queries(n_queries: int, n_terms: int, seed: int = 31):
    """Deterministic mixed stream: all five boolean classes plus 1/16
    similarity top-k (the production mix named in docs/ARCHITECTURE.md's
    serving section)."""
    from repro.serve import Query

    rng = np.random.default_rng(seed)
    names = [f"t{i}" for i in range(n_terms)]
    queries = []
    for i in range(n_queries):
        if i % 16 == 15:
            queries.append(Query.similar(
                names[int(rng.integers(n_terms))], k=10))
            continue
        kind = ("and", "or", "xor", "andnot",
                "threshold")[int(rng.integers(5))]
        terms = tuple(names[j] for j in rng.choice(
            n_terms, int(rng.integers(2, 6)), replace=False))
        if kind == "threshold":
            queries.append(Query.threshold(
                terms, int(rng.integers(1, len(terms) + 1))))
        else:
            queries.append(Query(kind, terms))
    return queries


def _arena_postings(n: int, seed: int = 37):
    """N single-chunk dense bitset bitmaps (one container row each), the
    serving shape where per-call staging hurts most: every query moves
    N * 8 KiB over PCIe unless the rows are arena-resident."""
    rng = np.random.default_rng(seed)
    out = []
    for r in range(n):
        size = min(50_000, int(6000 + 40_000 / (r + 1) ** 0.7))
        vals = rng.choice(1 << 16, size, replace=False).astype(np.uint32)
        out.append(RoaringBitmap.from_values(vals))
    return out


def arena_warm(rows, quick: bool = False) -> list[dict]:
    """BitmapArena (core/arena.py) staging economics, four rows per N:

    * ``arena_cold_build`` -- promote + upload N rows from scratch (the
      one-time cost a warm arena amortizes; no seed twin).
    * ``arena_warm_query`` -- end-to-end ``or_many`` with per-call
      pad/stack/transfer (seed) vs the same op over a warm arena
      (wide); results asserted bit-identical.
    * ``arena_warm_stage`` -- the staging step in isolation: host
      stack + host->device upload of N rows (seed, what every cold
      call pays) vs an on-device gather of the same N resident rows
      (wide, what a warm call pays).  The acceptance contract lives in
      the N=64 row: speedup >= 3x (docs/MEMORY.md section 5).
    * ``arena_repatch`` -- one postings edit, then incremental
      ``adopt`` + single-row scatter (wide) vs rebuilding and
      re-uploading a fresh arena (seed); both sides checksum the
      patched row from the host mirror.  Wall-clock on the CPU
      interpret backend understates the win (the functional scatter's
      copy-on-write clones the slab in host RAM at memcpy speed, while
      a real accelerator clones in HBM and only 1 row crosses PCIe),
      so the record also carries the measured transfer accounting from
      ``ArenaStats``: ``rows_moved_seed`` (= N+1) vs ``rows_moved_wide``
      (= 1) and their ratio -- the N=1024 acceptance (repatch <= 1/8
      rebuild) holds on the bytes-over-PCIe axis this suite exists to
      measure.
    """
    from repro.core.arena import BitmapArena

    records = []
    ns = (16, 64) if quick else (16, 64, 1024)
    repeats = 3 if quick else 5
    for n in ns:
        bms = _arena_postings(n)
        warm = BitmapArena(capacity=n + 1)
        warm.adopt_many(bms)
        warm.sync()

        def cold_build(bms=bms, n=n):
            a = BitmapArena(capacity=n + 1)
            a.adopt_many(bms)
            a.sync()
            return a.n_rows

        def warm_query(bms=bms, warm=warm):
            return aggregate.or_many(bms, backend="ref", arena=warm)

        # Idempotent re-add of a present value: the bitset mutator
        # copies words and replaces the container object, so each call
        # dirties exactly one row with unchanged bytes -- a steady-state
        # single-row patch that both sides can checksum identically.
        v0 = int(bms[0].to_array()[0])

        def repatch(bms=bms, warm=warm, v0=v0):
            bms[0].add(v0)
            warm.adopt(bms[0])
            warm.sync()
            return int(warm.host_row(
                warm.lookup(bms[0].containers[0])).sum())

        def rebuild(bms=bms, n=n, v0=v0):
            bms[0].add(v0)
            a = BitmapArena(capacity=n + 1)
            a.adopt_many(bms)
            a.sync()
            return int(a.host_row(
                a.lookup(bms[0].containers[0])).sum())

        benches = [
            ("arena_cold_build", None, cold_build),
            ("arena_warm_query",
             functools.partial(aggregate.or_many, bms, backend="ref"),
             warm_query),
            ("arena_repatch", rebuild, repatch),
        ]
        recs = _run_benches(rows, "arena", benches, "dense", n, repeats)

        # Measured PCIe row accounting for the repatch pair (fresh
        # arenas so counters start at zero): the incremental path moves
        # 1 row where the rebuild re-uploads the whole slab.
        probe = BitmapArena(capacity=n + 1)
        probe.adopt_many(bms)
        probe.sync()
        moved_seed = probe.stats.rows_uploaded          # full upload
        bms[0].add(v0)
        probe.adopt(bms[0])
        probe.sync()
        moved_wide = probe.stats.rows_uploaded - moved_seed
        for r in recs:
            if r["bench"] == "arena_repatch":
                r["rows_moved_seed"] = moved_seed
                r["rows_moved_wide"] = moved_wide
                r["rows_moved_ratio"] = moved_seed / moved_wide

        # Staging step in isolation (hand-rolled: the checksum parity
        # check must stay outside the timed region).
        ids = np.arange(1, n + 1, dtype=np.int32)
        host_rows = warm.host_rows(ids)
        slab = warm.device_slab()
        dev_ids = jnp.asarray(ids)

        def stage(host_rows=host_rows, n=n):
            s = np.stack([host_rows[i] for i in range(n)])
            return jnp.asarray(s.view(np.uint32).reshape(n, 2048))

        def gather(slab=slab, dev_ids=dev_ids):
            return jnp.take(slab, dev_ids, axis=0)

        ok = bool(np.array_equal(np.asarray(stage()),
                                 np.asarray(gather())))
        t_seed, _ = common.time_stats(
            lambda: stage().block_until_ready(), repeats=repeats)
        t_new, med_new = common.time_stats(
            lambda: gather().block_until_ready(), repeats=repeats)
        t_seed, t_new, med_new = (t_seed * 1e6, t_new * 1e6,
                                  med_new * 1e6)
        speedup = t_seed / t_new if t_new else float("inf")
        recs.append({"bench": "arena_warm_stage", "dist": "dense",
                     "k": n, "seed_us": t_seed, "wide_us": t_new,
                     "median_us": med_new, "speedup": speedup,
                     "correct": ok})
        common.emit(
            rows, "arena", "arena_warm_stage", f"k={n}", "dense", t_new,
            f"correct={ok};median_us={round(med_new, 1)};"
            f"seed_us={round(t_seed, 1)};speedup={round(speedup, 2)}")
        records += recs
    return records


def cold_start(rows, quick: bool = False) -> list[dict]:
    """Snapshot-on-disk -> first query answered (docs/FORMAT.md §6).

    Seed side: an RJ02 archive -- ``deserialize`` every blob (CRC +
    structural validation + payload copies) before anything can be
    queried.  Wide side: a frozen snapshot archive --
    ``data.index.load_index`` mmaps it and defers per-entry directory
    walks (``LazyBitmaps``) until a query touches the term.  Results
    asserted bit-identical throughout.  Three rows per N:

    * ``cold_start_open`` -- file -> every bitmap materialized (the
      frozen side forced eager with ``dict(...)``): isolates parse
      cost, frozen wins on copies-avoided only.
    * ``cold_start_first_query`` -- the serving recipe: file -> index
      -> ONE 4-term union answered on the host path.  Eager must parse
      all N first; the lazy snapshot walks exactly 4 directories and
      faults in only the pages those postings live on.  THE acceptance
      row: speedup >= 3x at N=1024.
    * ``cold_start_bulk_promote`` -- file -> ENTIRE snapshot
      device-resident (seed: per-container ``adopt_many``; wide: bulk
      ``adopt_frozen`` -- one batched conversion, one transfer) ->
      all-terms union on the kernel path.

    Dataset: ``_arena_postings`` (mostly-bitset serving shape, one 8 KiB
    row per posting) -- the shape where eager deserialization hurts
    most and the frozen mmap path pays nothing until pages are touched.
    """
    import os
    import struct
    import tempfile

    from repro.core import serde
    from repro.core.arena import BitmapArena
    from repro.data.index import load_index

    records = []
    ns = (16, 64) if quick else (16, 64, 1024)
    repeats = 3 if quick else 5
    with tempfile.TemporaryDirectory() as tmp:
        for n in ns:
            bms = _arena_postings(n)
            snap_path = os.path.join(tmp, f"idx{n}.snap")
            serde.write_snapshot(
                snap_path, {f"t{r}": bm for r, bm in enumerate(bms)},
                meta=n)
            # RJ02 archive: uint32 count, then (uint32 len, blob) pairs
            rj_path = os.path.join(tmp, f"idx{n}.rj02")
            with open(rj_path, "wb") as f:
                f.write(struct.pack("<I", n))
                for bm in bms:
                    blob = serde.serialize(bm)
                    f.write(struct.pack("<I", len(blob)))
                    f.write(blob)
            q_terms = [f"t{r}" for r in
                       range(0, n, max(1, n // 4))][:4]

            def eager_open(rj_path=rj_path):
                with open(rj_path, "rb") as f:
                    buf = f.read()
                cnt = struct.unpack_from("<I", buf, 0)[0]
                out, off = {}, 4
                for i in range(cnt):
                    ln = struct.unpack_from("<I", buf, off)[0]
                    off += 4
                    out[f"t{i}"] = serde.deserialize(buf[off:off + ln])
                    off += ln
                return out

            def frozen_open(snap_path=snap_path):
                return dict(serde.read_snapshot(snap_path).bitmaps)

            def open_vals(open_fn):
                return list(open_fn().values())

            def eager_first_query(eager_open=eager_open, n=n,
                                  q_terms=q_terms):
                from repro.data.index import InvertedIndex
                idx = InvertedIndex.from_postings(eager_open(), n)
                return idx.query_or(*q_terms)

            def frozen_first_query(snap_path=snap_path,
                                   q_terms=q_terms):
                idx = load_index(snap_path)
                return idx.query_or(*q_terms)

            def eager_promote_all(eager_open=eager_open, n=n):
                loaded = list(eager_open().values())
                a = BitmapArena(capacity=n + 1)
                a.adopt_many(loaded)
                a.sync()
                return aggregate.or_many(loaded, backend="ref", arena=a)

            def frozen_promote_all(snap_path=snap_path, n=n):
                loaded = list(serde.read_snapshot(snap_path)
                              .bitmaps.values())
                a = BitmapArena(capacity=n + 1)
                a.adopt_frozen(loaded)
                a.sync()
                return aggregate.or_many(loaded, backend="ref", arena=a)

            records += _run_benches(
                rows, "cold_start",
                [("cold_start_open",
                  functools.partial(open_vals, eager_open),
                  functools.partial(open_vals, frozen_open)),
                 ("cold_start_first_query",
                  eager_first_query, frozen_first_query),
                 ("cold_start_bulk_promote",
                  eager_promote_all, frozen_promote_all)],
                "dense", n, repeats)
    return records


def query_throughput(rows, quick: bool = False) -> list[dict]:
    """Server-coalesced dispatch vs sequential per-query kernel loop.

    ``k`` is the concurrency (queued queries per tick).  Both sides run
    the SAME "ref" kernel backend and the same warm similarity slab; the
    seed side executes one plan per query (one dispatch each), the wide
    side submits everything to a ``QueryServer`` and drains it (one
    dispatch per op class per tick).  ``correct`` asserts the server's
    results are bit-identical to the sequential loop.  The acceptance
    contract lives in the k=1024 row: speedup >= 3x."""
    from repro.core import aggregate
    from repro.data.index import InvertedIndex
    from repro.serve import QueryServer

    n_terms = 64
    ix = InvertedIndex()
    ix.postings = _serving_postings(n_terms)
    ix.n_docs = 1 << 16
    terms_list, eng = ix._sim_engine()       # warm slab: serving contract
    records = []
    concs = (64,) if quick else (1, 64, 1024)
    repeats = 3 if quick else 5
    for conc in concs:
        queries = _serving_queries(conc, n_terms)

        def sequential(queries=queries):
            out = []
            for q in queries:
                if q.kind == "similar":
                    idx, score, _ = eng.topk(
                        terms_list.index(q.terms[0]), q.k, q.metric,
                        backend="ref")
                    out.append([(terms_list[i], float(s))
                                for i, s in zip(idx.tolist(),
                                                score.tolist())])
                else:
                    plan = aggregate.plan_wide(
                        q.kind, [ix._get(t) for t in q.terms], q.t,
                        q.weights, backend="ref")
                    out.append(aggregate._finish(plan, "ref", None))
            return out

        def served(queries=queries, conc=conc):
            srv = QueryServer(ix, backend="ref", max_batch=conc,
                              max_queue=conc)
            tickets = [srv.submit(q) for q in queries]
            srv.run_until_idle()
            return [t.result.value for t in tickets]

        records += _run_benches(rows, "server",
                                [("query_throughput", sequential, served)],
                                "mixed", conc, repeats)
    return records


# ---------------------------------------------------------------------------
# similar_sharded suite: per-shard arena slabs + k-list merge vs the
# single-device fused engine path -- the PR 9 contract (>= 2x at N=1e5
# on 4 forced host devices, bit-identical, warm slabs move zero rows).
# ---------------------------------------------------------------------------

def _sharded_sim_postings(n: int, seed: int = 41):
    """Zipfian single-chunk candidate sets over a 2^16 document universe:
    candidate ``r`` matches ~50k/(r+1)^1.1 docs (sampled with replacement,
    deduped), so every candidate is exactly ONE container row and a
    million-candidate slab stays at 8 KiB/row.  The head is dense (bitset
    rows), the tail sparse arrays -- the cardinality skew the pruning
    planner feeds on."""
    rng = np.random.default_rng(seed)
    sizes = np.maximum(
        4, (50_000 / np.arange(1, n + 1) ** 1.1).astype(np.int64))
    out = []
    for r in range(n):
        vals = np.unique(rng.integers(0, 1 << 16, sizes[r],
                                      dtype=np.uint32))
        out.append(RoaringBitmap.from_values(vals))
    return out


def similar_sharded(rows, quick: bool = False) -> list[dict]:
    """Sharded ``SimilarityEngine.topk`` (per-shard slabs, fused score +
    select per shard, k-list all-gather, device merge) vs the
    single-device fused path on the SAME arena, head member query,
    ``k``=10 jaccard.

    ``correct`` is (idx, score, inter) tuple equality against the fused
    seed AND a warm-slab PCIe check: the per-shard ``rows_uploaded``
    counters must not move across the timed re-queries.  ``n_devices``
    joins the gate key, so records from a 1-device fallback run never
    gate against true multi-device ones; the quick CI sweep runs under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to match the
    committed baseline.  The 1-device record is the degraded path (the
    mesh-aware engine falls back to the pruned host sweep)."""
    import gc

    import jax

    from repro.core.arena import BitmapArena
    from repro.core.pairwise import SimilarityEngine
    from repro.launch.mesh import make_wide_mesh

    records = []
    sizes = (10_000,) if quick else (10_000, 100_000, 1_000_000)
    dev_counts = tuple(d for d in (1, 2, 4) if d <= jax.device_count())
    top_k = 10
    for n in sizes:
        repeats = 2 if n >= 1_000_000 else 3
        bms = _sharded_sim_postings(n)
        arena = BitmapArena(capacity=n + 8)
        seed_eng = SimilarityEngine(bms, arena=arena)

        def seed_topk(eng=seed_eng):
            i, s, t = eng.topk(0, top_k, backend="ref")
            return (tuple(i.tolist()), tuple(s.tolist()),
                    tuple(t.tolist()))

        for d in dev_counts:
            mesh = make_wide_mesh(d)
            eng = SimilarityEngine(bms, arena=arena, mesh=mesh)

            def sharded_topk(eng=eng):
                i, s, t = eng.topk(0, top_k)
                return (tuple(i.tolist()), tuple(s.tolist()),
                        tuple(t.tolist()))

            sharded_topk()          # build the per-shard slabs untimed
            shards = arena.shard_slabs(mesh) if d > 1 else None
            up0 = ([s.rows_uploaded for s in shards.stats]
                   if shards is not None else None)
            recs = _run_benches(
                rows, "similar_sharded",
                [(f"similar_sharded_d{d}", seed_topk, sharded_topk)],
                "zipf_chunk", n, repeats)
            warm_ok = (shards is None or
                       [s.rows_uploaded for s in shards.stats] == up0)
            for r in recs:
                r["n_devices"] = d
                r["correct"] = bool(r["correct"] and warm_ok)
            records += recs
            del eng
            gc.collect()
        del seed_eng, arena, bms
        gc.collect()
    return records


# ---------------------------------------------------------------------------
# wide_ops_arena_sharded suite: warm sharded wide aggregates on per-shard
# arena slabs (aggregate._shard_reduce_arena) vs per-call host-mirror
# staging of the SAME container bytes at the SAME mesh -- the PR 10
# contract (zero container rows over PCIe once warm, per shard).
# ---------------------------------------------------------------------------

def wide_ops_arena_sharded(rows, quick: bool = False) -> list[dict]:
    """Warm K-way aggregates over per-shard arena slabs vs the staged
    sharded path (per-call stack + upload of the same rows the arena
    holds resident), K=64/1024 dense single-chunk postings at 1/2/4
    devices.

    ``correct`` is bit-identity between the two paths AND the warm-PCIe
    check: across the timed re-queries every shard's ``rows_uploaded``
    counter (the arena's own on 1 device) must not move — the warm path
    ships only int32 positions and segment offsets.  ``n_devices`` joins
    the gate key, so 1-device fallback records never gate against true
    multi-device ones; the quick CI sweep runs under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to match the
    committed baseline's d1/d2/d4 records."""
    import gc

    import jax

    from repro.core.arena import BitmapArena
    from repro.launch.mesh import make_wide_mesh

    records = []
    ks = (64,) if quick else (64, 1024)
    dev_counts = tuple(d for d in (1, 2, 4) if d <= jax.device_count())
    for k in ks:
        repeats = 2 if k >= 1024 else (3 if quick else 5)
        bms = _arena_postings(k)
        weights = [1 + i % 3 for i in range(k)]
        t = max(2, k // 4)
        arena = BitmapArena(capacity=k + 8)
        arena.adopt_many(bms)
        for d in dev_counts:
            mesh = make_wide_mesh(d)
            benches = [
                ("or_arena_sharded",
                 functools.partial(aggregate.or_many, bms, mesh=mesh),
                 functools.partial(aggregate.or_many, bms, mesh=mesh,
                                   arena=arena)),
                ("threshold_arena_sharded",
                 functools.partial(aggregate.threshold_many, bms, t,
                                   mesh=mesh),
                 functools.partial(aggregate.threshold_many, bms, t,
                                   mesh=mesh, arena=arena)),
                ("threshold_weighted_arena_sharded",
                 functools.partial(aggregate.threshold_many, bms,
                                   sum(weights) // 4, weights=weights,
                                   mesh=mesh),
                 functools.partial(aggregate.threshold_many, bms,
                                   sum(weights) // 4, weights=weights,
                                   mesh=mesh, arena=arena)),
            ]
            for name, seed_fn, new_fn in benches:
                new_fn()            # build/warm the per-shard slabs
                if d > 1:
                    shards = arena.shard_slabs(mesh)
                    up0 = [s.rows_uploaded for s in shards.stats]
                else:
                    up0 = [arena.stats.rows_uploaded,
                           arena.stats.host_rows_staged]
                recs = _run_benches(rows, "wide_ops_arena_sharded",
                                    [(name, seed_fn, new_fn)],
                                    "dense", k, repeats)
                if d > 1:
                    warm_ok = ([s.rows_uploaded
                                for s in shards.stats] == up0)
                else:
                    warm_ok = ([arena.stats.rows_uploaded,
                                arena.stats.host_rows_staged] == up0)
                for r in recs:
                    r["n_devices"] = d
                    r["correct"] = bool(r["correct"] and warm_ok)
                records += recs
        del arena, bms
        gc.collect()
    return records
