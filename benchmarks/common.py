"""Benchmark harness helpers: datasets, timing, CSV output."""

from __future__ import annotations

import time

from benchmarks.baselines import STRUCTURES
from repro.data.synth import TABLE3, generate_dataset

CPU_GHZ = 3.4   # the paper's Skylake i7-6700; ns -> "cycles" conversion

_CACHE: dict = {}


def datasets(n_sets: int = 50, seed: int = 0):
    """Table 3 twin datasets: {name: (list of value arrays, universe)}."""
    key = (n_sets, seed)
    if key not in _CACHE:
        out = {}
        for spec in TABLE3:
            import dataclasses
            s = dataclasses.replace(spec, n_sets=n_sets)
            out[spec.name] = (generate_dataset(s, seed), spec.universe)
        _CACHE[key] = out
    return _CACHE[key]


def build_all(values_list, universe):
    """Build every structure over the dataset; returns {name: [sets]}."""
    out = {}
    for cls in STRUCTURES:
        out[cls.name] = [cls(v, universe) for v in values_list]
    return out


def best_of(fn, repeats: int = 3) -> float:
    """Best wall-clock seconds of `repeats` runs."""
    return time_stats(fn, repeats)[0]


def time_stats(fn, repeats: int = 3) -> tuple[float, float]:
    """(best, median) wall-clock seconds of `repeats` runs.  The median is
    what the CI regression gate compares -- it is far more stable than the
    mean under scheduler noise on shared runners."""
    import statistics
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times), statistics.median(times)


def emit(rows: list, table: str, bench: str, structure: str, dataset: str,
         us_per_call: float, derived: str):
    """One CSV row: name,us_per_call,derived."""
    name = f"{table}/{bench}/{structure}/{dataset}"
    rows.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def ns_per_value(seconds: float, n_values: int) -> float:
    return seconds * 1e9 / max(n_values, 1)


def cycles_per_value(seconds: float, n_values: int) -> float:
    return ns_per_value(seconds, n_values) * CPU_GHZ
