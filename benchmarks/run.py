# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: reproduces every paper table (sections 5.4-5.10 +
Appendices B/C) on the synthetic Table-3 twin datasets.

    PYTHONPATH=src python -m benchmarks.run            # CI-sized
    PYTHONPATH=src python -m benchmarks.run --full     # paper-sized (200 sets)
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized: 200 sets/dataset, ClusterData x50")
    ap.add_argument("--only", default="",
                    help="comma list: table3,table4,...,table14,kernels")
    args = ap.parse_args()

    from benchmarks import ablation, kernels_bench, tables
    n_sets = 200 if args.full else 40
    n_time = 200 if args.full else 24
    cluster_scale = 0.1 if args.full else 0.002

    rows: list = []
    print("name,us_per_call,derived")
    want = set(args.only.split(",")) if args.only else None

    def go(name, fn):
        if want is None or name in want:
            fn()

    go("table3", lambda: tables.table3_datasets(rows, n_sets))
    go("table4", lambda: tables.table4_memory(rows, n_sets))
    go("table5", lambda: tables.table5_sequential(rows, n_time))
    go("table6", lambda: tables.table6_membership(rows, n_time))
    go("table7", lambda: tables.table7_pairwise_ops(rows, n_time))
    go("table8", lambda: tables.table8_wide_union(rows, n_time))
    go("table9", lambda: tables.table9_fast_counts(rows, n_time))
    go("table10", lambda: ablation.table10_simd_ablation(rows))
    go("table12", lambda: tables.table12_clusterdata(
        rows, scale=cluster_scale))
    go("table14", lambda: ablation.table14_host_vs_device(rows))
    go("kernels", lambda: kernels_bench.kernel_sweeps(rows))
    if want is None or "wide_ops" in want:
        records = kernels_bench.wide_ops(rows)
        with open("BENCH_wide_ops.json", "w") as f:
            json.dump(records, f, indent=2)
        print("# wrote BENCH_wide_ops.json", file=sys.stderr)

    print(f"# {len(rows)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
