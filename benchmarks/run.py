# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: reproduces every paper table (sections 5.4-5.10 +
Appendices B/C) on the synthetic Table-3 twin datasets.

    PYTHONPATH=src python -m benchmarks.run            # CI-sized
    PYTHONPATH=src python -m benchmarks.run --full     # paper-sized (200 sets)
    PYTHONPATH=src python -m benchmarks.run --suite wide_ops --quick \
        --out BENCH_candidate.json                     # CI regression gate

``--suite`` selects table/suite names (comma list; alias of the older
``--only``).  Suites ``wide_ops`` and ``wide_ops_sharded`` additionally
emit JSON records; ``--quick`` shrinks them to a gate-sized subset whose
(bench, dist, k) keys are a strict subset of the full sweep's.  The
sharded suite only exercises real sharding when more than one device is
visible (CI forces 4 with ``XLA_FLAGS=--xla_force_host_platform_device_
count=4``).
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized: 200 sets/dataset, ClusterData x50")
    ap.add_argument("--only", "--suite", dest="suites", default="",
                    help="comma list: table3,...,table14,kernels,"
                         "wide_ops,wide_ops_sharded,pairwise,"
                         "arena_warm,cold_start,query_throughput,"
                         "similar_sharded,wide_ops_arena_sharded")
    ap.add_argument("--quick", action="store_true",
                    help="gate-sized wide_ops sweeps (subset of full keys)")
    ap.add_argument("--out", default="",
                    help="write wide-op JSON records here instead of "
                         "BENCH_wide_ops.json")
    args = ap.parse_args()

    from benchmarks import ablation, kernels_bench, tables
    n_sets = 200 if args.full else 40
    n_time = 200 if args.full else 24
    cluster_scale = 0.1 if args.full else 0.002

    rows: list = []
    print("name,us_per_call,derived")
    want = set(args.suites.split(",")) if args.suites else None

    def go(name, fn):
        if want is None or name in want:
            fn()

    go("table3", lambda: tables.table3_datasets(rows, n_sets))
    go("table4", lambda: tables.table4_memory(rows, n_sets))
    go("table5", lambda: tables.table5_sequential(rows, n_time))
    go("table6", lambda: tables.table6_membership(rows, n_time))
    go("table7", lambda: tables.table7_pairwise_ops(rows, n_time))
    go("table8", lambda: tables.table8_wide_union(rows, n_time))
    go("table9", lambda: tables.table9_fast_counts(rows, n_time))
    go("table10", lambda: ablation.table10_simd_ablation(rows))
    go("table12", lambda: tables.table12_clusterdata(
        rows, scale=cluster_scale))
    go("table14", lambda: ablation.table14_host_vs_device(rows))
    go("kernels", lambda: kernels_bench.kernel_sweeps(rows))

    records: list = []
    if want is None or "wide_ops" in want:
        records += kernels_bench.wide_ops(rows, quick=args.quick)
    if want is None or "wide_ops_sharded" in want:
        records += kernels_bench.wide_ops_sharded(rows, quick=args.quick)
    if want is None or "pairwise" in want:
        records += kernels_bench.pairwise_suite(rows, quick=args.quick)
    if want is None or "arena_warm" in want:
        records += kernels_bench.arena_warm(rows, quick=args.quick)
    if want is None or "cold_start" in want:
        records += kernels_bench.cold_start(rows, quick=args.quick)
    if want is None or "query_throughput" in want:
        records += kernels_bench.query_throughput(rows, quick=args.quick)
    if want is None or "similar_sharded" in want:
        records += kernels_bench.similar_sharded(rows, quick=args.quick)
    if want is None or "wide_ops_arena_sharded" in want:
        records += kernels_bench.wide_ops_arena_sharded(
            rows, quick=args.quick)
    if records:
        out = args.out or "BENCH_wide_ops.json"
        with open(out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"# wrote {out}", file=sys.stderr)

    print(f"# {len(rows)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
