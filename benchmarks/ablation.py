"""Scalar-vs-vectorized ablation (paper Tables 10/13) and the host-vs-jit
comparison (the paper's Java-vs-C Appendix C analogue, Table 14).

The numpy path plays the paper's SIMD role; repro.core.scalar is the
deactivated-optimizations build.  Ratios, not absolute cycles, are the
reproduction target.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import RoaringBitmap
from repro.core import containers as C
from repro.core import scalar as S


def _pair_containers(rng, kind: str, n1: int, n2: int):
    a = np.sort(rng.choice(65536, n1, replace=False)).astype(np.uint16)
    b = np.sort(rng.choice(65536, n2, replace=False)).astype(np.uint16)
    if kind == "bitset":
        return (C.positions_to_bitset(a), C.positions_to_bitset(b))
    return a, b


def table10_simd_ablation(rows, reps=20):
    rng = np.random.default_rng(5)
    wa, wb = _pair_containers(rng, "bitset", 20000, 24000)
    aa, ab = _pair_containers(rng, "array", 3000, 3500)

    cases = {
        "bitset_and_card": (
            lambda: C.popcount_words(wa & wb),
            lambda: S.bitset_op(wa, wb, "and")[1]),
        "bitset_popcount": (
            lambda: C.popcount_words(wa),
            lambda: S.bitset_popcount(wa)),
        "array_intersect": (
            lambda: C.array_intersect(aa, ab),
            lambda: S.intersect(aa, ab)),
        "array_union": (
            lambda: C.array_union(aa, ab),
            lambda: S.union(aa, ab)),
        "array_difference": (
            lambda: C.array_difference(aa, ab),
            lambda: S.difference(aa, ab)),
        "array_symmetric_difference": (
            lambda: C.array_symmetric_difference(aa, ab),
            lambda: S.symmetric_difference(aa, ab)),
        "bitset_to_array": (
            lambda: C.bitset_to_positions(wa),
            lambda: S.bitset_to_positions(wa)),
        "bitset_set_many": (
            lambda: C.bitset_set_many(wa.copy(), ab),
            lambda: S.bitset_set_many(wa.copy(), ab)),
    }
    for name, (vec, scalar) in cases.items():
        tv = common.best_of(lambda: [vec() for _ in range(reps)])
        ts = common.best_of(lambda: [scalar() for _ in range(2)]) * reps / 2
        ratio = ts / tv if tv > 0 else float("inf")
        common.emit(rows, "table10", "simd_ablation", name, "synthetic",
                    tv * 1e6 / reps, f"scalar_over_vectorized={ratio:.1f}")


def table14_host_vs_device(rows, reps=5):
    """Host-numpy roaring vs jit'd RoaringTensor device path (the paper's
    'two implementations of the same structure' comparison)."""
    import jax
    from repro.core.tensor import RoaringTensor
    rng = np.random.default_rng(6)
    n_bm = 16
    host_a = [RoaringBitmap.from_values(
        rng.integers(0, 1 << 19, 40_000).astype(np.uint32))
        for _ in range(n_bm)]
    host_b = [RoaringBitmap.from_values(
        rng.integers(0, 1 << 19, 40_000).astype(np.uint32))
        for _ in range(n_bm)]
    ta = RoaringTensor.from_bitmaps(host_a, capacity=10)
    tb = RoaringTensor.from_bitmaps(host_b, capacity=10)
    f = jax.jit(lambda x, y: x.and_card(y))
    f(ta, tb).block_until_ready()          # compile outside timing

    def host():
        for x, y in zip(host_a, host_b):
            x.and_card(y)

    def device():
        f(ta, tb).block_until_ready()

    th = common.best_of(lambda: [host() for _ in range(reps)])
    td = common.best_of(lambda: [device() for _ in range(reps)])
    common.emit(rows, "table14", "intersection_count", "host_numpy",
                "synthetic", th * 1e6 / (reps * n_bm), "impl=host")
    common.emit(rows, "table14", "intersection_count", "device_jit",
                "synthetic", td * 1e6 / (reps * n_bm),
                f"impl=jit;host_over_device={th / td:.2f}")
