"""repro.data"""
