"""Training data pipeline with Roaring-indexed sample selection.

This is the paper's home turf (inverted indexes over record ids): the
pipeline holds
  * `keep`  -- a Roaring bitmap of sample ids passing the quality filter
               (built by set algebra over per-criterion bitmaps), and
  * `seen`  -- a Roaring bitmap of consumed ids,
and draws batches from `keep \\ seen`.  Both sets checkpoint with the model
(serde.py is the wire format), so restarts never replay samples -- the
fault-tolerance property the trainer tests assert.

Tokens are synthetic (hash-derived) so the pipeline is self-contained and
deterministic given (seed, sample id).
"""

from __future__ import annotations

import numpy as np

from repro.core import RoaringBitmap, deserialize, serialize


class RoaringDataPipeline:
    def __init__(self, n_docs: int, seq_len: int, batch_size: int,
                 vocab: int, seed: int = 0,
                 filters: dict[str, RoaringBitmap] | None = None):
        self.n_docs = n_docs
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.vocab = vocab
        self.seed = seed
        self.filters = filters or {}
        # keep = AND of all criterion bitmaps (paper: predicate intersection)
        keep = RoaringBitmap.from_range(0, n_docs)
        for bm in self.filters.values():
            keep = keep & bm
        self.keep = keep
        self.seen = RoaringBitmap()
        self.rng = np.random.default_rng(seed)
        self.step = 0

    # ------------------------------------------------------------------
    def remaining(self) -> int:
        return self.keep.andnot_card(self.seen)

    def _draw_ids(self) -> np.ndarray:
        avail = self.keep - self.seen
        n_avail = avail.cardinality
        if n_avail < self.batch_size:           # epoch boundary: reset seen
            self.seen = RoaringBitmap()
            avail = self.keep
            n_avail = avail.cardinality
        # select by rank (Roaring select is O(containers))
        ranks = self.rng.choice(n_avail, self.batch_size, replace=False)
        ids = np.array([avail.select(int(r)) for r in sorted(ranks)],
                       np.uint32)
        for i in ids:
            self.seen.add(int(i))
        return ids

    def _tokens_for(self, doc_id: int) -> np.ndarray:
        r = np.random.default_rng((self.seed << 32) ^ doc_id)
        return r.integers(0, self.vocab, self.seq_len + 1).astype(np.int32)

    def next_batch(self) -> dict:
        ids = self._draw_ids()
        toks = np.stack([self._tokens_for(int(i)) for i in ids])
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "doc_ids": ids}

    # ------------------------------------------------------------------
    # checkpointable state (resume without replay)
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "seen": serialize(self.seen),
            "keep": serialize(self.keep),
            "rng": self.rng.bit_generator.state,
            "step": self.step,
        }

    def load_state_dict(self, state: dict):
        self.seen = deserialize(bytes(state["seen"]))
        self.keep = deserialize(bytes(state["keep"]))
        self.rng.bit_generator.state = state["rng"]
        self.step = int(state["step"])


def dedup_filter(doc_hashes: np.ndarray) -> RoaringBitmap:
    """Keep the first occurrence of each content hash: a Roaring bitmap of
    survivor ids (vectorized duplicate detection)."""
    _, first_idx = np.unique(doc_hashes, return_index=True)
    return RoaringBitmap.from_values(np.sort(first_idx).astype(np.uint32))


def quality_filter(scores: np.ndarray, threshold: float) -> RoaringBitmap:
    return RoaringBitmap.from_values(
        np.flatnonzero(scores >= threshold).astype(np.uint32))
