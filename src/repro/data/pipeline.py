"""Training data pipeline with Roaring-indexed sample selection.

This is the paper's home turf (inverted indexes over record ids): the
pipeline holds
  * `keep`  -- a Roaring bitmap of sample ids passing the quality filter
               (built by set algebra over per-criterion bitmaps), and
  * `seen`  -- a Roaring bitmap of consumed ids,
and draws batches from `keep \\ seen`.  Both sets checkpoint with the model
(serde.py is the wire format), so restarts never replay samples -- the
fault-tolerance property the trainer tests assert.

Tokens are synthetic (hash-derived) so the pipeline is self-contained and
deterministic given (seed, sample id).
"""

from __future__ import annotations

import os

import numpy as np

from repro.core import RoaringBitmap, deserialize, serde, serialize


class RoaringDataPipeline:
    def __init__(self, n_docs: int, seq_len: int, batch_size: int,
                 vocab: int, seed: int = 0,
                 filters: dict[str, RoaringBitmap] | None = None):
        self.n_docs = n_docs
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.vocab = vocab
        self.seed = seed
        self.filters = filters or {}
        # keep = AND of all criterion bitmaps (paper: predicate intersection)
        keep = RoaringBitmap.from_range(0, n_docs)
        for bm in self.filters.values():
            keep = keep & bm
        self.keep = keep
        self.seen = RoaringBitmap()
        self.rng = np.random.default_rng(seed)
        self.step = 0

    # ------------------------------------------------------------------
    def remaining(self) -> int:
        return self.keep.andnot_card(self.seen)

    def _draw_ids(self) -> np.ndarray:
        avail = self.keep - self.seen
        n_avail = avail.cardinality
        if n_avail < self.batch_size:           # epoch boundary: reset seen
            self.seen = RoaringBitmap()
            avail = self.keep
            n_avail = avail.cardinality
        # select by rank (Roaring select is O(containers))
        ranks = self.rng.choice(n_avail, self.batch_size, replace=False)
        ids = np.array([avail.select(int(r)) for r in sorted(ranks)],
                       np.uint32)
        for i in ids:
            self.seen.add(int(i))
        return ids

    def _tokens_for(self, doc_id: int) -> np.ndarray:
        r = np.random.default_rng((self.seed << 32) ^ doc_id)
        return r.integers(0, self.vocab, self.seq_len + 1).astype(np.int32)

    def next_batch(self) -> dict:
        ids = self._draw_ids()
        toks = np.stack([self._tokens_for(int(i)) for i in ids])
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "doc_ids": ids}

    # ------------------------------------------------------------------
    # checkpointable state (resume without replay)
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "seen": serialize(self.seen),
            "keep": serialize(self.keep),
            "rng": self.rng.bit_generator.state,
            "step": self.step,
        }

    def load_state_dict(self, state: dict):
        self.seen = deserialize(bytes(state["seen"]))
        self.keep = deserialize(bytes(state["keep"]))
        self.rng.bit_generator.state = state["rng"]
        self.step = int(state["step"])


class StreamingIndexBuilder:
    """Bounded-memory inverted-index construction: append postings in
    chunks, spill frozen segments to disk, finalize into ONE mmap-able
    snapshot archive a node can map and query in milliseconds.

    The cold-start ingest half of the PR-8 serde work (docs/FORMAT.md
    sections 2-3): instead of holding every posting list in RAM until
    the end, the builder accumulates raw doc-id chunks per term and --
    whenever the pending raw bytes cross ``segment_bytes`` -- freezes
    them into a segment file in the frozen zero-copy layout.
    :meth:`finalize` merges all segments (mmap-backed views, per-term
    ``or_many``) into the final archive at ``path`` and hands back the
    mapped index; with a single segment the merge is a rename.

    Typical use::

        b = StreamingIndexBuilder("idx.snap", segment_bytes=32 << 20)
        for doc_id, terms in corpus:
            b.add_document(doc_id, terms)
        index = b.finalize(arena=arena)   # mapped + device-warm

    Peak memory is O(segment_bytes + largest term's postings), not
    O(index); every spill is sequential I/O.
    """

    def __init__(self, path, *, segment_bytes: int = 64 << 20):
        """Args: ``path`` -- destination snapshot archive (segments
        spill beside it as ``<path>.seg<N>``); ``segment_bytes`` --
        raw pending-postings threshold (4 bytes per appended doc id)
        that triggers a spill."""
        self.path = os.fspath(path)
        self.segment_bytes = int(segment_bytes)
        self.n_docs = 0
        self._pend: dict[str, list[np.ndarray]] = {}
        self._pend_ids = 0              # appended ids since last spill
        self._segments: list[str] = []

    @property
    def pending_bytes(self) -> int:
        """Raw bytes of buffered postings (4 per pending doc id)."""
        return 4 * self._pend_ids

    def append_postings(self, term: str, doc_ids) -> None:
        """Bulk-append doc ids to one term's postings (columnar path).

        Args: ``doc_ids`` -- array-like of uint32 document ids, any
        order, duplicates allowed (deduped at spill).  Spills a frozen
        segment when the pending raw bytes cross ``segment_bytes``.
        Amortized O(len(doc_ids)).
        """
        ids = np.asarray(doc_ids, np.uint32).ravel()
        if ids.size == 0:
            return
        self.n_docs = max(self.n_docs, int(ids.max()) + 1)
        self._pend.setdefault(term, []).append(ids)
        self._pend_ids += ids.size
        if self.pending_bytes >= self.segment_bytes:
            self._spill()

    def add_document(self, doc_id: int, terms) -> None:
        """Row-wise append: register ``doc_id`` under each distinct
        term.  Convenience wrapper over :meth:`append_postings`."""
        one = np.array([doc_id], np.uint32)
        for t in set(terms):
            self.append_postings(t, one)

    def _spill(self) -> None:
        """Freeze pending postings into ``<path>.seg<N>`` and drop the
        buffers.  One bitmap per pending term (``from_values`` sorts +
        dedups, ``run_optimize`` picks the compact encoding)."""
        if not self._pend:
            return
        named = {}
        for term in sorted(self._pend):
            vals = np.concatenate(self._pend[term])
            named[term] = RoaringBitmap.from_values(vals).run_optimize()
        seg = f"{self.path}.seg{len(self._segments)}"
        serde.write_snapshot(seg, named, meta=self.n_docs)
        self._segments.append(seg)
        self._pend = {}
        self._pend_ids = 0

    def finalize(self, *, arena=None):
        """Spill the tail, merge every segment into the final archive
        at ``path``, delete the segments, and return the mapped index.

        Single-segment builds skip the merge (one ``os.replace``).
        Multi-segment merges mmap each segment and union per term
        (``or_many``), so peak memory is one term's merged postings,
        not the index.  Returns ``repro.data.index.load_index(path,
        arena=arena)`` -- an InvertedIndex over zero-copy views of the
        final file, bulk-promoted to the arena when one is given.
        Complexity: O(total payload bytes) once.
        """
        from repro.data.index import load_index
        self._spill()
        if not self._segments:
            serde.write_snapshot(self.path, {}, meta=self.n_docs)
        elif len(self._segments) == 1:
            os.replace(self._segments[0], self.path)
        else:
            snaps = [serde.read_snapshot(s) for s in self._segments]
            n_docs = max(s.meta for s in snaps)
            terms = sorted({t for s in snaps for t in s.bitmaps})
            merged = {}
            for t in terms:
                parts = [s.bitmaps[t] for s in snaps if t in s.bitmaps]
                merged[t] = (parts[0] if len(parts) == 1
                             else RoaringBitmap.or_many(parts))
            serde.write_snapshot(self.path, merged, meta=n_docs)
            del snaps
            for s in self._segments:
                os.remove(s)
        self._segments = []
        return load_index(self.path, arena=arena)


def dedup_filter(doc_hashes: np.ndarray) -> RoaringBitmap:
    """Keep the first occurrence of each content hash: a Roaring bitmap of
    survivor ids (vectorized duplicate detection)."""
    _, first_idx = np.unique(doc_hashes, return_index=True)
    return RoaringBitmap.from_values(np.sort(first_idx).astype(np.uint32))


def quality_filter(scores: np.ndarray, threshold: float) -> RoaringBitmap:
    return RoaringBitmap.from_values(
        np.flatnonzero(scores >= threshold).astype(np.uint32))
