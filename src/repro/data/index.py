"""A small inverted index on Roaring bitmaps -- the paper's motivating
application (section 1: "inverted indexes map query terms to document
identifiers").  Used by examples/analytics_index.py and the benchmarks.
"""

from __future__ import annotations

import numpy as np

from repro.core import RoaringBitmap


class InvertedIndex:
    def __init__(self):
        self.postings: dict[str, RoaringBitmap] = {}
        self.n_docs = 0

    def add_document(self, doc_id: int, terms) -> None:
        self.n_docs = max(self.n_docs, doc_id + 1)
        for t in set(terms):
            bm = self.postings.get(t)
            if bm is None:
                bm = self.postings[t] = RoaringBitmap()
            bm.add(doc_id)

    def build(self, docs: list[list[str]]) -> "InvertedIndex":
        # columnar build: term -> sorted doc ids, one from_values each
        by_term: dict[str, list[int]] = {}
        for i, terms in enumerate(docs):
            for t in set(terms):
                by_term.setdefault(t, []).append(i)
        self.n_docs = len(docs)
        for t, ids in by_term.items():
            self.postings[t] = RoaringBitmap.from_values(
                np.asarray(ids, np.uint32))
        return self

    def optimize(self):
        for bm in self.postings.values():
            bm.run_optimize()
        return self

    # query surface ------------------------------------------------------
    def _get(self, term: str) -> RoaringBitmap:
        return self.postings.get(term, RoaringBitmap())

    # query_and/query_or/query_xor/query_threshold all route through the
    # wide-aggregation planner (repro.core.aggregate): one fused kernel
    # dispatch per query regardless of the number of terms.
    def query_and(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.and_many([self._get(t) for t in terms])

    def query_or(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.or_many([self._get(t) for t in terms])

    def query_xor(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.xor_many([self._get(t) for t in terms])

    def query_threshold(self, terms, t: int, weights=None) -> RoaringBitmap:
        """Documents whose matched terms reach a total score of ``t``
        (T-occurrence query, Kaser & Lemire); optional per-term integer
        ``weights`` rank terms without leaving the one-dispatch plan."""
        return RoaringBitmap.threshold_many(
            [self._get(term) for term in terms], t, weights=weights)

    def query_andnot(self, keep: str, *drops: str) -> RoaringBitmap:
        """Documents matching ``keep`` and none of ``drops`` -- a
        difference chain planned as one fused dispatch (the union of the
        dropped postings is never materialized)."""
        return RoaringBitmap.andnot_many(
            self._get(keep), [self._get(d) for d in drops])

    def count_and(self, a: str, b: str) -> int:
        return self._get(a).and_card(self._get(b))  # fast count, sec 5.9

    def jaccard(self, a: str, b: str) -> float:
        return self._get(a).jaccard(self._get(b))

    def similar(self, term: str, top_k: int = 10,
                metric: str = "jaccard") -> list[tuple[str, float]]:
        """Top-k terms most similar to ``term`` -- a similarity join over
        every posting list, planned by the batched pairwise engine as one
        AND-count dispatch per container-type class instead of one
        per pair ("beyond unions and intersections", Kaser & Lemire).

        ``metric`` is "jaccard" (|A∩B| / |A∪B|), "cosine"
        (|A∩B| / sqrt(|A||B|)) or "containment" (|A∩B| / |A|, the query
        side).  Returns [(term, score)] sorted best-first."""
        if metric not in ("jaccard", "cosine", "containment"):
            raise ValueError(metric)
        q = self._get(term)
        others = [t for t in self.postings if t != term]
        if not others:
            return []
        pairs = [(q, self.postings[t]) for t in others]
        inter = RoaringBitmap.pairwise_card("and", pairs) \
            .astype(np.float64)
        qc = float(q.cardinality)
        oc = np.array([self.postings[t].cardinality for t in others],
                      np.float64)
        if metric == "jaccard":
            denom = qc + oc - inter
        elif metric == "cosine":
            denom = np.sqrt(qc * oc)
        else:
            denom = np.full_like(oc, qc)
        score = np.divide(inter, denom, out=np.ones_like(inter),
                          where=denom > 0)
        order = np.argsort(-score, kind="stable")[:top_k]
        return [(others[i], float(score[i])) for i in order.tolist()]

    def memory_bytes(self) -> int:
        return sum(bm.memory_bytes() for bm in self.postings.values())
