"""A small inverted index on Roaring bitmaps -- the paper's motivating
application (section 1: "inverted indexes map query terms to document
identifiers").  Used by examples/analytics_index.py and the benchmarks.
"""

from __future__ import annotations

import numpy as np

from repro.core import RoaringBitmap


class InvertedIndex:
    """Term -> document-id postings on Roaring bitmaps.

    Every query routes through a batched planner: the boolean surface
    (``query_and`` .. ``query_andnot``) plans ONE segmented-kernel
    dispatch per query via ``repro.core.aggregate``; the similarity
    surface (``similar``) runs on a cached ``SimilarityEngine`` slab,
    one fused score+select dispatch per query on kernel backends.  See
    docs/ARCHITECTURE.md for the paper-section -> module map.

    Unknown-term / empty-input contract (uniform across EVERY query
    entry point, relied on by the query server's admission path):
    a term absent from the index queries as an EMPTY posting list --
    never a ``KeyError`` -- and an empty term list yields an empty
    result.  Consequences: ``query_and``/``query_or``/``query_xor``
    with no or only-unknown terms return the empty bitmap;
    ``query_andnot`` with an unknown ``keep`` is empty and unknown
    ``drops`` subtract nothing; ``query_threshold`` prunes unknown
    terms' (zero) contributions; ``count_and`` returns 0;
    ``jaccard`` follows the set convention (two empty sets -> 1.0,
    empty vs non-empty -> 0.0); ``similar`` scores an unknown term as
    an empty query (all scores 0) and returns a full-length, validly
    ordered list.

    ``arena``: an optional ``core.arena.BitmapArena``.  When present,
    query entry points adopt their term postings into it first, so
    container rows live device-resident across queries (warm re-queries
    ship no container payloads over PCIe) and the cached
    ``SimilarityEngine`` becomes an arena view whose ``slab_mismatch``
    recovery is a generation revalidation -- only edited rows repatch --
    instead of a full slab rebuild (docs/MEMORY.md has the lifecycle).
    Results are bit-identical with or without an arena."""

    def __init__(self, *, arena=None):
        self.postings: dict[str, RoaringBitmap] = {}
        self.n_docs = 0
        self.arena = arena
        # cached (snapshot, terms, SimilarityEngine); the snapshot
        # revalidates against direct postings edits -- see _sim_engine
        self._sim = None

    @classmethod
    def from_postings(cls, postings, n_docs: int, *,
                      arena=None) -> "InvertedIndex":
        """Wrap pre-built posting lists -- the snapshot cold-start
        constructor (``load_index`` / ``StreamingIndexBuilder.finalize``
        route through here).

        Args: ``postings`` a mapping of term -> RoaringBitmap.  A lazy
        ``serde.LazyBitmaps`` mapping (what ``read_snapshot`` returns)
        is kept AS the postings store, so entries stay unmaterialized
        until a query touches them; any other mapping is copied into a
        plain dict.  ``n_docs`` is the document-id space size.
        ``arena``: an optional BitmapArena -- when given, ALL postings
        are materialized and bulk-promoted via ``arena.adopt_frozen``
        (one batched conversion + one device transfer) so every query
        is warm from the start; without one, cold start defers
        per-entry work entirely (the lazy first-query path the
        ``cold_start`` benchmark gates).

        Returns the index.  Complexity: O(1) without an arena; with
        one, O(total payload bytes) host work + one host->device
        transfer.  See docs/FORMAT.md for the on-disk layouts this
        pairs with.
        """
        from repro.core import serde
        idx = cls(arena=arena)
        idx.postings = (postings if isinstance(postings, serde.LazyBitmaps)
                        else dict(postings))
        idx.n_docs = int(n_docs)
        if arena is not None:
            arena.adopt_frozen(idx.postings.values())
        return idx

    def add_document(self, doc_id: int, terms) -> None:
        if self.arena is None:
            self._sim = None                      # postings changed
        # with an arena, _sim_engine revalidates generations instead
        self.n_docs = max(self.n_docs, doc_id + 1)
        for t in set(terms):
            bm = self.postings.get(t)
            if bm is None:
                bm = self.postings[t] = RoaringBitmap()
            bm.add(doc_id)

    def build(self, docs: list[list[str]]) -> "InvertedIndex":
        # columnar build: term -> sorted doc ids, one from_values each
        self._sim = None
        by_term: dict[str, list[int]] = {}
        for i, terms in enumerate(docs):
            for t in set(terms):
                by_term.setdefault(t, []).append(i)
        self.n_docs = len(docs)
        for t, ids in by_term.items():
            self.postings[t] = RoaringBitmap.from_values(
                np.asarray(ids, np.uint32))
        return self

    def optimize(self):
        if self.arena is None:
            self._sim = None
        for bm in self.postings.values():
            bm.run_optimize()
        return self

    # query surface ------------------------------------------------------
    def _get(self, term: str) -> RoaringBitmap:
        """Postings for ``term``; an unknown term is an empty posting
        list (the class-level contract: no KeyError, ever)."""
        return self.postings.get(term, RoaringBitmap())

    def _adopt(self, bms: list[RoaringBitmap]) -> list[RoaringBitmap]:
        """Adopt query operands into the arena (no-op without one).
        Only non-empty bitmaps register: the fresh empties ``_get``
        returns for unknown terms are per-call temporaries that must not
        pin arena rows."""
        if self.arena is not None:
            for bm in bms:
                if bm.containers:
                    self.arena.adopt(bm)
        return bms

    # query_and/query_or/query_xor/query_threshold all route through the
    # wide-aggregation planner (repro.core.aggregate): one fused kernel
    # dispatch per query regardless of the number of terms.
    def query_and(self, *terms) -> RoaringBitmap:
        """Documents matching ALL ``terms``: one fused dispatch with
        cardinality-ascending pruning (docs/ARCHITECTURE.md section 3).
        Unknown terms are empty postings, so the result is empty."""
        return RoaringBitmap.and_many(
            self._adopt([self._get(t) for t in terms]), arena=self.arena)

    def query_or(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.or_many(
            self._adopt([self._get(t) for t in terms]), arena=self.arena)

    def query_xor(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.xor_many(
            self._adopt([self._get(t) for t in terms]), arena=self.arena)

    def query_threshold(self, terms, t: int, weights=None) -> RoaringBitmap:
        """Documents whose matched terms reach a total score of ``t``
        (T-occurrence query, Kaser & Lemire); optional per-term integer
        ``weights`` rank terms without leaving the one-dispatch plan."""
        return RoaringBitmap.threshold_many(
            self._adopt([self._get(term) for term in terms]), t,
            weights=weights, arena=self.arena)

    def query_andnot(self, keep: str, *drops: str) -> RoaringBitmap:
        """Documents matching ``keep`` and none of ``drops`` -- a
        difference chain planned as one fused dispatch (the union of the
        dropped postings is never materialized)."""
        ops = self._adopt([self._get(keep)] + [self._get(d) for d in drops])
        return RoaringBitmap.andnot_many(ops[0], ops[1:],
                                         arena=self.arena)

    def count_and(self, a: str, b: str) -> int:
        return self._get(a).and_card(self._get(b))  # fast count, sec 5.9

    def jaccard(self, a: str, b: str) -> float:
        return self._get(a).jaccard(self._get(b))

    def _sim_engine(self, mesh=None):
        """Cached similarity engine over every posting list, rebuilt
        lazily after any postings mutation.  Mutations through the index
        API drop the cache eagerly; direct edits of the public
        ``postings`` dict (replaced bitmaps, new terms, point updates)
        are caught by an O(terms) snapshot of term names plus each
        bitmap's identity, mutation counter (``RoaringBitmap._version``,
        bumped by every add/remove/run_optimize), and cardinality.
        Only hand-assembled aliasing -- a DIFFERENT bitmap object
        recycled at the same address with equal version and cardinality
        -- could escape revalidation.

        With an arena, a stale snapshot over the SAME term set and
        bitmap objects refreshes the engine in place (``refresh()``:
        the arena repatches only the edited rows) instead of rebuilding
        the slab; term-set or object changes still rebuild.

        ``mesh``: optional 1-D ``("wide",)`` mesh.  With more than one
        device the engine runs the sharded per-shard-slab path (requires
        an arena-backed index); engines are cached per mesh, so sharded
        and single-device engines over the same postings coexist."""
        key = None
        if mesh is not None:
            from repro.dist import ctx
            m, size, _ = ctx.resolve_wide(mesh)
            if size > 1:
                if self.arena is None:
                    raise ValueError(
                        "similar(mesh=) requires an arena-backed index")
                key = m
        snap = tuple((t, id(bm), bm._version, bm.cardinality)
                     for t, bm in self.postings.items())
        cache = self._sim if isinstance(self._sim, dict) else {}
        ent = cache.get(key)
        if ent is None or ent[0] != snap:
            from repro.core.pairwise import SimilarityEngine
            terms = list(self.postings)
            if (self.arena is not None and ent is not None
                    and ent[1] == terms
                    and all(self.postings[t] is bm for t, bm in
                            zip(terms, ent[2]._bitmaps))):
                eng = ent[2]
                eng.refresh()
                ent = (snap, terms, eng)
            else:
                ent = (snap, terms,
                       SimilarityEngine((self.postings[t] for t in terms),
                                        arena=self.arena, mesh=key))
            cache[key] = ent
            self._sim = cache
        return ent[1], ent[2]

    def similar(self, term: str, top_k: int = 10,
                metric: str = "jaccard", *,
                backend: str | None = None,
                mesh=None) -> list[tuple[str, float]]:
        """Top-k terms most similar to ``term``: one fused score+select
        kernel dispatch over a device-resident candidate slab (kernel
        backends) or a bound-pruned vectorized sweep (CPU) -- see
        ``repro.core.pairwise.SimilarityEngine`` and docs/ARCHITECTURE.md.
        The slab is cached across queries and rebuilt after mutations.

        Args: ``term`` query term (an unknown term queries as an empty
        posting list); ``top_k`` results wanted (clamped to the term
        count); ``metric`` is "jaccard" (|A∩B| / |A∪B|), "cosine"
        (|A∩B| / sqrt(|A||B|)) or "containment" (|A∩B| / |A|, the query
        side); ``backend`` forces the kernel ("pallas"/"ref") or host
        (CPU default) path -- results are bit-identical either way;
        ``mesh`` a 1-D ``("wide",)`` mesh to run the sharded per-shard-
        slab path (requires an arena-backed index; a 1-device mesh
        degrades to the single-device engine) -- results stay
        bit-identical, including tie order.

        Returns [(term, score)] best-first; score ties order by index
        insertion order.  Complexity: one dispatch per query; host path
        skips every candidate whose cardinality bound cannot reach the
        running k-th score."""
        from repro.core.pairwise import METRICS
        if metric not in METRICS:
            raise ValueError(metric)
        terms, eng = self._sim_engine(mesh=mesh)
        if term in self.postings:
            query = terms.index(term)
        else:
            query = self._get(term)
        idx, score, _ = eng.topk(query, top_k, metric, backend=backend)
        return [(terms[i], float(s)) for i, s in zip(idx.tolist(),
                                                     score.tolist())]

    def memory_bytes(self) -> int:
        return sum(bm.memory_bytes() for bm in self.postings.values())


def load_index(path, *, arena=None, mmap: bool = True) -> InvertedIndex:
    """Map an on-disk snapshot archive straight into a queryable index.

    The cold-start path (docs/FORMAT.md section 3): the archive written
    by ``StreamingIndexBuilder.finalize`` (or ``serde.write_snapshot``)
    is mapped read-only, every posting list becomes numpy views over
    the mapped buffer (zero payload copies, pages fault in on first
    touch), and -- when ``arena`` is given -- the whole set is promoted
    to the device slab in one batched transfer.

    Args: ``path`` the snapshot file; ``arena`` optional BitmapArena
    for device-warm queries; ``mmap=False`` reads the file into memory
    instead (same views, private buffer).

    Returns an InvertedIndex whose ``n_docs`` is the archive's ``meta``
    field.  Raises ``ValueError`` on a corrupt archive.  Complexity:
    O(terms + containers) directory work; payload bytes are only
    touched by queries (or the arena promotion).
    """
    from repro.core import serde
    snap = serde.read_snapshot(path, mmap=mmap)
    return InvertedIndex.from_postings(snap.bitmaps, snap.meta,
                                       arena=arena)
