"""A small inverted index on Roaring bitmaps -- the paper's motivating
application (section 1: "inverted indexes map query terms to document
identifiers").  Used by examples/analytics_index.py and the benchmarks.
"""

from __future__ import annotations

import numpy as np

from repro.core import RoaringBitmap


class InvertedIndex:
    def __init__(self):
        self.postings: dict[str, RoaringBitmap] = {}
        self.n_docs = 0

    def add_document(self, doc_id: int, terms) -> None:
        self.n_docs = max(self.n_docs, doc_id + 1)
        for t in set(terms):
            bm = self.postings.get(t)
            if bm is None:
                bm = self.postings[t] = RoaringBitmap()
            bm.add(doc_id)

    def build(self, docs: list[list[str]]) -> "InvertedIndex":
        # columnar build: term -> sorted doc ids, one from_values each
        by_term: dict[str, list[int]] = {}
        for i, terms in enumerate(docs):
            for t in set(terms):
                by_term.setdefault(t, []).append(i)
        self.n_docs = len(docs)
        for t, ids in by_term.items():
            self.postings[t] = RoaringBitmap.from_values(
                np.asarray(ids, np.uint32))
        return self

    def optimize(self):
        for bm in self.postings.values():
            bm.run_optimize()
        return self

    # query surface ------------------------------------------------------
    def _get(self, term: str) -> RoaringBitmap:
        return self.postings.get(term, RoaringBitmap())

    # query_and/query_or/query_xor/query_threshold all route through the
    # wide-aggregation planner (repro.core.aggregate): one fused kernel
    # dispatch per query regardless of the number of terms.
    def query_and(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.and_many([self._get(t) for t in terms])

    def query_or(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.or_many([self._get(t) for t in terms])

    def query_xor(self, *terms) -> RoaringBitmap:
        return RoaringBitmap.xor_many([self._get(t) for t in terms])

    def query_threshold(self, terms, t: int, weights=None) -> RoaringBitmap:
        """Documents whose matched terms reach a total score of ``t``
        (T-occurrence query, Kaser & Lemire); optional per-term integer
        ``weights`` rank terms without leaving the one-dispatch plan."""
        return RoaringBitmap.threshold_many(
            [self._get(term) for term in terms], t, weights=weights)

    def query_andnot(self, keep: str, *drops: str) -> RoaringBitmap:
        """Documents matching ``keep`` and none of ``drops`` -- a
        difference chain planned as one fused dispatch (the union of the
        dropped postings is never materialized)."""
        return RoaringBitmap.andnot_many(
            self._get(keep), [self._get(d) for d in drops])

    def count_and(self, a: str, b: str) -> int:
        return self._get(a).and_card(self._get(b))  # fast count, sec 5.9

    def jaccard(self, a: str, b: str) -> float:
        return self._get(a).jaccard(self._get(b))

    def memory_bytes(self) -> int:
        return sum(bm.memory_bytes() for bm in self.postings.values())
