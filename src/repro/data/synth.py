"""Synthetic dataset twins of the paper's benchmark data (section 5.3).

The paper's CENSUS*/WEATHER*/WIKILEAKS* sets are bitmap-index postings lists
(record ids matching `column = value` predicates).  They are not
redistributable offline, so we generate distribution-matched twins keyed by
Table 3's statistics: universe size, mean cardinality and density, with
"sorted" variants modeling lexicographically-sorted tables (long runs --
which is what makes run containers and RLE formats shine on the *sort
datasets).

Also: the ClusterData generator of Anh & Moffat [62] used by the paper's
Appendix B large-scale experiment.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    universe: int
    avg_cardinality: float
    n_sets: int = 200
    sorted_runs: bool = False   # *sort variants: clustered long runs


# Table 3 twins (universe / avg cardinality from the paper)
TABLE3 = [
    DatasetSpec("census_inc", 199_523, 34_610.1),
    DatasetSpec("census_inc_sort", 199_523, 30_464.3, sorted_runs=True),
    DatasetSpec("census1881", 4_277_806, 5_019.3),
    DatasetSpec("census1881_sort", 4_277_735, 3_404.0, sorted_runs=True),
    DatasetSpec("weather", 1_015_367, 64_353.1),
    DatasetSpec("weather_sort", 1_015_367, 80_540.5, sorted_runs=True),
    DatasetSpec("wikileaks", 1_353_179, 1_376.8),
    DatasetSpec("wikileaks_sort", 1_353_133, 1_440.1, sorted_runs=True),
]


def generate_set(spec: DatasetSpec, rng: np.random.Generator) -> np.ndarray:
    """One postings list: sorted distinct uint32 values in [0, universe)."""
    # cardinalities are roughly log-normal around the table mean
    card = int(np.clip(rng.lognormal(np.log(spec.avg_cardinality), 0.6),
                       8, spec.universe * 0.98))
    if spec.sorted_runs:
        # sorted tables produce long runs: draw run starts + lengths
        mean_run = max(4, card // max(1, int(card / 64)))
        vals = []
        total = 0
        while total < card:
            run_len = max(1, int(rng.exponential(mean_run)))
            run_len = min(run_len, card - total)
            start = rng.integers(0, spec.universe - run_len)
            vals.append(np.arange(start, start + run_len, dtype=np.uint32))
            total += run_len
        arr = np.unique(np.concatenate(vals))
    else:
        # unsorted tables: clustered but scattered within clusters (adjacent
        # record ids rarely co-occur -> few runs, the regime where the paper
        # shows Roaring beating the word-aligned RLE formats)
        n_clusters = max(1, card // 256)
        centers = rng.integers(0, spec.universe, n_clusters)
        widths = rng.integers(2048, 65536, n_clusters)
        per = card // n_clusters + 1
        vals = (centers[:, None]
                + rng.integers(0, widths[:, None], (n_clusters, per)))
        arr = np.unique(vals.reshape(-1) % spec.universe).astype(np.uint32)
    return arr


def generate_dataset(spec: DatasetSpec, seed: int = 0) -> list[np.ndarray]:
    # crc32, not hash(): str hashes are salted per process (PYTHONHASHSEED),
    # which silently made "seeded" datasets irreproducible across runs
    import zlib
    rng = np.random.default_rng(seed ^ zlib.crc32(spec.name.encode()))
    return [generate_set(spec, rng) for _ in range(spec.n_sets)]


def cluster_data(n_values: int, universe: int, seed: int = 0,
                 f: float = 0.1) -> np.ndarray:
    """Anh-Moffat ClusterData: recursive span splitting leaves small gaps
    between successive integers with occasional large jumps (Appendix B).

    Iterative formulation: place values cluster by cluster; cluster sizes
    geometric, gap sizes heavy-tailed.
    """
    rng = np.random.default_rng(seed)
    out = np.empty(n_values, np.uint32)
    pos = 0
    filled = 0
    while filled < n_values:
        remaining_vals = n_values - filled
        remaining_space = universe - pos
        csize = min(int(rng.geometric(f)) + 1, remaining_vals)
        # dense cluster: consecutive-ish values (gap 1..3)
        gaps = rng.integers(1, 4, csize)
        vals = pos + np.cumsum(gaps)
        out[filled:filled + csize] = vals
        filled += csize
        pos = int(vals[-1])
        # big jump, keeping room for what's left
        max_jump = max(2, (remaining_space - 4 * remaining_vals)
                       // max(1, remaining_vals // csize + 1))
        pos += int(rng.integers(1, max(2, max_jump)))
        if pos >= universe - 4 * (n_values - filled):
            pos = universe - 4 * (n_values - filled) - 1
    return np.unique(out[:n_values])


def clusterdata_sets(n_sets: int = 100, values_per_set: int = 10_000_000,
                     universe: int = 1_000_000_000, seed: int = 0,
                     scale: float = 1.0) -> list[np.ndarray]:
    """Appendix B workload (scale < 1 shrinks it proportionally for CI)."""
    nv = int(values_per_set * scale)
    u = int(universe * scale)
    return [cluster_data(nv, u, seed=seed + i) for i in range(n_sets)]
