"""repro.core -- Roaring bitmaps: host (numpy) and device (JAX) paths.

Host path:   RoaringBitmap (dynamic containers, paper-faithful semantics)
Device path: RoaringTensor (fixed-capacity slab layout for jit/pjit)
"""

from repro.core.arena import ArenaStats, BitmapArena
from repro.core.bitmap import RoaringBitmap
from repro.core.builder import (
    complement, flip_range, from_dense, from_indices, to_dense,
)
from repro.core.containers import (
    ARRAY_MAX, BITSET_WORDS, CHUNK, MAX_RUNS,
    ArrayContainer, BitsetContainer, RunContainer,
)
from repro.core.serde import (
    FrozenSnapshot, LazyBitmaps, deserialize, deserialize_frozen,
    deserialize_portable, load_frozen, read_snapshot, serialize,
    serialize_frozen, serialize_portable, serialized_size_bytes,
    write_frozen, write_snapshot,
)

__all__ = [
    "RoaringBitmap", "ArrayContainer", "BitsetContainer", "RunContainer",
    "ARRAY_MAX", "BITSET_WORDS", "CHUNK", "MAX_RUNS",
    "from_indices", "from_dense", "to_dense", "complement", "flip_range",
    "serialize", "deserialize", "serialized_size_bytes",
    "serialize_portable", "deserialize_portable",
    "serialize_frozen", "deserialize_frozen", "write_frozen", "load_frozen",
    "FrozenSnapshot", "LazyBitmaps", "write_snapshot", "read_snapshot",
]
