"""Construction / conversion helpers around RoaringBitmap."""

from __future__ import annotations

import numpy as np

from repro.core.bitmap import RoaringBitmap


def from_indices(indices) -> RoaringBitmap:
    return RoaringBitmap.from_values(indices)


def from_dense(mask: np.ndarray) -> RoaringBitmap:
    """Boolean occupancy vector -> RoaringBitmap."""
    return RoaringBitmap.from_values(np.flatnonzero(np.asarray(mask)))


def to_dense(bm: RoaringBitmap, n: int) -> np.ndarray:
    out = np.zeros(n, dtype=bool)
    vals = bm.to_array()
    out[vals[vals < n]] = True
    return out


def complement(bm: RoaringBitmap, n: int) -> RoaringBitmap:
    """Complement within the universe [0, n)."""
    return RoaringBitmap.from_range(0, n) - bm


def flip_range(bm: RoaringBitmap, start: int, stop: int) -> RoaringBitmap:
    """Flip all bits in [start, stop) (paper: bitset negation, sec 2.2)."""
    window = RoaringBitmap.from_range(start, stop)
    inside_flipped = window - bm
    outside = bm - window
    return outside | inside_flipped
