"""Container-level algorithms for Roaring bitmaps (host / numpy path).

This module is the faithful reproduction of the paper's container layer:

  * array containers   -- <= 4096 sorted distinct uint16 values  (8 kB max)
  * bitset containers  -- 2^16 bits as 1024 x uint64 words (8 kB) + tracked
                          cardinality (the paper tracks cardinality per bitset
                          container; so do we)
  * run containers     -- sorted <start, length> pairs, run covers
                          [start, start + length] inclusive (paper section 1)

Vectorization: the numpy path plays the role of the paper's SIMD code (it is
what "wide registers" look like from Python); `repro.core.scalar` holds the
pure-python scalar twin used by the section 5.10 ablation benchmark.

Result-kind policy (paper section 1 / section 2.2): binary set operations
materialize either an array (card <= 4096) or a bitset (card > 4096); run
containers are produced only by `optimize` (the analogue of
`roaring_bitmap_run_optimize`), which picks the smallest of the three
representations subject to the paper's constraints (<= 2047 runs).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# constants (paper section 1)
# ---------------------------------------------------------------------------

CHUNK = 1 << 16          # values per chunk / container universe
ARRAY_MAX = 4096         # max cardinality of an array container
BITSET_WORDS = 1024      # 2^16 / 64 words of uint64
MAX_RUNS = 2047          # run container may hold at most this many runs
GALLOP_RATIO = 64        # size skew beyond which intersection gallops (sec 4.2)

_ONE = np.uint64(1)
_U64_63 = np.uint64(63)


# ---------------------------------------------------------------------------
# low level bitset helpers (the paper's section 3 primitives, vectorized)
# ---------------------------------------------------------------------------

def popcount_words(words: np.ndarray) -> int:
    """Population count of an array of uint64 words (section 4.1.1)."""
    return int(np.bitwise_count(words).sum())


def bitset_set_many(words: np.ndarray, values: np.ndarray) -> int:
    """Set bits at `values` (uint16 indexes); return the number of *newly*
    set bits, i.e. the cardinality change (paper section 3.2 XOR trick,
    vectorized).  Mutates `words` in place."""
    if values.size == 0:
        return 0
    idx = (values >> 4).astype(np.int64) >> 2          # values // 64
    bit = np.left_shift(_ONE, (values.astype(np.uint64) & _U64_63))
    old = words.copy()
    np.bitwise_or.at(words, idx, bit)
    # cardinality delta = popcount(old XOR new), exactly the paper's trick
    return int(np.bitwise_count(old ^ words).sum())


def bitset_clear_many(words: np.ndarray, values: np.ndarray) -> int:
    """Clear bits at `values`; return the number of bits actually cleared."""
    if values.size == 0:
        return 0
    idx = (values >> 4).astype(np.int64) >> 2
    bit = np.left_shift(_ONE, (values.astype(np.uint64) & _U64_63))
    old = words.copy()
    np.bitwise_and.at(words, idx, ~bit)
    return int(np.bitwise_count(old ^ words).sum())


def bitset_flip_many(words: np.ndarray, values: np.ndarray) -> int:
    """Flip bits at `values` (must be distinct); return cardinality delta."""
    if values.size == 0:
        return 0
    idx = (values >> 4).astype(np.int64) >> 2
    bit = np.left_shift(_ONE, (values.astype(np.uint64) & _U64_63))
    before = int(np.bitwise_count(words).sum())
    np.bitwise_xor.at(words, idx, bit)
    return int(np.bitwise_count(words).sum()) - before


def bitset_test_many(words: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Vectorized `bt`: boolean mask of which `values` are present."""
    if values.size == 0:
        return np.zeros(0, dtype=bool)
    idx = (values >> 4).astype(np.int64) >> 2
    sh = values.astype(np.uint64) & _U64_63
    return ((words[idx] >> sh) & _ONE).astype(bool)


def bitset_to_positions(words: np.ndarray) -> np.ndarray:
    """Bitset -> sorted uint16 array (paper section 3.1 blsi/tzcnt loop; the
    numpy idiom is unpackbits + flatnonzero, our TPU idiom is a prefix sum)."""
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")
    return np.flatnonzero(bits).astype(np.uint16)


def positions_to_bitset(values: np.ndarray) -> np.ndarray:
    """Sorted distinct uint16 values -> 1024 x uint64 bitset words.

    Indicator stores + packbits: a fresh bitset needs no read-modify-write
    scatter (np.bitwise_or.at) and no cardinality delta, so plain vector
    stores into a byte indicator beat bitset_set_many by a wide margin."""
    ind = np.zeros(CHUNK, dtype=np.uint8)
    ind[values] = 1
    return np.packbits(ind, bitorder="little").view(np.uint64)


def bitset_num_runs(words: np.ndarray) -> int:
    """Number of runs of consecutive 1s in the bitset (for run_optimize).

    runs = sum_w popcount(w & ~(w << 1))  with the carry of the previous
    word's msb folded in (standard CRoaring formula).
    """
    shifted = words << _ONE
    # bring in the msb of the previous word as lsb carry
    carry = np.zeros_like(words)
    carry[1:] = words[:-1] >> np.uint64(63)
    starts = words & ~(shifted | carry)
    return int(np.bitwise_count(starts).sum())


# ---------------------------------------------------------------------------
# containers
# ---------------------------------------------------------------------------

class ArrayContainer:
    """<= 4096 sorted distinct uint16 values."""

    __slots__ = ("values",)
    kind = "array"

    def __init__(self, values: np.ndarray):
        self.values = np.asarray(values, dtype=np.uint16)

    @property
    def card(self) -> int:
        return int(self.values.size)

    def contains(self, v: int) -> bool:
        i = int(np.searchsorted(self.values, np.uint16(v)))
        return i < self.values.size and int(self.values[i]) == int(v)

    def to_array_values(self) -> np.ndarray:
        return self.values

    def to_bitset(self) -> "BitsetContainer":
        return BitsetContainer(positions_to_bitset(self.values), self.card)

    def num_runs(self) -> int:
        if self.values.size == 0:
            return 0
        v = self.values.astype(np.int32)
        return int(np.count_nonzero(np.diff(v) > 1)) + 1

    def memory_bytes(self) -> int:
        return 2 * self.card

    def __eq__(self, other) -> bool:  # pragma: no cover - debugging aid
        return isinstance(other, ArrayContainer) and np.array_equal(
            self.values, other.values)


class BitsetContainer:
    """2^16-bit bitset with tracked cardinality."""

    __slots__ = ("words", "card")
    kind = "bitset"

    def __init__(self, words: np.ndarray, card: int | None = None):
        self.words = np.asarray(words, dtype=np.uint64)
        self.card = popcount_words(self.words) if card is None else int(card)

    def contains(self, v: int) -> bool:
        return bool((int(self.words[v >> 6]) >> (v & 63)) & 1)

    def to_array_values(self) -> np.ndarray:
        return bitset_to_positions(self.words)

    def to_bitset(self) -> "BitsetContainer":
        return self

    def num_runs(self) -> int:
        return bitset_num_runs(self.words)

    def memory_bytes(self) -> int:
        return 8 * BITSET_WORDS

    def __eq__(self, other) -> bool:  # pragma: no cover
        return isinstance(other, BitsetContainer) and np.array_equal(
            self.words, other.words)


class RunContainer:
    """Sorted non-overlapping, non-adjacent runs: (n, 2) int32 of
    [start, length]; run covers [start, start + length] inclusive."""

    __slots__ = ("runs",)
    kind = "run"

    def __init__(self, runs: np.ndarray):
        self.runs = np.asarray(runs, dtype=np.int32).reshape(-1, 2)

    @property
    def card(self) -> int:
        if self.runs.size == 0:
            return 0
        return int((self.runs[:, 1] + 1).sum())

    def contains(self, v: int) -> bool:
        if self.runs.size == 0:
            return False
        i = int(np.searchsorted(self.runs[:, 0], v, side="right")) - 1
        if i < 0:
            return False
        s, l = int(self.runs[i, 0]), int(self.runs[i, 1])
        return s <= v <= s + l

    def to_array_values(self) -> np.ndarray:
        if self.runs.size == 0:
            return np.zeros(0, dtype=np.uint16)
        lens = self.runs[:, 1] + 1
        total = int(lens.sum())
        # vectorized expansion of [s, s+l] ranges
        out = np.ones(total, dtype=np.int64)
        ends = np.cumsum(lens)
        starts_idx = np.concatenate(([0], ends[:-1]))
        out[starts_idx] = self.runs[:, 0]
        out[starts_idx[1:]] -= self.runs[:-1, 0] + self.runs[:-1, 1]
        return np.cumsum(out).astype(np.uint16)

    def to_bitset(self) -> BitsetContainer:
        n = self.runs.shape[0]
        if n == 0:
            return BitsetContainer(np.zeros(BITSET_WORDS, np.uint64), 0)
        if n < 8:
            # a handful of runs: per-run word masking beats the 2^16 sweep
            return self._to_bitset_scalar()
        # vectorized: +1/-1 deltas at run bounds, occupancy = prefix sum > 0
        starts = self.runs[:, 0].astype(np.int64)
        ends = starts + self.runs[:, 1].astype(np.int64)   # inclusive
        # runs are non-overlapping and non-adjacent, so the delta indices
        # are distinct within each statement: plain fancy stores suffice
        delta = np.zeros(CHUNK + 1, dtype=np.int32)
        delta[starts] = 1
        delta[ends + 1] = -1
        occ = np.cumsum(delta[:CHUNK]) > 0
        words = np.packbits(occ, bitorder="little").view(np.uint64)
        return BitsetContainer(words, self.card)

    def _to_bitset_scalar(self) -> BitsetContainer:
        words = np.zeros(BITSET_WORDS, dtype=np.uint64)
        card = 0
        for s, l in self.runs.tolist():
            e = s + l  # inclusive
            w0, w1 = s >> 6, e >> 6
            if w0 == w1:
                mask = ((1 << (e - s + 1)) - 1) << (s & 63)
                words[w0] |= np.uint64(mask & 0xFFFFFFFFFFFFFFFF)
            else:
                words[w0] |= np.uint64(
                    (0xFFFFFFFFFFFFFFFF << (s & 63)) & 0xFFFFFFFFFFFFFFFF)
                if w1 > w0 + 1:
                    words[w0 + 1:w1] = np.uint64(0xFFFFFFFFFFFFFFFF)
                words[w1] |= np.uint64(
                    0xFFFFFFFFFFFFFFFF >> (63 - (e & 63)))
            card += l + 1
        return BitsetContainer(words, card)

    def num_runs(self) -> int:
        return int(self.runs.shape[0])

    def memory_bytes(self) -> int:
        return 4 * self.num_runs() + 2

    def __eq__(self, other) -> bool:  # pragma: no cover
        return isinstance(other, RunContainer) and np.array_equal(
            self.runs, other.runs)


Container = ArrayContainer | BitsetContainer | RunContainer


# ---------------------------------------------------------------------------
# constructors / conversions
# ---------------------------------------------------------------------------

def container_from_values(values: np.ndarray) -> Container:
    """Build the canonical array-or-bitset container from sorted distinct
    uint16 values (paper: no array container may exceed 4096 values)."""
    values = np.asarray(values, dtype=np.uint16)
    if values.size <= ARRAY_MAX:
        return ArrayContainer(values)
    return BitsetContainer(positions_to_bitset(values), int(values.size))


def runs_from_sorted_values(values: np.ndarray) -> np.ndarray:
    """(n, 2) [start, length] runs from sorted distinct values."""
    if values.size == 0:
        return np.zeros((0, 2), dtype=np.int32)
    v = values.astype(np.int32)
    breaks = np.flatnonzero(np.diff(v) > 1)
    starts = np.concatenate(([0], breaks + 1))
    ends = np.concatenate((breaks, [v.size - 1]))
    return np.stack([v[starts], v[ends] - v[starts]], axis=1).astype(np.int32)


def optimize(c: Container) -> Container:
    """Pick the smallest representation (run_optimize + shrink_to_fit).

    Paper constraints: a run container with more than 4096 distinct values
    must have <= 2047 runs; below 4097 values the run count must be less than
    half the cardinality.  This is exactly "choose the smallest of
    {2*card, 8192, 4*runs+2} bytes" with the MAX_RUNS cap.
    """
    card = c.card
    if card == 0:
        return ArrayContainer(np.zeros(0, dtype=np.uint16))
    runs = c.num_runs()
    run_bytes = 4 * runs + 2
    array_bytes = 2 * card
    bitset_bytes = 8 * BITSET_WORDS
    best = min(run_bytes if runs <= MAX_RUNS else 1 << 30,
               array_bytes if card <= ARRAY_MAX else 1 << 30,
               bitset_bytes)
    if runs <= MAX_RUNS and best == run_bytes:
        if isinstance(c, RunContainer):
            return c
        return RunContainer(runs_from_sorted_values(c.to_array_values()))
    if card <= ARRAY_MAX and best == array_bytes:
        if isinstance(c, ArrayContainer):
            return c
        return ArrayContainer(c.to_array_values())
    return c.to_bitset()


def containers_to_word_rows(conts, block: int = 256) -> np.ndarray:
    """Batch-convert ``conts`` to an ``(len(conts), 1024)`` uint64
    block of bitset-domain word rows -- the vectorized twin of calling
    :func:`container_words64` per container.

    The bulk cold-start path (``BitmapArena.adopt_frozen``) rides on
    this: bitset rows are gathered with one fancy-index store, and ALL
    array/run containers convert through one shared uint8 indicator
    matrix + ``np.packbits`` sweep (runs expand with the same global
    cumsum trick as ``RunContainer.to_array_values``), processed in
    ``block``-row chunks to bound the indicator's memory at
    ``block * 64 KiB``.  No per-container conversion work happens in
    Python.  Complexity: O(total payload bytes); returns a fresh
    writable array safe to hand to a device slab.
    """
    n = len(conts)
    out = np.zeros((n, BITSET_WORDS), np.uint64)
    bit_idx, bit_rows = [], []
    dense_idx: list[int] = []          # array/run containers, in order
    val_parts, val_owner = [], []      # point values + local dense row
    run_parts, run_owner = [], []      # (m, 2) runs + local dense row
    for i, c in enumerate(conts):
        if isinstance(c, BitsetContainer):
            bit_idx.append(i)
            bit_rows.append(c.words)
        elif isinstance(c, ArrayContainer):
            if c.values.size:
                val_parts.append(c.values)
                val_owner.append((len(dense_idx), c.values.size))
            dense_idx.append(i)
        else:
            if c.runs.size:
                run_parts.append(c.runs.astype(np.int64))
                run_owner.append((len(dense_idx), c.runs.shape[0]))
            dense_idx.append(i)
    if bit_idx:
        out[np.asarray(bit_idx)] = np.stack(bit_rows)
    if not dense_idx:
        return out
    # one global (row, value) stream for every array value and every
    # run-expanded value
    rows_list, vals_list = [], []
    if val_parts:
        vals_list.append(np.concatenate(val_parts).astype(np.int64))
        rows_list.append(np.repeat(
            np.asarray([o for o, _ in val_owner], np.int64),
            np.asarray([s for _, s in val_owner], np.int64)))
    if run_parts:
        runs = np.concatenate(run_parts)           # (R, 2) [start, len]
        lens = runs[:, 1] + 1
        total = int(lens.sum())
        ends = np.cumsum(lens)
        starts_idx = np.concatenate(([0], ends[:-1]))
        expand = np.ones(total, dtype=np.int64)
        expand[starts_idx] = runs[:, 0]
        expand[starts_idx[1:]] -= runs[:-1, 0] + runs[:-1, 1]
        vals_list.append(np.cumsum(expand))
        owner = np.repeat(
            np.asarray([o for o, _ in run_owner], np.int64),
            np.asarray([m for _, m in run_owner], np.int64))
        rows_list.append(np.repeat(owner, lens))
    rows = np.concatenate(rows_list)
    vals = np.concatenate(vals_list)
    dense = np.asarray(dense_idx, np.int64)
    for lo in range(0, dense.size, block):
        hi = min(lo + block, dense.size)
        sel = (rows >= lo) & (rows < hi)
        ind = np.zeros((hi - lo, CHUNK), np.uint8)
        ind[rows[sel] - lo, vals[sel]] = 1
        out[dense[lo:hi]] = np.packbits(
            ind, axis=1, bitorder="little").view(np.uint64)
    return out


def container_words64(c: Container) -> np.ndarray:
    """Any container -> its (1024,) uint64 bitset-domain words (the
    shared promotion step of the aggregate / pairwise / top-k planners)."""
    if isinstance(c, BitsetContainer):
        return c.words
    return c.to_bitset().words


def _as_array_or_bitset(c: Container) -> Container:
    """Normalize a run container to whichever dense form is cheaper for ops."""
    if isinstance(c, RunContainer):
        return ArrayContainer(c.to_array_values()) if c.card <= ARRAY_MAX \
            else c.to_bitset()
    return c


def _result_from_bitset(words: np.ndarray, card: int | None = None) -> Container:
    card = popcount_words(words) if card is None else card
    if card > ARRAY_MAX:
        return BitsetContainer(words, card)
    return ArrayContainer(bitset_to_positions(words))


# ---------------------------------------------------------------------------
# array <-> array primitives (paper sections 4.2 - 4.5)
# ---------------------------------------------------------------------------

def array_intersect(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Sorted-array intersection.  Mirrors the paper's dual strategy: a
    merge-style intersection for similar sizes (the vectorized pcmpistrm
    algorithm's role) and a galloping / binary-search intersection when one
    input is much smaller (section 4.2, [42])."""
    if a.size == 0 or b.size == 0:
        return np.zeros(0, dtype=np.uint16)
    if a.size > b.size:
        a, b = b, a
    if b.size > GALLOP_RATIO * a.size:
        # galloping: binary-search each element of the small array
        idx = np.searchsorted(b, a)
        idx[idx == b.size] = b.size - 1
        return a[b[idx] == a]
    return np.intersect1d(a, b, assume_unique=True)


def array_union(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.union1d(a, b).astype(np.uint16)


def array_difference(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if a.size == 0:
        return np.zeros(0, dtype=np.uint16)
    if b.size == 0:
        return a.copy()
    if b.size > GALLOP_RATIO * a.size:
        idx = np.searchsorted(b, a)
        idx[idx == b.size] = b.size - 1
        return a[b[idx] != a]
    return np.setdiff1d(a, b, assume_unique=True).astype(np.uint16)


def array_symmetric_difference(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.setxor1d(a, b, assume_unique=True).astype(np.uint16)


# ---------------------------------------------------------------------------
# binary operations between containers
# ---------------------------------------------------------------------------

def container_and(x: Container, y: Container) -> Container:
    x, y = _as_array_or_bitset(x), _as_array_or_bitset(y)
    xa, ya = isinstance(x, ArrayContainer), isinstance(y, ArrayContainer)
    if xa and ya:
        return ArrayContainer(array_intersect(x.values, y.values))
    if xa:
        return ArrayContainer(x.values[bitset_test_many(y.words, x.values)])
    if ya:
        return ArrayContainer(y.values[bitset_test_many(x.words, y.values)])
    words = x.words & y.words
    return _result_from_bitset(words)


def container_or(x: Container, y: Container) -> Container:
    x, y = _as_array_or_bitset(x), _as_array_or_bitset(y)
    xa, ya = isinstance(x, ArrayContainer), isinstance(y, ArrayContainer)
    if xa and ya:
        # paper heuristic: guess whether the output exceeds the array limit
        if x.card + y.card > ARRAY_MAX:
            words = positions_to_bitset(x.values)
            card = popcount_words(words)
            card += bitset_set_many(words, y.values)
            return _result_from_bitset(words, card)
        return ArrayContainer(array_union(x.values, y.values))
    if xa:
        x, y = y, x  # x bitset, y array
    if isinstance(y, ArrayContainer):
        words = x.words.copy()
        card = x.card + bitset_set_many(words, y.values)
        return BitsetContainer(words, card)  # card >= x.card > 4096
    words = x.words | y.words
    return _result_from_bitset(words)


def container_xor(x: Container, y: Container) -> Container:
    x, y = _as_array_or_bitset(x), _as_array_or_bitset(y)
    xa, ya = isinstance(x, ArrayContainer), isinstance(y, ArrayContainer)
    if xa and ya:
        out = array_symmetric_difference(x.values, y.values)
        return container_from_values(out)
    if xa:
        x, y = y, x
    if isinstance(y, ArrayContainer):
        words = x.words.copy()
        card = x.card + bitset_flip_many(words, y.values)
        return _result_from_bitset(words, card)
    words = x.words ^ y.words
    return _result_from_bitset(words)


def container_andnot(x: Container, y: Container) -> Container:
    x, y = _as_array_or_bitset(x), _as_array_or_bitset(y)
    xa, ya = isinstance(x, ArrayContainer), isinstance(y, ArrayContainer)
    if xa and ya:
        return ArrayContainer(array_difference(x.values, y.values))
    if xa:
        keep = ~bitset_test_many(y.words, x.values)
        return ArrayContainer(x.values[keep])
    if ya:
        words = x.words.copy()
        card = x.card - bitset_clear_many(words, y.values)
        return _result_from_bitset(words, card)
    words = x.words & ~y.words
    return _result_from_bitset(words)


# ---------------------------------------------------------------------------
# in-container rank / select (the chunk-level half of paper section 6):
# vectorized per kind, never expanding the container to a value array.
# ---------------------------------------------------------------------------

def container_rank(c: Container, v: int) -> int:
    """Number of container values <= v (v in [0, 2^16))."""
    v = int(v)
    if isinstance(c, ArrayContainer):
        return int(np.searchsorted(c.values, np.uint16(v), side="right"))
    if isinstance(c, BitsetContainer):
        w = v >> 6
        partial = int(c.words[w]) & ((2 << (v & 63)) - 1)
        return int(np.bitwise_count(c.words[:w]).sum()) + partial.bit_count()
    if c.runs.size == 0:
        return 0
    i = int(np.searchsorted(c.runs[:, 0], v, side="right")) - 1
    if i < 0:
        return 0
    base = int((c.runs[:i, 1] + 1).sum())
    s, ln = int(c.runs[i, 0]), int(c.runs[i, 1])
    return base + min(v - s, ln) + 1


def container_select(c: Container, i: int) -> int:
    """The i-th smallest container value (0-based; requires i < card)."""
    i = int(i)
    if isinstance(c, ArrayContainer):
        return int(c.values[i])
    if isinstance(c, BitsetContainer):
        cs = np.cumsum(np.bitwise_count(c.words))
        w = int(np.searchsorted(cs, i, side="right"))
        prior = int(cs[w - 1]) if w else 0
        bits = np.flatnonzero(np.unpackbits(
            c.words[w:w + 1].view(np.uint8), bitorder="little"))
        return (w << 6) + int(bits[i - prior])
    cum = np.cumsum(c.runs[:, 1] + 1)
    r = int(np.searchsorted(cum, i, side="right"))
    prior = int(cum[r - 1]) if r else 0
    return int(c.runs[r, 0]) + (i - prior)


# ---------------------------------------------------------------------------
# count-only variants (paper section 5.9 "fast counts"):
# never materialize the result container.
# ---------------------------------------------------------------------------

def container_and_card(x: Container, y: Container) -> int:
    x, y = _as_array_or_bitset(x), _as_array_or_bitset(y)
    xa, ya = isinstance(x, ArrayContainer), isinstance(y, ArrayContainer)
    if xa and ya:
        return int(array_intersect(x.values, y.values).size)
    if xa:
        return int(np.count_nonzero(bitset_test_many(y.words, x.values)))
    if ya:
        return int(np.count_nonzero(bitset_test_many(x.words, y.values)))
    return popcount_words(x.words & y.words)


def container_or_card(x: Container, y: Container) -> int:
    return x.card + y.card - container_and_card(x, y)


def container_andnot_card(x: Container, y: Container) -> int:
    return x.card - container_and_card(x, y)


def container_xor_card(x: Container, y: Container) -> int:
    return x.card + y.card - 2 * container_and_card(x, y)


OPS = {
    "and": (container_and, container_and_card),
    "or": (container_or, container_or_card),
    "xor": (container_xor, container_xor_card),
    "andnot": (container_andnot, container_andnot_card),
}
