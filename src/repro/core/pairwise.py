"""Batched pairwise set-algebra planner: type-grouped container pairs,
one dispatch per class.

The paper's central performance contribution is *vectorized two-by-two*
set algebra over container pairs; this module is the host-side planner
that batches it.  Given one ``a ⊕ b`` (or M pairs at once -- the
similarity-join workload of "Compressed bitmap indexes: beyond unions and
intersections", Kaser & Lemire), it key-merges every pair, buckets the
matched container pairs by type class, and executes ONE batched kernel
dispatch per class instead of one per pair:

  * **bitset x bitset** (paper section 4.1.2): stacked ``(M, WORDS)`` word
    rows through ``kernels.pair_ops.bitset_pair_op`` -- a logical op id
    per row fused with the Harley-Seal cardinality (count-only twin for
    the fast-count path, section 5.9);
  * **array x array** (sections 4.2 union/4.3 intersection/4.4
    difference/4.5 symmetric difference): padded value slabs through the
    ``kernels.array_ops`` all-vs-all compare -- two-sided masks for
    materializing ops, count-only for similarity;
  * **array x bitset** (the asymmetric case of section 4.2): a vectorized
    probe of each array value against the bitset row
    (``kernels.pair_ops.array_bitset_probe``); OR/XOR promote the array
    side to the bitset domain and ride the bitset class;
  * **run containers** stay on the host fast paths (section 2.3: run ops
    are interval sweeps, already cheap at interval granularity).

Count-only planning exploits inclusion-exclusion (section 5.9): every op
count derives from the pair's intersection cardinality, so the batched
engine only ever runs AND and combines counts per pair on the host.

On CPU (no forced backend) each count class runs a vectorized numpy twin
with the same O(classes) bulk-dispatch shape and no device round-trip --
and the twins exploit the all-pairs structure directly: the array x array
class is an inverted token join (each unique container's values enter one
key-prefixed token stream; co-occurring tokens emit container-pair
counts), and the array x bitset class probes each unique array against
ALL of its key's bitsets at once.  Work scales with total postings, never
postings x pairs.  With ``backend="pallas"``/``"ref"`` or on TPU the
classes dispatch to the kernels.  Either way the O(N^2)-pair similarity
join issues a handful of batched class dispatches instead of one per
matched container pair.

The materializing single-pair merge batches by class only on a kernel
backend (that is where per-container dispatch overhead lives); on CPU a
lone pair stays on the scalar host merge, whose per-container numpy ops
are already vectorized.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import containers as C
from repro.core.containers import (
    ArrayContainer, BitsetContainer, Container, RunContainer,
    container_from_values, positions_to_bitset,
)
from repro.kernels import ops as kops
from repro.kernels import ref as _refk
from repro.kernels.ref import ARRAY_CAP, METRICS, PAIR_OPS, WORDS

__all__ = ["pairwise_card", "jaccard_matrix", "merge_one", "OP_IDS",
           "METRICS", "SimilarityEngine"]

OP_IDS = {o: i for i, o in enumerate(PAIR_OPS)}   # the kernels' row op ids

# below this many total keys a single pair stays on the scalar host merge:
# the class bookkeeping costs more than a handful of container ops
SMALL_PAIR = 16

_HOST_BLOCK = 8192      # bitset rows per host block (8 kB each -> <= 64 MB)
_KCODE = {ArrayContainer: 1, BitsetContainer: 2, RunContainer: 3}


def _bitmap_cls():
    from repro.core.bitmap import RoaringBitmap   # deferred: bitmap imports us
    return RoaringBitmap


def _prefer_kernel(backend: str | None) -> bool:
    """Kernel classes on TPU (or when a backend is forced, e.g. in tests);
    vectorized numpy twins on CPU (same batching, no device round-trip).
    The policy is shared with the wide-aggregation planner."""
    return kops.prefer_kernel(backend)


def _words32(w64: np.ndarray) -> np.ndarray:
    return w64.view(np.uint32)


def _result_words(w32_row: np.ndarray, card: int) -> Container:
    # .copy(): a view would pin the whole (M, WORDS) batch output alive
    # for the lifetime of one surviving container
    w64 = np.ascontiguousarray(w32_row).view(np.uint64).copy()
    return C._result_from_bitset(w64, card)


# ---------------------------------------------------------------------------
# scalar host twins (the pre-planner two-by-two path, kept for small pairs)
# ---------------------------------------------------------------------------

def _merge_host(a, b, op: str):
    """Scalar key-merge (the paper's top-level layout): one container op
    per matched key.  Small pairs stay here; large pairs batch by class."""
    fn = C.OPS[op][0]
    keys, conts = [], []
    i = j = 0
    a_keys, b_keys = a.keys, b.keys
    na, nb = len(a_keys), len(b_keys)
    while i < na and j < nb:
        ka, kb = a_keys[i], b_keys[j]
        if ka == kb:
            c = fn(a.containers[i], b.containers[j])
            if c.card:
                keys.append(ka)
                conts.append(c)
            i += 1
            j += 1
        elif ka < kb:
            if op in ("or", "xor", "andnot"):
                keys.append(ka)
                conts.append(a.containers[i])
            i += 1
        else:
            if op in ("or", "xor"):
                keys.append(kb)
                conts.append(b.containers[j])
            j += 1
    if op in ("or", "xor", "andnot"):
        while i < na:
            keys.append(a_keys[i])
            conts.append(a.containers[i])
            i += 1
    if op in ("or", "xor"):
        while j < nb:
            keys.append(b_keys[j])
            conts.append(b.containers[j])
            j += 1
    return _bitmap_cls()(keys, conts)


def _and_card_host(a, b) -> int:
    """Scalar fast-count twin (paper section 5.9) for small pairs."""
    cnt = 0
    i = j = 0
    while i < len(a.keys) and j < len(b.keys):
        ka, kb = a.keys[i], b.keys[j]
        if ka == kb:
            cnt += C.container_and_card(a.containers[i], b.containers[j])
            i += 1
            j += 1
        elif ka < kb:
            i += 1
        else:
            j += 1
    return cnt


# ---------------------------------------------------------------------------
# materializing two-by-two merge (one pair, class-batched)
# ---------------------------------------------------------------------------

def merge_one(a, b, op: str, *, backend: str | None = None):
    """``a ⊕ b`` through the type-grouped pair planner: matched container
    pairs bucket by class and each class executes as one batched dispatch;
    unmatched keys pass through zero-copy exactly like the scalar merge.

    On CPU (no kernel backend) a lone pair stays on the scalar host merge
    outright: with numpy already vectorizing each container op there is no
    dispatch overhead for class batching to amortize, and the stacking
    copies would only slow the bitset classes down.  Class batching pays
    on a kernel backend (one dispatch per class instead of one per matched
    container pair) and in the many-pair count APIs (``pairwise_card``)."""
    if op not in OP_IDS:
        raise ValueError(op)
    na, nb = len(a.keys), len(b.keys)
    if na + nb <= SMALL_PAIR or not _prefer_kernel(backend):
        return _merge_host(a, b, op)
    fn = C.OPS[op][0]
    ka = np.asarray(a.keys, np.int64)
    kb = np.asarray(b.keys, np.int64)
    common, ia, ib = np.intersect1d(ka, kb, assume_unique=True,
                                    return_indices=True)
    out: dict[int, Container] = {}
    if op in ("or", "xor", "andnot"):
        for i in np.setdiff1d(np.arange(na), ia,
                              assume_unique=True).tolist():
            out[a.keys[i]] = a.containers[i]
    if op in ("or", "xor"):
        for j in np.setdiff1d(np.arange(nb), ib,
                              assume_unique=True).tolist():
            out[b.keys[j]] = b.containers[j]

    aa: list[tuple[int, np.ndarray, np.ndarray]] = []
    probe: list[tuple[int, np.ndarray, np.ndarray, bool]] = []
    bb: list[tuple[int, np.ndarray, np.ndarray]] = []
    for k, i, j in zip(common.tolist(), ia.tolist(), ib.tolist()):
        ca, cb = a.containers[i], b.containers[j]
        xa = isinstance(ca, ArrayContainer)
        xb = isinstance(cb, ArrayContainer)
        if xa and xb:
            aa.append((int(k), ca.values, cb.values))
            continue
        if isinstance(ca, RunContainer) or isinstance(cb, RunContainer):
            c = fn(ca, cb)               # run fast paths stay on host
            if c.card:
                out[int(k)] = c
        elif xa or xb:
            if op == "and":
                arr, bs = (ca, cb) if xa else (cb, ca)   # AND commutes
                probe.append((int(k), arr.values, bs.words, False))
            elif op == "andnot" and xa:
                probe.append((int(k), ca.values, cb.words, True))
            else:
                # or / xor / bitset-minuend andnot: promote the array side
                # to the bitset domain and ride the bitset class
                wa = positions_to_bitset(ca.values) if xa else ca.words
                wb = positions_to_bitset(cb.values) if xb else cb.words
                bb.append((int(k), wa, wb))
        else:
            bb.append((int(k), ca.words, cb.words))
    _merge_aa(out, aa, op, backend)
    _merge_probe(out, probe, backend)
    _merge_bb(out, bb, op, backend)
    keys = sorted(out)
    return _bitmap_cls()(keys, [out[k] for k in keys])


def _assemble_aa(x: np.ndarray, y: np.ndarray, ha: np.ndarray,
                 hb: np.ndarray, op: str) -> np.ndarray:
    """Result values of one array-array pair from the two-sided masks."""
    if op == "and":
        return x[ha]
    if op == "andnot":
        return x[~ha]
    if op == "or":
        return np.sort(np.concatenate((x, y[~hb])))
    return np.sort(np.concatenate((x[~ha], y[~hb])))          # xor


def _merge_aa(out: dict, entries: list, op: str, backend) -> None:
    """array x array class: ONE two-sided-mask dispatch feeds all ops."""
    if not entries:
        return
    m = len(entries)
    av = np.zeros((m, ARRAY_CAP), np.int32)
    bv = np.zeros((m, ARRAY_CAP), np.int32)
    ac = np.zeros(m, np.int32)
    bc = np.zeros(m, np.int32)
    for r, (_, x, y) in enumerate(entries):
        av[r, :x.size] = x
        bv[r, :y.size] = y
        ac[r], bc[r] = x.size, y.size
    ma, mb, _ = kops.array_pair_masks(
        jnp.asarray(av), jnp.asarray(ac), jnp.asarray(bv),
        jnp.asarray(bc), backend=backend)
    ma = np.asarray(ma).astype(bool)
    mb = np.asarray(mb).astype(bool)
    for r, (k, x, y) in enumerate(entries):
        vals = _assemble_aa(x, y, ma[r, :x.size], mb[r, :y.size], op)
        if vals.size:
            out[k] = container_from_values(vals)


def _mask_in(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Membership of sorted ``x`` in sorted ``y`` (vectorized probe)."""
    if y.size == 0:
        return np.zeros(x.size, bool)
    idx = np.searchsorted(y, x)
    idx[idx == y.size] = y.size - 1
    return y[idx] == x


def _merge_probe(out: dict, entries: list, backend) -> None:
    """array x bitset class (AND / array-minuend ANDNOT): one probe
    dispatch; ``invert`` keeps the misses instead of the hits."""
    if not entries:
        return
    m = len(entries)
    vals = np.zeros((m, ARRAY_CAP), np.int32)
    cards = np.zeros(m, np.int32)
    words = np.zeros((m, WORDS), np.uint32)
    for r, (_, v, w, _) in enumerate(entries):
        vals[r, :v.size] = v
        cards[r] = v.size
        words[r] = _words32(w)
    mask, _ = kops.array_bitset_probe(
        jnp.asarray(vals), jnp.asarray(cards), jnp.asarray(words),
        backend=backend)
    mask = np.asarray(mask).astype(bool)
    for r, (k, v, _, inv) in enumerate(entries):
        hit = mask[r, :v.size]
        kept = v[~hit] if inv else v[hit]
        if kept.size:
            out[k] = ArrayContainer(kept)


def _merge_bb(out: dict, entries: list, op: str, backend) -> None:
    """bitset x bitset class: one stacked-words dispatch, op id per row."""
    if not entries:
        return
    a32 = np.stack([_words32(wa) for _, wa, _ in entries])
    b32 = np.stack([_words32(wb) for _, _, wb in entries])
    opids = np.full(len(entries), OP_IDS[op], np.int32)
    w, cards = kops.bitset_pair_op(jnp.asarray(a32), jnp.asarray(b32),
                                   opids, backend=backend)
    w = np.asarray(w)
    cards = np.asarray(cards)
    for r, (k, _, _) in enumerate(entries):
        if cards[r]:
            out[k] = _result_words(w[r], int(cards[r]))


# ---------------------------------------------------------------------------
# count-only batch (M pairs, one dispatch per class)
# ---------------------------------------------------------------------------

def pairwise_card(ops, pairs, *, backend: str | None = None) -> np.ndarray:
    """Batched count-only pairwise set algebra over M bitmap pairs.

    ``ops`` is one op name ("and" | "or" | "xor" | "andnot") or a length-M
    sequence of per-pair names; ``pairs`` is a sequence of
    ``(RoaringBitmap, RoaringBitmap)``.  Returns (M,) int64 counts.

    Every count derives from the pair's intersection cardinality by
    inclusion-exclusion (paper section 5.9), so the batched engine only
    ever runs AND over the matched container pairs -- O(container-type
    classes) dispatches regardless of M."""
    pairs = list(pairs)
    m = len(pairs)
    if isinstance(ops, str):
        op_list = [ops] * m
    else:
        op_list = [str(o) for o in ops]
        if len(op_list) != m:
            raise ValueError(
                f"need one op per pair: {len(op_list)} != {m}")
    for o in op_list:
        if o not in OP_IDS:
            raise ValueError(o)
    if m == 0:
        return np.zeros(0, np.int64)
    uniq, ia, ib = _dedupe(pairs)
    if m == 1 and len(pairs[0][0].keys) + len(pairs[0][1].keys) \
            <= SMALL_PAIR:
        inter = np.array([_and_card_host(*pairs[0])], np.int64)
    else:
        inter = _inter_counts(uniq, ia, ib, backend)
    cards = np.array([bm.cardinality for bm in uniq], np.int64)
    ca, cb = cards[ia], cards[ib]
    opv = np.array([OP_IDS[o] for o in op_list], np.int64)
    return np.where(opv == 0, inter,
                    np.where(opv == 1, ca + cb - inter,
                             np.where(opv == 2, ca + cb - 2 * inter,
                                      ca - inter)))


def _dedupe(pairs):
    """Unique bitmap objects + per-pair indices into the unique list."""
    seen: dict[int, int] = {}
    uniq = []
    for a, b in pairs:
        for bmp in (a, b):
            if id(bmp) not in seen:
                seen[id(bmp)] = len(uniq)
                uniq.append(bmp)
    ia = np.array([seen[id(a)] for a, _ in pairs], np.int64)
    ib = np.array([seen[id(b)] for _, b in pairs], np.int64)
    return uniq, ia, ib


def _tables(bitmaps):
    """Per-(bitmap, chunk-key) kind codes and container indices."""
    all_keys = sorted({k for bm in bitmaps for k in bm.keys})
    kidx = {k: i for i, k in enumerate(all_keys)}
    n, nk = len(bitmaps), len(all_keys)
    kind = np.zeros((n, nk), np.int8)
    cidx = np.zeros((n, nk), np.int32)
    for i, bm in enumerate(bitmaps):
        for j, (k, c) in enumerate(zip(bm.keys, bm.containers)):
            col = kidx[k]
            kind[i, col] = _KCODE[type(c)]
            cidx[i, col] = j
    return kind, cidx


def _inter_counts(uniq, ia, ib, backend) -> np.ndarray:
    """(M,) intersection cardinalities: vectorized key matching over a
    presence table, then one batched AND-count dispatch per class.

    The host twins exploit the all-pairs structure: a container shared by
    many pairs enters the computation ONCE (an inverted token join for
    array x array, a per-key grouped probe for array x bitset), so the
    work scales with total postings, not postings-times-pairs."""
    m = ia.size
    kind, cidx = _tables(uniq)
    if kind.shape[1] == 0:
        return np.zeros(m, np.int64)
    kind_a, kind_b = kind[ia], kind[ib]
    pe, ke = np.nonzero((kind_a > 0) & (kind_b > 0))
    if pe.size == 0:
        return np.zeros(m, np.int64)
    ja, jb = ia[pe], ib[pe]
    ka, kb = kind[ja, ke], kind[jb, ke]
    conts_a = [uniq[i].containers[cidx[i, k]]
               for i, k in zip(ja.tolist(), ke.tolist())]
    conts_b = [uniq[i].containers[cidx[i, k]]
               for i, k in zip(jb.tolist(), ke.tolist())]
    counts = np.zeros(pe.size, np.int64)

    is_run = (ka == 3) | (kb == 3)
    is_aa = (ka == 1) & (kb == 1)
    is_bb = (ka == 2) & (kb == 2)
    is_ab = ~(is_run | is_aa | is_bb)

    for e in np.flatnonzero(is_run).tolist():      # run fast paths: host
        counts[e] = C.container_and_card(conts_a[e], conts_b[e])

    idx = np.flatnonzero(is_aa)
    if idx.size:
        counts[idx] = _aa_counts(ke[idx],
                                 [conts_a[e] for e in idx.tolist()],
                                 [conts_b[e] for e in idx.tolist()],
                                 backend)
    idx = np.flatnonzero(is_ab)
    if idx.size:
        arrs, sets = [], []
        for e in idx.tolist():
            x, y = conts_a[e], conts_b[e]
            if not isinstance(x, ArrayContainer):
                x, y = y, x
            arrs.append(x)
            sets.append(y)
        counts[idx] = _ab_counts(ke[idx], arrs, sets, backend)
    idx = np.flatnonzero(is_bb)
    if idx.size:
        counts[idx] = _bb_counts([conts_a[e] for e in idx.tolist()],
                                 [conts_b[e] for e in idx.tolist()],
                                 backend)
    inter = np.zeros(m, np.int64)
    np.add.at(inter, pe, counts)
    return inter


def _aa_counts(keys_e, xs, ys, backend) -> np.ndarray:
    """array x array intersection counts.

    Kernel path: padded value slabs, one count-only all-vs-all dispatch.
    Host path: an inverted token join -- every unique container's values
    enter ONE key-prefixed token stream; tokens shared by g containers
    emit g*(g-1)/2 co-occurrence pairs (one vectorized pass per rank
    offset), accumulating a container-pair count matrix that all entries
    read off.  Work scales with total postings, never postings x pairs."""
    n = len(xs)
    if _prefer_kernel(backend):
        av = np.zeros((n, ARRAY_CAP), np.int32)
        bv = np.zeros((n, ARRAY_CAP), np.int32)
        ac = np.zeros(n, np.int32)
        bc = np.zeros(n, np.int32)
        for r, (x, y) in enumerate(zip(xs, ys)):
            av[r, :x.values.size] = x.values
            bv[r, :y.values.size] = y.values
            ac[r], bc[r] = x.values.size, y.values.size
        return np.asarray(kops.array_intersect_card(
            jnp.asarray(av), jnp.asarray(ac), jnp.asarray(bv),
            jnp.asarray(bc), backend=backend)).astype(np.int64)
    # unique containers; token = key << 16 | value, so containers of
    # different chunk keys never collide
    uid: dict[int, int] = {}
    pool: list[np.ndarray] = []
    ua = np.empty(n, np.int64)
    ub = np.empty(n, np.int64)
    for r, (k, x, y) in enumerate(zip(keys_e.tolist(), xs, ys)):
        for side, c in ((ua, x), (ub, y)):
            u = uid.get(id(c))
            if u is None:
                u = uid[id(c)] = len(pool)
                pool.append(c.values.astype(np.int64)
                            + (np.int64(k) << 16))
            side[r] = u
    nu = len(pool)
    if nu > 4096:
        # the co-occurrence matrix would be nu^2: fall back to the
        # replicated per-entry membership probe (still one bulk op)
        return _aa_counts_probe(keys_e, xs, ys)
    lens = np.array([v.size for v in pool], np.int64)
    tokens = np.concatenate(pool)
    owner = np.repeat(np.arange(nu, dtype=np.int64), lens)
    comb = tokens * nu + owner                # value-major, owner-minor
    comb.sort()
    val_of = comb // nu
    own_of = comb % nu
    g = np.zeros((nu, nu), np.int32)
    d = 1
    while d < comb.size:
        same = val_of[d:] == val_of[:-d]
        if not same.any():
            break
        np.add.at(g, (own_of[:-d][same], own_of[d:][same]), 1)
        d += 1
    res = (g[ua, ub] + g[ub, ua]).astype(np.int64)
    self_pair = ua == ub             # a container against itself: |values|
    if self_pair.any():
        res[self_pair] = lens[ua[self_pair]]
    return res


def _aa_counts_probe(keys_e, xs, ys) -> np.ndarray:
    """Replicated-entry fallback: offset-concatenate both sides (entry id
    in the high bits keeps entries apart in one sort order) and count
    matches of A's stream in B's with a single vectorized probe."""
    n = len(xs)
    lens_a = np.array([x.values.size for x in xs], np.int64)
    lens_b = np.array([y.values.size for y in ys], np.int64)
    eids = np.arange(n, dtype=np.int64) << 16
    a_all = np.concatenate([x.values for x in xs]).astype(np.int64) \
        + np.repeat(eids, lens_a)
    b_all = np.concatenate([y.values for y in ys]).astype(np.int64) \
        + np.repeat(eids, lens_b)
    hit = _mask_in(a_all, b_all)
    eid_a = np.repeat(np.arange(n), lens_a)
    return np.bincount(eid_a[hit], minlength=n).astype(np.int64)


def _ab_counts(keys_e, arrs, sets, backend) -> np.ndarray:
    """array x bitset probe counts.

    Kernel path: one batched probe dispatch.  Host path: per chunk key,
    every unique array's values probe ALL of that key's unique bitsets at
    once (word gather + bit test, segment-summed per array), so each
    value is touched once per bitset instead of once per pair."""
    n = len(arrs)
    if _prefer_kernel(backend):
        vals = np.zeros((n, ARRAY_CAP), np.int32)
        cards = np.zeros(n, np.int32)
        words = np.zeros((n, WORDS), np.uint32)
        for r, (x, y) in enumerate(zip(arrs, sets)):
            vals[r, :x.values.size] = x.values
            cards[r] = x.values.size
            words[r] = _words32(y.words)
        _, cnt = kops.array_bitset_probe(
            jnp.asarray(vals), jnp.asarray(cards), jnp.asarray(words),
            backend=backend)
        return np.asarray(cnt).astype(np.int64)
    out = np.zeros(n, np.int64)
    order = np.argsort(keys_e, kind="stable")
    bounds = np.flatnonzero(np.concatenate(
        ([True], np.diff(keys_e[order]) != 0, [True])))
    for s, e in zip(bounds[:-1].tolist(), bounds[1:].tolist()):
        ent = order[s:e]                      # entries of one chunk key
        aid: dict[int, int] = {}
        bid: dict[int, int] = {}
        a_list: list[np.ndarray] = []
        b_list: list[np.ndarray] = []
        ea = np.empty(ent.size, np.int64)
        eb = np.empty(ent.size, np.int64)
        for r, i in enumerate(ent.tolist()):
            u = aid.get(id(arrs[i]))
            if u is None:
                u = aid[id(arrs[i])] = len(a_list)
                a_list.append(arrs[i].values)
            ea[r] = u
            u = bid.get(id(sets[i]))
            if u is None:
                u = bid[id(sets[i])] = len(b_list)
                b_list.append(sets[i].words)
            eb[r] = u
        lens = np.array([v.size for v in a_list], np.int64)
        vals = np.concatenate(a_list).astype(np.int64)
        stack = np.stack(b_list)              # (nb, 1024) uint64
        bits = ((stack[:, vals >> 6]
                 >> (vals & 63).astype(np.uint64)) & np.uint64(1))
        starts = np.concatenate(([0], np.cumsum(lens)[:-1]))
        seg = np.add.reduceat(bits, starts, axis=1)   # (nb, na)
        out[ent] = seg[eb, ea]
    return out


def _bb_counts(xs, ys, backend) -> np.ndarray:
    """bitset x bitset AND-popcount counts, one dispatch."""
    n = len(xs)
    if _prefer_kernel(backend):
        a32 = np.stack([_words32(x.words) for x in xs])
        b32 = np.stack([_words32(y.words) for y in ys])
        return np.asarray(kops.bitset_pair_card(
            jnp.asarray(a32), jnp.asarray(b32),
            np.zeros(n, np.int32), backend=backend)).astype(np.int64)
    out = np.zeros(n, np.int64)
    for lo in range(0, n, _HOST_BLOCK):
        hi = min(lo + _HOST_BLOCK, n)
        a64 = np.stack([x.words for x in xs[lo:hi]])
        b64 = np.stack([y.words for y in ys[lo:hi]])
        out[lo:hi] = np.bitwise_count(a64 & b64).sum(axis=1)
    return out


# ---------------------------------------------------------------------------
# top-k similarity engine (device-resident candidate slab + pruning planner)
# ---------------------------------------------------------------------------

def _scores_host(inter, q_card, cards, metric: str) -> np.ndarray:
    """Numpy twin of ``kernels.ref.similarity_scores``: float32 with the
    SAME operation order, so host selection is bit-identical (including
    tie ordering) to the fused device kernel."""
    interf = np.asarray(inter).astype(np.float32)
    qc = np.float32(q_card)
    oc = np.asarray(cards).astype(np.float32)
    if metric == "jaccard":
        denom = qc + oc - interf
    elif metric == "cosine":
        denom = np.sqrt(qc * oc)
    elif metric == "containment":
        denom = np.broadcast_to(qc, oc.shape)
    else:
        raise ValueError(metric)
    return np.divide(interf, denom, out=np.ones_like(interf),
                     where=denom > 0)


class SimilarityEngine:
    """Top-k similarity joins against a fixed candidate set, one engine
    dispatch per query (paper section 5.9 taken to its conclusion: not
    even the scores round-trip through the host).

    Construction promotes every candidate container to the bitset domain
    ONCE into a candidate-major row slab over the global chunk-key set --
    the layout ``kernels/topk_ops.similarity_topk`` consumes -- and keeps
    a lazily-uploaded device copy, so the per-query work is one fused
    score+select dispatch (kernel backends) or a pruned vectorized
    popcount sweep (CPU).  Memory: 8 kB per candidate container (sparse
    containers inflate to bitset rows; this is a query-serving cache, the
    stored bitmaps keep their compressed kinds).

    The CPU path is the *candidate-pruning planner* (the galloping/skip
    analogue of paper section 4.2 lifted to the planner layer): candidate
    scores are bounded above by evaluating the metric at
    ``inter = min(|Q|, |C|)``, the k best bounds are scored exactly to
    establish the running k-th score, and every candidate whose bound
    cannot reach it is skipped without touching its postings.  The score
    formula is evaluated in float32 with a fixed operation order on every
    path (see ``kernels.ref.similarity_scores``), and both selectors
    break ties toward the lower candidate index, so kernel and host
    results are bit-identical -- the ``backend=`` switch can never change
    an answer.  See docs/ARCHITECTURE.md for the module map.

    With an ``arena`` (core/arena.py) the candidate slab becomes an
    **arena view**: candidates are adopted into the shared arena, the
    engine stores slab row ids instead of owning a private copy, and the
    device slab is a device-side gather from the arena's resident rows
    (the host ``rows`` mirror is gathered from the arena's host mirror --
    same bytes, so host and kernel paths stay bit-identical).  A postings
    edit then costs one :meth:`refresh` -- the arena repatches only the
    changed rows (one scatter) and the engine re-gathers, instead of
    re-promoting and re-uploading the whole candidate set.
    """

    def __init__(self, bitmaps, *, arena=None, mesh=None):
        """``bitmaps``: the candidate set, index-aligned with results.
        ``arena``: optional shared ``BitmapArena``; candidates are
        adopted into it and the engine becomes a view over its slab
        (see the class docstring and docs/MEMORY.md).
        ``mesh``: optional 1-D ``("wide",)`` mesh; with more than one
        device the engine runs the sharded path (:meth:`_topk_sharded`)
        over the arena's per-shard slabs -- requires ``arena``.  A
        1-device mesh degrades to the single-device engine."""
        self._bitmaps = list(bitmaps)
        self._arena = arena
        self._mesh = None
        self._nshards = 1
        self._shard_axis = None
        if mesh is not None:
            from repro.dist import ctx
            m, size, axis = ctx.resolve_wide(mesh)
            if size > 1:
                if arena is None:
                    raise ValueError(
                        "sharded SimilarityEngine (mesh=) requires an "
                        "arena-backed engine")
                self._mesh, self._nshards, self._shard_axis = m, size, axis
        self._build()

    def _build(self) -> None:
        bitmaps = self._bitmaps
        arena = self._arena
        self.n = len(bitmaps)
        self.cards = np.array([bm.cardinality for bm in bitmaps],
                              np.int64)
        if self.cards.size and int(self.cards.max()) >= 2**31:
            # the kernel path carries cardinalities as int32; refuse to
            # build rather than silently wrap on one backend
            raise ValueError("candidate cardinality >= 2^31 unsupported")
        if arena is not None:
            arena.adopt_many(bitmaps)
        keys = sorted({k for bm in bitmaps for k in bm.keys})
        self.key_col = {k: i for i, k in enumerate(keys)}
        self.n_keys = len(keys)
        rows, row_col = [], []
        starts = np.zeros(self.n + 1, np.int32)
        for i, bm in enumerate(bitmaps):
            for k, c in zip(bm.keys, bm.containers):
                rows.append(arena.lookup(c) if arena is not None
                            else C.container_words64(c))
                row_col.append(self.key_col[k])
            starts[i + 1] = len(rows)
        if arena is not None:
            # arena view: keep row ids + a host-mirror gather (identical
            # bytes to promoting, without re-running promotion)
            self.row_ids = np.asarray(rows, np.int32)
            self.rows = arena.host_rows(self.row_ids) if rows else \
                np.zeros((0, 1024), np.uint64)
            self._snap = tuple((id(bm), bm._version) for bm in bitmaps)
        else:
            self.row_ids = None
            self.rows = np.stack(rows) if rows else \
                np.zeros((0, 1024), np.uint64)
            self._snap = None
        self.row_col = np.asarray(row_col, np.int32)
        self.starts = starts
        seg = int(np.diff(starts).max()) if self.n else 1
        self.jmax = 1 if seg <= 1 else 1 << (seg - 1).bit_length()
        self._dev = None                         # lazy device upload
        self._shard_jit = {}                     # (metric, k, backend) -> fn

    def refresh(self) -> bool:
        """Generation revalidation for an arena-backed engine: re-adopt
        candidates whose ``_version`` moved (the arena repatches only
        their changed rows -- one scatter), rebuild the cheap host index
        arrays, and drop the device view so the next query re-gathers
        from the patched slab ON DEVICE.  Returns True when anything
        changed; a no-op (False) when every candidate is current.

        This is the incremental path the query server's ``slab_mismatch``
        rung uses instead of discarding the engine (docs/ARCHITECTURE.md
        §6); cost is O(changed rows) transfer instead of O(slab)."""
        if self._arena is None:
            raise ValueError("refresh() requires an arena-backed engine")
        snap = tuple((id(bm), bm._version) for bm in self._bitmaps)
        if snap == self._snap:
            return False
        self._build()
        return True

    # -- query preparation ----------------------------------------------

    def _query_words(self, query) -> np.ndarray:
        """(C, 1024) uint64 host query rows over the global keys.
        ``query`` is a candidate index (rows gathered from the cached
        slab) or any RoaringBitmap (keys outside the candidate universe
        carry no candidate rows and are dropped -- they cannot
        intersect)."""
        q64 = np.zeros((max(self.n_keys, 1), 1024), np.uint64)
        if isinstance(query, (int, np.integer)):
            s, e = int(self.starts[query]), int(self.starts[query + 1])
            q64[self.row_col[s:e]] = self.rows[s:e]
            return q64
        for k, cont in zip(query.keys, query.containers):
            col = self.key_col.get(k)
            if col is not None:
                q64[col] = C.container_words64(cont)
        return q64

    def _query_words_dev(self, query):
        """(C, WORDS) uint32 DEVICE query block with minimal transfer:
        a member query gathers its rows from the resident slab (nothing
        crosses the host bridge); a bitmap query ships only its occupied
        rows and scatters them into place on device."""
        dev_rows, dev_col, _, _ = self._device()
        nc = max(self.n_keys, 1)
        zeros = jnp.zeros((nc, WORDS), jnp.uint32)
        if isinstance(query, (int, np.integer)):
            s, e = int(self.starts[query]), int(self.starts[query + 1])
            if s == e:
                return zeros
            return zeros.at[dev_col[s:e]].set(dev_rows[s:e])
        cols, rows = [], []
        for k, cont in zip(query.keys, query.containers):
            col = self.key_col.get(k)
            if col is not None:
                cols.append(col)
                rows.append(C.container_words64(cont))
        if not cols:
            return zeros
        stack = np.stack(rows).view(np.uint32).reshape(-1, WORDS)
        return zeros.at[jnp.asarray(np.asarray(cols, np.int32))] \
            .set(jnp.asarray(stack))

    def _query_words_dev_batch(self, queries):
        """(B, C, WORDS) uint32 DEVICE query block for a whole batch in
        TWO scatters (one gathering member queries' rows from the
        resident slab, one shipping bitmap queries' occupied rows) --
        the per-query ``_query_words_dev`` loop costs one jit dispatch
        per query, which dominates coalesced similarity batches."""
        dev_rows, dev_col, _, _ = self._device()
        nc = max(self.n_keys, 1)
        block = jnp.zeros((len(queries), nc, WORDS), jnp.uint32)
        mem_b, mem_r = [], []            # member queries: slab row ids
        bm_b, bm_c, bm_rows = [], [], []  # bitmap queries: host words
        for b, q in enumerate(queries):
            if isinstance(q, (int, np.integer)):
                s, e = int(self.starts[q]), int(self.starts[q + 1])
                mem_b.extend([b] * (e - s))
                mem_r.extend(range(s, e))
                continue
            for k, cont in zip(q.keys, q.containers):
                col = self.key_col.get(k)
                if col is not None:
                    bm_b.append(b)
                    bm_c.append(col)
                    bm_rows.append(C.container_words64(cont))
        if mem_r:
            r = jnp.asarray(np.asarray(mem_r, np.int32))
            block = block.at[jnp.asarray(np.asarray(mem_b, np.int32)),
                             dev_col[r]].set(dev_rows[r])
        if bm_b:
            stack = np.stack(bm_rows).view(np.uint32).reshape(-1, WORDS)
            block = block.at[jnp.asarray(np.asarray(bm_b, np.int32)),
                             jnp.asarray(np.asarray(bm_c, np.int32))
                             ].set(jnp.asarray(stack))
        return block

    def _device(self):
        if self._dev is None:
            if self._arena is not None and self.row_ids is not None \
                    and self.row_ids.size:
                # arena view: gather the candidate rows from the resident
                # slab ON DEVICE -- container words never cross PCIe here
                dev_rows = jnp.take(self._arena.device_slab(),
                                    jnp.asarray(self.row_ids), axis=0)
                self._arena.stats.device_gathers += 1
            elif self.rows.size:
                dev_rows = jnp.asarray(
                    self.rows.view(np.uint32).reshape(-1, WORDS))
            else:
                dev_rows = jnp.zeros((1, WORDS), jnp.uint32)
            self._dev = (
                dev_rows,
                jnp.asarray(self.row_col if self.row_col.size else
                            np.zeros(1, np.int32)),
                jnp.asarray(self.starts),
                jnp.asarray(self.cards.astype(np.int32)),
            )
        return self._dev

    # -- the query surface ----------------------------------------------

    def topk(self, query, k: int, metric: str = "jaccard", *,
             backend: str | None = None
             ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Top-k most similar candidates to ``query``.

        query:  candidate index (int; excluded from its own result) or a
                RoaringBitmap.
        k:      results wanted; clamped to the candidate count.
        metric: "jaccard" | "cosine" | "containment" (all derived from
                the AND cardinality by inclusion-exclusion).
        backend: kernel override; None = fused kernel on TPU, pruned
                host sweep on CPU; "host" forces the jax-free host sweep
                (the query server's degradation path).  Results are
                bit-identical on every path.

        Returns (idx (k',) int64, score (k',) float32, inter (k',) int64)
        best-first; ties at equal score order by ascending index.
        Complexity: one dispatch over the resident slab (kernel) or
        O(rows of unpruned candidates) popcounts (host).
        """
        if metric not in METRICS:
            raise ValueError(metric)
        if isinstance(query, (int, np.integer)):
            exclude = int(query)
            if not 0 <= exclude < self.n:
                raise IndexError(f"candidate index {exclude} out of "
                                 f"range [0, {self.n})")
            qc = int(self.cards[exclude])
        else:
            exclude = None
            qc = query.cardinality
        n_cand = self.n - (1 if exclude is not None else 0)
        k = min(int(k), n_cand)
        if k <= 0:
            return (np.zeros(0, np.int64), np.zeros(0, np.float32),
                    np.zeros(0, np.int64))
        if qc >= 2**31:                          # int32 on the kernel path
            raise ValueError("query cardinality >= 2^31 unsupported")
        if self.rows.shape[0] == 0:              # all-empty candidates
            score = _scores_host(np.zeros(self.n, np.int64), qc,
                                 self.cards, metric)
            if exclude is not None:
                score[exclude] = np.float32(-1.0)
            order = np.argsort(-score, kind="stable")[:k]
            return (order.astype(np.int64), score[order],
                    np.zeros(k, np.int64))
        if self._mesh is not None and backend != "host":
            return self._topk_sharded(query, qc, k, metric, exclude,
                                      backend)
        if backend != "host" and _prefer_kernel(backend):
            dev_rows, dev_col, dev_starts, dev_cards = self._device()
            idx, score, inter = kops.similarity_topk(
                dev_rows, dev_col, dev_starts,
                self._query_words_dev(query),
                qc, dev_cards, metric=metric, k=k,
                jmax=self.jmax,
                exclude=-1 if exclude is None else exclude,
                backend=backend)
            return (np.asarray(idx).astype(np.int64),
                    np.asarray(score),
                    np.asarray(inter).astype(np.int64))
        return self._topk_host(self._query_words(query), qc, k, metric,
                               exclude)

    # -- sharded path (per-shard arena slabs, k-list all-gather) --------

    def _query_words_dev_sharded(self, query, shards):
        """(C, WORDS) uint32 device query block for the sharded path: a
        member query gathers its rows from the ASSEMBLED per-shard slab
        (container words never cross the host bridge); a bitmap query
        ships only its occupied rows -- the query payload itself, never
        candidate rows."""
        nc = max(self.n_keys, 1)
        zeros = jnp.zeros((nc, WORDS), jnp.uint32)
        if isinstance(query, (int, np.integer)):
            s, e = int(self.starts[query]), int(self.starts[query + 1])
            if s == e:
                return zeros
            pos = shards.positions(self.row_ids[s:e])
            rows = jnp.take(shards.assembled(),
                            jnp.asarray(pos, jnp.int32), axis=0)
            return zeros.at[jnp.asarray(self.row_col[s:e])].set(rows)
        cols, rows = [], []
        for key, cont in zip(query.keys, query.containers):
            col = self.key_col.get(key)
            if col is not None:
                cols.append(col)
                rows.append(C.container_words64(cont))
        if not cols:
            return zeros
        stack = np.stack(rows).view(np.uint32).reshape(-1, WORDS)
        return zeros.at[jnp.asarray(np.asarray(cols, np.int32))] \
            .set(jnp.asarray(stack))

    def _sharded_fn(self, metric: str, k: int, backend):
        """One jit'd sharded dispatch per (metric, k, backend) class:
        gather survivor rows from the assembled sharded slab, run the
        fused score+select per shard under ``shard_map``, all-gather
        ONLY the k-lists, and merge to the global top-k on device."""
        key = (metric, k, backend)
        fn = self._shard_jit.get(key)
        if fn is not None:
            return fn
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        mesh, axis, jmax = self._mesh, self._shard_axis, self.jmax

        def body(rows_d, col_d, starts_d, gidx_d, cards_d, nval_d,
                 q, qc, ex):
            idx, sco, itr = kops.similarity_topk_ids(
                rows_d[0], col_d[0], starts_d[0], q, qc, cards_d[0],
                gidx_d[0], metric=metric, k=k, jmax=jmax,
                n_valid=nval_d[0], exclude=ex, backend=backend)
            return (jax.lax.all_gather(idx, axis),
                    jax.lax.all_gather(sco, axis),
                    jax.lax.all_gather(itr, axis))

        sp = P(axis)
        sm = shard_map(body, mesh=mesh,
                       in_specs=(sp, sp, sp, sp, sp, sp, P(), P(), P()),
                       out_specs=(P(), P(), P()), check_rep=False)

        def run(slab, pos, col, starts, gidx, cards, nval, q, qc, ex):
            s, r = pos.shape
            rows_all = jnp.take(slab, pos.reshape(-1),
                                axis=0).reshape(s, r, WORDS)
            gi, gs, gn = sm(rows_all, col, starts, gidx, cards, nval,
                            q, qc, ex)
            return kops.topk_merge(gs.reshape(-1), gn.reshape(-1),
                                   gi.reshape(-1), k, backend=backend)

        fn = jax.jit(run)
        self._shard_jit[key] = fn
        return fn

    def _plan_sharded(self, q64, qc, k, metric, exclude, shards):
        """Host planning for one sharded query: run the SAME pruning
        derivation as :meth:`_topk_host` (bounds -> k seed exact scores
        -> running k-th score tau -> survivors = bound >= tau), then
        round-robin the survivors to their ``t % S`` home shards and
        pack per-shard padded arrays for the shard_map dispatch.

        Returns ``(counts, gidx, cards, starts, pos, col)``: per-shard
        valid-candidate counts (S,), global candidate ids (S, L) (pad:
        ``self.n``, masked by ``n_valid``), their cardinalities (S, L),
        row segment starts (S, L+1) (pad: repeat last -- empty
        segments), assembled-slab row positions (S, R) (pad: position 0,
        the reserved all-zero row), and key columns (S, R).  L and R are
        padded to powers of two so jit retraces stay bounded."""
        ub = _scores_host(np.minimum(qc, self.cards), qc, self.cards,
                          metric)
        if exclude is not None:
            ub[exclude] = np.float32(-1.0)
        seeds = np.argsort(-ub, kind="stable")[:k]
        seed_score = _scores_host(self._host_inter(seeds, q64), qc,
                                  self.cards[seeds], metric)
        tau = seed_score.min()
        # exact seed scores are <= their bounds, so seeds survive; the
        # excluded candidate's bound is -1 < 0 <= tau, so it never does
        surv = np.where(ub >= tau)[0]
        S = self._nshards
        sh = (surv % S).astype(np.int64)
        counts = np.bincount(sh, minlength=S).astype(np.int32)
        lmax = max(1, int(counts.max()))
        lpad = 1 << (lmax - 1).bit_length()      # pow2: bounded retraces
        gidx_p = np.full((S, lpad), self.n, np.int32)   # pad: masked slot
        cards_p = np.zeros((S, lpad), np.int32)
        starts_p = np.zeros((S, lpad + 1), np.int32)
        rid_shard = []
        rmax = 1
        for s in range(S):
            cs = surv[sh == s]                   # ascending global ids
            gidx_p[s, : cs.size] = cs
            cards_p[s, : cs.size] = self.cards[cs]
            lens = (self.starts[cs + 1] - self.starts[cs]).astype(np.int64)
            tot = int(lens.sum())
            st = np.zeros(lpad + 1, np.int64)
            st[1: cs.size + 1] = np.cumsum(lens)
            st[cs.size + 1:] = tot               # pad: repeat last start
            starts_p[s] = st
            if tot:
                offs = np.repeat(np.cumsum(lens) - lens, lens)
                ridx = np.arange(tot) - offs + np.repeat(
                    self.starts[cs].astype(np.int64), lens)
            else:
                ridx = np.zeros(0, np.int64)
            rid_shard.append(ridx)
            rmax = max(rmax, tot)
        rpad = 1 << (rmax - 1).bit_length()
        pos_p = np.zeros((S, rpad), np.int32)    # pad: reserved zero row 0
        col_p = np.zeros((S, rpad), np.int32)
        for s, ridx in enumerate(rid_shard):
            pos_p[s, : ridx.size] = shards.positions(self.row_ids[ridx])
            col_p[s, : ridx.size] = self.row_col[ridx]
        return counts, gidx_p, cards_p, starts_p, pos_p, col_p

    def _topk_sharded(self, query, qc, k, metric, exclude, backend):
        """The sharded query path: the host pruning planner (same bound /
        seed / tau derivation as :meth:`_topk_host`, so the SAME
        candidates survive) selects the survivor set, survivors are
        round-robined to their ``t % S`` home shards, each shard runs the
        fused score+select over its survivors' arena rows (gathered from
        the assembled per-shard slab by global position -- ids over the
        bridge, never container words), and only the S k-lists are
        all-gathered and merged on device.  Ties resolve to the lowest
        GLOBAL candidate index at both the per-shard select and the
        merge, so results are bit-identical to the single-device path."""
        shards = self._arena.shard_slabs(self._mesh)
        q64 = self._query_words(query)           # host mirror, no PCIe
        (counts, gidx_p, cards_p, starts_p, pos_p, col_p
         ) = self._plan_sharded(q64, qc, k, metric, exclude, shards)
        q_dev = self._query_words_dev_sharded(query, shards)
        for st in shards.stats:
            st.device_gathers += 1
        fn = self._sharded_fn(metric, k, backend)
        with self._mesh:
            idx, score, inter = fn(
                shards.assembled(), jnp.asarray(pos_p),
                jnp.asarray(col_p), jnp.asarray(starts_p),
                jnp.asarray(gidx_p), jnp.asarray(cards_p),
                jnp.asarray(counts.astype(np.int32)), q_dev,
                jnp.asarray(np.int32(qc)),
                jnp.asarray(np.int32(-1 if exclude is None else exclude)))
        return (np.asarray(idx).astype(np.int64), np.asarray(score),
                np.asarray(inter).astype(np.int64))

    def topk_batch(self, queries, k: int, metric: str = "jaccard", *,
                   backend: str | None = None) -> list:
        """Batched ``topk``: score many queries against the SAME resident
        candidate slab (the query server's similarity coalescing path).

        On the jnp-oracle kernel backend every query sharing an effective
        ``k`` lowers to ONE vmapped score+select dispatch over the cached
        slab; the Pallas kernel and the pruned host sweep fall back to a
        per-query loop that still shares every cached structure.  Returns
        ``[self.topk(q, k, metric) for q in queries]`` bit for bit on
        every path (asserted by the test suite)."""
        queries = list(queries)
        if metric not in METRICS:
            raise ValueError(metric)
        out: list = [None] * len(queries)
        batch: dict[int, list[int]] = {}          # effective k -> indices
        use_vmap = (backend != "host" and _prefer_kernel(backend)
                    and not kops._use_pallas(backend)
                    and self._mesh is None
                    and self.rows.shape[0] > 0)
        for i, q in enumerate(queries):
            if not use_vmap:
                out[i] = self.topk(q, k, metric, backend=backend)
                continue
            n_cand = self.n - (1 if isinstance(q, (int, np.integer))
                               else 0)
            kk = min(int(k), n_cand)
            if kk <= 0:
                out[i] = self.topk(q, k, metric, backend=backend)
            else:
                batch.setdefault(kk, []).append(i)
        for kk, idxs in batch.items():
            dev_rows, dev_col, dev_starts, dev_cards = self._device()
            q_card, excl = [], []
            for i in idxs:
                q = queries[i]
                if isinstance(q, (int, np.integer)):
                    if not 0 <= int(q) < self.n:
                        raise IndexError(f"candidate index {int(q)} out "
                                         f"of range [0, {self.n})")
                    qc, ex = int(self.cards[int(q)]), int(q)
                else:
                    qc, ex = q.cardinality, -1
                if qc >= 2**31:
                    raise ValueError(
                        "query cardinality >= 2^31 unsupported")
                q_card.append(qc)
                excl.append(ex)
            idx, score, inter = _batched_topk(metric, kk)(
                dev_rows, dev_col, dev_starts,
                self._query_words_dev_batch([queries[i] for i in idxs]),
                jnp.asarray(q_card, jnp.int32), dev_cards,
                jnp.asarray(excl, jnp.int32))
            idx = np.asarray(idx).astype(np.int64)
            score = np.asarray(score)
            inter = np.asarray(inter).astype(np.int64)
            for j, i in enumerate(idxs):
                out[i] = (idx[j], score[j], inter[j])
        return out

    # -- pruned host path -----------------------------------------------

    def _host_inter(self, sel: np.ndarray, q64: np.ndarray) -> np.ndarray:
        """Exact intersection cardinalities of the selected candidates:
        gather their cached rows, AND against the query's key columns,
        popcount, segment-sum per candidate."""
        out = np.zeros(sel.size, np.int64)
        lens = (self.starts[sel + 1] - self.starts[sel]).astype(np.int64)
        total = int(lens.sum())
        if total == 0:
            return out
        offs = np.repeat(np.cumsum(lens) - lens, lens)
        ridx = np.arange(total) - offs + np.repeat(
            self.starts[sel].astype(np.int64), lens)
        per = np.bitwise_count(
            self.rows[ridx] & q64[self.row_col[ridx]]).sum(axis=1)
        np.add.at(out, np.repeat(np.arange(sel.size), lens),
                  per.astype(np.int64))
        return out

    def _topk_host(self, q64, qc, k, metric, exclude):
        """The pruning planner: score upper bounds from cardinalities
        alone (metric at ``inter = min(|Q|, |C|)`` -- monotone in inter,
        so a true float32 bound), exact-score the k best bounds to pin
        the running k-th score, and skip every candidate whose bound
        falls strictly below it."""
        ub = _scores_host(np.minimum(qc, self.cards), qc, self.cards,
                          metric)
        if exclude is not None:
            ub[exclude] = np.float32(-1.0)
        order_ub = np.argsort(-ub, kind="stable")
        seeds = order_ub[:k]
        score = np.full(self.n, np.float32(-1.0), np.float32)
        inter = np.zeros(self.n, np.int64)
        inter[seeds] = self._host_inter(seeds, q64)
        score[seeds] = _scores_host(inter[seeds], qc, self.cards[seeds],
                                    metric)
        tau = score[seeds].min()                 # running k-th score
        rest = order_ub[k:]
        survivors = rest[ub[rest] >= tau]        # bound < tau: skipped
        if survivors.size:
            inter[survivors] = self._host_inter(survivors, q64)
            score[survivors] = _scores_host(
                inter[survivors], qc, self.cards[survivors], metric)
        if exclude is not None:
            score[exclude] = np.float32(-1.0)
        order = np.argsort(-score, kind="stable")[:k]
        return order.astype(np.int64), score[order], inter[order]


@functools.lru_cache(maxsize=64)
def _batched_topk(metric: str, k: int):
    """One jit'd vmap of the similarity oracle per (metric, k) class:
    in_axes batch the query block / cardinality / exclusion index while
    the resident candidate slab broadcasts."""
    fn = functools.partial(_refk.similarity_topk, metric=metric, k=k)
    return jax.jit(jax.vmap(fn, in_axes=(None, None, None, 0, 0, None, 0)))


# ---------------------------------------------------------------------------
# similarity joins
# ---------------------------------------------------------------------------

def jaccard_matrix(bitmaps, *, backend: str | None = None) -> np.ndarray:
    """(N, N) Jaccard similarity matrix over N bitmaps: the all-pairs
    similarity join, planned as one batched AND-count dispatch per
    container-type class over all N*(N-1)/2 pairs (not one per pair)."""
    bitmaps = list(bitmaps)
    n = len(bitmaps)
    out = np.ones((n, n), np.float64)
    if n < 2:
        return out
    iu, ju = np.triu_indices(n, k=1)
    pairs = [(bitmaps[i], bitmaps[j]) for i, j in zip(iu.tolist(),
                                                      ju.tolist())]
    inter = pairwise_card("and", pairs, backend=backend).astype(np.float64)
    cards = np.array([bm.cardinality for bm in bitmaps], np.float64)
    union = cards[iu] + cards[ju] - inter
    sim = np.divide(inter, union, out=np.ones_like(inter),
                    where=union > 0)
    out[iu, ju] = sim
    out[ju, iu] = sim
    return out
