"""Wide-aggregation planner: K-bitmap OR/AND/XOR/threshold, one dispatch.

The paper's wide union (section 5.8, ``roaring_bitmap_or_many``) streams
containers through an in-register accumulator; sections 4.1.2 and 5.9 insist
the logical op and the population count happen in the same pass.  "Compressed
bitmap indexes: beyond unions and intersections" (Kaser & Lemire) extends
wide aggregation past OR/AND, and "Threshold and Symmetric Functions over
Bitmaps" (Kaser & Lemire) motivates the T-occurrence query implemented here.

The planner walks the K input bitmaps' key lists once and groups containers
by 16-bit chunk key.  Each key is then either

  * a **pass-through** -- singleton keys (OR/XOR) are shared zero-copy;
    full-chunk runs short-circuit OR; groups a host fast path can finish
    cheaply stay on the host: run-only groups reduce with a vectorized
    boundary sweep at interval granularity (never touching 2^16 bits),
    array-only XOR/threshold groups count occurrences with bincount,
    small all-array unions concatenate, and AND anchors on the smallest
    member with vectorized membership filtering in cardinality-ascending
    order;
  * or a **slab segment** -- every remaining container is promoted to the
    device bitset layout (array containers of one OR/XOR group collapse into
    a single indicator row first), the rows are stacked segment-major into
    one ``(N, WORDS)`` uint32 slab, and a single
    ``kernels.ops.segment_reduce`` dispatch produces each segment's reduced
    words fused with its Harley-Seal cardinality -- O(1) dispatches
    regardless of K or container count, with the cardinality computed
    lazily once per segment (never per accumulation step).

Kernel results are repacked via ``optimize`` (run_optimize semantics), so
the output uses the memory-optimal container kind per chunk.

AND runs the paper's cardinality-ascending planning at the top level too:
key sets intersect cheapest-bitmap-first and the whole query exits early the
moment the candidate key set goes empty.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import containers as C
from repro.core.containers import (
    ARRAY_MAX, CHUNK, ArrayContainer, BitsetContainer, Container,
    RunContainer, optimize,
)
from repro.kernels import ops as kops
from repro.kernels.ref import WORDS

__all__ = ["or_many", "and_many", "xor_many", "threshold_many"]


def _bitmap_cls():
    from repro.core.bitmap import RoaringBitmap  # deferred: bitmap imports us
    return RoaringBitmap


def _pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def _group(bitmaps) -> dict[int, list[Container]]:
    groups: dict[int, list[Container]] = {}
    for bm in bitmaps:
        for k, c in zip(bm.keys, bm.containers):
            groups.setdefault(k, []).append(c)
    return groups


def _shallow(bm):
    RB = _bitmap_cls()
    return RB(list(bm.keys), list(bm.containers))


def _build(merged: dict[int, Container]):
    RB = _bitmap_cls()
    keys = sorted(merged)
    return RB(keys, [merged[k] for k in keys])


def _full_run() -> RunContainer:
    return RunContainer(np.array([[0, CHUNK - 1]], np.int32))


def _is_full(c: Container) -> bool:
    """card == 2^16 without touching the O(runs) card property."""
    if isinstance(c, RunContainer):
        return (c.runs.shape[0] == 1 and int(c.runs[0, 0]) == 0
                and int(c.runs[0, 1]) == CHUNK - 1)
    return c.card == CHUNK


def _prefer_kernel(backend: str | None) -> bool:
    """Whether dense array-only groups should ride the slab kernel.

    On TPU (or when a backend is forced, e.g. in tests) the fused segmented
    kernel wins; on CPU the host indicator path avoids a device round-trip
    that the jnp reference backend cannot amortize.  Run-only groups always
    use the interval sweep: it is strictly cheaper than bit-level promotion
    on every backend."""
    if backend in ("pallas", "ref"):
        return True
    import jax
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# promotion helpers (host side of the slab)
# ---------------------------------------------------------------------------

def _words_row(c: Container) -> np.ndarray:
    """Container -> (1024,) uint64 bitset words."""
    if isinstance(c, BitsetContainer):
        return c.words
    return c.to_bitset().words


def _array_indicator(arrays: list[ArrayContainer], op: str) -> np.ndarray:
    """(CHUNK,) 0/1 indicator of the OR / XOR of the group's arrays.

    OR: duplicate values across members are harmless, so plain indicator
    stores suffice.  XOR: the parity of the occurrence counts (bincount is
    a counting sort: O(values), no comparison sort)."""
    vals = arrays[0].values if len(arrays) == 1 else \
        np.concatenate([a.values for a in arrays])
    if op == "or" or len(arrays) == 1:
        ind = np.zeros(CHUNK, np.uint8)
        ind[vals] = 1
        return ind
    return (np.bincount(vals, minlength=CHUNK) & 1).astype(np.uint8)


def _indicator_row(arrays: list[ArrayContainer], op: str) -> np.ndarray:
    """Collapse every array container of one group into a single bitset
    row of the slab."""
    return np.packbits(_array_indicator(arrays, op),
                       bitorder="little").view(np.uint64)


def _from_indicator(ind: np.ndarray) -> Container | None:
    """(CHUNK,) 0/1 indicator -> optimal container (None when empty)."""
    card = int(ind.sum())
    if card == 0:
        return None
    if card <= ARRAY_MAX:
        return optimize(ArrayContainer(np.flatnonzero(ind).astype(np.uint16)))
    words = np.packbits(ind.astype(np.uint8),
                        bitorder="little").view(np.uint64)
    return optimize(BitsetContainer(words, card))


def _count_arrays(arrays: list[ArrayContainer], op: str,
                  t: int) -> Container | None:
    """All-array group fast path: occurrence counting via bincount, entirely
    on the host.  op "xor" keeps odd counts, "threshold" counts >= t."""
    vals = arrays[0].values if len(arrays) == 1 else \
        np.concatenate([a.values for a in arrays])
    cnt = np.bincount(vals, minlength=CHUNK)
    ind = (cnt & 1) if op == "xor" else (cnt >= t)
    return _from_indicator(ind.astype(np.uint8))


def _sweep_run_groups(run_groups: list[tuple[int, list[RunContainer]]],
                      op: str, t: int) -> dict[int, Container]:
    """Run-only groups, ALL reduced in one vectorized boundary sweep at
    *interval* granularity (never expanding to 2^16 bits) -- the host twin
    of the slab's single dispatch.

    Each group's runs are lifted into a global coordinate space
    (``key << 16 | start``); chunks never overlap, so one sweep serves every
    group.  Each member's runs are disjoint, hence the coverage count over
    an elementary interval equals the number of members containing it:
    OR is count >= 1, AND count == K (per group), XOR odd count, threshold
    count >= t.  ``run_groups`` must be key-sorted."""
    out: dict[int, Container] = {}
    if not run_groups:
        return out
    starts_l, ends_l = [], []
    for k, conts in run_groups:
        r = conts[0].runs if len(conts) == 1 else \
            np.concatenate([c.runs for c in conts])
        s = r[:, 0].astype(np.int64) + (np.int64(k) << 16)
        starts_l.append(s)
        ends_l.append(s + r[:, 1] + 1)                  # exclusive
    starts = np.concatenate(starts_l)
    ends = np.concatenate(ends_l)
    pts = np.concatenate((starts, ends))
    delta = np.concatenate((np.ones(starts.size, np.int32),
                            np.full(ends.size, -1, np.int32)))
    order = np.argsort(pts, kind="stable")
    upts, first = np.unique(pts[order], return_index=True)
    cov = np.cumsum(np.add.reduceat(delta[order], first))[:-1]  # / interval
    if op == "or":
        keep = cov >= 1
    elif op == "xor":
        keep = (cov & 1) == 1
    elif op == "and":
        gk = np.array([k for k, _ in run_groups], np.int64)
        gn = np.array([len(c) for _, c in run_groups], np.int64)
        need = gn[np.searchsorted(gk, upts[:-1] >> 16)]
        keep = cov >= need                 # gap intervals have cov 0 < need
    else:
        keep = cov >= t
    lo, hi = upts[:-1][keep], upts[1:][keep]
    if lo.size == 0:
        return out
    # merge contiguous intervals, but never across a chunk-key border
    same_key = (lo[1:] >> 16) == ((hi[:-1] - 1) >> 16)
    brk = np.concatenate(([True], (lo[1:] > hi[:-1]) | ~same_key))
    si = np.flatnonzero(brk)
    ei = np.concatenate((si[1:] - 1, [lo.size - 1]))
    rlo, rhi = lo[si], hi[ei]
    rkey = rlo >> 16
    runs_all = np.stack([rlo - (rkey << 16), rhi - 1 - rlo],
                        axis=1).astype(np.int32)
    uk, kfirst = np.unique(rkey, return_index=True)
    bounds = np.concatenate((kfirst, [rkey.size]))
    for i, k in enumerate(uk.tolist()):
        out[int(k)] = optimize(RunContainer(runs_all[bounds[i]:bounds[i + 1]]))
    return out


def _filter_values(vals: np.ndarray, c: Container) -> np.ndarray:
    """Keep the sorted uint16 ``vals`` that are members of container ``c``
    (the AND fast path's vectorized membership probe)."""
    if vals.size == 0:
        return vals
    if isinstance(c, BitsetContainer):
        return vals[C.bitset_test_many(c.words, vals)]
    if isinstance(c, ArrayContainer):
        if c.values.size == 0:
            return vals[:0]
        idx = np.searchsorted(c.values, vals)
        idx[idx == c.values.size] = c.values.size - 1
        return vals[c.values[idx] == vals]
    starts = c.runs[:, 0]
    v = vals.astype(np.int32)
    i = np.searchsorted(starts, v, side="right") - 1
    i_c = np.maximum(i, 0)
    ok = (i >= 0) & (v <= starts[i_c] + c.runs[i_c, 1])
    return vals[ok]


# ---------------------------------------------------------------------------
# the single kernel dispatch
# ---------------------------------------------------------------------------

def _dispatch(seg_keys: list[int], seg_rows: list[list[np.ndarray]],
              op: str, threshold: int, backend) -> dict[int, Container]:
    """Stack per-segment rows into one slab, reduce in one kernel call,
    repack each segment's (words, card) into the optimal container kind."""
    if not seg_keys:
        return {}
    lens = [len(r) for r in seg_rows]
    starts = np.zeros(len(lens) + 1, np.int32)
    starts[1:] = np.cumsum(lens)
    slab64 = np.stack([w for rows in seg_rows for w in rows])
    n = slab64.shape[0]
    slab32 = slab64.view(np.uint32).reshape(n, WORDS)
    # pad rows / segments / depth to powers of two so jit and kernel
    # specializations are reused across calls
    n_pad = _pow2(n)
    if n_pad != n:
        slab32 = np.concatenate(
            [slab32, np.zeros((n_pad - n, WORDS), np.uint32)])
    s = len(lens)
    s_pad = _pow2(s)
    if s_pad != s:
        starts = np.concatenate(
            [starts, np.full(s_pad - s, starts[-1], np.int32)])
    jmax = _pow2(max(lens))
    words, cards = kops.segment_reduce(
        jnp.asarray(slab32), jnp.asarray(starts), op, jmax=jmax,
        threshold=threshold, backend=backend)
    words = np.asarray(words[:s])
    cards = np.asarray(cards[:s])
    out: dict[int, Container] = {}
    for key, w32, card in zip(seg_keys, words, cards):
        card = int(card)
        if card == 0:
            continue
        w64 = np.ascontiguousarray(w32).view(np.uint64).copy()
        out[key] = optimize(C._result_from_bitset(w64, card))
    return out


# ---------------------------------------------------------------------------
# public wide aggregates
# ---------------------------------------------------------------------------

def or_many(bitmaps, *, backend: str | None = None):
    """Union of K bitmaps in one kernel dispatch (paper section 5.8)."""
    bitmaps = list(bitmaps)
    if not bitmaps:
        return _bitmap_cls()()
    if len(bitmaps) == 1:
        return _shallow(bitmaps[0])
    prefer_kernel = _prefer_kernel(backend)
    groups = _group(bitmaps)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(groups):
        g = groups[k]
        if len(g) == 1:
            merged[k] = g[0]                       # zero-copy pass-through
            continue
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval-level union
            continue
        if any(_is_full(c) for c in g):
            merged[k] = _full_run()                # full-chunk short-circuit
            continue
        arrays = [c for c in g if isinstance(c, ArrayContainer)]
        others = [c for c in g if not isinstance(c, ArrayContainer)]
        if not others:
            if sum(a.card for a in arrays) <= ARRAY_MAX:
                merged[k] = ArrayContainer(
                    np.unique(np.concatenate([a.values for a in arrays])))
                continue
            if not prefer_kernel:
                c = _from_indicator(_array_indicator(arrays, "or"))
                if c is not None:
                    merged[k] = c
                continue
        rows = [_indicator_row(arrays, "or")] if arrays else []
        rows.extend(_words_row(c) for c in others)
        seg_keys.append(k)
        seg_rows.append(rows)
    merged.update(_sweep_run_groups(run_groups, "or", 0))
    merged.update(_dispatch(seg_keys, seg_rows, "or", 0, backend))
    return _build(merged)


def xor_many(bitmaps, *, backend: str | None = None):
    """Wide symmetric difference: a value survives iff it occurs in an odd
    number of inputs (K-ary XOR)."""
    bitmaps = list(bitmaps)
    if not bitmaps:
        return _bitmap_cls()()
    if len(bitmaps) == 1:
        return _shallow(bitmaps[0])
    groups = _group(bitmaps)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(groups):
        g = groups[k]
        if len(g) == 1:
            merged[k] = g[0]
            continue
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval-level parity
            continue
        arrays = [c for c in g if isinstance(c, ArrayContainer)]
        others = [c for c in g if not isinstance(c, ArrayContainer)]
        if not others:
            c = _count_arrays(arrays, "xor", 0)    # host occurrence parity
            if c is not None:
                merged[k] = c
            continue
        rows = [_indicator_row(arrays, "xor")] if arrays else []
        rows.extend(_words_row(c) for c in others)
        seg_keys.append(k)
        seg_rows.append(rows)
    merged.update(_sweep_run_groups(run_groups, "xor", 0))
    merged.update(_dispatch(seg_keys, seg_rows, "xor", 0, backend))
    return _build(merged)


def and_many(bitmaps, *, backend: str | None = None):
    """Intersection of K bitmaps: cardinality-ascending key pruning with
    empty-key early exit, array-anchored host filtering for sparse groups,
    one kernel dispatch for the dense remainder."""
    bitmaps = list(bitmaps)
    if not bitmaps:
        return _bitmap_cls()()
    if len(bitmaps) == 1:
        return _shallow(bitmaps[0])
    order = sorted(bitmaps, key=lambda b: b.cardinality)
    common = set(order[0].keys)
    for bm in order[1:]:
        common &= set(bm.keys)
        if not common:
            return _bitmap_cls()()                 # empty-key early exit
    lookup = [dict(zip(bm.keys, bm.containers)) for bm in bitmaps]
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(common):
        g = sorted((lk[k] for lk in lookup), key=lambda c: c.card)
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval intersection
            continue
        smallest = g[0]
        if isinstance(smallest, RunContainer) and smallest.card <= ARRAY_MAX:
            smallest = ArrayContainer(smallest.to_array_values())
        if isinstance(smallest, ArrayContainer):
            # array-anchored: the result is a subset of the smallest member,
            # so vectorized membership probes beat promoting the group
            vals = smallest.values
            for c in g[1:]:
                vals = _filter_values(vals, c)
                if vals.size == 0:
                    break
            if vals.size:
                merged[k] = ArrayContainer(vals)
            continue
        seg_keys.append(k)
        seg_rows.append([_words_row(c) for c in g])
    merged.update(_sweep_run_groups(run_groups, "and", 0))
    merged.update(_dispatch(seg_keys, seg_rows, "and", 0, backend))
    return _build(merged)


def threshold_many(bitmaps, t: int, *, backend: str | None = None):
    """T-occurrence query: values present in at least ``t`` of the K inputs
    (Kaser & Lemire's threshold function; T=1 is union, T=K intersection).

    Keys appearing in fewer than ``t`` inputs are pruned on the host; the
    rest run through the kernel's bit-sliced counter circuit."""
    bitmaps = list(bitmaps)
    t = int(t)
    if t < 1:
        raise ValueError(f"threshold must be >= 1, got {t}")
    if not bitmaps or t > len(bitmaps):
        return _bitmap_cls()()
    if t == 1:
        return or_many(bitmaps, backend=backend)
    groups = _group(bitmaps)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(groups):
        g = groups[k]
        if len(g) < t:
            continue                               # can never reach T
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval-level counting
            continue
        if all(isinstance(c, ArrayContainer) for c in g):
            c = _count_arrays(g, "threshold", t)   # host occurrence counts
            if c is not None:
                merged[k] = c
            continue
        seg_keys.append(k)
        seg_rows.append([_words_row(c) for c in g])
    merged.update(_sweep_run_groups(run_groups, "threshold", t))
    merged.update(_dispatch(seg_keys, seg_rows, "threshold", t, backend))
    return _build(merged)
