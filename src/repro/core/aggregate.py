"""Wide-aggregation planner: K-bitmap OR/AND/XOR/threshold, one dispatch.

The paper's wide union (section 5.8, ``roaring_bitmap_or_many``) streams
containers through an in-register accumulator; sections 4.1.2 and 5.9 insist
the logical op and the population count happen in the same pass.  "Compressed
bitmap indexes: beyond unions and intersections" (Kaser & Lemire) extends
wide aggregation past OR/AND, and "Threshold and Symmetric Functions over
Bitmaps" (Kaser & Lemire) motivates the T-occurrence query implemented here.

The planner walks the K input bitmaps' key lists once and groups containers
by 16-bit chunk key.  Each key is then either

  * a **pass-through** -- singleton keys (OR/XOR) are shared zero-copy;
    full-chunk runs short-circuit OR; groups a host fast path can finish
    cheaply stay on the host: run-only groups reduce with a vectorized
    boundary sweep at interval granularity (never touching 2^16 bits),
    array-only XOR/threshold groups count occurrences with bincount,
    small all-array unions concatenate, and AND anchors on the smallest
    member with vectorized membership filtering in cardinality-ascending
    order;
  * or a **slab segment** -- every remaining container is promoted to the
    device bitset layout (array containers of one OR/XOR group collapse into
    a single indicator row first), the rows are stacked segment-major into
    one ``(N, WORDS)`` uint32 slab, and a single
    ``kernels.ops.segment_reduce`` dispatch produces each segment's reduced
    words fused with its Harley-Seal cardinality -- O(1) dispatches
    regardless of K or container count, with the cardinality computed
    lazily once per segment (never per accumulation step).

Kernel results are repacked via ``optimize`` (run_optimize semantics), so
the output uses the memory-optimal container kind per chunk.

AND runs the paper's cardinality-ascending planning at the top level too:
key sets intersect cheapest-bitmap-first and the whole query exits early the
moment the candidate key set goes empty.

**Sharded multi-device path.**  When a 1-D device mesh is supplied (or
installed with ``set_default_mesh``), each slab segment's rows are
round-robined across the mesh axis and every shard runs the same
``segment_reduce`` kernel on its local rows.  Partials combine with a
``psum``-style all-reduce (``all_gather`` + an exact bitwise fold):

  * OR / XOR partials fold with the op itself (both are associative and
    commutative over disjoint row sets, so results are bit-identical to the
    single-device plan);
  * ANDNOT replicates the minuend row on every shard -- local partials
    ``a & ~local_or`` then fold with AND, since
    ``(a & ~x) & (a & ~y) == a & ~(x | y)``;
  * threshold exchanges the bit-sliced occurrence counters themselves
    (``kernels.ref.segment_counters``): local counters are all-gathered,
    ripple-carry added in the bit-sliced domain, and one comparator pass
    emits the result words;
  * AND exchanges a per-shard occupancy mask with the partials: shards
    holding no rows of a segment contribute the all-ones identity (the
    kernel's empty-segment convention is all-zeros, which would be wrong
    to fold), and a segment occupied by no shard resolves to empty.

A one-device mesh falls back transparently to the single-dispatch path.

With an ``arena`` (core/arena.py), the sharded path never re-stages
resident rows: each shard gathers them from its LOCAL slab via
``ShardSlabs.assembled()`` global positions inside one jit
(``_shard_reduce_arena``, mirroring ``pairwise._topk_sharded``), so warm
sharded aggregates move only segment ids over the bridge and fold
partials on device -- zero container rows over PCIe, stat-asserted per
shard.  Cold rows ride a small replicated staged block whose row 0 is
reserved zero, making ``assembled[pos] | staged[sidx]`` exact slot
selection.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import containers as C
from repro.core.containers import (
    ARRAY_MAX, CHUNK, ArrayContainer, BitsetContainer, Container,
    RunContainer, optimize,
)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.ref import WORDS
from repro.kernels.segment_ops import counter_planes

__all__ = ["or_many", "and_many", "xor_many", "andnot_many",
           "threshold_many", "set_default_mesh", "WidePlan", "plan_wide",
           "execute_plans", "execute_plan_host"]

def set_default_mesh(mesh) -> None:
    """Install a mesh used by every wide aggregate that is not given an
    explicit ``mesh=``; pass None to restore the single-device path.

    The mesh is stored in ``repro.dist.ctx`` (the single mesh source of
    truth shared with the model sharding layer); this function and
    ``ctx.set_wide_mesh`` / ``ctx.install_wide_mesh`` are interchangeable.
    """
    from repro.dist import ctx
    ctx.set_wide_mesh(mesh)


def _resolve_mesh(mesh):
    from repro.dist import ctx
    return ctx.resolve_wide(mesh)[0]


def _mesh_size(mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def _mesh_axis(mesh) -> str:
    # one shared 1-D rule for every wide path (ctx.resolve_wide)
    from repro.dist import ctx
    return ctx.resolve_wide(mesh)[2]


def _bitmap_cls():
    from repro.core.bitmap import RoaringBitmap  # deferred: bitmap imports us
    return RoaringBitmap


def _pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def _group(bitmaps) -> dict[int, list[Container]]:
    groups: dict[int, list[Container]] = {}
    for bm in bitmaps:
        for k, c in zip(bm.keys, bm.containers):
            groups.setdefault(k, []).append(c)
    return groups


def _shallow(bm):
    RB = _bitmap_cls()
    return RB(list(bm.keys), list(bm.containers))


def _build(merged: dict[int, Container]):
    RB = _bitmap_cls()
    keys = sorted(merged)
    return RB(keys, [merged[k] for k in keys])


def _full_run() -> RunContainer:
    return RunContainer(np.array([[0, CHUNK - 1]], np.int32))


def _is_full(c: Container) -> bool:
    """card == 2^16 without touching the O(runs) card property."""
    if isinstance(c, RunContainer):
        return (c.runs.shape[0] == 1 and int(c.runs[0, 0]) == 0
                and int(c.runs[0, 1]) == CHUNK - 1)
    return c.card == CHUNK


def _prefer_kernel(backend: str | None) -> bool:
    """Whether dense array-only groups should ride the slab kernel
    (kernels.ops.prefer_kernel: TPU or a forced backend).  Run-only
    groups always use the interval sweep: it is strictly cheaper than
    bit-level promotion on every backend."""
    return kops.prefer_kernel(backend)


# ---------------------------------------------------------------------------
# promotion helpers (host side of the slab)
# ---------------------------------------------------------------------------

_words_row = C.container_words64      # container -> (1024,) uint64 words


def _array_indicator(arrays: list[ArrayContainer], op: str) -> np.ndarray:
    """(CHUNK,) 0/1 indicator of the OR / XOR of the group's arrays.

    OR: duplicate values across members are harmless, so plain indicator
    stores suffice.  XOR: the parity of the occurrence counts (bincount is
    a counting sort: O(values), no comparison sort)."""
    vals = arrays[0].values if len(arrays) == 1 else \
        np.concatenate([a.values for a in arrays])
    if op == "or" or len(arrays) == 1:
        ind = np.zeros(CHUNK, np.uint8)
        ind[vals] = 1
        return ind
    return (np.bincount(vals, minlength=CHUNK) & 1).astype(np.uint8)


def _indicator_row(arrays: list[ArrayContainer], op: str) -> np.ndarray:
    """Collapse every array container of one group into a single bitset
    row of the slab."""
    return np.packbits(_array_indicator(arrays, op),
                       bitorder="little").view(np.uint64)


def _row_ref(c: Container, arena):
    """Slab-row reference for one container: the arena row id (int) when
    the container is resident, else its promoted (1024,) uint64 words.
    ``_dispatch`` gathers int refs on-device (zero PCIe) and stages only
    the ndarray refs per call (see core/arena.py)."""
    if arena is not None:
        rid = arena.lookup(c)
        if rid is not None:
            return rid
    return _words_row(c)


def _array_rows(arrays: list[ArrayContainer], op: str, arena) -> list:
    """Slab rows for one group's array containers.  Without an arena the
    group collapses into a single indicator row (host bincount).  With an
    arena, resident arrays keep their individual device rows -- reducing
    them row-wise is bit-identical to the collapsed indicator for "or" /
    "xor" (parity per value is associative) -- and only the cold remainder
    collapses into one staged indicator row."""
    if not arrays:
        return []
    if arena is None:
        return [_indicator_row(arrays, op)]
    rows: list = []
    cold: list[ArrayContainer] = []
    for a in arrays:
        rid = arena.lookup(a)
        if rid is not None:
            rows.append(rid)
        else:
            cold.append(a)
    if cold:
        rows.append(_indicator_row(cold, op))
    return rows


def _from_indicator(ind: np.ndarray) -> Container | None:
    """(CHUNK,) 0/1 indicator -> optimal container (None when empty)."""
    card = int(ind.sum())
    if card == 0:
        return None
    if card <= ARRAY_MAX:
        return optimize(ArrayContainer(np.flatnonzero(ind).astype(np.uint16)))
    words = np.packbits(ind.astype(np.uint8),
                        bitorder="little").view(np.uint64)
    return optimize(BitsetContainer(words, card))


def _count_arrays(arrays: list[ArrayContainer], op: str,
                  t: int) -> Container | None:
    """All-array group fast path: occurrence counting via bincount, entirely
    on the host.  op "xor" keeps odd counts, "threshold" counts >= t."""
    vals = arrays[0].values if len(arrays) == 1 else \
        np.concatenate([a.values for a in arrays])
    cnt = np.bincount(vals, minlength=CHUNK)
    ind = (cnt & 1) if op == "xor" else (cnt >= t)
    return _from_indicator(ind.astype(np.uint8))


_SUB = np.int64(1) << 40        # andnot sweep: subtrahend coverage marker


def _sweep_run_groups(run_groups: list[tuple], op: str,
                      t: int) -> dict[int, Container]:
    """Run-only groups, ALL reduced in one vectorized boundary sweep at
    *interval* granularity (never expanding to 2^16 bits) -- the host twin
    of the slab's single dispatch.

    Each group is ``(key, containers)`` or ``(key, containers, weights)``;
    runs are lifted into a global coordinate space (``key << 16 | start``);
    chunks never overlap, so one sweep serves every group.  Each member's
    runs are disjoint, hence the (weighted) coverage count over an
    elementary interval equals the summed weight of members containing it:
    OR is count >= 1, AND count == K (per group), XOR odd count, threshold
    count >= t.  ANDNOT weights the minuend (the FIRST container of each
    group) 1 and every subtrahend ``_SUB``, keeping intervals with coverage
    exactly 1.  ``run_groups`` must be key-sorted."""
    out: dict[int, Container] = {}
    if not run_groups:
        return out
    starts_l, ends_l, delta_l = [], [], []
    for grp in run_groups:
        k, conts = grp[0], grp[1]
        wts = grp[2] if len(grp) > 2 else None
        if op == "andnot":
            wts = [1] + [_SUB] * (len(conts) - 1)
        r = conts[0].runs if len(conts) == 1 else \
            np.concatenate([c.runs for c in conts])
        if wts is not None:                 # weighted / andnot groups only
            delta_l.append(np.repeat(np.asarray(wts, np.int64),
                                     [c.runs.shape[0] for c in conts]))
        s = r[:, 0].astype(np.int64) + (np.int64(k) << 16)
        starts_l.append(s)
        ends_l.append(s + r[:, 1] + 1)                  # exclusive
    starts = np.concatenate(starts_l)
    ends = np.concatenate(ends_l)
    if delta_l:
        wdelta = np.concatenate(delta_l)
    else:
        wdelta = np.ones(starts.size, np.int64)
    pts = np.concatenate((starts, ends))
    delta = np.concatenate((wdelta, -wdelta))
    order = np.argsort(pts, kind="stable")
    upts, first = np.unique(pts[order], return_index=True)
    cov = np.cumsum(np.add.reduceat(delta[order], first))[:-1]  # / interval
    if op == "or":
        keep = cov >= 1
    elif op == "xor":
        keep = (cov & 1) == 1
    elif op == "and":
        gk = np.array([g[0] for g in run_groups], np.int64)
        gn = np.array([len(g[1]) for g in run_groups], np.int64)
        need = gn[np.searchsorted(gk, upts[:-1] >> 16)]
        keep = cov >= need                 # gap intervals have cov 0 < need
    elif op == "andnot":
        keep = cov == 1                    # minuend present, no subtrahend
    else:
        keep = cov >= t
    lo, hi = upts[:-1][keep], upts[1:][keep]
    if lo.size == 0:
        return out
    # merge contiguous intervals, but never across a chunk-key border
    same_key = (lo[1:] >> 16) == ((hi[:-1] - 1) >> 16)
    brk = np.concatenate(([True], (lo[1:] > hi[:-1]) | ~same_key))
    si = np.flatnonzero(brk)
    ei = np.concatenate((si[1:] - 1, [lo.size - 1]))
    rlo, rhi = lo[si], hi[ei]
    rkey = rlo >> 16
    runs_all = np.stack([rlo - (rkey << 16), rhi - 1 - rlo],
                        axis=1).astype(np.int32)
    uk, kfirst = np.unique(rkey, return_index=True)
    bounds = np.concatenate((kfirst, [rkey.size]))
    for i, k in enumerate(uk.tolist()):
        out[int(k)] = optimize(RunContainer(runs_all[bounds[i]:bounds[i + 1]]))
    return out


def _member_mask(vals: np.ndarray, c: Container) -> np.ndarray:
    """Boolean membership of the sorted uint16 ``vals`` in container ``c``
    (the AND / ANDNOT fast paths' vectorized membership probe)."""
    if isinstance(c, BitsetContainer):
        return C.bitset_test_many(c.words, vals)
    if isinstance(c, ArrayContainer):
        if c.values.size == 0:
            return np.zeros(vals.size, bool)
        idx = np.searchsorted(c.values, vals)
        idx[idx == c.values.size] = c.values.size - 1
        return c.values[idx] == vals
    starts = c.runs[:, 0]
    v = vals.astype(np.int32)
    i = np.searchsorted(starts, v, side="right") - 1
    i_c = np.maximum(i, 0)
    return (i >= 0) & (v <= starts[i_c] + c.runs[i_c, 1])


def _filter_values(vals: np.ndarray, c: Container) -> np.ndarray:
    """Keep the sorted uint16 ``vals`` that are members of ``c``."""
    if vals.size == 0:
        return vals
    return vals[_member_mask(vals, c)]


def _filter_values_out(vals: np.ndarray, c: Container) -> np.ndarray:
    """Keep the sorted uint16 ``vals`` that are NOT members of ``c``."""
    if vals.size == 0:
        return vals
    return vals[~_member_mask(vals, c)]


# ---------------------------------------------------------------------------
# the single kernel dispatch (and its sharded multi-device twin)
# ---------------------------------------------------------------------------

def _planes_for(totals: list[int], threshold: int) -> int:
    """Bit-sliced counter width for a threshold dispatch: wide enough for
    the largest attainable per-segment count AND for every bit of ``t``
    (the comparator reads t bit-by-bit; truncating high bits would compare
    against t mod 2^planes)."""
    return max(counter_planes(max(totals)), int(threshold).bit_length())


def _repack_segments(seg_keys, words, cards) -> dict[int, Container]:
    """(words, card) per segment -> optimal container kind per chunk."""
    out: dict[int, Container] = {}
    for key, w32, card in zip(seg_keys, np.asarray(words), np.asarray(cards)):
        card = int(card)
        if card == 0:
            continue
        w64 = np.ascontiguousarray(w32).view(np.uint64).copy()
        out[key] = optimize(C._result_from_bitset(w64, card))
    return out


def _dispatch(seg_keys: list, seg_rows: list[list[np.ndarray]],
              op: str, threshold, backend,
              seg_weights: list[list[int]] | None = None,
              mesh=None, arena=None) -> dict:
    """Stack per-segment rows into one slab, reduce in one kernel call,
    repack each segment's (words, card) into the optimal container kind.
    With a multi-device mesh, rows shard across the mesh axis instead
    (see ``_shard_reduce``).

    ``seg_keys`` are opaque hashable identities (plain chunk keys for one
    query; ``(query, chunk-key)`` tuples on the coalesced multi-query
    path).  ``threshold`` is an int, or -- for op "threshold" -- a
    per-segment sequence aligned with ``seg_keys`` (each coalesced query
    carries its own T into the same dispatch).  With an ``arena``
    (core/arena.py), row entries may be int slab-row ids: those gather
    from the resident device slab (no per-call staging) and only ndarray
    rows ride a staged block appended after it."""
    if not seg_keys:
        return {}
    tvec = None if isinstance(threshold, (int, np.integer)) else \
        [int(x) for x in threshold]

    def _t(i: int) -> int:
        return tvec[i] if tvec is not None else threshold

    # peel single-row segments: reducing one row is the identity (a lone
    # minuend for "andnot"; for "threshold" the row survives iff its own
    # weight reaches t), so a host popcount beats the pad/stack/transfer
    # of a kernel dispatch.  This is the small-K hot path: collapsed
    # array groups contribute exactly one indicator row per key.  Arena-
    # resident singletons (int row ids) are NOT peeled: their words are
    # already on device, so the device gather beats pulling them back to
    # the host just to popcount.
    peeled: dict = {}
    keep = [i for i, rows in enumerate(seg_rows)
            if len(rows) > 1 or not isinstance(rows[0], np.ndarray)]
    if len(keep) != len(seg_keys):
        for i, (key, rows) in enumerate(zip(seg_keys, seg_rows)):
            if len(rows) != 1 or not isinstance(rows[0], np.ndarray):
                continue
            if op == "threshold" and \
                    (seg_weights[i][0] if seg_weights else 1) < _t(i):
                continue
            card = int(np.bitwise_count(rows[0]).sum())
            if card:
                peeled[key] = optimize(C._result_from_bitset(rows[0], card))
        seg_keys = [seg_keys[i] for i in keep]
        seg_rows = [seg_rows[i] for i in keep]
        if seg_weights is not None:
            seg_weights = [seg_weights[i] for i in keep]
        if tvec is not None:
            tvec = [tvec[i] for i in keep]
        if not seg_keys:
            return peeled
    mesh = _resolve_mesh(mesh)
    if mesh is not None and _mesh_size(mesh) > 1:
        lens = [len(r) for r in seg_rows]
        tmax = max(tvec) if tvec is not None else threshold
        planes = None
        if op == "threshold" and seg_weights is not None:
            planes = _planes_for([sum(w) for w in seg_weights], tmax)
        t_arg = threshold if tvec is None else np.asarray(tvec, np.int32)
        if arena is not None:
            # resident rows gather from each shard's LOCAL slab inside
            # the jit (ShardSlabs.assembled positions) -- ids over the
            # bridge, zero container rows over PCIe
            words, cards = _shard_reduce_arena(
                arena, seg_rows, lens, seg_weights, op, t_arg,
                backend, mesh, planes=planes, tmax=tmax)
        else:
            slab64 = np.stack([w for rows in seg_rows for w in rows])
            slab32 = slab64.view(np.uint32).reshape(slab64.shape[0],
                                                    WORDS)
            words, cards = _shard_reduce(
                jnp.asarray(slab32), lens, seg_weights, op, t_arg,
                backend, mesh, planes=planes, tmax=tmax)
        peeled.update(_repack_segments(seg_keys, words, cards))
        return peeled
    # bucket segments by padded depth: the reduce materializes an
    # (S, jmax, WORDS) gather, so one deep segment would inflate every
    # shallow coalesced query's compute to the global jmax.  Per-depth
    # kernel calls (<= log2 of the deepest segment, each at its own
    # power-of-two depth) keep the multi-query amortization without the
    # padding tax.  Small batches stay in ONE global-depth call: below
    # ~64 segments the extra dispatches cost more than the padding they
    # avoid (measured in the query_throughput bench at 64 concurrent).
    by_depth: dict[int, list[int]] = {}
    if len(seg_rows) >= 64:
        for i, rows in enumerate(seg_rows):
            by_depth.setdefault(_pow2(len(rows)), []).append(i)
    else:
        by_depth[_pow2(max(len(r) for r in seg_rows))] = \
            list(range(len(seg_rows)))
    for jmax, idxs in sorted(by_depth.items()):
        rows_g = [seg_rows[i] for i in idxs]
        lens = [len(r) for r in rows_g]
        n = sum(lens)
        wts_g = None if seg_weights is None else \
            [seg_weights[i] for i in idxs]
        tv_g = None if tvec is None else [tvec[i] for i in idxs]
        planes = None
        wbits = 1
        if op == "threshold" and wts_g is not None:
            planes = _planes_for([sum(w) for w in wts_g],
                                 max(tv_g) if tv_g is not None
                                 else threshold)
            wbits = max(int(w).bit_length() for ws in wts_g for w in ws)
        t_arg = threshold if tv_g is None else np.asarray(tv_g, np.int32)
        starts = np.zeros(len(lens) + 1, np.int32)
        starts[1:] = np.cumsum(lens)
        weights = None
        if wts_g is not None:
            weights = np.concatenate(
                [np.asarray(w, np.int32) for w in wts_g])
        # pad rows / segments to powers of two so jit and kernel
        # specializations are reused across calls
        n_pad = _pow2(n)
        if weights is not None and n_pad != n:
            weights = np.concatenate(
                [weights, np.ones(n_pad - n, np.int32)])
        s = len(lens)
        s_pad = _pow2(s)
        if s_pad != s:
            starts = np.concatenate(
                [starts, np.full(s_pad - s, starts[-1], np.int32)])
            if tv_g is not None:
                # padded segments are empty (zero rows): their T is inert
                t_arg = np.concatenate(
                    [t_arg, np.ones(s_pad - s, np.int32)])
        t_kw = t_arg if tv_g is None else jnp.asarray(t_arg)
        w_kw = None if weights is None else jnp.asarray(weights)
        if arena is None:
            slab64 = np.stack([w for rows in rows_g for w in rows])
            slab32 = slab64.view(np.uint32).reshape(n, WORDS)
            if n_pad != n:
                slab32 = np.concatenate(
                    [slab32, np.zeros((n_pad - n, WORDS), np.uint32)])
            words, cards = kops.segment_reduce(
                jnp.asarray(slab32), jnp.asarray(starts), op, jmax=jmax,
                threshold=t_kw, weights=w_kw,
                planes=planes, wbits=wbits, backend=backend)
        else:
            pos, sidx, staged = _stage_arena_rows(arena, rows_g, n_pad)
            if staged is None:              # warm: pure resident gather
                words, cards = kops.segment_reduce_rows(
                    arena.device_slab(), pos, jnp.asarray(starts), op,
                    jmax=jmax, threshold=t_kw, weights=w_kw,
                    planes=planes, wbits=wbits, backend=backend)
            else:
                words, cards = kops.segment_reduce_rows_dual(
                    arena.device_slab(), staged, pos, sidx,
                    jnp.asarray(starts), op, jmax=jmax, threshold=t_kw,
                    weights=w_kw, planes=planes, wbits=wbits,
                    backend=backend)
        peeled.update(_repack_segments(
            [seg_keys[i] for i in idxs], words[:s], cards[:s]))
    return peeled


def _stage_arena_rows(arena, rows_g: list[list], n_pad: int):
    """Turn one depth bucket's row refs into dual-source gather inputs
    ``(pos, sidx, staged)``: resident ids index the arena's device slab
    by position, cold ndarray rows stage into a small pow2-padded host
    block (row 0 reserved zero) indexed by ``sidx``.  Exactly one side of
    each slot is a real row; the other points at a zero row, so
    ``table[pos] | staged[sidx]`` is exact slot selection
    (``kernels.ops.segment_reduce_rows_dual``) and the resident slab is
    never copied per call.  Padding slots point both indices at the zero
    rows (the kernel masks padding by segment length anyway).  Warm
    queries return ``staged=None``: the only host->device traffic is the
    position vector itself."""
    pos: list[int] = []
    sidx: list[int] = []
    host: list[np.ndarray] = []
    for rows in rows_g:
        for r in rows:
            if isinstance(r, np.ndarray):
                pos.append(0)               # arena row 0: reserved zero
                sidx.append(1 + len(host))
                host.append(r)
            else:
                pos.append(int(r))
                sidx.append(0)              # staged row 0: reserved zero
    pos.extend([0] * (n_pad - len(pos)))
    sidx.extend([0] * (n_pad - len(sidx)))
    staged = None
    if host:
        h_pad = _pow2(1 + len(host))
        hb = np.zeros((h_pad, 1024), np.uint64)
        hb[1: 1 + len(host)] = np.stack(host)
        staged = jnp.asarray(hb.view(np.uint32).reshape(h_pad, WORDS))
        arena.stats.host_rows_staged += len(host)
    arena.stats.device_gathers += 1
    return (jnp.asarray(np.asarray(pos, np.int32)),
            jnp.asarray(np.asarray(sidx, np.int32)), staged)


def _shard_plan(seg_sizes: list[int], d: int, op: str,
                seg_weights: list[list[int]] | None):
    """Round-robin each segment's rows across ``d`` shards.

    Returns per-device (row ids into the segment-major slab, per-row
    weights, segment starts); every device sees the SAME segment structure
    (some local segments may be empty -> the kernel's identity).  For
    "andnot" the minuend (each segment's row 0) is REPLICATED on every
    shard so the local partials ``a & ~local_or`` fold with AND."""
    ids = [[] for _ in range(d)]
    wts = [[] for _ in range(d)]
    starts = [[0] for _ in range(d)]
    base = 0
    for si, nrow in enumerate(seg_sizes):
        w = None if seg_weights is None else seg_weights[si]
        for dev in range(d):
            if op == "andnot":
                mine = [base] + list(range(base + 1 + dev, base + nrow, d))
                mw = [1] * len(mine)
            else:
                mine = list(range(base + dev, base + nrow, d))
                mw = [1] * len(mine) if w is None else \
                    [w[i - base] for i in mine]
            ids[dev].extend(mine)
            wts[dev].extend(mw)
            starts[dev].append(len(ids[dev]))
        base += nrow
    return ids, wts, starts


def _shard_reduce(slab: jax.Array, seg_sizes: list[int],
                  seg_weights: list[list[int]] | None, op: str,
                  threshold, backend, mesh, planes: int | None = None,
                  tmax: int | None = None):
    """Sharded segmented reduce: split rows across the mesh axis, reduce
    per shard with the SAME segment kernel, all-reduce the partials.

    slab: (N, WORDS) uint32 rows, segment-major (segment s owns
    ``sum(seg_sizes[:s]) : sum(seg_sizes[:s+1])``).  Returns
    (words (S, WORDS), cards (S,)) identical to the single-device plan:
    OR/XOR partials fold with the op, ANDNOT partials (minuend replicated)
    fold with AND, threshold all-gathers the bit-sliced occurrence
    counters and adds them before one comparator pass, and AND exchanges a
    per-shard *occupancy mask* alongside the partials: a shard holding no
    rows of a segment contributes the all-ones identity (masked in after
    the kernel, whose empty-segment convention is all-zeros), and a
    segment no shard occupies resolves to empty -- the shard-safe
    empty-shard identity the single-device plan never needed.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    d = _mesh_size(mesh)
    axis = _mesh_axis(mesh)
    s = len(seg_sizes)
    ids, wts, starts = _shard_plan(seg_sizes, d, op, seg_weights)
    n_pad = _pow2(max(max(len(i) for i in ids), 1))
    s_pad = _pow2(s)
    ids_all = np.zeros((d, n_pad), np.int32)
    w_all = np.ones((d, n_pad), np.int32)
    starts_all = np.zeros((d, s_pad + 1), np.int32)
    jmax = 1
    for dev in range(d):
        k = len(ids[dev])
        ids_all[dev, :k] = ids[dev]
        w_all[dev, :k] = wts[dev]
        st = np.asarray(starts[dev], np.int32)
        starts_all[dev, :s + 1] = st
        starts_all[dev, s + 1:] = st[-1]
        jmax = max(jmax, int(np.diff(st).max(initial=1)))
    jmax = _pow2(jmax)
    if op == "threshold" and planes is None:
        planes = _planes_for(
            seg_sizes if seg_weights is None else
            [sum(w) for w in seg_weights],
            tmax if tmax is not None else threshold)
    if op == "threshold" and not isinstance(threshold, (int, np.integer)) \
            and s_pad != s:
        # per-segment T vector must match the padded segment count; the
        # padded segments are empty, so T=1 keeps their zero counters inert
        threshold = np.concatenate([np.asarray(threshold, np.int32),
                                    np.ones(s_pad - s, np.int32)])
    slab_all = jnp.take(slab.astype(jnp.uint32),
                        jnp.asarray(ids_all.reshape(-1)),
                        axis=0).reshape(d, n_pad, WORDS)

    def body(slab_d, starts_d, w_d):
        slab_l, starts_l, w_l = slab_d[0], starts_d[0], w_d[0]
        if op == "threshold":
            local = kops.segment_counters(
                slab_l, starts_l, jmax=jmax, planes=planes, weights=w_l,
                backend=backend)
            allp = jax.lax.all_gather(local, axis)      # (D, S, L, WORDS)
            tot = allp[0]
            for i in range(1, d):
                tot = kref.bitsliced_add(tot, allp[i])
            words = kref.counters_ge(tot, jnp.asarray(threshold, jnp.int32))
        elif op == "and":
            pw, _ = kops.segment_reduce(slab_l, starts_l, op, jmax=jmax,
                                        backend=backend)
            occ = (starts_l[1:] - starts_l[:-1]) > 0    # local occupancy
            pw = jnp.where(occ[:, None], pw, jnp.uint32(0xFFFFFFFF))
            allw = jax.lax.all_gather(pw, axis)         # (D, S, WORDS)
            allo = jax.lax.all_gather(occ, axis)        # (D, S)
            words, any_occ = allw[0], allo[0]
            for i in range(1, d):
                words = words & allw[i]
                any_occ = any_occ | allo[i]
            words = jnp.where(any_occ[:, None], words, jnp.uint32(0))
        else:
            pw, _ = kops.segment_reduce(slab_l, starts_l, op, jmax=jmax,
                                        backend=backend)
            allw = jax.lax.all_gather(pw, axis)         # (D, S, WORDS)
            comb = {"or": jnp.bitwise_or, "xor": jnp.bitwise_xor,
                    "andnot": jnp.bitwise_and}[op]
            words = allw[0]
            for i in range(1, d):
                words = comb(words, allw[i])
        return words, kref.popcount_words(words)

    spec = PartitionSpec(axis)
    with mesh:
        words, cards = shard_map(
            body, mesh=mesh, in_specs=(spec, spec, spec),
            out_specs=(PartitionSpec(), PartitionSpec()),
            check_rep=False)(slab_all, jnp.asarray(starts_all),
                             jnp.asarray(w_all))
    return words[:s], cards[:s]


_SHARD_JIT: dict = {}       # (mesh, op, backend, d, jmax, planes) -> fn


def _sharded_rows_fn(mesh, axis: str, op: str, backend, d: int,
                     jmax: int, planes: int | None):
    """One jit'd sharded dispatch per (mesh, op, backend, depth) class --
    the boolean twin of ``pairwise.SimilarityEngine._sharded_fn``: gather
    every shard's rows from the assembled per-shard slab (resident
    positions) OR'd with a small replicated staged block (cold rows),
    reduce per shard with the segment kernel, and fold the partials with
    the exact ``_shard_reduce`` exchange rules.  The threshold rides as a
    traced argument (scalar or per-segment vector), so T-sweeps and
    coalesced batches reuse one compilation."""
    key = (mesh, op, backend, d, jmax, planes)
    fn = _SHARD_JIT.get(key)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(slab_d, starts_d, w_d, t):
        slab_l, starts_l, w_l = slab_d[0], starts_d[0], w_d[0]
        if op == "threshold":
            local = kops.segment_counters(
                slab_l, starts_l, jmax=jmax, planes=planes, weights=w_l,
                backend=backend)
            allp = jax.lax.all_gather(local, axis)      # (D, S, L, WORDS)
            tot = allp[0]
            for i in range(1, d):
                tot = kref.bitsliced_add(tot, allp[i])
            words = jnp.asarray(kref.counters_ge(tot, t))
        elif op == "and":
            pw, _ = kops.segment_reduce(slab_l, starts_l, op, jmax=jmax,
                                        backend=backend)
            occ = (starts_l[1:] - starts_l[:-1]) > 0    # local occupancy
            pw = jnp.where(occ[:, None], pw, jnp.uint32(0xFFFFFFFF))
            allw = jax.lax.all_gather(pw, axis)         # (D, S, WORDS)
            allo = jax.lax.all_gather(occ, axis)        # (D, S)
            words, any_occ = allw[0], allo[0]
            for i in range(1, d):
                words = words & allw[i]
                any_occ = any_occ | allo[i]
            words = jnp.where(any_occ[:, None], words, jnp.uint32(0))
        else:
            pw, _ = kops.segment_reduce(slab_l, starts_l, op, jmax=jmax,
                                        backend=backend)
            allw = jax.lax.all_gather(pw, axis)         # (D, S, WORDS)
            comb = {"or": jnp.bitwise_or, "xor": jnp.bitwise_xor,
                    "andnot": jnp.bitwise_and}[op]
            words = allw[0]
            for i in range(1, d):
                words = comb(words, allw[i])
        return words, kref.popcount_words(words)

    sp = P(axis)
    sm = shard_map(body, mesh=mesh, in_specs=(sp, sp, sp, P()),
                   out_specs=(P(), P()), check_rep=False)

    def run(slab, staged, pos, sidx, starts_all, w_all, t):
        dd, n_pad = pos.shape
        rows = kref.gather_rows_dual(
            slab, staged, pos.reshape(-1), sidx.reshape(-1)
        ).reshape(dd, n_pad, WORDS)
        return sm(rows, starts_all, w_all, t)

    fn = jax.jit(run)
    _SHARD_JIT[key] = fn
    return fn


def _shard_reduce_arena(arena, seg_rows: list[list], seg_sizes: list[int],
                        seg_weights: list[list[int]] | None, op: str,
                        threshold, backend, mesh,
                        planes: int | None = None,
                        tmax: int | None = None):
    """Sharded segmented reduce over arena row refs, end-to-end through
    ``ShardSlabs``: resident rows gather from each shard's LOCAL slab via
    ``ShardSlabs.assembled()`` global positions INSIDE one jit (ids over
    the bridge, zero container rows over PCIe -- mirroring
    ``pairwise._topk_sharded``); only cold ndarray rows ride a small
    replicated staged block (row 0 reserved zero, so ``assembled[pos] |
    staged[sidx]`` is exact slot selection).  Row routing
    (``_shard_plan``) and partial folds are identical to
    ``_shard_reduce``, so results are bit-identical to the single-device
    plan by construction."""
    shards = arena.shard_slabs(mesh)
    d, axis = shards.size, shards.axis
    s = len(seg_sizes)
    ids, wts, starts = _shard_plan(seg_sizes, d, op, seg_weights)
    flat = [r for rows in seg_rows for r in rows]
    pos_flat = np.zeros(len(flat), np.int64)
    sidx_flat = np.zeros(len(flat), np.int32)
    host: list[np.ndarray] = []
    res_slots: list[int] = []
    res_ids: list[int] = []
    for i, r in enumerate(flat):
        if isinstance(r, np.ndarray):
            sidx_flat[i] = 1 + len(host)    # staged row 0: reserved zero
            host.append(r)
        else:                               # pos 0: global row 0 is zero
            res_slots.append(i)
            res_ids.append(int(r))
    if res_slots:
        pos_flat[np.asarray(res_slots, np.int64)] = \
            shards.positions(np.asarray(res_ids, np.int64))
    h_pad = _pow2(1 + len(host))
    hb = np.zeros((h_pad, 1024), np.uint64)
    if host:
        hb[1: 1 + len(host)] = np.stack(host)
        arena.stats.host_rows_staged += len(host)
    n_pad = _pow2(max(max(len(i) for i in ids), 1))
    s_pad = _pow2(s)
    pos_all = np.zeros((d, n_pad), np.int32)
    sidx_all = np.zeros((d, n_pad), np.int32)
    w_all = np.ones((d, n_pad), np.int32)
    starts_all = np.zeros((d, s_pad + 1), np.int32)
    jmax = 1
    for dev in range(d):
        k = len(ids[dev])
        sel = np.asarray(ids[dev], np.int64)
        pos_all[dev, :k] = pos_flat[sel]
        sidx_all[dev, :k] = sidx_flat[sel]
        w_all[dev, :k] = wts[dev]
        st = np.asarray(starts[dev], np.int32)
        starts_all[dev, :s + 1] = st
        starts_all[dev, s + 1:] = st[-1]
        jmax = max(jmax, int(np.diff(st).max(initial=1)))
    jmax = _pow2(jmax)
    if op == "threshold" and planes is None:
        planes = _planes_for(
            seg_sizes if seg_weights is None else
            [sum(w) for w in seg_weights],
            tmax if tmax is not None else threshold)
    if isinstance(threshold, (int, np.integer)):
        t_dev = np.int32(threshold)
    else:
        t_dev = np.asarray(threshold, np.int32)
        if s_pad != s:      # padded segments are empty: T=1 stays inert
            t_dev = np.concatenate(
                [t_dev, np.ones(s_pad - s, np.int32)])
    for st_ in shards.stats:
        st_.device_gathers += 1
    fn = _sharded_rows_fn(mesh, axis, op, backend, d, jmax, planes)
    staged = jnp.asarray(hb.view(np.uint32).reshape(h_pad, WORDS))
    with mesh:
        words, cards = fn(shards.assembled(), staged,
                          jnp.asarray(pos_all), jnp.asarray(sidx_all),
                          jnp.asarray(starts_all), jnp.asarray(w_all),
                          jnp.asarray(t_dev))
    return words[:s], cards[:s]


# ---------------------------------------------------------------------------
# query plans: planning separated from dispatch so N queries can coalesce
# into ONE dispatch per op class (the query server's engine tick)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WidePlan:
    """One wide aggregate, planned but not yet dispatched.

    ``merged`` holds every chunk the host fast paths already resolved
    (zero-copy pass-throughs, run sweeps, bincount groups); ``seg_keys`` /
    ``seg_rows`` describe the dense remainder awaiting the slab kernel.
    ``execute_plans`` coalesces many plans into one ``segment_reduce``
    dispatch per op class -- a query id is just another segment
    coordinate -- and ``execute_plan_host`` is the numpy-only twin the
    query server degrades to when a kernel batch fails (bit-identical by
    construction: same rows, same repack).

    With an ``arena`` (core/arena.py), ``seg_rows`` entries may be int
    device-slab row ids instead of promoted uint64 rows: those never
    cross PCIe at dispatch.  ``execute_plans`` only coalesces plans that
    share the same arena (or its absence)."""
    op: str                               # dispatch class (OPS member)
    threshold: int                        # per-plan T (0 off-threshold)
    merged: dict[int, Container]          # host-resolved chunks
    seg_keys: list[int]                   # chunk key per pending segment
    seg_rows: list[list]                  # uint64 row | arena row id each
    seg_weights: list[list[int]] | None = None
    arena: object | None = None           # BitmapArena owning the id rows

    def slab_bytes(self) -> int:
        """Bytes this plan contributes to a coalesced slab (the admission
        queue's max-bytes accounting)."""
        return sum(len(r) for r in self.seg_rows) * 8192


def plan_wide(op: str, bitmaps, t: int = 0, weights=None, *,
              backend: str | None = None, arena=None) -> WidePlan:
    """Plan one wide aggregate without dispatching it.

    ``op`` is "or" | "and" | "xor" | "andnot" | "threshold"; for "andnot"
    the FIRST bitmap is the minuend and the rest are subtrahends; for
    "threshold", ``t`` / ``weights`` follow ``threshold_many`` (t == 1
    degenerates to an "or" plan and coalesces with the or class).
    Validation errors (bad op, t < 1, bad weights) raise here, at
    admission time -- never inside a dispatch batch.

    ``arena``: a ``core.arena.BitmapArena``; containers already resident
    in it plan as device-slab row ids (no promotion, no staging at
    dispatch).  Containers the arena does not know stage per-call exactly
    as without one -- results are bit-identical either way, residency is
    purely a transfer optimization (adopt bitmaps first to get warm
    plans)."""
    bitmaps = list(bitmaps)
    if op == "or":
        return _plan_or(bitmaps, backend, arena)
    if op == "xor":
        return _plan_xor(bitmaps, backend, arena)
    if op == "and":
        return _plan_and(bitmaps, backend, arena)
    if op == "andnot":
        if not bitmaps:
            raise ValueError("andnot needs at least the minuend")
        return _plan_andnot(bitmaps[0], bitmaps[1:], backend, arena)
    if op == "threshold":
        return _plan_threshold(bitmaps, t, weights, backend, arena)
    raise ValueError(f"unknown wide op {op!r}")


def _finish(plan: WidePlan, backend, mesh):
    merged = dict(plan.merged)
    merged.update(_dispatch(plan.seg_keys, plan.seg_rows, plan.op,
                            plan.threshold, backend,
                            seg_weights=plan.seg_weights, mesh=mesh,
                            arena=plan.arena))
    return _build(merged)


def execute_plans(plans, *, backend: str | None = None,
                  mesh=None) -> list:
    """Execute many ``WidePlan``s with ONE slab dispatch per op class.

    Every plan's pending segments join one slab per op (threshold plans
    ride together via per-segment T -- see ``kernels.ops.segment_reduce``),
    so a batch of N queries costs O(op classes) dispatches, not O(N).
    Returns one RoaringBitmap per plan, bit-identical to finishing each
    plan alone: segment results are independent by construction, and the
    repack path is shared."""
    plans = list(plans)
    results = [dict(p.merged) for p in plans]
    by_op: dict[tuple, list[int]] = {}       # (op, arena identity) class
    for i, p in enumerate(plans):
        if p.seg_keys:
            by_op.setdefault((p.op, id(p.arena)), []).append(i)
    for (op, _), idxs in by_op.items():
        keys: list = []
        rows: list[list] = []
        wts: list[list[int]] = []
        ts: list[int] = []
        any_w = any(plans[i].seg_weights is not None for i in idxs)
        for i in idxs:
            p = plans[i]
            keys.extend((i, k) for k in p.seg_keys)
            rows.extend(p.seg_rows)
            ts.extend([p.threshold] * len(p.seg_keys))
            if any_w:
                wts.extend(p.seg_weights if p.seg_weights is not None
                           else [[1] * len(r) for r in p.seg_rows])
        out = _dispatch(keys, rows, op,
                        ts if op == "threshold" else 0, backend,
                        seg_weights=wts if any_w else None, mesh=mesh,
                        arena=plans[idxs[0]].arena)
        for (i, k), cont in out.items():
            results[i][k] = cont
    return [_build(r) for r in results]


def execute_plan_host(plan: WidePlan):
    """Numpy-only execution of one plan: the query server's graceful-
    degradation path when a kernel batch keeps failing.

    Reduces each pending segment's uint64 rows with exact host bit math
    (the same rows the slab dispatch would consume) and repacks through
    the same ``optimize(C._result_from_bitset(...))`` path, so the result
    is bit-identical to the kernel plan -- only slower.  Touches no jax
    API at all: arena row ids resolve through the arena's authoritative
    HOST mirror, never the device slab."""
    merged = dict(plan.merged)
    for i, (key, seg) in enumerate(zip(plan.seg_keys, plan.seg_rows)):
        if plan.arena is not None:
            seg = [r if isinstance(r, np.ndarray)
                   else plan.arena.host_row(r) for r in seg]
        stack = np.stack(seg)                       # (R, 1024) uint64
        if plan.op == "or":
            w = np.bitwise_or.reduce(stack, axis=0)
        elif plan.op == "and":
            w = np.bitwise_and.reduce(stack, axis=0)
        elif plan.op == "xor":
            w = np.bitwise_xor.reduce(stack, axis=0)
        elif plan.op == "andnot":
            w = stack[0]
            if stack.shape[0] > 1:
                w = w & ~np.bitwise_or.reduce(stack[1:], axis=0)
        elif plan.op == "threshold":
            bits = np.unpackbits(stack.view(np.uint8), axis=1,
                                 bitorder="little").astype(np.int64)
            if plan.seg_weights is not None:
                bits *= np.asarray(plan.seg_weights[i],
                                   np.int64)[:, None]
            keepbits = bits.sum(axis=0) >= plan.threshold
            w = np.packbits(keepbits, bitorder="little").view(np.uint64)
        else:
            raise ValueError(plan.op)
        card = int(np.bitwise_count(w).sum())
        if card:
            merged[key] = optimize(C._result_from_bitset(w.copy(), card))
    return _build(merged)


# ---------------------------------------------------------------------------
# public wide aggregates
# ---------------------------------------------------------------------------

def or_many(bitmaps, *, backend: str | None = None, mesh=None,
            arena=None):
    """Union of K bitmaps in one kernel dispatch (paper section 5.8);
    with a multi-device ``mesh``, one sharded dispatch per shard.
    ``arena``: resident containers dispatch from the device slab without
    per-call staging (see ``plan_wide``)."""
    return _finish(plan_wide("or", bitmaps, backend=backend,
                             arena=arena), backend, mesh)


def _plan_or(bitmaps, backend, arena=None) -> WidePlan:
    if len(bitmaps) <= 1:
        return WidePlan("or", 0,
                        dict(zip(bitmaps[0].keys, bitmaps[0].containers))
                        if bitmaps else {}, [], [])
    prefer_kernel = _prefer_kernel(backend)
    groups = _group(bitmaps)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(groups):
        g = groups[k]
        if len(g) == 1:
            merged[k] = g[0]                       # zero-copy pass-through
            continue
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval-level union
            continue
        if any(_is_full(c) for c in g):
            merged[k] = _full_run()                # full-chunk short-circuit
            continue
        arrays = [c for c in g if isinstance(c, ArrayContainer)]
        others = [c for c in g if not isinstance(c, ArrayContainer)]
        if not others:
            if sum(a.card for a in arrays) <= ARRAY_MAX:
                merged[k] = ArrayContainer(
                    np.unique(np.concatenate([a.values for a in arrays])))
                continue
            if not prefer_kernel:
                c = _from_indicator(_array_indicator(arrays, "or"))
                if c is not None:
                    merged[k] = c
                continue
        rows = _array_rows(arrays, "or", arena)
        rows.extend(_row_ref(c, arena) for c in others)
        seg_keys.append(k)
        seg_rows.append(rows)
    merged.update(_sweep_run_groups(run_groups, "or", 0))
    return WidePlan("or", 0, merged, seg_keys, seg_rows, arena=arena)


def xor_many(bitmaps, *, backend: str | None = None, mesh=None,
             arena=None):
    """Wide symmetric difference: a value survives iff it occurs in an odd
    number of inputs (K-ary XOR).  ``arena``: resident containers dispatch
    from the device slab without per-call staging (see ``plan_wide``)."""
    return _finish(plan_wide("xor", bitmaps, backend=backend,
                             arena=arena), backend, mesh)


def _plan_xor(bitmaps, backend, arena=None) -> WidePlan:
    if len(bitmaps) <= 1:
        return WidePlan("xor", 0,
                        dict(zip(bitmaps[0].keys, bitmaps[0].containers))
                        if bitmaps else {}, [], [])
    groups = _group(bitmaps)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(groups):
        g = groups[k]
        if len(g) == 1:
            merged[k] = g[0]
            continue
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval-level parity
            continue
        arrays = [c for c in g if isinstance(c, ArrayContainer)]
        others = [c for c in g if not isinstance(c, ArrayContainer)]
        if not others:
            c = _count_arrays(arrays, "xor", 0)    # host occurrence parity
            if c is not None:
                merged[k] = c
            continue
        rows = _array_rows(arrays, "xor", arena)
        rows.extend(_row_ref(c, arena) for c in others)
        seg_keys.append(k)
        seg_rows.append(rows)
    merged.update(_sweep_run_groups(run_groups, "xor", 0))
    return WidePlan("xor", 0, merged, seg_keys, seg_rows, arena=arena)


def and_many(bitmaps, *, backend: str | None = None, mesh=None,
             arena=None):
    """Intersection of K bitmaps: cardinality-ascending key pruning with
    empty-key early exit, array-anchored host filtering for sparse groups,
    one kernel dispatch for the dense remainder.

    With a multi-device ``mesh``, dense segments shard across the mesh
    axis like the other aggregates: each shard ANDs its local rows and
    exchanges an occupancy mask with its partial, so shards holding no
    rows of a segment contribute the all-ones identity instead of the
    kernel's empty-segment zeros (see ``_shard_reduce``).  ``arena``:
    resident containers dispatch from the device slab without per-call
    staging (see ``plan_wide``)."""
    return _finish(plan_wide("and", bitmaps, backend=backend,
                             arena=arena), backend, mesh)


def _plan_and(bitmaps, backend, arena=None) -> WidePlan:
    if len(bitmaps) <= 1:
        return WidePlan("and", 0,
                        dict(zip(bitmaps[0].keys, bitmaps[0].containers))
                        if bitmaps else {}, [], [])
    order = sorted(bitmaps, key=lambda b: b.cardinality)
    common = set(order[0].keys)
    for bm in order[1:]:
        common &= set(bm.keys)
        if not common:
            return WidePlan("and", 0, {}, [], [])  # empty-key early exit
    lookup = [dict(zip(bm.keys, bm.containers)) for bm in bitmaps]
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(common):
        g = sorted((lk[k] for lk in lookup), key=lambda c: c.card)
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval intersection
            continue
        smallest = g[0]
        if isinstance(smallest, RunContainer) and smallest.card <= ARRAY_MAX:
            smallest = ArrayContainer(smallest.to_array_values())
        if isinstance(smallest, ArrayContainer):
            # array-anchored: the result is a subset of the smallest member,
            # so vectorized membership probes beat promoting the group
            vals = smallest.values
            for c in g[1:]:
                vals = _filter_values(vals, c)
                if vals.size == 0:
                    break
            if vals.size:
                merged[k] = ArrayContainer(vals)
            continue
        seg_keys.append(k)
        seg_rows.append([_row_ref(c, arena) for c in g])
    merged.update(_sweep_run_groups(run_groups, "and", 0))
    return WidePlan("and", 0, merged, seg_keys, seg_rows, arena=arena)


def andnot_many(minuend, subtrahends, *, backend: str | None = None,
                mesh=None, arena=None):
    """Difference chain ``a - (b1 | b2 | ...)`` as ONE plan: subtrahends
    OR-reduce segment-wise and a fused ANDNOT finalizes in the kernel
    ("Compressed bitmap indexes: beyond unions and intersections",
    Kaser & Lemire -- never materializes the intermediate union).

    Keys absent from every subtrahend pass through zero-copy; keys whose
    subtrahend group contains a full chunk drop immediately; array-probe
    and interval-sweep fast paths mirror the other aggregates.
    ``arena``: resident containers dispatch from the device slab without
    per-call staging (see ``plan_wide``)."""
    return _finish(plan_wide("andnot", [minuend, *subtrahends],
                             backend=backend, arena=arena), backend,
                   mesh)


def _plan_andnot(minuend, subtrahends, backend, arena=None) -> WidePlan:
    if not subtrahends:
        return WidePlan("andnot", 0,
                        dict(zip(minuend.keys, minuend.containers)),
                        [], [])
    sub_groups = _group(subtrahends)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[Container]]] = []
    for k, c in zip(minuend.keys, minuend.containers):
        g = sub_groups.get(k)
        if g is None:
            merged[k] = c                          # zero-copy pass-through
            continue
        if any(_is_full(x) for x in g):
            continue                               # chunk fully subtracted
        if isinstance(c, RunContainer) and \
                all(isinstance(x, RunContainer) for x in g):
            run_groups.append((k, [c] + g))        # interval-level diff
            continue
        cc = c
        if isinstance(cc, RunContainer) and cc.card <= ARRAY_MAX:
            cc = ArrayContainer(cc.to_array_values())
        if isinstance(cc, ArrayContainer):
            # array-anchored: the result is a subset of the minuend, so
            # vectorized NOT-member probes beat promoting the group
            vals = cc.values
            for x in sorted(g, key=lambda q: -q.card):
                vals = _filter_values_out(vals, x)
                if vals.size == 0:
                    break
            if vals.size:
                merged[k] = ArrayContainer(vals)
            continue
        arrays = [x for x in g if isinstance(x, ArrayContainer)]
        others = [x for x in g if not isinstance(x, ArrayContainer)]
        rows = [_row_ref(c, arena)]                # minuend is row 0
        rows.extend(_array_rows(arrays, "or", arena))
        rows.extend(_row_ref(x, arena) for x in others)
        seg_keys.append(k)
        seg_rows.append(rows)
    merged.update(_sweep_run_groups(run_groups, "andnot", 0))
    return WidePlan("andnot", 0, merged, seg_keys, seg_rows, arena=arena)


def _check_weights(weights, k: int) -> list[int] | None:
    """Validate per-bitmap threshold weights; None when they degenerate to
    the unweighted path (all 1).  The total weight must fit int32: the
    kernel's counters and the jnp oracle accumulate in int32 (the host
    fast paths are int64, and results must not depend on container kind).
    """
    if weights is None:
        return None
    w = [int(x) for x in weights]
    if len(w) != k:
        raise ValueError(f"need one weight per bitmap: {len(w)} != {k}")
    if any(x < 1 for x in w):
        raise ValueError(f"weights must be >= 1, got {w}")
    if sum(w) >= 1 << 31:
        raise ValueError(
            f"total weight {sum(w)} overflows the int32 counter domain")
    return None if all(x == 1 for x in w) else w


def threshold_many(bitmaps, t: int, *, weights=None,
                   backend: str | None = None, mesh=None, arena=None):
    """T-occurrence query: values whose (weighted) occurrence count over
    the K inputs reaches ``t`` (Kaser & Lemire's threshold function; T=1 is
    union, unweighted T=K intersection).

    ``weights`` are per-bitmap positive integers added into the same
    bit-sliced counter circuit (weight 1 everywhere degenerates to the
    unweighted plan, bit for bit).  Keys whose total attainable weight
    stays below ``t`` are pruned on the host.  ``arena``: resident
    containers dispatch from the device slab without per-call staging
    (see ``plan_wide``)."""
    return _finish(plan_wide("threshold", bitmaps, t, weights,
                             backend=backend, arena=arena), backend,
                   mesh)


def _plan_threshold(bitmaps, t, weights, backend, arena=None) -> WidePlan:
    t = int(t)
    if t < 1:
        raise ValueError(f"threshold must be >= 1, got {t}")
    weights = _check_weights(weights, len(bitmaps))
    if not bitmaps or (weights is None and t > len(bitmaps)) or \
            (weights is not None and t > sum(weights)):
        return WidePlan("threshold", t, {}, [], [])
    if t == 1:
        return _plan_or(bitmaps, backend, arena)   # coalesces with "or"
    if weights is not None:
        return _plan_threshold_weighted(bitmaps, t, weights, backend,
                                        arena)
    groups = _group(bitmaps)
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    run_groups: list[tuple[int, list[RunContainer]]] = []
    for k in sorted(groups):
        g = groups[k]
        if len(g) < t:
            continue                               # can never reach T
        if all(isinstance(c, RunContainer) for c in g):
            run_groups.append((k, g))              # interval-level counting
            continue
        if all(isinstance(c, ArrayContainer) for c in g):
            c = _count_arrays(g, "threshold", t)   # host occurrence counts
            if c is not None:
                merged[k] = c
            continue
        seg_keys.append(k)
        seg_rows.append([_row_ref(c, arena) for c in g])
    merged.update(_sweep_run_groups(run_groups, "threshold", t))
    return WidePlan("threshold", t, merged, seg_keys, seg_rows,
                    arena=arena)


def _plan_threshold_weighted(bitmaps, t: int, weights: list[int],
                             backend, arena=None) -> WidePlan:
    """Weighted threshold body: identical planning shape, with per-member
    weights threaded through the sweep, the bincount fast path, and the
    kernel's shift-and-add counter circuit."""
    groups: dict[int, list[tuple[Container, int]]] = {}
    for bm, w in zip(bitmaps, weights):
        for k, c in zip(bm.keys, bm.containers):
            groups.setdefault(k, []).append((c, w))
    merged: dict[int, Container] = {}
    seg_keys: list[int] = []
    seg_rows: list[list[np.ndarray]] = []
    seg_wts: list[list[int]] = []
    run_groups: list[tuple] = []
    for k in sorted(groups):
        g = groups[k]
        if sum(w for _, w in g) < t:
            continue                               # can never reach T
        if all(isinstance(c, RunContainer) for c, _ in g):
            run_groups.append((k, [c for c, _ in g], [w for _, w in g]))
            continue
        if all(isinstance(c, ArrayContainer) for c, _ in g):
            vals = np.concatenate([c.values for c, _ in g])
            wrep = np.repeat(np.asarray([w for _, w in g], np.int64),
                             [c.values.size for c, _ in g])
            # bincount's float64 sums are exact for int totals < 2^53
            # (weights are bounded to the int32 domain by _check_weights)
            cnt = np.bincount(vals, weights=wrep, minlength=CHUNK)
            c = _from_indicator((cnt >= t).astype(np.uint8))
            if c is not None:
                merged[k] = c
            continue
        seg_keys.append(k)
        seg_rows.append([_row_ref(c, arena) for c, _ in g])
        seg_wts.append([w for _, w in g])
    merged.update(_sweep_run_groups(run_groups, "threshold", t))
    return WidePlan("threshold", t, merged, seg_keys, seg_rows, seg_wts,
                    arena=arena)
