"""Serialization for RoaringBitmap: three on-disk layouts, one module.

Byte-exact specifications (plus a worked hex example and a CRoaring
compatibility table) live in ``docs/FORMAT.md``; this docstring is the
short map.  Paper section 5.1: "The CRoaring library supports a compact
and portable serialization format"; in-memory and serialized sizes are
nearly identical.

1. **RJ02** (``serialize`` / ``deserialize``) -- the private
   checksummed format: CRC-32 over the whole body, explicit kind bytes,
   strict structural validation.  Use it for checkpoints that must
   detect corruption (``data/pipeline.py`` checkpoints ride on it).
2. **Portable** (``serialize_portable`` / ``deserialize_portable``) --
   the CRoaring/RoaringFormatSpec interchange layout (cookies 12346 /
   12347): what ``roaring_bitmap_portable_serialize`` writes and every
   Roaring implementation (C, Java, Go, ...) reads.  No checksum; kind
   is inferred (run flag bitmap, else cardinality > 4096 => bitset).
3. **Frozen** (``serialize_frozen`` / ``deserialize_frozen``) -- the
   mmap-first layout: payloads grouped into per-kind zones so
   deserialization is a handful of numpy *views* over one buffer --
   zero payload bytes are read or copied (``np.shares_memory`` holds
   for every container, asserted by tests).  A node maps a snapshot
   and answers its first query in milliseconds; see
   ``BitmapArena.adopt_frozen`` for the bulk device promotion.

``write_snapshot`` / ``read_snapshot`` bundle many *named* frozen
bitmaps (an inverted index) into one mmap-able archive -- the segment
format of ``data.pipeline.StreamingIndexBuilder``.

Robustness contract: ``deserialize`` of ANY corrupted RJ02 buffer
raises ``ValueError`` -- never a crash, hang, or a silently-wrong
bitmap -- and every truncation/validation error reports the byte
offset where the parse died plus the container index when one is in
scope.  Two layers enforce it: the CRC rejects every byte flip up
front (CRC-32 catches all error bursts <= 32 bits, so every
single-byte corruption), and structural validation (sorted keys,
per-kind payload invariants, card cross-checks, no trailing bytes)
rejects buffers that were built wrong rather than damaged in flight.
The portable format has no checksum (the spec has none), so only the
structural layer stands: header/cardinality/offset corruption is
detected, but a flipped *key* byte that stays sorted is not -- see
docs/FORMAT.md section 4 for the honest table.  The frozen format
validates its directory vectorized but never touches payload zones
(that would defeat lazy mmap paging); treat it as trusted local
storage, not an interchange format.
"""

from __future__ import annotations

import struct
import zlib
from collections.abc import MutableMapping

import numpy as np

from repro.core.bitmap import RoaringBitmap
from repro.core.containers import (
    ARRAY_MAX, ArrayContainer, BitsetContainer, RunContainer, BITSET_WORDS,
)

MAGIC = b"RJ02"

# CRoaring / RoaringFormatSpec constants (docs/FORMAT.md section 3)
SERIAL_COOKIE = 12347                  # with run containers (uint16)
SERIAL_COOKIE_NO_RUNCONTAINER = 12346  # without run containers (uint32)
NO_OFFSET_THRESHOLD = 4                # run format omits offsets below this

MAGIC_FROZEN = b"RJFZ0001"
MAGIC_SNAPSHOT = b"RJSN0001"

_MAX_CONTAINERS = 1 << 16              # keys are uint16, so n can't exceed


# ---------------------------------------------------------------------------
# RJ02: the private checksummed format
# ---------------------------------------------------------------------------

def serialize(bm: RoaringBitmap) -> bytes:
    """Serialize ``bm`` to the private checksummed RJ02 wire format.

    Args: ``bm`` any RoaringBitmap (container kinds are preserved
    exactly, including bitsets below the 4096 threshold).

    Returns ``bytes``: magic + CRC-32 + directory + payloads
    (docs/FORMAT.md section 2 has the byte-exact layout).  Complexity:
    O(total payload bytes); one pass, no per-value work.
    """
    n = len(bm.keys)
    parts = [struct.pack("<I", n)]
    parts.append(np.asarray(bm.keys, dtype=np.uint16).tobytes())
    kinds, cards = [], []
    for c in bm.containers:
        kinds.append({"array": 1, "bitset": 2, "run": 3}[c.kind])
        cards.append(c.card - 1)
    parts.append(np.asarray(kinds, dtype=np.uint8).tobytes())
    parts.append(np.asarray(cards, dtype=np.uint16).tobytes())
    for c in bm.containers:
        if isinstance(c, ArrayContainer):
            parts.append(c.values.tobytes())
        elif isinstance(c, BitsetContainer):
            parts.append(c.words.tobytes())
        else:
            runs = c.runs.astype(np.uint16)
            parts.append(struct.pack("<H", runs.shape[0]))
            parts.append(runs.tobytes())
    body = b"".join(parts)
    return MAGIC + struct.pack("<I", zlib.crc32(body)) + body


def _need(buf: bytes, off: int, nbytes: int, what: str) -> None:
    """Bounds check with an actionable message: truncated/corrupt
    payloads must fail with ValueError (never a bare struct/buffer
    error) that names *what* was being parsed and the exact byte
    offset where the parse died."""
    if off + nbytes > len(buf):
        raise ValueError(
            f"truncated roaring payload: need {nbytes} byte(s) for {what} "
            f"at byte offset {off}, but only {len(buf) - off} remain")


def deserialize(buf: bytes) -> RoaringBitmap:
    """Parse an RJ02 payload produced by :func:`serialize`.

    Args: ``buf`` bytes-like.  Returns a new RoaringBitmap (container
    kinds exactly as serialized).

    Raises ``ValueError`` on ANY corruption -- CRC first (catches every
    single-byte flip), then structural validation; every message
    carries the byte offset where the parse died and the container
    index when one is in scope.  Complexity: O(total payload bytes)
    including the CRC pass.  See docs/FORMAT.md section 2.
    """
    buf = bytes(buf)
    _need(buf, 0, 12, "header")
    if buf[:4] != MAGIC:
        raise ValueError(
            "bad magic; not an RJ02 roaring payload (at byte offset 0)")
    (crc,) = struct.unpack_from("<I", buf, 4)
    if zlib.crc32(buf[8:]) != crc:
        raise ValueError(
            "checksum mismatch; corrupt roaring payload "
            "(crc field at byte offset 4)")
    (n,) = struct.unpack_from("<I", buf, 8)
    if n > _MAX_CONTAINERS:
        raise ValueError(
            f"container count {n} exceeds the 65536 maximum "
            "(count field at byte offset 8)")
    off = 12
    _need(buf, off, 5 * n, f"directory of {n} container(s)")
    keys = np.frombuffer(buf, dtype=np.uint16, count=n, offset=off)
    off += 2 * n
    kinds = np.frombuffer(buf, dtype=np.uint8, count=n, offset=off)
    off += n
    cards = np.frombuffer(buf, dtype=np.uint16, count=n, offset=off)
    off += 2 * n
    if n > 1 and not (keys[1:] > keys[:-1]).all():
        raise ValueError(
            "container keys not strictly increasing "
            "(key directory at byte offset 12)")
    out_keys, out_conts = [], []
    for i in range(n):
        card = int(cards[i]) + 1
        kind = int(kinds[i])
        po = off                      # payload start, for error messages
        if kind == 1:
            _need(buf, off, 2 * card, f"array container {i} ({card} values)")
            vals = np.frombuffer(buf, dtype=np.uint16, count=card, offset=off)
            off += 2 * card
            if card > 1 and not (vals[1:] > vals[:-1]).all():
                raise ValueError(
                    f"array container {i}: values not strictly increasing "
                    f"(payload at byte offset {po})")
            out_conts.append(ArrayContainer(vals.copy()))
        elif kind == 2:
            _need(buf, off, 8 * BITSET_WORDS, f"bitset container {i}")
            words = np.frombuffer(buf, dtype=np.uint64,
                                  count=BITSET_WORDS, offset=off)
            off += 8 * BITSET_WORDS
            pop = int(np.bitwise_count(words).sum())
            if pop != card:
                raise ValueError(
                    f"bitset container {i}: stored cardinality {card} "
                    f"!= popcount {pop} (payload at byte offset {po})")
            out_conts.append(BitsetContainer(words.copy(), card))
        elif kind == 3:
            _need(buf, off, 2, f"run count of container {i}")
            (nr,) = struct.unpack_from("<H", buf, off)
            off += 2
            _need(buf, off, 4 * nr, f"run container {i} ({nr} runs)")
            runs = np.frombuffer(buf, dtype=np.uint16, count=2 * nr,
                                 offset=off).reshape(nr, 2)
            off += 4 * nr
            starts = runs[:, 0].astype(np.int64)
            ends = starts + runs[:, 1].astype(np.int64)
            if nr == 0 or (ends > 0xFFFF).any() or \
                    (nr > 1 and (starts[1:] <= ends[:-1] + 1).any()):
                raise ValueError(
                    f"run container {i}: runs not disjoint ascending "
                    f"in-bounds intervals (payload at byte offset {po})")
            if int((ends - starts + 1).sum()) != card:
                raise ValueError(
                    f"run container {i}: stored cardinality {card} "
                    f"!= run length total (payload at byte offset {po})")
            out_conts.append(RunContainer(runs.astype(np.int32)))
        else:
            raise ValueError(
                f"bad container kind {kind} for container {i} "
                f"(kind directory at byte offset {12 + 2 * n + i})")
        out_keys.append(int(keys[i]))
    if off != len(buf):
        raise ValueError(
            f"trailing garbage: {len(buf) - off} byte(s) past the last "
            f"container payload (at byte offset {off})")
    return RoaringBitmap(out_keys, out_conts)


def serialized_size_bytes(bm: RoaringBitmap, format: str = "rj02") -> int:
    """Size in bytes ``bm`` serializes to in the given ``format``
    ("rj02" | "portable" | "frozen"), computed WITHOUT serializing
    (the CRoaring ``portable_size_in_bytes`` parity API).

    Complexity: O(containers); no payload bytes are touched.  See
    docs/FORMAT.md for the per-format size formulas.
    """
    if format == "rj02":
        size = 12 + 5 * len(bm.keys)
        for c in bm.containers:
            if isinstance(c, ArrayContainer):
                size += 2 * c.card
            elif isinstance(c, BitsetContainer):
                size += 8 * BITSET_WORDS
            else:
                size += 2 + 4 * c.runs.shape[0]
        return size
    if format == "portable":
        conts = [_portable_canonical(c) for c in bm.containers]
        n = len(conts)
        has_run = any(isinstance(c, RunContainer) for c in conts)
        if has_run:
            size = 4 + (n + 7) // 8
            if n >= NO_OFFSET_THRESHOLD:
                size += 4 * n
        else:
            size = 8 + 4 * n
        size += 4 * n
        return size + sum(_portable_payload_size(c) for c in conts)
    if format == "frozen":
        n = len(bm.keys)
        n_bitset = sum(isinstance(c, BitsetContainer) for c in bm.containers)
        n_values = sum(c.card for c in bm.containers
                       if isinstance(c, ArrayContainer))
        n_runs = sum(c.runs.shape[0] for c in bm.containers
                     if isinstance(c, RunContainer))
        size = _align(32 + 5 * n, 4) + 8 * n
        size = _align(size, 8) + 8 * BITSET_WORDS * n_bitset + 2 * n_values
        return _align(size, 4) + 8 * n_runs
    raise ValueError(f"unknown serialization format {format!r}")


# ---------------------------------------------------------------------------
# portable: the CRoaring / RoaringFormatSpec interchange layout
# ---------------------------------------------------------------------------

def _portable_canonical(c):
    """The portable format infers container kind (run flag, else
    cardinality > 4096 => bitset), so writers must canonicalize: a
    bitset holding <= 4096 values becomes an array, a >4096-value
    array (cannot exist under ARRAY_MAX, kept for safety) a bitset."""
    if isinstance(c, RunContainer):
        return c
    if c.card > ARRAY_MAX:
        return c if isinstance(c, BitsetContainer) else c.to_bitset()
    return c if isinstance(c, ArrayContainer) \
        else ArrayContainer(c.to_array_values())


def _portable_payload_size(c) -> int:
    if isinstance(c, ArrayContainer):
        return 2 * c.card
    if isinstance(c, BitsetContainer):
        return 8 * BITSET_WORDS
    return 2 + 4 * c.runs.shape[0]


def serialize_portable(bm: RoaringBitmap) -> bytes:
    """Serialize ``bm`` to the CRoaring portable interchange format
    (RoaringFormatSpec; what ``roaring_bitmap_portable_serialize``
    writes and CRoaring/RoaringBitmap-Java/roaring-rs read).

    Args: ``bm`` any RoaringBitmap; kinds are canonicalized first
    (bitsets <= 4096 values become arrays) because the wire format
    infers kind from the run-flag bitmap and the cardinality.

    Returns ``bytes``.  Complexity: O(total payload bytes).  No
    checksum -- pair with RJ02 when corruption detection matters
    (docs/FORMAT.md sections 3-4).
    """
    conts = [_portable_canonical(c) for c in bm.containers]
    n = len(conts)
    run_flags = np.array([isinstance(c, RunContainer) for c in conts],
                         dtype=bool)
    has_run = bool(run_flags.any())
    parts = []
    if has_run:
        parts.append(struct.pack("<HH", SERIAL_COOKIE, n - 1))
        bits = np.zeros((n + 7) // 8, np.uint8)
        idx = np.flatnonzero(run_flags)
        np.bitwise_or.at(bits, idx >> 3,
                         (1 << (idx & 7)).astype(np.uint8))
        parts.append(bits.tobytes())
    else:
        parts.append(struct.pack("<II", SERIAL_COOKIE_NO_RUNCONTAINER, n))
    desc = np.empty(2 * n, np.uint16)
    if n:
        desc[0::2] = np.asarray(bm.keys, np.uint16)
        desc[1::2] = np.asarray([c.card - 1 for c in conts], np.uint16)
    parts.append(desc.tobytes())
    with_offsets = (not has_run) or n >= NO_OFFSET_THRESHOLD
    sizes = [_portable_payload_size(c) for c in conts]
    if with_offsets:
        first = sum(len(p) for p in parts) + 4 * n
        offs = first + np.concatenate(
            ([0], np.cumsum(sizes[:-1]))) if n else np.zeros(0)
        parts.append(np.asarray(offs, np.uint32).tobytes())
    for c in conts:
        if isinstance(c, ArrayContainer):
            parts.append(c.values.tobytes())
        elif isinstance(c, BitsetContainer):
            parts.append(c.words.tobytes())
        else:
            runs = c.runs.astype(np.uint16)
            parts.append(struct.pack("<H", runs.shape[0]))
            parts.append(runs.tobytes())
    return b"".join(parts)


def deserialize_portable(buf: bytes) -> RoaringBitmap:
    """Parse a CRoaring portable payload (any compliant writer's
    output) into a RoaringBitmap.

    Args: ``buf`` bytes-like.  Returns a new RoaringBitmap whose
    container kinds follow the format's inference rule (run flag,
    else cardinality > 4096 => bitset, else array).

    Raises ``ValueError`` with the byte offset and container index on
    truncation, bad cookies, unsorted keys/values, offset-header
    mismatches, cardinality cross-check failures, or trailing bytes.
    The format carries no checksum, so corruption that preserves all
    structural invariants (e.g. a flipped key byte that stays sorted)
    is undetectable by design -- see docs/FORMAT.md section 4.
    Complexity: O(total payload bytes).
    """
    buf = bytes(buf)
    _need(buf, 0, 4, "portable cookie")
    (cookie16,) = struct.unpack_from("<H", buf, 0)
    if cookie16 == SERIAL_COOKIE:
        (n_minus_1,) = struct.unpack_from("<H", buf, 2)
        n = n_minus_1 + 1
        has_run = True
        off = 4
        flag_bytes = (n + 7) // 8
        _need(buf, off, flag_bytes, "run-container flag bitmap")
        flags = np.frombuffer(buf, np.uint8, flag_bytes, off)
        run_flags = np.unpackbits(flags, bitorder="little")[:n].astype(bool)
        off += flag_bytes
    else:
        (cookie32,) = struct.unpack_from("<I", buf, 0)
        if cookie32 != SERIAL_COOKIE_NO_RUNCONTAINER:
            raise ValueError(
                f"bad cookie {cookie16}; not a portable roaring payload "
                "(at byte offset 0)")
        _need(buf, 0, 8, "portable header")
        (n,) = struct.unpack_from("<I", buf, 4)
        has_run = False
        run_flags = np.zeros(n, dtype=bool)
        off = 8
    if n > _MAX_CONTAINERS:
        raise ValueError(
            f"container count {n} exceeds the 65536 maximum "
            "(count field at byte offset 4)")
    desc_off = off
    _need(buf, off, 4 * n, f"descriptive header of {n} container(s)")
    desc = np.frombuffer(buf, np.uint16, 2 * n, off)
    keys, cards = desc[0::2], desc[1::2].astype(np.int64) + 1
    off += 4 * n
    if n > 1 and not (keys[1:] > keys[:-1]).all():
        raise ValueError(
            "container keys not strictly increasing "
            f"(descriptive header at byte offset {desc_off})")
    with_offsets = (not has_run) or n >= NO_OFFSET_THRESHOLD
    offsets = None
    if with_offsets:
        _need(buf, off, 4 * n, f"offset header of {n} container(s)")
        offsets = np.frombuffer(buf, np.uint32, n, off)
        off += 4 * n
    out_keys, out_conts = [], []
    for i in range(n):
        card = int(cards[i])
        po = off
        if offsets is not None and int(offsets[i]) != po:
            raise ValueError(
                f"offset header mismatch for container {i}: stored "
                f"{int(offsets[i])}, payload actually at byte offset {po}")
        if run_flags[i]:
            _need(buf, off, 2, f"run count of container {i}")
            (nr,) = struct.unpack_from("<H", buf, off)
            off += 2
            _need(buf, off, 4 * nr, f"run container {i} ({nr} runs)")
            runs = np.frombuffer(buf, np.uint16, 2 * nr, off).reshape(nr, 2)
            off += 4 * nr
            starts = runs[:, 0].astype(np.int64)
            ends = starts + runs[:, 1].astype(np.int64)
            if nr == 0 or (ends > 0xFFFF).any() or \
                    (nr > 1 and (starts[1:] <= ends[:-1] + 1).any()):
                raise ValueError(
                    f"run container {i}: runs not disjoint ascending "
                    f"in-bounds intervals (payload at byte offset {po})")
            if int((ends - starts + 1).sum()) != card:
                raise ValueError(
                    f"run container {i}: stored cardinality {card} "
                    f"!= run length total (payload at byte offset {po})")
            out_conts.append(RunContainer(runs.astype(np.int32)))
        elif card > ARRAY_MAX:
            _need(buf, off, 8 * BITSET_WORDS, f"bitset container {i}")
            words = np.frombuffer(buf, np.uint64, BITSET_WORDS, off)
            off += 8 * BITSET_WORDS
            pop = int(np.bitwise_count(words).sum())
            if pop != card:
                raise ValueError(
                    f"bitset container {i}: stored cardinality {card} "
                    f"!= popcount {pop} (payload at byte offset {po})")
            out_conts.append(BitsetContainer(words.copy(), card))
        else:
            _need(buf, off, 2 * card, f"array container {i} ({card} values)")
            vals = np.frombuffer(buf, np.uint16, card, off)
            off += 2 * card
            if card > 1 and not (vals[1:] > vals[:-1]).all():
                raise ValueError(
                    f"array container {i}: values not strictly increasing "
                    f"(payload at byte offset {po})")
            out_conts.append(ArrayContainer(vals.copy()))
        out_keys.append(int(keys[i]))
    if off != len(buf):
        raise ValueError(
            f"trailing garbage: {len(buf) - off} byte(s) past the last "
            f"container payload (at byte offset {off})")
    return RoaringBitmap(out_keys, out_conts)


# ---------------------------------------------------------------------------
# frozen: zero-copy view-based layout for mmap-ed snapshots
# ---------------------------------------------------------------------------

def _align(off: int, to: int) -> int:
    return (off + to - 1) // to * to


def _bad_direc(dir_off: int):
    raise ValueError(
        "frozen directory entry out of zone bounds or cardinality "
        f"mismatch (directory at byte offset {dir_off})")


def _as_u8(buf) -> np.ndarray:
    """Any bytes-like / ndarray / memmap as a flat uint8 array WITHOUT
    copying (views into the result alias the caller's buffer)."""
    if isinstance(buf, np.ndarray):
        # .view(np.ndarray) strips subclasses (np.memmap): the subclass
        # __array_finalize__ hook taxes EVERY downstream slice, which
        # dominates directory-walk time on large mapped snapshots.
        return buf.reshape(-1).view(np.uint8).view(np.ndarray)
    return np.frombuffer(buf, dtype=np.uint8)


def serialize_frozen(bm: RoaringBitmap) -> bytes:
    """Serialize ``bm`` to the frozen zero-copy layout: payloads
    grouped into per-kind zones (bitset words, array values, run
    pairs) behind a vectorized directory, every zone aligned for
    direct numpy views (docs/FORMAT.md section 5).

    Args: ``bm`` any RoaringBitmap; kinds are preserved exactly.
    Returns ``bytes`` whose :func:`deserialize_frozen` twin copies
    ZERO payload bytes.  Complexity: O(total payload bytes) to write.
    """
    n = len(bm.keys)
    kinds = np.empty(n, np.uint8)
    cards = np.empty(n, np.uint16)
    direc = np.zeros((n, 2), np.uint32)
    bitset_rows, values_parts, run_parts = [], [], []
    n_bitset = n_values = n_runs = 0
    for i, c in enumerate(bm.containers):
        cards[i] = c.card - 1
        if isinstance(c, ArrayContainer):
            kinds[i] = 1
            direc[i] = (n_values, c.card)
            values_parts.append(c.values)
            n_values += c.card
        elif isinstance(c, BitsetContainer):
            kinds[i] = 2
            direc[i] = (n_bitset, 0)
            bitset_rows.append(c.words)
            n_bitset += 1
        else:
            kinds[i] = 3
            nr = c.runs.shape[0]
            direc[i] = (n_runs, nr)
            run_parts.append(c.runs.astype(np.int32))
            n_runs += nr
    dir_off = _align(32 + 5 * n, 4)
    bitset_off = _align(dir_off + 8 * n, 8)
    values_off = bitset_off + 8 * BITSET_WORDS * n_bitset
    runs_off = _align(values_off + 2 * n_values, 4)
    total = runs_off + 8 * n_runs
    out = bytearray(total)
    out[0:8] = MAGIC_FROZEN
    struct.pack_into("<IIIIQ", out, 8, n, n_bitset, n_values, n_runs, total)
    out[32:32 + 2 * n] = np.asarray(bm.keys, np.uint16).tobytes()
    out[32 + 2 * n:32 + 3 * n] = kinds.tobytes()
    out[32 + 3 * n:32 + 5 * n] = cards.tobytes()
    out[dir_off:dir_off + 8 * n] = direc.tobytes()
    pos = bitset_off
    for words in bitset_rows:
        out[pos:pos + 8 * BITSET_WORDS] = words.tobytes()
        pos += 8 * BITSET_WORDS
    pos = values_off
    for vals in values_parts:
        out[pos:pos + 2 * vals.size] = vals.tobytes()
        pos += 2 * vals.size
    pos = runs_off
    for runs in run_parts:
        out[pos:pos + 8 * runs.shape[0]] = runs.tobytes()
        pos += 8 * runs.shape[0]
    return bytes(out)


def deserialize_frozen(buf) -> RoaringBitmap:
    """Reconstruct a RoaringBitmap as pure numpy VIEWS over ``buf``:
    zero payload bytes are read or copied (``np.shares_memory`` holds
    for every container payload), so mapping a multi-GB snapshot and
    calling this costs directory-validation time only -- payload pages
    fault in lazily as queries touch them.

    Args: ``buf`` bytes, memoryview, ``np.memmap`` or any uint8
    ndarray (pass a ``np.memmap(path, np.uint8, "r")`` for the mmap
    path; :func:`load_frozen` does exactly that).

    Returns a RoaringBitmap whose container payloads alias ``buf``.
    Buffers from ``bytes`` or read-only maps yield non-writeable
    views; every ``RoaringBitmap`` mutator is copy-on-write, so
    frozen-backed bitmaps stay safely immutable underneath.

    Raises ``ValueError`` (byte offset + container index included) on
    bad magic, size mismatches, unsorted keys, bad kinds, or directory
    entries pointing outside their zone -- all validated VECTORIZED
    over the directory; payload zones are never touched (trusted local
    format, docs/FORMAT.md section 5).  Complexity: O(containers) for
    the directory walk; O(1) payload bytes.
    """
    u8 = _as_u8(buf)
    if u8.size < 32:
        raise ValueError(
            f"truncated frozen payload: need 32 byte(s) for header "
            f"at byte offset 0, but only {u8.size} remain")
    head = u8[:32].tobytes()
    if head[:8] != MAGIC_FROZEN:
        raise ValueError(
            "bad magic; not an RJFZ frozen roaring payload "
            "(at byte offset 0)")
    n, n_bitset, n_values, n_runs, total = struct.unpack_from("<IIIIQ",
                                                              head, 8)
    if n > _MAX_CONTAINERS:
        raise ValueError(
            f"container count {n} exceeds the 65536 maximum "
            "(count field at byte offset 8)")
    if total != u8.size:
        raise ValueError(
            f"frozen payload size mismatch: header says {total} byte(s), "
            f"buffer has {u8.size} (size field at byte offset 24)")
    dir_off = _align(32 + 5 * n, 4)
    bitset_off = _align(dir_off + 8 * n, 8)
    values_off = bitset_off + 8 * BITSET_WORDS * n_bitset
    runs_off = _align(values_off + 2 * n_values, 4)
    if runs_off + 8 * n_runs != total:
        raise ValueError(
            "frozen zone sizes inconsistent with the header counts "
            "(directory at byte offset 32)")
    keys_l = u8[32:32 + 2 * n].view(np.uint16).tolist()
    kinds_l = u8[32 + 2 * n:32 + 3 * n].tolist()
    cards_l = u8[32 + 3 * n:32 + 5 * n].view(np.uint16).tolist()
    direc_l = u8[dir_off:dir_off + 8 * n].view(np.uint32) \
        .reshape(n, 2).tolist()
    bitset_zone = u8[bitset_off:values_off].view(np.uint64).reshape(
        n_bitset, BITSET_WORDS)
    values_zone = u8[values_off:values_off + 2 * n_values].view(np.uint16)
    run_zone = u8[runs_off:runs_off + 8 * n_runs].view(np.int32).reshape(
        n_runs, 2)
    # Validation runs as SCALAR checks inside the construction loop: on
    # the tiny per-container arrays involved, vectorized numpy checks
    # cost ~30x the whole loop (cold-start opens thousands of frozen
    # payloads, so the constant here is what snapshot-open time IS).
    conts: list = []
    append = conts.append
    n_bit_seen = 0
    prev_key = -1
    for i in range(n):            # views only: no payload reads/copies
        k = kinds_l[i]
        s, c = direc_l[i]
        key = keys_l[i]
        if key <= prev_key:
            raise ValueError(
                "container keys not strictly increasing "
                "(key directory at byte offset 32)")
        prev_key = key
        if k == 2:
            if s >= n_bitset:
                _bad_direc(dir_off)
            n_bit_seen += 1
            append(BitsetContainer(bitset_zone[s], cards_l[i] + 1))
        elif k == 1:
            if c != cards_l[i] + 1 or s + c > n_values:
                _bad_direc(dir_off)
            append(ArrayContainer(values_zone[s:s + c]))
        elif k == 3:
            if c < 1 or s + c > n_runs:
                _bad_direc(dir_off)
            append(RunContainer(run_zone[s:s + c]))
        else:
            raise ValueError(
                f"bad container kind {k} for container {i} "
                f"(kind directory at byte offset {32 + 2 * n + i})")
    if n_bit_seen != n_bitset:
        _bad_direc(dir_off)
    return RoaringBitmap(keys_l, conts)


def write_frozen(path, bm: RoaringBitmap) -> int:
    """Write ``bm`` in the frozen layout to ``path`` (a str/Path).
    Returns the number of bytes written.  Read it back zero-copy with
    :func:`load_frozen`."""
    payload = serialize_frozen(bm)
    with open(path, "wb") as f:
        f.write(payload)
    return len(payload)


def load_frozen(path) -> RoaringBitmap:
    """Map ``path`` (written by :func:`write_frozen`) read-only and
    return a RoaringBitmap of views over the map: O(containers)
    directory work, zero payload reads -- pages fault in lazily as
    queries touch them (docs/FORMAT.md section 5)."""
    return deserialize_frozen(np.memmap(path, dtype=np.uint8, mode="r"))


# ---------------------------------------------------------------------------
# snapshot archive: many named frozen bitmaps, one mmap-able file
# ---------------------------------------------------------------------------

class LazyBitmaps(MutableMapping):
    """Name -> RoaringBitmap mapping over a snapshot archive that
    defers each entry's directory walk until the entry is FIRST read
    (``docs/FORMAT.md`` section 6): opening a 100k-term snapshot costs
    table-parse time only, and a query that touches 4 terms pays for 4
    ``deserialize_frozen`` calls -- the rest of the file is never
    walked (and with mmap, never paged in).

    Behaves as an ordinary mutable mapping (``dict(m)``, ``m[k]``,
    ``.get``/``.items``/``.values``, assignment) -- materialized
    entries are cached, assignments shadow pending entries.  Keys are
    available without materializing anything (``len``, ``in``,
    iteration)."""

    __slots__ = ("_buf", "_order", "_pending", "_cache")

    def __init__(self, buf, order: list, pending: dict):
        self._buf = buf
        self._order = order                 # archive key order
        self._pending = pending             # name -> (pay_off, pay_len)
        self._cache: dict = {}

    def __getitem__(self, key):
        try:
            return self._cache[key]
        except KeyError:
            off, ln = self._pending.pop(key)     # KeyError if absent
            bm = self._cache[key] = deserialize_frozen(
                self._buf[off:off + ln])
            return bm

    def __setitem__(self, key, value):
        if key not in self._cache and key not in self._pending:
            self._order.append(key)
        self._pending.pop(key, None)
        self._cache[key] = value

    def __delitem__(self, key):
        if self._cache.pop(key, None) is None and \
                self._pending.pop(key, None) is None:
            raise KeyError(key)
        self._order.remove(key)

    def __iter__(self):
        return iter(self._order)

    def __len__(self):
        return len(self._order)

    def __contains__(self, key):
        return key in self._cache or key in self._pending


class FrozenSnapshot:
    """A read-only view over a snapshot archive: ``bitmaps`` is a
    :class:`LazyBitmaps` mapping of name -> frozen-view RoaringBitmap,
    every entry aliasing the archive's single buffer (``buffer``) and
    materialized on first access; ``meta`` is the writer's uint32 (the
    streaming index builder stores ``n_docs`` there); ``nbytes`` the
    archive size.  See docs/FORMAT.md section 6."""

    __slots__ = ("bitmaps", "meta", "nbytes", "buffer")

    def __init__(self, bitmaps, meta: int, nbytes: int, buffer):
        self.bitmaps = bitmaps
        self.meta = meta
        self.nbytes = nbytes
        self.buffer = buffer


def write_snapshot(path, named, *, meta: int = 0) -> int:
    """Write a snapshot archive of named bitmaps to ``path``.

    Args: ``named`` a mapping (or iterable of pairs) of ``str`` name
    -> RoaringBitmap, each stored in the frozen layout, 8-aligned so
    :func:`read_snapshot` views them in place; ``meta`` a uint32 the
    reader gets back verbatim (``StreamingIndexBuilder`` stores
    ``n_docs``).

    Returns bytes written.  Complexity: O(total payload bytes), one
    sequential write.
    """
    items = list(named.items()) if hasattr(named, "items") else list(named)
    names = [str(k).encode("utf-8") for k, _ in items]
    payloads = [serialize_frozen(bm) for _, bm in items]
    n = len(items)
    table_off = 24
    names_off = table_off + 24 * n
    name_offs, pos = [], names_off
    for nm in names:
        name_offs.append(pos)
        pos += len(nm)
    pay_offs, pos = [], _align(pos, 8)
    for p in payloads:
        pay_offs.append(pos)
        pos += _align(len(p), 8)
    total = pos
    out = bytearray(total)
    out[0:8] = MAGIC_SNAPSHOT
    struct.pack_into("<IIQ", out, 8, n, meta, total)
    for i in range(n):
        struct.pack_into("<IIQQ", out, table_off + 24 * i,
                         name_offs[i], len(names[i]),
                         pay_offs[i], len(payloads[i]))
        out[name_offs[i]:name_offs[i] + len(names[i])] = names[i]
        out[pay_offs[i]:pay_offs[i] + len(payloads[i])] = payloads[i]
    with open(path, "wb") as f:
        f.write(out)
    return total


def read_snapshot(path, *, mmap: bool = True) -> FrozenSnapshot:
    """Open a snapshot archive written by :func:`write_snapshot`.

    Args: ``path`` the archive; ``mmap`` maps it read-only (the
    zero-copy cold-start path -- payload pages fault in lazily) or,
    when False, reads it into memory first (same views, private
    buffer).

    Returns a :class:`FrozenSnapshot` whose ``bitmaps`` are LAZY: the
    entry table is parsed and bounds-checked vectorized up front, but
    each bitmap's directory walk (:func:`deserialize_frozen`) is
    deferred to first access, so open time is O(entry table) no matter
    how large the payloads are.  Raises ``ValueError`` on bad magic /
    size mismatches / out-of-bounds table entries.
    """
    if mmap:
        u8 = np.memmap(path, dtype=np.uint8, mode="r").view(np.ndarray)
    else:
        with open(path, "rb") as f:
            u8 = np.frombuffer(f.read(), dtype=np.uint8)
    if u8.size < 24 or u8[:8].tobytes() != MAGIC_SNAPSHOT:
        raise ValueError(
            "bad magic; not an RJSN snapshot archive (at byte offset 0)")
    n, meta, total = struct.unpack_from("<IIQ", u8[:24].tobytes(), 8)
    if total != u8.size:
        raise ValueError(
            f"snapshot size mismatch: header says {total} byte(s), "
            f"file has {u8.size} (size field at byte offset 16)")
    table = u8[24:24 + 24 * n]
    if table.size != 24 * n:
        raise ValueError(
            f"truncated snapshot: need {24 * n} byte(s) for the entry "
            f"table at byte offset 24, but only {u8.size - 24} remain")
    ent = table.view(np.dtype([("name_off", "<u4"), ("name_len", "<u4"),
                               ("pay_off", "<u8"), ("pay_len", "<u8")]))
    oob = (ent["name_off"].astype(np.uint64) + ent["name_len"] > total) \
        | (ent["pay_off"] + ent["pay_len"] > total)
    if oob.any():
        i = int(np.flatnonzero(oob)[0])
        raise ValueError(
            f"snapshot entry {i} points outside the archive "
            f"(entry at byte offset {24 + 24 * i})")
    name_offs = ent["name_off"].tolist()
    name_lens = ent["name_len"].tolist()
    pay_offs = ent["pay_off"].tolist()
    pay_lens = ent["pay_len"].tolist()
    order, pending = [], {}
    for i in range(n):
        a = name_offs[i]
        name = u8[a:a + name_lens[i]].tobytes().decode("utf-8")
        order.append(name)
        pending[name] = (pay_offs[i], pay_lens[i])
    return FrozenSnapshot(LazyBitmaps(u8, order, pending),
                          meta, int(total), u8)


def sniff_format(buf) -> str:
    """Identify which serde layout ``buf`` holds ("rj02" | "portable"
    | "frozen" | "snapshot") from its magic/cookie -- the dispatcher
    behind ``RoaringBitmap.deserialize(format="auto")``.  Raises
    ``ValueError`` when no layout matches."""
    u8 = _as_u8(buf)
    head = u8[:8].tobytes()
    if head[:4] == MAGIC:
        return "rj02"
    if head == MAGIC_FROZEN:
        return "frozen"
    if head == MAGIC_SNAPSHOT:
        return "snapshot"
    if len(head) >= 4:
        (c16,) = struct.unpack_from("<H", head, 0)
        if c16 == SERIAL_COOKIE:
            return "portable"
        (c32,) = struct.unpack_from("<I", head, 0)
        if c32 == SERIAL_COOKIE_NO_RUNCONTAINER:
            return "portable"
    raise ValueError("unrecognized roaring serialization format")
