"""Compact, portable serialization for RoaringBitmap (paper section 5.1:
"The CRoaring library supports a compact and portable serialization format";
in-memory and serialized sizes are nearly identical).

Layout (little-endian):
    magic   4 bytes  b"RJ02"
    crc     uint32   CRC-32 (zlib) of every byte after this field
    n       uint32   number of containers
    keys    n x uint16     (strictly increasing)
    kinds   n x uint8      (1 array / 2 bitset / 3 run)
    cards   n x uint16     (cardinality - 1; a container is never empty)
    payloads, per container:
      array : card x uint16 values (strictly increasing)
      bitset: 1024 x uint64 words  (popcount must equal card)
      run   : uint16 n_runs, then n_runs x (uint16 start, uint16 length)
              (runs disjoint, ascending, in-bounds; lengths sum to card)

Robustness contract: ``deserialize`` of ANY corrupted buffer raises
``ValueError`` -- never a crash, hang, or a silently-wrong bitmap.  Two
layers enforce it: the CRC rejects every byte flip up front (CRC-32
catches all error bursts <= 32 bits, so every single-byte corruption),
and structural validation (sorted keys, per-kind payload invariants,
card cross-checks, no trailing bytes) rejects buffers that were built
wrong rather than damaged in flight.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

from repro.core.bitmap import RoaringBitmap
from repro.core.containers import (
    ArrayContainer, BitsetContainer, RunContainer, BITSET_WORDS,
)

MAGIC = b"RJ02"


def serialize(bm: RoaringBitmap) -> bytes:
    n = len(bm.keys)
    parts = [struct.pack("<I", n)]
    parts.append(np.asarray(bm.keys, dtype=np.uint16).tobytes())
    kinds, cards = [], []
    for c in bm.containers:
        kinds.append({"array": 1, "bitset": 2, "run": 3}[c.kind])
        cards.append(c.card - 1)
    parts.append(np.asarray(kinds, dtype=np.uint8).tobytes())
    parts.append(np.asarray(cards, dtype=np.uint16).tobytes())
    for c in bm.containers:
        if isinstance(c, ArrayContainer):
            parts.append(c.values.tobytes())
        elif isinstance(c, BitsetContainer):
            parts.append(c.words.tobytes())
        else:
            runs = c.runs.astype(np.uint16)
            parts.append(struct.pack("<H", runs.shape[0]))
            parts.append(runs.tobytes())
    body = b"".join(parts)
    return MAGIC + struct.pack("<I", zlib.crc32(body)) + body


def _need(buf: bytes, off: int, nbytes: int, what: str) -> None:
    """Bounds check with an actionable message (truncated/corrupt payloads
    must fail with ValueError, never a bare struct/buffer error)."""
    if off + nbytes > len(buf):
        raise ValueError(
            f"truncated roaring payload: need {nbytes} byte(s) for {what} "
            f"at offset {off}, but only {len(buf) - off} remain")


def deserialize(buf: bytes) -> RoaringBitmap:
    buf = bytes(buf)
    _need(buf, 0, 12, "header")
    if buf[:4] != MAGIC:
        raise ValueError("bad magic; not an RJ02 roaring payload")
    (crc,) = struct.unpack_from("<I", buf, 4)
    if zlib.crc32(buf[8:]) != crc:
        raise ValueError("checksum mismatch; corrupt roaring payload")
    (n,) = struct.unpack_from("<I", buf, 8)
    off = 12
    _need(buf, off, 5 * n, f"directory of {n} container(s)")
    keys = np.frombuffer(buf, dtype=np.uint16, count=n, offset=off)
    off += 2 * n
    kinds = np.frombuffer(buf, dtype=np.uint8, count=n, offset=off)
    off += n
    cards = np.frombuffer(buf, dtype=np.uint16, count=n, offset=off)
    off += 2 * n
    if n > 1 and not (keys[1:] > keys[:-1]).all():
        raise ValueError("container keys not strictly increasing")
    out_keys, out_conts = [], []
    for i in range(n):
        card = int(cards[i]) + 1
        kind = int(kinds[i])
        if kind == 1:
            _need(buf, off, 2 * card, f"array container {i} ({card} values)")
            vals = np.frombuffer(buf, dtype=np.uint16, count=card, offset=off)
            off += 2 * card
            if card > 1 and not (vals[1:] > vals[:-1]).all():
                raise ValueError(
                    f"array container {i}: values not strictly increasing")
            out_conts.append(ArrayContainer(vals.copy()))
        elif kind == 2:
            _need(buf, off, 8 * BITSET_WORDS, f"bitset container {i}")
            words = np.frombuffer(buf, dtype=np.uint64,
                                  count=BITSET_WORDS, offset=off)
            off += 8 * BITSET_WORDS
            pop = int(np.bitwise_count(words).sum())
            if pop != card:
                raise ValueError(
                    f"bitset container {i}: stored cardinality {card} "
                    f"!= popcount {pop}")
            out_conts.append(BitsetContainer(words.copy(), card))
        elif kind == 3:
            _need(buf, off, 2, f"run count of container {i}")
            (nr,) = struct.unpack_from("<H", buf, off)
            off += 2
            _need(buf, off, 4 * nr, f"run container {i} ({nr} runs)")
            runs = np.frombuffer(buf, dtype=np.uint16, count=2 * nr,
                                 offset=off).reshape(nr, 2)
            off += 4 * nr
            starts = runs[:, 0].astype(np.int64)
            ends = starts + runs[:, 1].astype(np.int64)
            if nr == 0 or (ends > 0xFFFF).any() or \
                    (nr > 1 and (starts[1:] <= ends[:-1] + 1).any()):
                raise ValueError(
                    f"run container {i}: runs not disjoint ascending "
                    f"in-bounds intervals")
            if int((ends - starts + 1).sum()) != card:
                raise ValueError(
                    f"run container {i}: stored cardinality {card} "
                    f"!= run length total")
            out_conts.append(RunContainer(runs.astype(np.int32)))
        else:
            raise ValueError(f"bad container kind {kind}")
        out_keys.append(int(keys[i]))
    if off != len(buf):
        raise ValueError(
            f"trailing garbage: {len(buf) - off} byte(s) past the last "
            f"container payload")
    return RoaringBitmap(out_keys, out_conts)


def serialized_size_bytes(bm: RoaringBitmap) -> int:
    return len(serialize(bm))
