"""RoaringBitmap: the paper's two-level data structure (host path).

A Roaring bitmap is a sorted list of 16-bit keys (the high half of each
present 32-bit value) paired with containers holding the low halves
(paper section 1, Fig. 1).  This class reproduces CRoaring's public surface:
construction, membership, set algebra (two-by-two and wide), count-only
("fast count") variants, run optimization, memory accounting, and a compact
serialization format.

The top level is scalar python (as in CRoaring the top level is scalar C);
all heavy lifting happens inside the vectorized container layer.

docs/ARCHITECTURE.md maps every paper section to its module and
documents the one-dispatch-per-class contract the query surface below
rides on.
"""

from __future__ import annotations

import bisect

import numpy as np

from repro.core import containers as C
from repro.core.containers import (
    ArrayContainer, BitsetContainer, RunContainer, Container,
    container_from_values, optimize,
)

__all__ = ["RoaringBitmap"]


class RoaringBitmap:
    """Compressed set of uint32 values."""

    __slots__ = ("keys", "containers", "_prefix", "_version")

    def __init__(self, keys: list[int] | None = None,
                 conts: list[Container] | None = None):
        self.keys: list[int] = keys if keys is not None else []
        self.containers: list[Container] = conts if conts is not None else []
        self._prefix: np.ndarray | None = None    # cumulative cards cache
        # bumped by every mutator (add/remove/run_optimize): caches over
        # live bitmaps (SimilarityEngine snapshots) revalidate against it
        self._version: int = 0

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_values(cls, values) -> "RoaringBitmap":
        """Build from any iterable / array of uint32 values (deduplicated)."""
        arr = np.asarray(values, dtype=np.uint32)
        if arr.size == 0:
            return cls()
        arr = np.unique(arr)                     # sorted + distinct
        his = (arr >> np.uint32(16)).astype(np.int64)
        los = arr.astype(np.uint16)              # low 16 bits
        keys_u, starts = np.unique(his, return_index=True)
        bounds = np.concatenate((starts, [arr.size]))
        keys, conts = [], []
        for i, k in enumerate(keys_u.tolist()):
            chunk = los[bounds[i]:bounds[i + 1]]
            keys.append(int(k))
            conts.append(container_from_values(chunk))
        return cls(keys, conts)

    @classmethod
    def from_range(cls, start: int, stop: int) -> "RoaringBitmap":
        """Dense range [start, stop) -- built directly as run containers."""
        if stop <= start:
            return cls()
        keys, conts = [], []
        k0, k1 = start >> 16, (stop - 1) >> 16
        for k in range(k0, k1 + 1):
            lo = start - (k << 16) if k == k0 else 0
            hi = (stop - 1) - (k << 16) if k == k1 else 0xFFFF
            keys.append(k)
            conts.append(RunContainer(np.array([[lo, hi - lo]],
                                               dtype=np.int32)))
        return cls(keys, conts)

    def copy(self) -> "RoaringBitmap":
        return RoaringBitmap(list(self.keys), list(self.containers))

    # ------------------------------------------------------------------
    # basic queries
    # ------------------------------------------------------------------

    def _card_prefix(self) -> np.ndarray:
        """Cached cumulative container cardinalities (paper section 6):
        rank/select navigate the top level with ONE binary search instead
        of a scalar per-container scan.  Invalidated by ``add`` /
        ``remove`` / ``run_optimize``."""
        if self._prefix is None or \
                self._prefix.size != len(self.containers):
            self._prefix = np.cumsum(
                [c.card for c in self.containers]).astype(np.int64)
        return self._prefix

    @property
    def cardinality(self) -> int:
        p = self._card_prefix()
        return int(p[-1]) if p.size else 0

    def __len__(self) -> int:
        return self.cardinality

    def __bool__(self) -> bool:
        return bool(self.containers)

    def __contains__(self, v: int) -> bool:
        """Logarithmic random access (paper section 1): binary search the key,
        then probe the container."""
        i = bisect.bisect_left(self.keys, int(v) >> 16)
        if i == len(self.keys) or self.keys[i] != int(v) >> 16:
            return False
        return self.containers[i].contains(int(v) & 0xFFFF)

    def contains_many(self, values) -> np.ndarray:
        """Vectorized membership for an array of uint32 values."""
        arr = np.asarray(values, dtype=np.uint32)
        out = np.zeros(arr.size, dtype=bool)
        if not self.keys:
            return out
        his = (arr >> np.uint32(16)).astype(np.int64)
        keys_np = np.asarray(self.keys, dtype=np.int64)
        idx = np.searchsorted(keys_np, his)
        idx_c = np.minimum(idx, keys_np.size - 1)
        hit = keys_np[idx_c] == his
        for ci in np.unique(idx_c[hit]).tolist():
            sel = hit & (idx_c == ci)
            lo = arr[sel].astype(np.uint16)
            cont = self.containers[ci]
            if isinstance(cont, BitsetContainer):
                out[sel] = C.bitset_test_many(cont.words, lo)
            elif isinstance(cont, ArrayContainer):
                pos = np.searchsorted(cont.values, lo)
                pos[pos == cont.values.size] = max(cont.values.size - 1, 0)
                out[sel] = (cont.values[pos] == lo) if cont.values.size else False
            else:
                out[sel] = np.fromiter(
                    (cont.contains(int(x)) for x in lo), bool, lo.size)
        return out

    def to_array(self) -> np.ndarray:
        """All values, sorted, as uint32 (sequential access, paper sec 5.5)."""
        parts = []
        for k, c in zip(self.keys, self.containers):
            parts.append((np.uint32(k) << np.uint32(16)) |
                         c.to_array_values().astype(np.uint32))
        if not parts:
            return np.zeros(0, dtype=np.uint32)
        return np.concatenate(parts)

    def __iter__(self):
        return iter(self.to_array().tolist())

    def __eq__(self, other) -> bool:
        if not isinstance(other, RoaringBitmap):
            return NotImplemented
        return np.array_equal(self.to_array(), other.to_array())

    def __hash__(self):  # content hash for caching in the data pipeline
        return hash(self.to_array().tobytes())

    # ------------------------------------------------------------------
    # point updates
    # ------------------------------------------------------------------

    def add(self, v: int) -> None:
        self._prefix = None                      # invalidate rank cache
        self._version += 1
        hi, lo = int(v) >> 16, int(v) & 0xFFFF
        i = bisect.bisect_left(self.keys, hi)
        if i < len(self.keys) and self.keys[i] == hi:
            cont = self.containers[i]
            if isinstance(cont, BitsetContainer):
                # copy-on-write: wide aggregates pass containers through
                # zero-copy, so point updates must never mutate in place
                words = cont.words.copy()
                delta = C.bitset_set_many(
                    words, np.array([lo], dtype=np.uint16))
                self.containers[i] = BitsetContainer(words,
                                                     cont.card + delta)
            else:
                vals = cont.to_array_values()
                j = int(np.searchsorted(vals, np.uint16(lo)))
                if j < vals.size and int(vals[j]) == lo:
                    return
                vals = np.insert(vals, j, np.uint16(lo))
                self.containers[i] = container_from_values(vals)
        else:
            self.keys.insert(i, hi)
            self.containers.insert(
                i, ArrayContainer(np.array([lo], dtype=np.uint16)))

    def remove(self, v: int) -> None:
        self._prefix = None                      # invalidate rank cache
        self._version += 1
        hi, lo = int(v) >> 16, int(v) & 0xFFFF
        i = bisect.bisect_left(self.keys, hi)
        if i == len(self.keys) or self.keys[i] != hi:
            return
        cont = self.containers[i]
        if isinstance(cont, BitsetContainer):
            words = cont.words.copy()              # copy-on-write, as in add
            delta = C.bitset_clear_many(
                words, np.array([lo], dtype=np.uint16))
            cont = BitsetContainer(words, cont.card - delta)
            self.containers[i] = cont
            # paper: deleting from a bitset container may force an array
            # conversion (Roaring tracks cardinality; BitMagic cannot)
            if cont.card <= C.ARRAY_MAX:
                self.containers[i] = ArrayContainer(cont.to_array_values())
        else:
            vals = cont.to_array_values()
            j = int(np.searchsorted(vals, np.uint16(lo)))
            if j >= vals.size or int(vals[j]) != lo:
                return
            vals = np.delete(vals, j)
            self.containers[i] = container_from_values(vals)
        if self.containers[i].card == 0:
            del self.keys[i]
            del self.containers[i]

    # ------------------------------------------------------------------
    # two-by-two set algebra (key-merge at the top, paper layout)
    # ------------------------------------------------------------------

    def _merge(self, other: "RoaringBitmap", op: str) -> "RoaringBitmap":
        """Two-by-two set algebra through the type-grouped pair planner
        (repro.core.pairwise): matched container pairs bucket by class
        (bitset x bitset, array x array, array x bitset) and each class
        executes as ONE batched dispatch; small pairs stay on the scalar
        key-merge (paper sections 4.2-4.5)."""
        from repro.core import pairwise
        return pairwise.merge_one(self, other, op)

    def __and__(self, other):
        return self._merge(other, "and")

    def __or__(self, other):
        return self._merge(other, "or")

    def __xor__(self, other):
        return self._merge(other, "xor")

    def __sub__(self, other):
        return self._merge(other, "andnot")

    def andnot(self, other):
        return self._merge(other, "andnot")

    # ------------------------------------------------------------------
    # count-only ("fast count", paper section 5.9) and similarity
    # ------------------------------------------------------------------

    def and_card(self, other: "RoaringBitmap") -> int:
        """Intersection cardinality without materializing the result
        (paper section 5.9), planned as a batch of one pair.

        Returns int.  Complexity: O(matched containers) with at most one
        kernel dispatch per container-type class (tiny pairs stay on the
        scalar host merge).  See docs/ARCHITECTURE.md section 2."""
        from repro.core import pairwise
        return int(pairwise.pairwise_card("and", [(self, other)])[0])

    def or_card(self, other) -> int:
        return self.cardinality + other.cardinality - self.and_card(other)

    def andnot_card(self, other) -> int:
        return self.cardinality - self.and_card(other)

    def xor_card(self, other) -> int:
        return (self.cardinality + other.cardinality
                - 2 * self.and_card(other))

    def jaccard(self, other) -> float:
        inter = self.and_card(other)
        union = self.cardinality + other.cardinality - inter
        return inter / union if union else 1.0

    def cosine(self, other) -> float:
        inter = self.and_card(other)
        denom = (self.cardinality * other.cardinality) ** 0.5
        return inter / denom if denom else 1.0

    def intersects(self, other) -> bool:
        return self.and_card(other) > 0

    # ------------------------------------------------------------------
    # batched pairwise engine (similarity joins: "Compressed bitmap
    # indexes: beyond unions and intersections", Kaser & Lemire)
    # ------------------------------------------------------------------

    @staticmethod
    def pairwise_card(ops, pairs, *, backend=None) -> np.ndarray:
        """Count-only set algebra over M bitmap pairs in O(container-type
        classes) dispatches (not O(pairs)).

        Args: ``ops`` is one of "and" | "or" | "xor" | "andnot" or a
        length-M sequence of per-pair op names; ``pairs`` is a sequence
        of ``(RoaringBitmap, RoaringBitmap)``; ``backend`` forces the
        kernel ("pallas"/"ref") or host-twin (CPU default) path.

        Returns (M,) int64 counts.  Complexity: every count derives from
        the pair's AND cardinality by inclusion-exclusion (paper section
        5.9); the CPU twins scale with total postings, never postings x
        pairs.  See docs/ARCHITECTURE.md sections 2-3."""
        from repro.core import pairwise
        return pairwise.pairwise_card(ops, pairs, backend=backend)

    @staticmethod
    def jaccard_matrix(bitmaps, *, backend=None) -> np.ndarray:
        """(N, N) float64 Jaccard similarity matrix: the all-pairs
        similarity join, batched class-wise over all N*(N-1)/2 pairs
        (diagonal is 1.0; empty-vs-empty scores 1.0 by convention).
        Complexity: O(container-type classes) dispatches regardless of
        N.  For top-k neighbour queries use
        ``repro.core.pairwise.SimilarityEngine`` instead -- it never
        materializes the full matrix."""
        from repro.core import pairwise
        return pairwise.jaccard_matrix(bitmaps, backend=backend)

    # ------------------------------------------------------------------
    # wide aggregates (paper section 5.8: roaring_bitmap_or_many), routed
    # through the segmented-aggregation planner (repro.core.aggregate):
    # containers sharing a chunk key are stacked into one slab and reduced
    # with a single fused kernel dispatch, regardless of K.
    # ------------------------------------------------------------------

    @staticmethod
    def or_many(bitmaps: list["RoaringBitmap"], *,
                mesh=None, arena=None) -> "RoaringBitmap":
        """Wide union (paper section 5.8, ``roaring_bitmap_or_many``).

        Args: ``bitmaps`` any iterable of RoaringBitmap; ``mesh`` an
        optional multi-device mesh (rows shard round-robin, partials
        all-reduce with OR -- bit-identical to the 1-device plan);
        ``arena`` an optional ``core.arena.BitmapArena`` -- containers
        already adopted dispatch from the resident device slab with no
        per-call staging (docs/MEMORY.md), bit-identical either way.

        Returns a new RoaringBitmap.  Complexity: one segmented-kernel
        dispatch for any K after the planner's zero-copy / host fast
        paths (docs/ARCHITECTURE.md section 3 has the full table)."""
        from repro.core import aggregate
        return aggregate.or_many(bitmaps, mesh=mesh, arena=arena)

    @staticmethod
    def and_many(bitmaps: list["RoaringBitmap"], *,
                 mesh=None, arena=None) -> "RoaringBitmap":
        """Wide intersection with cardinality-ascending key pruning and
        empty-key early exit at the top level (the paper's AND planning
        generalized to K inputs).

        Args as ``or_many`` (including ``arena``); the sharded path
        exchanges a per-shard occupancy mask so row-less shards
        contribute the AND identity.  Returns a new RoaringBitmap; one
        dispatch for the dense remainder.  See docs/ARCHITECTURE.md
        sections 3 and 5."""
        from repro.core import aggregate
        return aggregate.and_many(bitmaps, mesh=mesh, arena=arena)

    @staticmethod
    def xor_many(bitmaps: list["RoaringBitmap"], *,
                 mesh=None, arena=None) -> "RoaringBitmap":
        """Wide symmetric difference: values present in an odd number of
        inputs.  Args/returns/complexity as ``or_many`` (including
        ``arena``)."""
        from repro.core import aggregate
        return aggregate.xor_many(bitmaps, mesh=mesh, arena=arena)

    @staticmethod
    def andnot_many(minuend: "RoaringBitmap",
                    subtrahends: list["RoaringBitmap"], *,
                    mesh=None, arena=None) -> "RoaringBitmap":
        """Difference chain ``a - (b1 | b2 | ...)`` as ONE fused plan:
        the subtrahend union is never materialized (subtrahends OR into
        VMEM scratch, ANDNOT + popcount fuse into finalization).

        Args: ``minuend`` the kept bitmap, ``subtrahends`` the dropped
        ones, ``mesh`` / ``arena`` as in ``or_many`` (minuend replicated
        per shard).  Returns a new RoaringBitmap; one dispatch for the
        dense remainder."""
        from repro.core import aggregate
        return aggregate.andnot_many(minuend, subtrahends, mesh=mesh,
                                     arena=arena)

    @staticmethod
    def threshold_many(bitmaps: list["RoaringBitmap"], t: int, *,
                       weights=None, mesh=None,
                       arena=None) -> "RoaringBitmap":
        """T-occurrence query ("Threshold and Symmetric Functions over
        Bitmaps", Kaser & Lemire): values whose occurrence count across
        the inputs reaches ``t``.

        Args: ``t`` runtime threshold (sweeps over the same inputs share
        one compiled kernel); ``weights`` optional per-bitmap positive
        int weights (shift-and-add into the bit-sliced counter circuit;
        weight 1 degenerates to the unweighted plan); ``mesh`` /
        ``arena`` as in ``or_many`` (counters all-gather and add
        bit-sliced).

        Returns a new RoaringBitmap; one dispatch for the dense
        remainder regardless of K."""
        from repro.core import aggregate
        return aggregate.threshold_many(bitmaps, t, weights=weights,
                                        mesh=mesh, arena=arena)

    # ------------------------------------------------------------------
    # serialization (paper section 5.1; docs/FORMAT.md)
    # ------------------------------------------------------------------

    def serialize(self, format: str = "rj02") -> bytes:
        """Serialize to one of the three wire formats (docs/FORMAT.md):
        ``"rj02"`` (private, CRC-checksummed), ``"portable"`` (the
        CRoaring/RoaringFormatSpec interchange layout, paper section
        5.1) or ``"frozen"`` (zero-copy mmap layout whose deserialize
        is pure views).  Returns ``bytes``; complexity O(payload
        bytes).  Module-level twins live in ``repro.core.serde``."""
        from repro.core import serde
        try:
            fn = {"rj02": serde.serialize,
                  "portable": serde.serialize_portable,
                  "frozen": serde.serialize_frozen}[format]
        except KeyError:
            raise ValueError(
                f"unknown serialization format {format!r}") from None
        return fn(self)

    @classmethod
    def deserialize(cls, buf, format: str = "auto") -> "RoaringBitmap":
        """Parse any of the three wire formats (docs/FORMAT.md).

        Args: ``buf`` bytes-like (or ``np.memmap`` for the frozen
        zero-copy path); ``format`` one of ``"auto"`` (sniff the
        magic/cookie), ``"rj02"``, ``"portable"``, ``"frozen"``.

        Returns a RoaringBitmap (frozen buffers yield view-backed
        containers -- zero payload copies).  Raises ``ValueError``
        with byte offset + container index on corruption."""
        from repro.core import serde
        if format == "auto":
            format = serde.sniff_format(buf)
        try:
            fn = {"rj02": serde.deserialize,
                  "portable": serde.deserialize_portable,
                  "frozen": serde.deserialize_frozen}[format]
        except KeyError:
            raise ValueError(
                f"unknown serialization format {format!r}") from None
        return fn(buf)

    # ------------------------------------------------------------------
    # maintenance (paper: run_optimize / shrink_to_fit)
    # ------------------------------------------------------------------

    def run_optimize(self) -> "RoaringBitmap":
        self.containers = [optimize(c) for c in self.containers]
        self._prefix = None                      # invalidate rank cache
        self._version += 1
        return self

    def memory_bytes(self) -> int:
        """Estimated in-memory footprint (paper section 5.4 accounting):
        per-container payload + 8 bytes/container of key+type+card overhead
        + 16 bytes of top-level header."""
        payload = sum(c.memory_bytes() for c in self.containers)
        return payload + 8 * len(self.containers) + 16

    def bits_per_value(self) -> float:
        card = self.cardinality
        return 8.0 * self.memory_bytes() / card if card else float("inf")

    # ------------------------------------------------------------------
    # rank / select (advanced queries, paper section 6)
    # ------------------------------------------------------------------

    def rank(self, v: int) -> int:
        """Number of elements <= v: one binary search over the cached
        cumulative-cardinality prefix (paper section 6), then a per-kind
        in-container rank -- no per-container Python loop."""
        hi, lo = int(v) >> 16, int(v) & 0xFFFF
        if not self.keys:
            return 0
        prefix = self._card_prefix()
        i = bisect.bisect_left(self.keys, hi)
        base = int(prefix[i - 1]) if i > 0 else 0
        if i < len(self.keys) and self.keys[i] == hi:
            return base + C.container_rank(self.containers[i], lo)
        return base

    def select(self, i: int) -> int:
        """i-th smallest element (0-based): binary search the cached
        prefix for the owning container, then a per-kind in-container
        select (paper section 6)."""
        i = int(i)
        if i < 0:
            raise IndexError(i)
        prefix = self._card_prefix()
        if prefix.size == 0 or i >= int(prefix[-1]):
            raise IndexError("select out of range")
        j = int(np.searchsorted(prefix, i, side="right"))
        local = i - (int(prefix[j - 1]) if j else 0)
        return (self.keys[j] << 16) | \
            C.container_select(self.containers[j], local)

    def min(self) -> int:
        if not self.containers:
            raise ValueError("empty bitmap")
        return self.select(0)

    def max(self) -> int:
        if not self.containers:
            raise ValueError("empty bitmap")
        c = self.containers[-1]
        return (self.keys[-1] << 16) | C.container_select(c, c.card - 1)

    def __repr__(self) -> str:
        kinds = {}
        for c in self.containers:
            kinds[c.kind] = kinds.get(c.kind, 0) + 1
        return (f"RoaringBitmap(card={self.cardinality}, "
                f"containers={len(self.containers)}, kinds={kinds})")
