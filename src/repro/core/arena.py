"""Device-resident bitmap arena: promote containers ONCE, query forever.

Every kernel path in this repo used to re-stage containers from host
numpy into a fresh padded slab on each call (``aggregate._dispatch``
pad/stack/transfer, ``pairwise`` per-class staging).  ``BitmapArena``
fixes that on the hot path: container rows are promoted once into a
device-resident slab, a host-side directory maps container objects to
slab rows, and warm queries move only row *ids*, segment offsets, and
results over PCIe -- never container payloads.

Layout and lifecycle (see docs/MEMORY.md for diagrams):

* **Host mirror** ``_host`` -- ``(capacity, 1024)`` uint64, the
  authoritative copy.  Row 0 is permanently reserved all-zero so kernel
  paths can pad ragged segments with id 0.
* **Device slab** ``_dev`` -- ``(capacity, 2048)`` uint32 ``jax`` array,
  uploaded lazily on the first :meth:`device_slab` call.  Edits batch
  into ONE scatter (``slab.at[ids].set(rows)``); the functional update
  allocates a fresh device buffer, so in-flight dispatches that captured
  the previous slab stay valid -- copy-on-write for free.
* **Directory** -- ``id(container) -> row``.  Correctness is structural,
  not generational: ``RoaringBitmap`` mutators replace container objects
  copy-on-write (the PR 6 ``_version`` audit), so a stale bitmap's new
  containers simply *miss* the lookup and are staged from host --
  bit-identical either way.  The per-bitmap ``_version`` snapshot only
  decides *when* :meth:`adopt` re-walks a bitmap; rows shared between
  bitmaps are refcounted.

Typical use::

    arena = BitmapArena()
    arena.adopt_many(bitmaps)                    # promote once
    or_many(bitmaps, arena=arena)                # warm: zero row uploads
    bitmaps[0].add(7)                            # host edit
    arena.adopt(bitmaps[0])                      # patches 1 row, 1 scatter

Complexity: :meth:`adopt` is O(changed containers) host work plus one
O(changed rows) device scatter; :meth:`lookup` is a dict hit; warm
dispatch gathers rows on-device (no PCIe).  ``docs/ARCHITECTURE.md`` §7
covers the data flow, ``docs/MEMORY.md`` the memory lifecycle.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import containers as C
from repro.kernels.ref import WORDS


@dataclasses.dataclass
class ArenaStats:
    """Monotone transfer/patch counters -- the observability contract the
    zero-transfer tests assert against.

    ``rows_uploaded`` counts every container row that crossed host ->
    device (initial slab upload + incremental patches); a warm re-query
    must leave it unchanged.  ``host_rows_staged`` is bumped by
    ``aggregate._dispatch`` for each non-resident row it had to stage
    per-call (an arena *miss*).  ``device_gathers`` counts dispatches
    that gathered resident rows on-device (zero PCIe for those rows).
    """

    rows_promoted: int = 0      # container -> word-row promotions (host)
    rows_uploaded: int = 0      # rows that crossed host -> device
    rows_patched: int = 0       # scatter updates to already-device rows
    rows_freed: int = 0         # rows released back to the free list
    revalidations: int = 0      # adopt() calls that found a stale version
    device_gathers: int = 0     # on-device row gathers (no PCIe)
    host_rows_staged: int = 0   # per-call staged rows (arena misses)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Entry:
    """Per-registered-bitmap directory entry (strong refs keep ``id``
    keys valid for the arena's lifetime)."""
    bm: object
    version: int
    conts: dict            # chunk key -> container object at last adopt


class BitmapArena:
    """Device-resident container slab with generation-tracked
    incremental maintenance.  See the module docstring for the layout;
    ``docs/MEMORY.md`` walks the full lifecycle.

    Args:
        capacity: initial row capacity (grows by doubling; device growth
            concatenates zero rows on-device, never re-uploads).
    """

    def __init__(self, capacity: int = 64):
        cap = max(int(capacity), 2)
        self._host = np.zeros((cap, 1024), np.uint64)
        self._n = 1                       # row 0 reserved all-zero
        self._free: list[int] = []
        self._dev = None                  # lazy (capacity, WORDS) uint32
        self._dirty: list[int] = []       # host rows not yet scattered
        self._entries: dict[int, _Entry] = {}   # id(bm) -> _Entry
        self._row_of: dict[int, int] = {}       # id(container) -> row
        self._ref: dict[int, int] = {}          # row -> refcount
        self._shards: ShardSlabs | None = None  # lazy per-shard slab mode
        self.stats = ArenaStats()

    # -- directory ----------------------------------------------------

    def lookup(self, cont) -> int | None:
        """Row id for a *container object*, or None if not resident.

        Container identity IS the generation check: mutators replace
        container objects, so edited-but-not-readopted containers miss.
        """
        return self._row_of.get(id(cont))

    def resident(self, bm) -> bool:
        """True iff ``bm`` is registered at its current ``_version``."""
        e = self._entries.get(id(bm))
        return e is not None and e.version == bm._version

    @property
    def n_rows(self) -> int:
        """Allocated rows (including reserved row 0)."""
        return self._n - len(self._free)

    @property
    def capacity(self) -> int:
        """Slab row capacity (doubles on growth; 8 KiB per row)."""
        return self._host.shape[0]

    # -- adoption / incremental maintenance ---------------------------

    def adopt(self, bm) -> int:
        """Register ``bm`` (or revalidate its generation), promoting only
        containers that changed since the last adopt.

        Returns the number of rows promoted/re-promoted (0 when the
        version snapshot matches -- the warm no-op).  Dirty rows are
        batched; the single device scatter happens lazily at the next
        :meth:`device_slab` / :meth:`sync`.
        """
        e = self._entries.get(id(bm))
        if e is not None and e.version == bm._version:
            return 0
        if e is None:
            e = _Entry(bm, -1, {})
            self._entries[id(bm)] = e
        else:
            self.stats.revalidations += 1
        cur = dict(zip(bm.keys, bm.containers))
        for k, old in list(e.conts.items()):
            if cur.get(k) is old:
                continue
            self._release_cont(old)
            del e.conts[k]
        changed = 0
        for k, c in cur.items():
            if e.conts.get(k) is c:
                continue
            self._register_cont(c)
            e.conts[k] = c
            changed += 1
        e.version = bm._version
        return changed

    def adopt_many(self, bitmaps) -> int:
        """:meth:`adopt` each bitmap; returns total rows promoted."""
        return sum(self.adopt(bm) for bm in bitmaps)

    def adopt_frozen(self, bitmaps) -> int:
        """Bulk-promote an entire (typically frozen / mmap-backed)
        snapshot into the slab: ONE vectorized host conversion and ONE
        host->device transfer, instead of per-container Python work.

        Args: ``bitmaps`` -- a single RoaringBitmap or an iterable of
        them (e.g. ``snapshot.bitmaps.values()`` from
        ``repro.core.serde.read_snapshot``); frozen view-backed and
        ordinary bitmaps both work, and results are bit-identical to
        per-bitmap :meth:`adopt`.

        Every container not yet resident is converted in one batched
        ``containers_to_word_rows`` sweep (bitset rows gathered
        vectorized, arrays/runs through one shared indicator +
        packbits pass) and lands in the device slab in a single
        scatter at the next :meth:`device_slab` / :meth:`sync` --
        ``ArenaStats.rows_uploaded`` grows by exactly the new row
        count.  Returns the number of rows promoted.  Complexity:
        O(total new payload bytes) host work + one device transfer;
        registered-and-current bitmaps cost O(1) each.
        """
        if hasattr(bitmaps, "containers"):      # a single RoaringBitmap
            bitmaps = [bitmaps]
        bitmaps = list(bitmaps)
        fresh, seen = [], set()
        for bm in bitmaps:
            e = self._entries.get(id(bm))
            if e is not None and e.version == bm._version:
                continue
            for c in bm.containers:
                ci = id(c)
                if ci not in self._row_of and ci not in seen:
                    seen.add(ci)
                    fresh.append(c)
        if fresh:
            rows = C.containers_to_word_rows(fresh)
            ids = [self._alloc() for _ in fresh]
            self._host[np.asarray(ids)] = rows
            for c, rid in zip(fresh, ids):
                self._row_of[id(c)] = rid
                self._ref[rid] = 0              # adopt() bumps it below
            self.stats.rows_promoted += len(fresh)
            self._note_dirty(ids)
        for bm in bitmaps:
            self.adopt(bm)
        return len(fresh)

    def revalidate(self) -> int:
        """Re-adopt every registered bitmap whose version moved (the
        query server's ``slab_mismatch`` rung).  Returns rows patched."""
        return sum(self.adopt(e.bm) for e in list(self._entries.values()))

    def release(self, bm) -> None:
        """Drop ``bm`` from the arena, freeing rows not shared with
        other registered bitmaps."""
        e = self._entries.pop(id(bm), None)
        if e is None:
            return
        for c in e.conts.values():
            self._release_cont(c)

    def _register_cont(self, c) -> int:
        rid = self._row_of.get(id(c))
        if rid is not None:
            self._ref[rid] += 1
            return rid
        rid = self._alloc()
        self._host[rid] = C.container_words64(c)
        self._row_of[id(c)] = rid
        self._ref[rid] = 1
        self.stats.rows_promoted += 1
        self._note_dirty([rid])
        return rid

    def _release_cont(self, c) -> None:
        rid = self._row_of.get(id(c))
        if rid is None:
            return
        self._ref[rid] -= 1
        if self._ref[rid] == 0:
            del self._ref[rid]
            del self._row_of[id(c)]
            self._free.append(rid)
            self.stats.rows_freed += 1

    def _note_dirty(self, ids) -> None:
        """Record host-mirror edits against every materialized device
        view: the single-device slab's dirty list AND (when the arena is
        in per-shard slab mode) the owning shard's pending set.  Views
        that were never uploaded skip the bookkeeping -- their first
        build reads the whole host mirror anyway."""
        if self._dev is not None:
            self._dirty.extend(ids)
        if self._shards is not None:
            self._shards.note_many(ids)

    def _alloc(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n == self._host.shape[0]:
            self._grow()
        rid = self._n
        self._n += 1
        return rid

    def _grow(self) -> None:
        cap = self._host.shape[0] * 2
        host = np.zeros((cap, 1024), np.uint64)
        host[: self._n] = self._host[: self._n]
        self._host = host
        if self._dev is not None:
            # Grow on-device: existing rows never cross PCIe again.
            pad = jnp.zeros((cap - self._dev.shape[0], WORDS), jnp.uint32)
            self._dev = jnp.concatenate([self._dev, pad])

    # -- host/device views --------------------------------------------

    def host_row(self, rid: int) -> np.ndarray:
        """(1024,) uint64 view of one row in the host mirror."""
        return self._host[int(rid)]

    def host_rows(self, ids) -> np.ndarray:
        """Gather ``ids`` rows from the host mirror (copy).  Same bytes
        as re-promoting the containers, so host twins stay bit-identical
        without re-running promotion."""
        return self._host[np.asarray(ids, np.int64)]

    def device_slab(self):
        """The resident ``(capacity, 2048)`` uint32 slab, uploading lazily
        on first call and flushing pending edits in ONE scatter after.

        The scatter is a functional update (fresh buffer): dispatches
        already in flight keep their captured slab -- copy-on-write.
        """
        if self._dev is None:
            # copy=True: on CPU backends jnp.asarray may ALIAS numpy
            # memory zero-copy, and an aliased slab would mutate under
            # in-flight consumers whenever the host mirror is edited --
            # exactly the copy-on-write contract this class documents.
            self._dev = jnp.array(
                self._host.view(np.uint32).reshape(-1, WORDS), copy=True)
            self.stats.rows_uploaded += self._n
            self._dirty = []
        elif self._dirty:
            ids = np.array(sorted(set(self._dirty)), np.int32)
            rows = np.ascontiguousarray(self._host[ids])
            rows32 = rows.view(np.uint32).reshape(len(ids), WORDS)
            self._dev = self._dev.at[jnp.asarray(ids)].set(
                jnp.asarray(rows32))
            self.stats.rows_uploaded += len(ids)
            self.stats.rows_patched += len(ids)
            self._dirty = []
        return self._dev

    def sync(self) -> None:
        """Flush pending patches (uploading the slab if it never was)
        and block until the device copy is ready (benchmark fencing).
        When the arena is in per-shard slab mode the shard slabs are
        fenced too."""
        self.device_slab().block_until_ready()
        if self._shards is not None:
            self._shards.sync()

    # -- per-shard slab mode -------------------------------------------

    def shard_slabs(self, mesh=None) -> "ShardSlabs":
        """Per-shard slab mode: the arena's rows round-robined across the
        devices of a 1-D ``("wide",)`` mesh (row ``r`` lives on shard
        ``r % S`` at local index ``r // S``), host mirror still
        authoritative, CoW patching per shard.

        The first call stripes the host mirror into ``S`` device-local
        slabs (one upload per shard); later calls return the same
        :class:`ShardSlabs`, flushing host edits shard-by-shard (only
        shards owning dirty rows pay a scatter).  Passing a different
        mesh rebuilds.  ``mesh=None`` resolves through the installed
        wide mesh (``dist.ctx.resolve_wide``)."""
        from repro.dist import ctx
        mesh, size, axis = ctx.resolve_wide(mesh)
        if mesh is None:
            raise ValueError("shard_slabs needs a mesh (none installed)")
        if self._shards is None or self._shards.mesh != mesh:
            self._shards = ShardSlabs(self, mesh, size, axis)
        return self._shards


class ShardSlabs:
    """Round-robin per-shard device slabs over a 1-D mesh -- the arena
    scale-out mode behind the sharded ``SimilarityEngine`` path.

    Layout (docs/MEMORY.md "Per-shard slab layout"):

    * global row ``r`` -> shard ``r % S``, local index ``r // S`` (the
      wide-aggregate round-robin, so the mapping never changes when the
      arena grows -- growth only pads each shard with device-local
      zeros, existing rows never cross PCIe again);
    * each shard holds a ``(cap_s, 2048)`` uint32 slab committed to its
      mesh device, ``cap_s = ceil(capacity / S)``;
    * :meth:`assembled` presents the ``S`` slabs as ONE global
      ``(S * cap_s, 2048)`` jax array sharded over the mesh axis --
      metadata-only assembly (``make_array_from_single_device_arrays``),
      no copies -- so global row ``r`` sits at assembled position
      ``(r % S) * cap_s + r // S`` (:meth:`positions`);
    * host edits batch into per-shard CoW scatters: only shards owning
      dirty rows re-patch, each in ONE functional ``.at[].set`` (in-
      flight dispatches keep their captured slabs).

    ``stats[s]`` is a per-shard :class:`ArenaStats`: shard uploads and
    patches are accounted *here*, not in the arena's global stats (which
    keep tracking the single-device slab) -- the warm-query zero-PCIe
    assertions sum these counters.
    """

    def __init__(self, arena: BitmapArena, mesh, size: int, axis: str):
        self.arena = arena
        self.mesh = mesh
        self.size = int(size)
        self.axis = axis
        self.cap_s = 0
        self._devs: list | None = None       # per-shard (cap_s, WORDS) u32
        self._assembled = None               # cached global sharded view
        self._pending: set[int] = set()      # global rows dirty since flush
        self.stats = [ArenaStats() for _ in range(self.size)]

    def note_many(self, ids) -> None:
        """Mark global rows dirty (called by the arena on host edits)."""
        if self._devs is not None:
            self._pending.update(int(r) for r in ids)

    def _devices(self):
        return list(self.mesh.devices.reshape(-1))

    def _ensure(self) -> None:
        """Build the per-shard slabs on first use; afterwards grow
        (device-local zero padding) and flush pending rows (per-shard
        CoW scatters)."""
        import jax
        S = self.size
        host = self.arena._host
        need = -(-host.shape[0] // S)
        if self._devs is None:
            devs = self._devices()
            self._devs = []
            for s in range(S):
                block = np.zeros((need, 1024), np.uint64)
                rows_s = host[s::S]
                block[: rows_s.shape[0]] = rows_s
                self._devs.append(jax.device_put(
                    block.view(np.uint32).reshape(-1, WORDS), devs[s]))
                self.stats[s].rows_uploaded += max(
                    0, -(-(self.arena._n - s) // S))
            self.cap_s = need
            self._pending.clear()
            self._assembled = None
            return
        if need > self.cap_s:
            devs = self._devices()
            for s in range(S):
                pad = jax.device_put(
                    jnp.zeros((need - self.cap_s, WORDS), jnp.uint32),
                    devs[s])
                self._devs[s] = jnp.concatenate([self._devs[s], pad])
            self.cap_s = need
            self._assembled = None
        if self._pending:
            devs = self._devices()
            by_shard: dict[int, list[int]] = {}
            for r in self._pending:
                by_shard.setdefault(r % S, []).append(r)
            for s, rids in by_shard.items():
                rids = np.array(sorted(rids), np.int64)
                rows32 = np.ascontiguousarray(
                    host[rids]).view(np.uint32).reshape(len(rids), WORDS)
                self._devs[s] = self._devs[s].at[
                    jnp.asarray(rids // S, jnp.int32)].set(
                        jax.device_put(rows32, devs[s]))
                self.stats[s].rows_uploaded += len(rids)
                self.stats[s].rows_patched += len(rids)
            self._pending.clear()
            self._assembled = None

    def assembled(self):
        """The global ``(S * cap_s, 2048)`` uint32 slab, sharded over the
        mesh axis -- zero-copy metadata assembly of the per-shard slabs,
        flushed first.  Index it with :meth:`positions`."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        self._ensure()
        if self._assembled is None:
            sharding = NamedSharding(self.mesh, PartitionSpec(self.axis))
            self._assembled = jax.make_array_from_single_device_arrays(
                (self.size * self.cap_s, WORDS), sharding, self._devs)
        return self._assembled

    def positions(self, ids):
        """Assembled-array positions of global rows ``ids`` (numpy).
        Builds/flushes the slabs first: positions are only meaningful
        against the CURRENT ``cap_s`` (growth changes the stride)."""
        self._ensure()
        ids = np.asarray(ids, np.int64)
        return (ids % self.size) * self.cap_s + ids // self.size

    def shard_slab(self, s: int):
        """Shard ``s``'s ``(cap_s, 2048)`` slab (flushed)."""
        self._ensure()
        return self._devs[s]

    def sync(self) -> None:
        """Flush every shard and block (benchmark fencing)."""
        self._ensure()
        for d in self._devs:
            d.block_until_ready()
