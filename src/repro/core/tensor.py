"""RoaringTensor: a fixed-capacity, jit-compatible device layout for batches
of Roaring bitmaps (DESIGN.md section 5).

Layout (B bitmaps, C container slots each):
    keys  (B, C) int32   -- chunk key (high 16 bits); SENTINEL for empty slots
    kinds (B, C) int32   -- 0 empty / 1 array / 2 bitset / 3 run
    cards (B, C) int32   -- tracked cardinality (the paper tracks it; we do too)
    aux   (B, C) int32   -- run count for run slots, 0 otherwise
    slab  (B, C, 4096) uint16 -- 8 kB payload:
        array : sorted values, tail padded with 0xFFFF
        bitset: 4096 16-bit words (bit i at word i>>4, position i&15)
        run   : interleaved [start0, len0, start1, len1, ...]

Every CRoaring container is <= 8 kB, so the uniform slab wastes < 2x vs the
ideal dynamic layout while giving static shapes; the *HBM* footprint of a
stored bitmap is still governed by the container kinds via `packed_nbytes`.

Compute plan (DESIGN.md section 3): binary algebra normalizes both operands
to the bitset domain (two VPU registers per container on TPU), runs the fused
logical-op+popcount kernel, then `repack()` re-derives the memory-optimal
kinds -- mirroring roaring_bitmap_run_optimize.  Keys are aligned with a
static-capacity sorted merge.  Count-only variants never materialize results
(paper section 5.9).

docs/ARCHITECTURE.md section 2 lists this class's dispatch bounds next
to the host planners'.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmap import RoaringBitmap
from repro.core.containers import (
    ARRAY_MAX, ArrayContainer, BitsetContainer, RunContainer,
)
from repro.kernels import ops as kops
from repro.kernels.ref import PAIR_OPS, WORDS, CONTAINER_BITS

SENTINEL = np.int32(0x7FFFFFFF)
KIND_EMPTY, KIND_ARRAY, KIND_BITSET, KIND_RUN = 0, 1, 2, 3
SLAB16 = 4096  # uint16 entries per slab


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RoaringTensor:
    keys: jax.Array    # (B, C) int32
    kinds: jax.Array   # (B, C) int32
    cards: jax.Array   # (B, C) int32
    aux: jax.Array     # (B, C) int32
    slab: jax.Array    # (B, C, SLAB16) uint16

    # -- pytree plumbing ------------------------------------------------
    def tree_flatten(self):
        return (self.keys, self.kinds, self.cards, self.aux, self.slab), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)

    # -- basic properties -----------------------------------------------
    @property
    def batch(self) -> int:
        return self.keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.keys.shape[1]

    def cardinality(self) -> jax.Array:
        """(B,) int32 total cardinalities -- a pure reduction over the
        tracked per-container cards (the paper tracks them; so do we),
        O(B * C), jit-able, no kernel dispatch."""
        return jnp.where(self.kinds > 0, self.cards, 0).sum(axis=1)

    def take(self, idx) -> "RoaringTensor":
        """Device gather of batch rows: ``take(idx).keys[i] ==
        keys[idx[i]]`` for every component array.  jit-able; rows may
        repeat, so index-driven pair joins never bridge through host
        lists (see ``pairwise_card``).  Concrete out-of-range indices
        raise IndexError (jnp.take would silently fill); traced indices
        cannot be validated and are the caller's contract."""
        idx = jnp.asarray(idx, jnp.int32)
        if not isinstance(idx, jax.core.Tracer) and idx.size:
            iv = np.asarray(idx)
            if int(iv.min()) < 0 or int(iv.max()) >= self.batch:
                raise IndexError(
                    f"batch index out of range [0, {self.batch}): "
                    f"{int(iv.min())}..{int(iv.max())}")
        return RoaringTensor(*(jnp.take(x, idx, axis=0)
                               for x in (self.keys, self.kinds, self.cards,
                                         self.aux, self.slab)))

    def packed_nbytes(self) -> jax.Array:
        """(B,) int32: serialized footprint implied by the container kinds
        (what HBM/storage would hold after compaction) -- the device twin of
        RoaringBitmap.memory_bytes."""
        per = jnp.where(
            self.kinds == KIND_ARRAY, 2 * self.cards,
            jnp.where(self.kinds == KIND_BITSET, 2 * SLAB16,
                      jnp.where(self.kinds == KIND_RUN, 4 * self.aux + 2, 0)))
        overhead = jnp.where(self.kinds > 0, 8, 0)
        return (per + overhead).sum(axis=1) + 16

    # ====================================================================
    # construction
    # ====================================================================

    @staticmethod
    def from_bitmaps(bitmaps: list[RoaringBitmap],
                     capacity: int | None = None) -> "RoaringTensor":
        """Host -> device bridge (not jit-able)."""
        b = len(bitmaps)
        cap = capacity or max(1, max((len(bm.keys) for bm in bitmaps),
                                     default=1))
        keys = np.full((b, cap), SENTINEL, np.int32)
        kinds = np.zeros((b, cap), np.int32)
        cards = np.zeros((b, cap), np.int32)
        aux = np.zeros((b, cap), np.int32)
        slab = np.zeros((b, cap, SLAB16), np.uint16)
        for i, bm in enumerate(bitmaps):
            if len(bm.keys) > cap:
                raise ValueError(
                    f"bitmap {i} has {len(bm.keys)} containers > capacity {cap}")
            for j, (k, c) in enumerate(zip(bm.keys, bm.containers)):
                keys[i, j] = k
                cards[i, j] = c.card
                if isinstance(c, ArrayContainer):
                    kinds[i, j] = KIND_ARRAY
                    slab[i, j, :c.card] = c.values
                    slab[i, j, c.card:] = 0xFFFF
                elif isinstance(c, BitsetContainer):
                    kinds[i, j] = KIND_BITSET
                    slab[i, j] = c.words.view(np.uint16)
                else:
                    kinds[i, j] = KIND_RUN
                    nr = c.num_runs()
                    aux[i, j] = nr
                    flat = c.runs.astype(np.uint16).reshape(-1)
                    slab[i, j, :2 * nr] = flat
        return RoaringTensor(jnp.asarray(keys), jnp.asarray(kinds),
                             jnp.asarray(cards), jnp.asarray(aux),
                             jnp.asarray(slab))

    def to_bitmaps(self) -> list[RoaringBitmap]:
        """Device -> host bridge (not jit-able)."""
        keys = np.asarray(self.keys)
        kinds = np.asarray(self.kinds)
        cards = np.asarray(self.cards)
        aux = np.asarray(self.aux)
        slab = np.asarray(self.slab)
        out = []
        for i in range(self.batch):
            ks, cs = [], []
            order = np.argsort(keys[i], kind="stable")
            for j in order:
                if kinds[i, j] == KIND_EMPTY:
                    continue
                ks.append(int(keys[i, j]))
                if kinds[i, j] == KIND_ARRAY:
                    cs.append(ArrayContainer(slab[i, j, :cards[i, j]].copy()))
                elif kinds[i, j] == KIND_BITSET:
                    cs.append(BitsetContainer(
                        slab[i, j].view(np.uint64).copy(), int(cards[i, j])))
                else:
                    nr = int(aux[i, j])
                    runs = slab[i, j, :2 * nr].astype(np.int32).reshape(nr, 2)
                    cs.append(RunContainer(runs))
            out.append(RoaringBitmap(ks, cs))
        return out

    def to_arena(self, arena=None):
        """Adopt the whole batch into a ``core.arena.BitmapArena`` (the
        host bridge runs ONCE; thereafter wide aggregates over the
        returned bitmaps dispatch from the resident slab with no
        per-call staging -- see docs/MEMORY.md).

        Args: ``arena`` an existing arena to adopt into, or None to
        create a fresh one.  Returns ``(arena, bitmaps)`` where
        ``bitmaps[i]`` is the host twin of batch row ``i``, registered
        in the arena; pass them to ``aggregate.or_many(...,
        arena=arena)`` etc.  Mutating a twin later costs one
        ``arena.adopt(bm)`` repatch, not a rebuild."""
        from repro.core.arena import BitmapArena
        if arena is None:
            arena = BitmapArena()
        bms = self.to_bitmaps()
        arena.adopt_many(bms)
        return arena, bms

    # ====================================================================
    # bitset-domain decompression (DESIGN.md: "decompress array/run ->
    # bitset in VMEM, operate in bitset domain")
    # ====================================================================

    def to_words(self) -> jax.Array:
        """(B, C, WORDS) uint32 bitset-domain view of every slot."""
        b, c = self.batch, self.capacity
        flat_slab = self.slab.reshape(b * c, SLAB16)
        kinds = self.kinds.reshape(b * c)
        cards = self.cards.reshape(b * c)
        aux = self.aux.reshape(b * c)

        # bitset slots: plain bitcast uint16 -> uint32
        bs_words = slab16_to_words32(flat_slab)

        # array slots: disjoint-contribution scatter (masked to array kind)
        a_card = jnp.where(kinds == KIND_ARRAY, cards, 0)
        ar_words = kops.array_to_bitset(flat_slab.astype(jnp.int32), a_card)

        # run slots: delta-coding + prefix sum over the 2^16 universe
        n_runs = jnp.where(kinds == KIND_RUN, aux, 0)
        run_words = _runs_to_words(flat_slab, n_runs)

        words = jnp.where((kinds == KIND_BITSET)[:, None], bs_words,
                          jnp.where((kinds == KIND_ARRAY)[:, None], ar_words,
                                    jnp.where((kinds == KIND_RUN)[:, None],
                                              run_words, jnp.uint32(0))))
        return words.reshape(b, c, WORDS)

    # ====================================================================
    # set algebra
    # ====================================================================

    def _align(self, other: "RoaringTensor"):
        """Static-capacity key merge: returns (out_keys (B, Co), a_words,
        b_words, hit_a, hit_b) with Co = Ca + Cb."""
        ka = jnp.where(self.kinds > 0, self.keys, SENTINEL)
        kb = jnp.where(other.kinds > 0, other.keys, SENTINEL)
        allk = jnp.sort(jnp.concatenate([ka, kb], axis=1), axis=1)
        prev = jnp.pad(allk[:, :-1], ((0, 0), (1, 0)),
                       constant_values=-1)
        outk = jnp.sort(jnp.where(allk == prev, SENTINEL, allk), axis=1)

        def locate(keys_row, out_row):
            return jnp.searchsorted(keys_row, out_row).astype(jnp.int32)

        ia = jax.vmap(locate)(ka, outk)
        ib = jax.vmap(locate)(kb, outk)
        ia_c = jnp.minimum(ia, ka.shape[1] - 1)
        ib_c = jnp.minimum(ib, kb.shape[1] - 1)
        hit_a = (jnp.take_along_axis(ka, ia_c, axis=1) == outk) & \
                (outk != SENTINEL)
        hit_b = (jnp.take_along_axis(kb, ib_c, axis=1) == outk) & \
                (outk != SENTINEL)
        aw = self.to_words()
        bw = other.to_words()
        aw = jnp.take_along_axis(aw, ia_c[:, :, None], axis=1)
        bw = jnp.take_along_axis(bw, ib_c[:, :, None], axis=1)
        aw = jnp.where(hit_a[:, :, None], aw, jnp.uint32(0))
        bw = jnp.where(hit_b[:, :, None], bw, jnp.uint32(0))
        return outk, aw, bw, hit_a, hit_b

    def _binary(self, other: "RoaringTensor", op: str,
                backend: str | None = None) -> "RoaringTensor":
        outk, aw, bw, hit_a, hit_b = self._align(other)
        b, co = outk.shape
        opids = jnp.full((b * co,), PAIR_OPS.index(op), jnp.int32)
        rw, cards = kops.bitset_pair_op(aw.reshape(b * co, WORDS),
                                        bw.reshape(b * co, WORDS), opids,
                                        backend=backend)
        rw = rw.reshape(b, co, WORDS)
        cards = cards.reshape(b, co)
        if op == "and":
            present = hit_a & hit_b
        elif op == "or":
            present = hit_a | hit_b
        elif op == "xor":
            present = hit_a | hit_b
        else:  # andnot
            present = hit_a
        present = present & (cards > 0)
        return repack(jnp.where(present, outk, SENTINEL), cards, rw)

    def __and__(self, other):
        return self._binary(other, "and")

    def __or__(self, other):
        return self._binary(other, "or")

    def __xor__(self, other):
        return self._binary(other, "xor")

    def andnot(self, other):
        return self._binary(other, "andnot")

    # count-only variants (paper section 5.9) --------------------------------
    def _binary_card(self, other, op: str, backend=None) -> jax.Array:
        outk, aw, bw, hit_a, hit_b = self._align(other)
        b, co = outk.shape
        opids = jnp.full((b * co,), PAIR_OPS.index(op), jnp.int32)
        cards = kops.bitset_pair_card(aw.reshape(b * co, WORDS),
                                      bw.reshape(b * co, WORDS), opids,
                                      backend=backend).reshape(b, co)
        return cards.sum(axis=1)

    def pairwise_card(self, other: "RoaringTensor", ops, *,
                      lhs_idx=None, rhs_idx=None,
                      backend: str | None = None) -> jax.Array:
        """Batched pair counts with a per-pair op, ONE mixed-op kernel
        dispatch (op id per row -- the device twin of the host pairwise
        planner's bitset class).

        Args: ``ops`` is one op name ("and"|"or"|"xor"|"andnot") or a
        length-P sequence; ``lhs_idx`` / ``rhs_idx`` are optional (P,)
        index arrays picking pair rows from ``self`` / ``other`` ON
        DEVICE (``jnp.take``; no host pair-list bridge), so arbitrary
        similarity-join pair sets -- including repeated rows -- run
        against resident tensors.  Omitted, pairs align row-by-row
        (P = B, requires equal batches).

        Returns (P,) int32 counts.  Complexity: one gather + one fused
        AND/popcount dispatch over P * (Ca + Cb) container slots.  See
        docs/ARCHITECTURE.md (paper sections 4.2-4.5 / 5.9)."""
        a = self if lhs_idx is None else self.take(lhs_idx)
        b_t = other if rhs_idx is None else other.take(rhs_idx)
        if a.batch != b_t.batch:
            raise ValueError(f"pair row counts differ: {a.batch} != "
                             f"{b_t.batch} (use lhs_idx/rhs_idx)")
        outk, aw, bw, _, _ = a._align(b_t)
        b, co = outk.shape
        if isinstance(ops, str):
            opids = jnp.full((b,), PAIR_OPS.index(ops), jnp.int32)
        else:
            opids = jnp.asarray([PAIR_OPS.index(o) for o in ops],
                                jnp.int32)
            if opids.shape[0] != b:
                raise ValueError(f"need one op per pair row: "
                                 f"{opids.shape[0]} != {b}")
        cards = kops.bitset_pair_card(
            aw.reshape(b * co, WORDS), bw.reshape(b * co, WORDS),
            jnp.repeat(opids, co), backend=backend).reshape(b, co)
        return cards.sum(axis=1)

    def and_card(self, other) -> jax.Array:
        """(B,) intersection cardinalities, row i vs row i: one count-only
        mixed-op dispatch, result words never reach HBM (paper section
        5.9).  ``or_card``/``xor_card``/``andnot_card`` are the
        inclusion-exclusion siblings; arbitrary pair sets go through
        ``pairwise_card(lhs_idx=, rhs_idx=)``."""
        return self._binary_card(other, "and")

    def or_card(self, other) -> jax.Array:
        return self._binary_card(other, "or")

    def xor_card(self, other) -> jax.Array:
        return self._binary_card(other, "xor")

    def andnot_card(self, other) -> jax.Array:
        return self._binary_card(other, "andnot")

    def jaccard(self, other) -> jax.Array:
        """(B,) float32 per-row Jaccard similarities from one count-only
        dispatch (empty-vs-empty rows score 1.0, matching the host
        convention)."""
        inter = self.and_card(other).astype(jnp.float32)
        union = (self.cardinality() + other.cardinality()).astype(jnp.float32) \
            - inter
        return jnp.where(union > 0, inter / union, 1.0)

    # ====================================================================
    # membership (paper section 5.6)
    # ====================================================================

    def contains(self, queries: jax.Array) -> jax.Array:
        """Batched membership (paper section 5.6): (B, Q) uint32 queries
        -> (B, Q) bool.  Jit-able, no kernel dispatch: a key binary
        search then the per-kind probe (bitset `bt`, array binary
        search, run-start binary search), all vectorized over (B, Q)."""
        hi = (queries >> 16).astype(jnp.int32)
        lo = (queries & 0xFFFF).astype(jnp.int32)
        ks = jnp.where(self.kinds > 0, self.keys, SENTINEL)

        def locate(keys_row, q_row):
            return jnp.searchsorted(keys_row, q_row).astype(jnp.int32)

        idx = jax.vmap(locate)(ks, hi)
        idx_c = jnp.minimum(idx, self.capacity - 1)
        hit = jnp.take_along_axis(ks, idx_c, axis=1) == hi
        kind = jnp.take_along_axis(self.kinds, idx_c, axis=1)
        card = jnp.take_along_axis(self.cards, idx_c, axis=1)
        aux = jnp.take_along_axis(self.aux, idx_c, axis=1)
        slab = jnp.take_along_axis(self.slab, idx_c[:, :, None], axis=1)

        # bitset probe (paper's `bt`)
        word = jnp.take_along_axis(
            slab, (lo >> 4)[:, :, None], axis=2)[:, :, 0].astype(jnp.int32)
        in_bitset = ((word >> (lo & 15)) & 1).astype(bool)

        # array probe: binary search in the sorted slab (tail = 0xFFFF)
        def bsearch(slab_row, lo_row):
            return jax.vmap(
                lambda s, q: jnp.searchsorted(s, q.astype(jnp.uint16))
            )(slab_row, lo_row).astype(jnp.int32)

        pos = jax.vmap(bsearch)(slab, lo)
        pos_c = jnp.minimum(pos, SLAB16 - 1)
        at = jnp.take_along_axis(slab, pos_c[:, :, None],
                                 axis=2)[:, :, 0].astype(jnp.int32)
        in_array = (pos < card) & (at == lo)

        # run probe: binary search over run starts (even slab positions)
        starts = slab[:, :, 0::2].astype(jnp.int32)
        lens = slab[:, :, 1::2].astype(jnp.int32)
        n_half = SLAB16 // 2
        starts_m = jnp.where(
            jnp.arange(n_half)[None, None, :] < aux[:, :, None],
            starts, jnp.int32(CONTAINER_BITS))

        def rsearch(st_row, lo_row):
            return jax.vmap(
                lambda s, q: jnp.searchsorted(s, q, side="right")
            )(st_row, lo_row).astype(jnp.int32)

        r = jax.vmap(rsearch)(starts_m, lo) - 1
        r_c = jnp.clip(r, 0, n_half - 1)
        s_at = jnp.take_along_axis(starts, r_c[:, :, None], axis=2)[:, :, 0]
        l_at = jnp.take_along_axis(lens, r_c[:, :, None], axis=2)[:, :, 0]
        in_run = (r >= 0) & (r < aux) & (lo >= s_at) & (lo <= s_at + l_at)

        found = jnp.where(kind == KIND_BITSET, in_bitset,
                          jnp.where(kind == KIND_ARRAY, in_array,
                                    jnp.where(kind == KIND_RUN, in_run,
                                              False)))
        return hit & found

    # ====================================================================
    # wide aggregation (paper section 5.8 on device)
    # ====================================================================

    def reduce_or(self, backend: str | None = None,
                  mesh=None) -> "RoaringTensor":
        """OR-reduce the whole batch axis into a single bitmap using ONE
        segmented-kernel dispatch (host bridge, not jit-able: the segment
        plan depends on the concrete keys).

        Every non-empty slot of every batch row becomes one slab row; slots
        sharing a chunk key across the batch form a segment; the same
        ``segment_reduce`` kernel that powers ``RoaringBitmap.or_many``
        reduces them fused with the Harley-Seal cardinality.  With a
        multi-device ``mesh``, each segment's rows shard across the mesh
        axis and partials all-reduce with OR (see aggregate._shard_reduce).
        Returns a batch-1 tensor whose capacity is the number of distinct
        keys."""
        from repro.core import aggregate
        keys = np.asarray(self.keys).reshape(-1)
        kinds = np.asarray(self.kinds).reshape(-1)
        live = np.flatnonzero(kinds != KIND_EMPTY)
        if live.size == 0:
            return RoaringTensor(
                jnp.full((1, 1), SENTINEL, jnp.int32),
                jnp.zeros((1, 1), jnp.int32), jnp.zeros((1, 1), jnp.int32),
                jnp.zeros((1, 1), jnp.int32),
                jnp.zeros((1, 1, SLAB16), jnp.uint16))
        order = live[np.argsort(keys[live], kind="stable")]
        sorted_keys = keys[order]
        uniq, first = np.unique(sorted_keys, return_index=True)
        starts = np.concatenate((first, [sorted_keys.size])).astype(np.int32)
        words = self.to_words().reshape(-1, WORDS)
        mesh = aggregate._resolve_mesh(mesh)
        if mesh is not None and aggregate._mesh_size(mesh) > 1:
            slab = jnp.take(words, jnp.asarray(order), axis=0)
            rw, cards = aggregate._shard_reduce(
                slab, np.diff(starts).tolist(), None, "or", 0, backend,
                mesh)
            return repack(jnp.asarray(uniq.astype(np.int32))[None, :],
                          cards[None, :], rw[None])
        jmax = int(np.diff(starts).max())
        # pad rows / segments / depth to powers of two so the jit cache is
        # reused across calls (same scheme as aggregate._dispatch); padded
        # segments are empty -> card 0 -> dropped by repack
        pow2 = lambda x: 1 if x <= 1 else 1 << (x - 1).bit_length()
        jmax = pow2(jmax)
        n_pad = pow2(order.size)
        order = np.concatenate((order, np.zeros(n_pad - order.size,
                                                order.dtype)))
        s_pad = pow2(uniq.size)
        out_keys = np.full(s_pad, SENTINEL, np.int32)
        out_keys[:uniq.size] = uniq
        starts = np.concatenate(
            (starts, np.full(s_pad - uniq.size, starts[-1], np.int32)))
        slab = jnp.take(words, jnp.asarray(order), axis=0)
        rw, cards = kops.segment_reduce(slab, jnp.asarray(starts), "or",
                                        jmax=jmax, backend=backend)
        return repack(jnp.asarray(out_keys)[None, :],
                      cards[None, :], rw[None])

    # ====================================================================
    # maintenance
    # ====================================================================

    def run_optimize(self) -> "RoaringTensor":
        """Device-side roaring_bitmap_run_optimize: re-derive the cheapest
        kind including runs (DESIGN.md: runs matter for contiguous attention
        windows)."""
        words = self.to_words()
        b, c = self.batch, self.capacity
        keys = jnp.where(self.kinds > 0, self.keys, SENTINEL)
        return repack(keys, jnp.where(self.kinds > 0, self.cards, 0),
                      words, allow_runs=True)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def slab16_to_words32(slab: jax.Array) -> jax.Array:
    """(..., 4096) uint16 -> (..., 2048) uint32 (little-endian packing)."""
    pairs = slab.reshape(*slab.shape[:-1], SLAB16 // 2, 2)
    lo = pairs[..., 0].astype(jnp.uint32)
    hi = pairs[..., 1].astype(jnp.uint32)
    return lo | (hi << np.uint32(16))


def words32_to_slab16(words: jax.Array) -> jax.Array:
    """(..., 2048) uint32 -> (..., 4096) uint16."""
    lo = (words & np.uint32(0xFFFF)).astype(jnp.uint16)
    hi = (words >> np.uint32(16)).astype(jnp.uint16)
    return jnp.stack([lo, hi], axis=-1).reshape(*words.shape[:-1], SLAB16)


def _runs_to_words(flat_slab: jax.Array, n_runs: jax.Array) -> jax.Array:
    """(N, 4096) uint16 interleaved runs + (N,) run counts -> (N, WORDS)
    uint32, via delta coding + prefix sum (no data-dependent shapes)."""
    n = flat_slab.shape[0]
    starts = flat_slab[:, 0::2].astype(jnp.int32)
    lens = flat_slab[:, 1::2].astype(jnp.int32)
    r = SLAB16 // 2
    valid = jnp.arange(r)[None, :] < n_runs[:, None]
    s = jnp.where(valid, starts, CONTAINER_BITS)        # OOB drops
    e = jnp.where(valid, starts + lens + 1, CONTAINER_BITS)

    def one(s_row, e_row):
        delta = jnp.zeros(CONTAINER_BITS + 1, jnp.int32)
        delta = delta.at[s_row].add(1, mode="drop")
        delta = delta.at[e_row].add(-1, mode="drop")
        occ = (jnp.cumsum(delta[:CONTAINER_BITS]) > 0)
        bits = occ.reshape(WORDS, 32).astype(jnp.uint32)
        weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
        return (bits * weights[None, :]).sum(axis=1, dtype=jnp.uint32)

    return jax.vmap(one)(s, e)


def _num_runs_words(words: jax.Array) -> jax.Array:
    """(N, WORDS) uint32 -> (N,) number of runs of consecutive 1s."""
    shifted = words << np.uint32(1)
    carry = jnp.pad(words[:, :-1] >> np.uint32(31), ((0, 0), (1, 0)))
    starts = words & ~(shifted | carry)
    return kops.popcount(starts, backend="ref")


def _extract_runs(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(N, WORDS) -> (slab (N, 4096) uint16 interleaved runs, n_runs (N,)).
    Only meaningful when n_runs <= 2047."""
    n = words.shape[0]
    bit_pos = jnp.arange(CONTAINER_BITS)
    occ = ((words[:, bit_pos >> 5] >> (bit_pos & 31).astype(jnp.uint32))
           & np.uint32(1)).astype(jnp.int32)
    prev = jnp.pad(occ[:, :-1], ((0, 0), (1, 0)))
    nxt = jnp.pad(occ[:, 1:], ((0, 0), (0, 1)))
    is_start = occ & (1 - prev)
    is_end = occ & (1 - nxt)
    r = SLAB16 // 2
    targets = jnp.arange(1, r + 1)

    def pos_of(flags):
        cs = jnp.cumsum(flags)
        return jnp.searchsorted(cs, targets, side="left").astype(jnp.int32)

    spos = jax.vmap(pos_of)(is_start)
    epos = jax.vmap(pos_of)(is_end)
    n_runs = is_start.sum(axis=1).astype(jnp.int32)
    valid = targets[None, :] <= n_runs[:, None]
    starts16 = jnp.where(valid, spos, 0).astype(jnp.uint16)
    lens16 = jnp.where(valid, epos - spos, 0).astype(jnp.uint16)
    slab = jnp.stack([starts16, lens16], axis=-1).reshape(n, SLAB16)
    return slab, n_runs


def repack(keys: jax.Array, cards: jax.Array, words: jax.Array,
           allow_runs: bool = False) -> RoaringTensor:
    """Re-derive canonical kinds/slabs from bitset-domain words.

    keys: (B, C) int32 with SENTINEL for empty; cards: (B, C); words:
    (B, C, WORDS).  Mirrors the paper's result-kind policy: array if
    card <= 4096 else bitset; runs only when allow_runs (run_optimize).
    Slots are re-sorted by key so searchsorted lookups stay valid.
    """
    b, c = keys.shape
    empty = (keys == SENTINEL) | (cards == 0)
    keys = jnp.where(empty, SENTINEL, keys)
    cards = jnp.where(empty, 0, cards)

    kind = jnp.where(empty, KIND_EMPTY,
                     jnp.where(cards <= ARRAY_MAX, KIND_ARRAY, KIND_BITSET))
    aux = jnp.zeros_like(cards)

    flat_words = words.reshape(b * c, WORDS)
    # array extraction (clip pads 65536 -> 0xFFFF for sorted-tail invariant)
    vals, _ = kops.bitset_to_array(flat_words)
    arr_slab = jnp.minimum(vals, CONTAINER_BITS - 1).astype(jnp.uint16) \
        .reshape(b, c, SLAB16)
    bs_slab = words32_to_slab16(words)
    slab = jnp.where((kind == KIND_ARRAY)[:, :, None], arr_slab, bs_slab)

    if allow_runs:
        n_runs = _num_runs_words(flat_words).reshape(b, c)
        run_bytes = 4 * n_runs + 2
        arr_bytes = jnp.where(cards <= ARRAY_MAX, 2 * cards, 1 << 30)
        bs_bytes = 2 * SLAB16
        best_run = (n_runs <= 2047) & (run_bytes < arr_bytes) & \
                   (run_bytes < bs_bytes) & ~empty
        run_slab, _ = _extract_runs(flat_words)
        run_slab = run_slab.reshape(b, c, SLAB16)
        slab = jnp.where(best_run[:, :, None], run_slab, slab)
        kind = jnp.where(best_run, KIND_RUN, kind)
        aux = jnp.where(best_run, n_runs, aux)

    slab = jnp.where((kind == KIND_EMPTY)[:, :, None], jnp.uint16(0), slab)

    # canonicalize slot order (empties at the end)
    order = jnp.argsort(keys, axis=1, stable=True)
    keys = jnp.take_along_axis(keys, order, axis=1)
    kind = jnp.take_along_axis(kind, order, axis=1)
    cards = jnp.take_along_axis(cards, order, axis=1)
    aux = jnp.take_along_axis(aux, order, axis=1)
    slab = jnp.take_along_axis(slab, order[:, :, None], axis=1)
    return RoaringTensor(keys, kind, cards, aux, slab)


# ---------------------------------------------------------------------------
# attention-mask utilities (serving integration)
# ---------------------------------------------------------------------------

def block_mask_words(bitmaps: list[RoaringBitmap], n_blocks: int) -> jax.Array:
    """Host bridge: per-sequence visible-block sets -> (B, ceil(n/32)) uint32
    words for the block-sparse attention kernel.  Universe must fit one
    container (n_blocks <= 65536)."""
    assert n_blocks <= CONTAINER_BITS
    n_words = max(1, (n_blocks + 31) // 32)
    out = np.zeros((len(bitmaps), n_words), np.uint32)
    for i, bm in enumerate(bitmaps):
        vals = bm.to_array()
        vals = vals[vals < n_blocks]
        np.bitwise_or.at(out[i], vals >> 5,
                         np.uint32(1) << (vals & np.uint32(31)))
    return jnp.asarray(out)
