"""Pure-python *scalar* twins of the vectorized container algorithms.

The paper (section 5.10, Tables 10/13) compares CRoaring with its SIMD
optimizations disabled ("scalar code") against the SIMD build.  In this
reproduction the numpy path plays the role of the SIMD code; this module is
the deliberately scalar counterpart: element-at-a-time loops with no numpy
vector ops, used only by ``benchmarks/ablation.py`` and the equivalence
tests.
"""

from __future__ import annotations

import numpy as np

from repro.core.containers import BITSET_WORDS

_M1 = 0x5555555555555555
_M2 = 0x3333333333333333
_M4 = 0x0F0F0F0F0F0F0F0F


def popcount64(w: int) -> int:
    """Scalar SWAR popcount of one 64-bit word (paper section 4.1 baseline)."""
    w -= (w >> 1) & _M1
    w = (w & _M2) + ((w >> 2) & _M2)
    w = (w + (w >> 4)) & _M4
    return ((w * 0x0101010101010101) & 0xFFFFFFFFFFFFFFFF) >> 56


def bitset_popcount(words) -> int:
    """Word-at-a-time population count of a bitset container."""
    return sum(popcount64(int(w)) for w in words)


def bitset_op(a, b, op: str):
    """Word-at-a-time logical op + cardinality (the scalar form of the
    paper's section 4.1.2 fused loop).  Returns (words, card)."""
    out = np.zeros(BITSET_WORDS, dtype=np.uint64)
    card = 0
    for i in range(BITSET_WORDS):
        x, y = int(a[i]), int(b[i])
        if op == "and":
            r = x & y
        elif op == "or":
            r = x | y
        elif op == "xor":
            r = x ^ y
        else:
            r = x & ~y & 0xFFFFFFFFFFFFFFFF
        out[i] = r
        card += popcount64(r)
    return out, card


def intersect(a, b):
    """Two-pointer scalar intersection of sorted uint16 arrays."""
    out = []
    i = j = 0
    na, nb = len(a), len(b)
    while i < na and j < nb:
        x, y = int(a[i]), int(b[j])
        if x == y:
            out.append(x)
            i += 1
            j += 1
        elif x < y:
            i += 1
        else:
            j += 1
    return np.asarray(out, dtype=np.uint16)


def union(a, b):
    out = []
    i = j = 0
    na, nb = len(a), len(b)
    while i < na and j < nb:
        x, y = int(a[i]), int(b[j])
        if x == y:
            out.append(x)
            i += 1
            j += 1
        elif x < y:
            out.append(x)
            i += 1
        else:
            out.append(y)
            j += 1
    while i < na:
        out.append(int(a[i]))
        i += 1
    while j < nb:
        out.append(int(b[j]))
        j += 1
    return np.asarray(out, dtype=np.uint16)


def difference(a, b):
    out = []
    i = j = 0
    na, nb = len(a), len(b)
    while i < na and j < nb:
        x, y = int(a[i]), int(b[j])
        if x == y:
            i += 1
            j += 1
        elif x < y:
            out.append(x)
            i += 1
        else:
            j += 1
    while i < na:
        out.append(int(a[i]))
        i += 1
    return np.asarray(out, dtype=np.uint16)


def symmetric_difference(a, b):
    out = []
    i = j = 0
    na, nb = len(a), len(b)
    while i < na and j < nb:
        x, y = int(a[i]), int(b[j])
        if x == y:
            i += 1
            j += 1
        elif x < y:
            out.append(x)
            i += 1
        else:
            out.append(y)
            j += 1
    while i < na:
        out.append(int(a[i]))
        i += 1
    while j < nb:
        out.append(int(b[j]))
        j += 1
    return np.asarray(out, dtype=np.uint16)


def bitset_to_positions(words):
    """Scalar blsi/tzcnt extraction loop (paper section 3.1)."""
    out = []
    for i in range(BITSET_WORDS):
        w = int(words[i])
        base = i << 6
        while w:
            t = w & (-w)            # blsi
            out.append(base + (t.bit_length() - 1))   # tzcnt
            w ^= t
    return np.asarray(out, dtype=np.uint16)


def bitset_set_many(words, values) -> int:
    """Scalar branchless set-with-cardinality loop (paper section 3.2)."""
    card_delta = 0
    for v in values:
        v = int(v)
        old = int(words[v >> 6])
        new = old | (1 << (v & 63))
        card_delta += (old ^ new) >> (v & 63)
        words[v >> 6] = np.uint64(new)
    return card_delta
