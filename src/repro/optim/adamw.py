"""AdamW with fp32 master weights, global-norm clipping and LR schedules.

Hand-rolled (no optax dependency) so the optimizer state layout is explicit
for the sharding rules engine: state leaves mirror parameter leaves and
inherit their PartitionSpecs (ZeRO-style sharded optimizer state).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
