"""repro.optim"""
