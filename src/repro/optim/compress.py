"""Top-k gradient compression with Roaring coordinate sets (DESIGN.md sec 2).

Distributed-optimization trick for data-parallel reduction: instead of
all-reducing the full dense gradient (N * 4 bytes per replica pair), each
replica sends its top-k magnitudes as (values, coordinate set).  On the
host/bookkeeping side the coordinate set is exactly a Roaring bitmap (the
paper's data structure) -- sorted int32 ids, heavily clustered, run-friendly
after momentum warmup.  On the wire inside jit we all-gather k (value, index)
pairs per replica and scatter-add, which lowers to an all-gather of
2 * k * 4 bytes instead of an all-reduce of N * 4 bytes: visible in the
dry-run's collective table when k << N.

Error feedback (residual accumulation) keeps the compressed SGD unbiased in
the long run (Stich et al.); the residual lives in optimizer state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmap import RoaringBitmap


def topk_sparsify(g: jax.Array, k: int):
    """Dense gradient -> (values (k,), indices (k,), dense residual)."""
    flat = g.reshape(-1).astype(jnp.float32)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx.astype(jnp.int32), residual


def densify(values: jax.Array, indices: jax.Array, shape) -> jax.Array:
    n = int(np.prod(shape))
    return jnp.zeros((n,), jnp.float32).at[indices].add(values).reshape(shape)


def sparse_allreduce(g: jax.Array, axis_name: str, k: int,
                     residual: jax.Array | None = None):
    """Inside shard_map over `axis_name`: compress, all-gather, scatter-add.

    Returns (reduced dense gradient averaged over the axis, new residual).
    """
    if residual is not None:
        g = g + residual
    vals, idx, new_res = topk_sparsify(g, k)
    all_vals = jax.lax.all_gather(vals, axis_name)   # (R, k)
    all_idx = jax.lax.all_gather(idx, axis_name)     # (R, k)
    r = all_vals.shape[0]
    dense = densify(all_vals.reshape(-1), all_idx.reshape(-1), g.shape)
    return dense / r, new_res


def coordinate_bitmap(indices) -> RoaringBitmap:
    """Host-side: the transmitted coordinate set as a Roaring bitmap.
    Used for logging compression telemetry (bits/coordinate) and for
    delta-coding coordinate sets across steps (A xor B)."""
    return RoaringBitmap.from_values(np.asarray(indices, np.uint32))


def wire_bytes_dense(n: int) -> int:
    return 4 * n


def wire_bytes_sparse(indices) -> int:
    """4 bytes/value + the Roaring-serialized coordinate set."""
    from repro.core.serde import serialized_size_bytes
    bm = coordinate_bitmap(indices)
    return 4 * len(bm) + serialized_size_bytes(bm.run_optimize())
