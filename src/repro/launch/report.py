"""Render EXPERIMENTS.md sections from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report results/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def load(out_dir):
    cells = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            cells.append((os.path.basename(p)[:-5], json.load(f)))
    return cells


def improvement_note(d):
    r = d.get("roofline", {})
    dom = r.get("dominant")
    step = d.get("step")
    if dom == "memory":
        if step == "train":
            return ("fuse attention-tile elementwise chains / bf16 tiles; "
                    "cut remat traffic")
        return "shrink KV reads (roaring block-sparse; quantized cache)"
    if dom == "collective":
        return ("reduce TP all-reduces (sequence-parallel norms) or "
                "gradient compression on the dp axis")
    return "increase per-chip arithmetic intensity (bigger microbatch)"


def dryrun_section(cells):
    out = ["### Dry-run results (per cell)", "",
           "| cell | mesh | status | compile | arg bytes/dev | temp "
           "bytes/dev | HLO GFLOPs/dev | coll bytes/dev | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for name, d in cells:
        if "skipped" in d:
            out.append(f"| {name} | - | SKIP: {d['skipped'][:60]} "
                       "| - | - | - | - | - | - |")
            continue
        if "error" in d:
            out.append(f"| {name} | - | **FAIL**: {d['error'][:60]} "
                       "| - | - | - | - | - | - |")
            continue
        m = d["memory"]
        coll = d["collectives"]
        parts = [f"{k.split('-')[0][:3]}{k.split('-')[1][:3] if '-' in k else ''}:"
                 f"{fmt_bytes(v)}"
                 for k, v in coll.items()
                 if k != "total" and v]
        out.append(
            f"| {name} | {d['mesh']} | ok | {d['compile_s']}s "
            f"| {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['temp_bytes'])} "
            f"| {d['analysis']['flops'] / 1e9:.0f} "
            f"| {fmt_bytes(coll['total'])} "
            f"| {' '.join(parts) or '-'} |")
    return "\n".join(out)


def roofline_section(cells, single_only=True):
    out = ["### Roofline terms (single-pod 16x16, per device)", "",
           "| arch x shape | compute | memory | collective | dominant | "
           "MODEL_FLOPS/HLO | note |",
           "|---|---|---|---|---|---|---|"]
    for name, d in cells:
        if "roofline" not in d:
            continue
        if single_only and not name.endswith("-single"):
            continue
        r = d["roofline"]
        out.append(
            f"| {name.replace('-single', '')} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['model_to_hlo_flops']:.2f} "
            f"| {improvement_note(d)} |")
    return "\n".join(out)


def reanalyze(out_dir):
    """Recompute roofline terms from saved .hlo.gz (no recompilation)."""
    import gzip

    from repro.launch import roofline as R
    from repro.launch.hlo_analysis import analyze_text
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        hlo_path = p[:-5] + ".hlo.gz"
        if not os.path.exists(hlo_path):
            continue
        with open(p) as f:
            d = json.load(f)
        if "roofline" not in d:
            continue
        with gzip.open(hlo_path, "rt") as f:
            ana = analyze_text(f.read())
        d["analysis"] = {"flops": ana["flops"], "bytes": ana["bytes"],
                         "transcendentals": ana["transcendentals"]}
        d["collectives"] = {k: ana[k] for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute")}
        d["collectives"]["total"] = ana["collective_total"]
        d["roofline"] = R.roofline_terms_from_analysis(
            ana, d["roofline"]["model_flops_global"], d["chips"])
        with open(p, "w") as f:
            json.dump(d, f, indent=1)
        print("reanalyzed", os.path.basename(p))


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "--reanalyze":
        reanalyze(sys.argv[2] if len(sys.argv) > 2 else "results/dryrun")
        return
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load(out_dir)
    n_ok = sum(1 for _, d in cells if "roofline" in d)
    n_skip = sum(1 for _, d in cells if "skipped" in d)
    n_fail = sum(1 for _, d in cells if "error" in d)
    print(f"<!-- {n_ok} ok / {n_skip} skipped / {n_fail} failed -->\n")
    print(dryrun_section(cells))
    print()
    print(roofline_section(cells))


if __name__ == "__main__":
    main()
