import os
# The production meshes need 256/512 devices; on a plain host we fake them.
# An operator-provided XLA_FLAGS wins -- main() preflights the resulting
# device count and fails with instructions instead of a mesh traceback.
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step / prefill /
decode_step) with ShapeDtypeStruct inputs under the production mesh,
compiles it, and records memory_analysis / cost_analysis / the collective
schedule parsed from the optimized HLO.  Failures here are sharding bugs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

import repro.configs as C

try:
    from repro.dist import ctx as _ctx
    from repro.dist import sharding as SH
    _DIST_ERR = None
except ImportError as _e:            # pragma: no cover - broken install
    _ctx = SH = None
    _DIST_ERR = _e

from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import train_step as TS


def _sds(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def lower_cell(cfg, shape_name: str, mesh, *, compile_: bool = True,
               hlo_path: str | None = None):
    """Returns a result dict for one (arch, shape, mesh) cell."""
    spec = C.SHAPES[shape_name]
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.monotonic()

    pdp = getattr(cfg, "pure_dp", False)
    _ctx.set_pure_dp(pdp)
    param_shapes = T.param_shapes(cfg)
    p_shard = SH.param_shardings(param_shapes, mesh, pure_dp=pdp)
    batch_shapes = C.input_specs(cfg, shape_name)
    b_shard = SH.batch_shardings(batch_shapes, mesh, pure_dp=pdp)

    if spec.step == "train":
        opt_shapes = jax.eval_shape(adamw.init_state, param_shapes)
        o_shard = jax.tree.map(
            lambda l, s=None: None, opt_shapes)  # placeholder, built below
        # optimizer state mirrors params; step counter replicated
        o_shard = {
            "m": SH.param_shardings(opt_shapes["m"], mesh, pure_dp=pdp),
            "v": SH.param_shardings(opt_shapes["v"], mesh, pure_dp=pdp),
            "step": SH.replicated(mesh),
        }
        opt_cfg = adamw.AdamWConfig()
        fn = TS.make_train_step(cfg, opt_cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None))
        args = (_sds(param_shapes), _sds(opt_shapes), batch_shapes)
        model_flops = R.model_flops_train(cfg, spec.seq_len,
                                          spec.global_batch)
    elif spec.step == "prefill":
        fn = lambda params, batch: T.prefill(params, batch, cfg,
                                             s_max=spec.seq_len)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        args = (_sds(param_shapes), batch_shapes)
        model_flops = R.model_flops_prefill(cfg, spec.seq_len,
                                            spec.global_batch)
    else:  # decode
        state_shapes = C.decode_state_specs(cfg, shape_name)
        s_shard = SH.decode_state_shardings(state_shapes, mesh, pure_dp=pdp)
        inputs = C.input_specs(cfg, shape_name)
        tok_shard = SH.batch_shardings({"tokens": inputs["tokens"]},
                                       mesh, pure_dp=pdp)["tokens"]
        if "block_mask_words" in inputs:
            fn = lambda params, state, tokens, mask: T.decode_step(
                params, state, tokens, cfg, mask)
            mask_shard = SH.batch_shardings(
                {"m": inputs["block_mask_words"]}, mesh)["m"]
            jitted = jax.jit(
                fn, in_shardings=(p_shard, s_shard, tok_shard, mask_shard),
                out_shardings=(None, s_shard))
            args = (_sds(param_shapes), state_shapes, inputs["tokens"],
                    inputs["block_mask_words"])
        else:
            fn = lambda params, state, tokens: T.decode_step(
                params, state, tokens, cfg)
            jitted = jax.jit(
                fn, in_shardings=(p_shard, s_shard, tok_shard),
                out_shardings=(None, s_shard))
            args = (_sds(param_shapes), state_shapes, inputs["tokens"])
        model_flops = R.model_flops_decode(cfg, spec.global_batch)

    with _ctx.activate(mesh):
        lowered = jitted.lower(*args)
        result = {
            "arch": cfg.name, "shape": shape_name,
            "mesh": "x".join(map(str, mesh.devices.shape)),
            "chips": chips,
            "step": spec.step,
            "lower_s": round(time.monotonic() - t0, 1),
        }
        if not compile_:
            return result
        compiled = lowered.compile()
    result["compile_s"] = round(time.monotonic() - t0, 1)

    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<0.5: one dict per computation
        cost = cost[0] if cost else {}
    result["cost"] = {k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float)) and
                      k in ("flops", "bytes accessed", "transcendentals",
                            "optimal_seconds", "utilization operand")}
    hlo = compiled.as_text()
    if hlo_path:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    from repro.launch.hlo_analysis import analyze_text
    ana = analyze_text(hlo)
    result["collectives"] = {
        k: ana[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    result["collectives"]["total"] = ana["collective_total"]
    result["analysis"] = {"flops": ana["flops"], "bytes": ana["bytes"],
                          "transcendentals": ana["transcendentals"]}
    result["roofline"] = R.roofline_terms_from_analysis(
        ana, model_flops, chips)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="",
                    help="config variant fn, e.g. roaring_sparse_variant")
    args = ap.parse_args()

    if SH is None:
        raise SystemExit(
            f"dryrun: the repro.dist sharding package failed to import "
            f"({_DIST_ERR}).\nThe dry-run lowers every cell under "
            f"production param/batch shardings and cannot run without "
            f"it.  Run from the repo root with PYTHONPATH=src (see "
            f"ROADMAP.md 'Tier-1 verify').")
    need = {"single": 256, "multi": 512, "both": 512}[args.mesh]
    have = jax.device_count()
    if have < need:
        platform = jax.devices()[0].platform
        if platform == "cpu":
            hint = (f"On a CPU host, fake them with\n"
                    f"    XLA_FLAGS=--xla_force_host_platform_device_"
                    f"count={need}\n(the default when XLA_FLAGS is "
                    f"unset; your environment sets XLA_FLAGS to "
                    f"something else).")
        else:
            hint = (f"This host's {platform} backend exposes {have} "
                    f"device(s); run on a slice with >= {need} chips, "
                    f"or dry-run on CPU (JAX_PLATFORMS=cpu fakes the "
                    f"devices automatically).")
        raise SystemExit(
            f"dryrun: --mesh {args.mesh} needs {need} devices to build "
            f"the production mesh but only {have} are available.\n{hint}")

    archs = C.ARCH_IDS if args.arch == "all" else \
        [C.ALIASES.get(args.arch, args.arch)]
    shapes = list(C.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        if args.variant:
            import importlib
            mod = importlib.import_module(f"repro.configs.{arch}")
            cfg = getattr(mod, args.variant)()
        else:
            cfg = C.get_config(arch)
        for shape in shapes:
            ok, why = C.applicable(cfg, shape)
            for multi in meshes:
                tag = f"{arch}-{shape}-{'multi' if multi else 'single'}"
                if args.variant:
                    tag += f"-{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[cached] {tag}")
                    continue
                if not ok:
                    json.dump({"arch": cfg.name, "shape": shape,
                               "skipped": why}, open(path, "w"), indent=1)
                    print(f"[skip] {tag}: {why}")
                    n_skip += 1
                    continue
                mesh = make_production_mesh(multi_pod=multi)
                try:
                    res = lower_cell(cfg, shape, mesh,
                                     hlo_path=path[:-5] + ".hlo.gz")
                    json.dump(res, open(path, "w"), indent=1)
                    r = res["roofline"]
                    print(f"[ok] {tag}: compile={res['compile_s']}s "
                          f"compute={r['compute_s']:.3e}s "
                          f"memory={r['memory_s']:.3e}s "
                          f"coll={r['collective_s']:.3e}s "
                          f"dominant={r['dominant']}")
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    err = f"{type(e).__name__}: {e}"
                    json.dump({"arch": cfg.name, "shape": shape,
                               "error": err[:2000]},
                              open(path, "w"), indent=1)
                    print(f"[FAIL] {tag}: {err[:500]}")
                    traceback.print_exc()
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
