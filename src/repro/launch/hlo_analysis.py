"""Trip-count-aware cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
which under-reports FLOPs/bytes/collectives of scanned-layer models by a
factor of the trip count (layers, attention chunks, scan steps...).  This
module re-derives the three roofline inputs from the HLO text itself:

  * dot FLOPs   = 2 x |output| x |contracting dims|, multiplied through the
                  call graph (while bodies x known_trip_count);
  * bytes       = sum over materializing instructions of
                  (operand bytes + output bytes) -- XLA's own fusion-level
                  accounting convention;
  * collectives = operand bytes of all-reduce / all-gather / reduce-scatter /
                  all-to-all / collective-permute, per kind, with loop
                  multipliers.

Trip counts come from the ``backend_config={"known_trip_count":{"n":...}}``
annotation XLA attaches after loop analysis, with a fallback to the loop
condition's comparison constant.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3": 1, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "after-all", "iota", "partition-id",
    "replica-id",
    # standalone dtype converts fuse into their consumers on TPU (the
    # consumer is charged the converted-size operand read); standalone
    # materialization is a CPU-backend bf16-legalization artifact
    "convert",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(type_str: str) -> int:
    n = 1
    for d in _dims_of(type_str):
        n *= d
    return n


@dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    raw: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instructions: list[Instruction] = field(default_factory=list)
    types: dict = field(default_factory=dict)  # symbol -> type str


def _split_type_and_rest(s: str) -> tuple[str, str]:
    s = s.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1], s[i + 1:]
    i = s.find(" ")
    return s[:i], s[i:]


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")


def parse_module(text: str) -> tuple[dict, str]:
    """Returns ({comp_name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                # parameter types from the header
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?"
                                      r"(?:\[[\d,]*\])?(?:\{[^}]*\})?)",
                                      m.group(2)):
                    cur.types[pm.group(1)] = pm.group(2)
                continue
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        type_str, tail = _split_type_and_rest(rest)
        om = re.match(r"\s*([\w\-]+)\(", tail)
        if not om:
            continue
        opcode = om.group(1)
        # operand segment: up to matching close paren
        args = tail[om.end():]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args_str, attrs = args[:i], args[i + 1:]
                    break
        else:
            args_str, attrs = args, ""
        operands = re.findall(r"%([\w.\-]+)", args_str)
        inst = Instruction(name, type_str, opcode, operands,
                           stripped, stripped.startswith("ROOT"))
        cur.instructions.append(inst)
        cur.types[name] = type_str
    return comps, entry


def _attr(raw: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w.\-]+)", raw)
    return m.group(1) if m else None


def _trip_count(inst: Instruction, comps: dict) -> int:
    m = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)', inst.raw)
    if m:
        return int(m.group(1))
    cond = _attr(inst.raw, "condition")
    if cond and cond in comps:
        for ci in comps[cond].instructions:
            cm = re.search(r"constant\((\d+)\)", ci.raw)
            if cm:
                return int(cm.group(1))
        # condition may compare against a fused constant
        for ci in comps[cond].instructions:
            if ci.opcode == "fusion":
                callee = _attr(ci.raw, "calls")
                if callee and callee in comps:
                    for fi in comps[callee].instructions:
                        cm = re.search(r"constant\((\d+)\)", fi.raw)
                        if cm:
                            return int(cm.group(1))
    return 1


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    out_elems = _elems(inst.type_str)
    lhs = inst.operands[0] if inst.operands else None
    lhs_type = comp.types.get(lhs, "")
    dims = _dims_of(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.raw)
    k = 1
    if m and dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * out_elems * k


# loop-invariant tensors up to this size are assumed VMEM-resident across
# iterations (charged once, not x trip_count) -- e.g. recurrent weight
# matrices; larger invariants still pay HBM per iteration.
VMEM_RESIDENT_CAP = 8 * 1024 * 1024


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._cache: dict = {}

    def _loop_invariants(self, body_name: str) -> frozenset:
        """Symbols of while-carry elements passed through unchanged (and
        small enough to stay VMEM-resident)."""
        comp = self.comps.get(body_name)
        if comp is None:
            return frozenset()
        root = None
        gte_by_index: dict[int, Instruction] = {}
        for inst in comp.instructions:
            if inst.opcode == "get-tuple-element":
                m = re.search(r"index=(\d+)", inst.raw)
                if m:
                    gte_by_index[int(m.group(1))] = inst
            if inst.is_root:
                root = inst
        if root is None or root.opcode != "tuple":
            return frozenset()
        out = set()
        for i, operand in enumerate(root.operands):
            gte = gte_by_index.get(i)
            if gte is not None and gte.name == operand and \
                    _type_bytes(gte.type_str) <= VMEM_RESIDENT_CAP:
                out.add(gte.name)
        return frozenset(out)

    def _cost_of(self, comp_name: str,
                 invariants: frozenset = frozenset()) -> dict:
        key = (comp_name, invariants)
        if key in self._cache:
            return self._cache[key]
        comp = self.comps.get(comp_name)
        cost = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
                **{c: 0.0 for c in _COLLECTIVES}}
        if comp is None:
            return cost
        self._cache[key] = cost  # guards recursion
        for inst in comp.instructions:
            op = inst.opcode
            if op == "while":
                n = _trip_count(inst, self.comps)
                body = _attr(inst.raw, "body")
                cond = _attr(inst.raw, "condition")
                invs = self._loop_invariants(body) if body else frozenset()
                for sub in (body, cond):
                    if sub:
                        s = self._cost_of(sub, invs)
                        for k in cost:
                            cost[k] += n * s[k]
                # invariant residents charged once for the initial load
                if body and invs:
                    bcomp = self.comps[body]
                    cost["bytes"] += sum(
                        _type_bytes(bcomp.types.get(sym, ""))
                        for sym in invs)
                continue
            if op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      inst.raw)
                names = re.findall(r"%([\w.\-]+)",
                                   branches[0]) if branches else []
                tc = _attr(inst.raw, "true_computation")
                fc = _attr(inst.raw, "false_computation")
                names += [x for x in (tc, fc) if x]
                for sub in names:
                    s = self._cost_of(sub)
                    for k in cost:
                        cost[k] += s[k]
                continue
            inplace_bytes = None
            if op == "dot":
                cost["flops"] += _dot_flops(inst, comp)
            if op == "dynamic-update-slice" and len(inst.operands) >= 2:
                # in-place semantics: read + write only the updated window
                upd = _type_bytes(comp.types.get(inst.operands[1], ""))
                inplace_bytes = 2 * upd
            if op in ("dynamic-slice", "gather"):
                # reads only the addressed windows, not the whole operand
                inplace_bytes = 2 * _type_bytes(inst.type_str)
            if op == "scatter" and len(inst.operands) >= 3:
                # in-place: touches only the update windows + indices
                upd = _type_bytes(comp.types.get(inst.operands[2], ""))
                idxb = _type_bytes(comp.types.get(inst.operands[1], ""))
                inplace_bytes = 2 * upd + idxb
            if op == "fusion":
                callee = _attr(inst.raw, "calls")
                if callee and callee in self.comps:
                    # dots / transcendentals nested in fusions
                    sub = self.comps[callee]
                    # pure dtype/layout shim fusions (parameter + converts /
                    # bitcasts only) are a CPU-backend bf16-legalization
                    # artifact: on TPU they fuse into their consumers, which
                    # already pay the operand read.  Charge zero here.
                    if sub.instructions and all(
                            fi.opcode in ("parameter", "convert", "bitcast",
                                          "copy", "reshape", "transpose",
                                          "broadcast")
                            for fi in sub.instructions):
                        continue
                    root = None
                    param_by_idx: dict[int, str] = {}
                    for fi in sub.instructions:
                        if fi.is_root:
                            root = fi
                        if fi.opcode == "parameter":
                            pm = re.search(r"parameter\((\d+)\)", fi.raw)
                            if pm:
                                param_by_idx[int(pm.group(1))] = fi.name
                        if fi.opcode == "dot":
                            cost["flops"] += _dot_flops(fi, sub)
                        elif fi.opcode in ("exponential", "tanh", "log",
                                           "rsqrt", "sqrt", "power",
                                           "logistic", "sine", "cosine"):
                            cost["transcendentals"] += _elems(fi.type_str)
                    if root is None and sub.instructions:
                        root = sub.instructions[-1]
                    # effective root: CPU bf16 legalization wraps the real
                    # dus/scatter in converts; trace back through view ops
                    _by_name = {fi.name: fi for fi in sub.instructions}
                    _view = {"bitcast", "reshape", "copy", "convert",
                             "transpose"}
                    seen_r = 0
                    while root is not None and root.opcode in _view and \
                            len(root.operands) == 1 and \
                            root.operands[0] in _by_name and seen_r < 8:
                        root = _by_name[root.operands[0]]
                        seen_r += 1
                    # window-accurate fusion accounting:
                    #  * an operand used ONLY via internal dynamic-slices is
                    #    charged the slice windows, not the whole buffer
                    #    (scan xs / KV caches feed fusions this way);
                    #  * a root dynamic-update-slice/scatter aliases its big
                    #    operand and writes only the updated window.
                    # origin map traces params through view/convert chains
                    # (bitcast/reshape/copy/convert) so aliasing is detected
                    # even when XLA interposes a bitcast.
                    view_ops = {"bitcast", "reshape", "copy", "convert",
                                "transpose"}
                    origin: dict[str, str] = {v: v
                                              for v in param_by_idx.values()}
                    for fi in sub.instructions:
                        if fi.opcode in view_ops and len(fi.operands) == 1 \
                                and fi.operands[0] in origin:
                            origin[fi.name] = origin[fi.operands[0]]
                    in_b = 0
                    for pi, o in enumerate(inst.operands):
                        if o in invariants:
                            continue
                        full = _type_bytes(comp.types.get(o, ""))
                        pname = param_by_idx.get(pi)
                        if pname is not None:
                            uses = [fi for fi in sub.instructions
                                    if fi.opcode not in view_ops and any(
                                        origin.get(u) == pname
                                        for u in fi.operands)]
                            if uses and all(
                                    u.opcode in ("dynamic-slice", "gather")
                                    and u.operands and
                                    origin.get(u.operands[0]) == pname
                                    for u in uses):
                                # windowed reads only (slices / gathered
                                # blocks), not the whole buffer
                                in_b += sum(_type_bytes(u.type_str)
                                            for u in uses)
                                continue
                            if root is not None and \
                                    root.opcode in ("dynamic-update-slice",
                                                    "scatter") \
                                    and root.operands and \
                                    origin.get(root.operands[0]) == pname:
                                continue  # aliased in-place destination
                        in_b += full
                    if root is not None and \
                            root.opcode == "dynamic-update-slice" and \
                            len(root.operands) >= 2:
                        out_b = 2 * _type_bytes(
                            sub.types.get(root.operands[1], ""))
                    elif root is not None and root.opcode == "scatter" and \
                            len(root.operands) >= 3:
                        out_b = 2 * _type_bytes(
                            sub.types.get(root.operands[2], ""))
                        # the aliased scatter destination operand
                        in_b = max(0, in_b - _type_bytes(inst.type_str))
                    else:
                        out_b = _type_bytes(inst.type_str)
                    inplace_bytes = in_b + out_b
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-start"):
                    b = sum(_type_bytes(comp.types.get(o, ""))
                            for o in inst.operands
                            if o in comp.types)
                    if b == 0:
                        b = _type_bytes(inst.type_str)
                    cost[c] += b
            if op in _SKIP_BYTES_OPS or op.endswith("-done"):
                continue
            if inplace_bytes is not None:
                cost["bytes"] += inplace_bytes
                continue
            out_b = _type_bytes(inst.type_str)
            in_b = sum(_type_bytes(comp.types.get(o, ""))
                       for o in inst.operands
                       if o in comp.types and o not in invariants)
            cost["bytes"] += out_b + in_b
        return cost

    def analyze(self) -> dict:
        cost = self._cost_of(self.entry)
        out = dict(cost)
        out["collective_total"] = sum(cost[c] for c in _COLLECTIVES)
        return out


def analyze_text(text: str) -> dict:
    return Analyzer(text).analyze()
