"""repro.launch"""
