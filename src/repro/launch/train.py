"""Production train launcher.

On real hardware this process runs per-host under `jax.distributed`; here it
also runs standalone on CPU with reduced configs.  The dry-run
(launch/dryrun.py) is the no-hardware proof of the full-scale path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 50 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed from env (TPU fleets)")
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize()

    import repro.configs as C
    from repro.data.pipeline import RoaringDataPipeline
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer

    cfg = C.get_config(args.arch, reduced=args.reduced)
    pipe = RoaringDataPipeline(
        n_docs=65536, seq_len=args.seq_len, batch_size=args.batch,
        vocab=cfg.vocab, seed=0)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    tr = Trainer(cfg, opt, pipe, args.ckpt, ckpt_every=args.ckpt_every)
    if args.resume and tr.maybe_resume():
        print(f"resumed at step {tr.step}")
    tr.train(args.steps, log_every=10)


if __name__ == "__main__":
    main()
