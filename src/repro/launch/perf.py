import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb harness: lower one cell with config overrides and compare
its roofline terms against the stored baseline (EXPERIMENTS.md sec Perf).

    PYTHONPATH=src python -m repro.launch.perf --arch xlstm-350m \
        --shape train_4k --set xlstm_chunk=64 --tag chunked_mlstm
"""

import argparse
import ast
import dataclasses
import json

import repro.configs as C
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides key=value (python literals)")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--baseline", default="results/dryrun")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    cfg = C.get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    os.makedirs(args.out, exist_ok=True)
    arch_key = C.ALIASES.get(args.arch, args.arch)
    suffix = "multi" if args.multi_pod else "single"
    tag = f"{arch_key}-{args.shape}-{suffix}-{args.tag}"
    res = lower_cell(cfg, args.shape, mesh,
                     hlo_path=os.path.join(args.out, tag + ".hlo.gz"))
    res["overrides"] = overrides
    json.dump(res, open(os.path.join(args.out, tag + ".json"), "w"),
              indent=1)

    base_path = os.path.join(args.baseline,
                             f"{arch_key}-{args.shape}-{suffix}.json")
    r = res["roofline"]
    print(f"\n=== {tag} ===")
    if os.path.exists(base_path):
        base = json.load(open(base_path))
        if "roofline" in base:
            b = base["roofline"]
            for term in ("compute_s", "memory_s", "collective_s"):
                delta = (r[term] / b[term] - 1) * 100 if b[term] else 0
                print(f"{term:13s}: {b[term]:.3e} -> {r[term]:.3e} "
                      f"({delta:+.1f}%)")
            print(f"dominant     : {b['dominant']} -> {r['dominant']}")
            print(f"model/HLO    : {b['model_to_hlo_flops']:.3f} -> "
                  f"{r['model_to_hlo_flops']:.3f}")
            print(f"roofline_frac: {b['roofline_fraction']:.4f} -> "
                  f"{r['roofline_fraction']:.4f}")
            return
    print({k: f"{v:.3e}" if isinstance(v, float) else v
           for k, v in r.items()})


if __name__ == "__main__":
    main()
