"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ('data', 'model') -- 256 chips.
    Multi-pod:  (2, 16, 16) = ('pod', 'data', 'model') -- 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has (tests / examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


# Hardware constants for the roofline analysis (TPU v5e-class chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
