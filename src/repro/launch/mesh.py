"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ('data', 'model') -- 256 chips.
    Multi-pod:  (2, 16, 16) = ('pod', 'data', 'model') -- 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Whatever this host has (tests / examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_wide_mesh(n: int | None = None):
    """1-D mesh over the ``wide`` axis for sharded wide aggregation
    (core.aggregate): each slab segment's rows split across this axis and
    partial bitset words / bit-sliced counters all-reduce over it.

    ``n`` defaults to every local device; a 1-device mesh makes the
    aggregates fall back to the single-dispatch path, so this is always
    safe to install via ``aggregate.set_default_mesh``."""
    from jax.experimental import mesh_utils
    devs = jax.devices()
    n = len(devs) if n is None else min(n, len(devs))
    return jax.sharding.Mesh(
        mesh_utils.create_device_mesh((n,), devices=devs[:n]), ("wide",))


# Hardware constants for the roofline analysis (TPU v5e-class chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
