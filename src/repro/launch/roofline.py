"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds (see the brief):

    compute    = per_device_HLO_FLOPs / PEAK_FLOPS_BF16
    memory     = per_device_HLO_bytes / HBM_BW
    collective = per_device_collective_bytes / ICI_BW

``cost_analysis()`` runs on the post-SPMD per-device module, so its flops /
bytes are already per-device; the brief's ``HLO_FLOPs / (chips x peak)``
with *global* FLOPs is the same number (global = per_device x chips).  The
collective bytes come from parsing the optimized HLO and summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instructions (also per-device shapes).
"""

from __future__ import annotations

import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s+"
                     r"([\w\-]+)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, handling tuples."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from optimized HLO text."""
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            sizes[m.group(1).lstrip("%")] = _type_bytes(m.group(2))
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        # operand list inside the parens
        args = ln[ln.index("(") + 1:]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        b = 0
        for ref in re.findall(r"%?([\w.\-]+)", args):
            if ref in sizes:
                b += sizes[ref]
        if b == 0:
            # fallback: use the result size
            b = _type_bytes(m.group(2))
        out[kind] += b
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(cost: dict, coll: dict, model_flops: float,
                   chips: int) -> dict:
    """cost: compiled.cost_analysis(); coll: collective_bytes()."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.get("total", 0))
    return _terms(flops_dev, bytes_dev, coll_dev, model_flops, chips)


def roofline_terms_from_analysis(ana: dict, model_flops: float,
                                 chips: int) -> dict:
    """ana: hlo_analysis.analyze_text() output (trip-count-aware)."""
    return _terms(float(ana["flops"]), float(ana["bytes"]),
                  float(ana["collective_total"]), model_flops, chips)


def _terms(flops_dev: float, bytes_dev: float, coll_dev: float,
           model_flops: float, chips: int) -> dict:
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_coll)
    useful = model_flops / chips / PEAK_FLOPS_BF16 if model_flops else 0.0
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "model_flops_global": model_flops,
        # how much of compiled compute is useful (catches remat waste)
        "model_to_hlo_flops": (model_flops / (flops_dev * chips)
                               if flops_dev else 0.0),
        # fraction of roofline if the dominant term were perfectly achieved
        "roofline_fraction": (useful / bound) if bound > 0 else 0.0,
    }


def model_flops_train(cfg, seq_len: int, global_batch: int) -> float:
    """6 * N(_active) * D for a train step."""
    n = cfg.active_params_count()
    return 6.0 * n * seq_len * global_batch


def model_flops_prefill(cfg, seq_len: int, global_batch: int) -> float:
    return 2.0 * cfg.active_params_count() * seq_len * global_batch


def model_flops_decode(cfg, global_batch: int) -> float:
    """One token per sequence."""
    return 2.0 * cfg.active_params_count() * global_batch
