"""Serving launcher: batched generation with the Roaring feature set.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --reduced \
        --batch 4 --new-tokens 16
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--sink-blocks", type=int, default=1)
    ap.add_argument("--local-blocks", type=int, default=8)
    args = ap.parse_args()

    import jax
    import numpy as np

    import repro.configs as C
    from repro.models import transformer as T
    from repro.serve.engine import BlockPolicy, Engine

    cfg = C.get_config(args.arch, reduced=args.reduced)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    params = T.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, max_seq=args.max_seq,
                 policy=BlockPolicy(args.sink_blocks, args.local_blocks))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts, args.new_tokens)
    for i, row in enumerate(out):
        print(f"seq{i}: {row.tolist()}")
    print(f"paged KV pages used: "
          f"{eng.allocator.n_pages - eng.allocator.n_free}")


if __name__ == "__main__":
    main()
