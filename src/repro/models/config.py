"""Model configuration for every assigned architecture family.

A model is a (prefix + repeated pattern) of blocks.  Each block is a
(mixer, ffn) pair:

  mixer: full | local | global | mla | mamba | mlstm | slstm | enc
  ffn  : mlp | moe | none

`full` is causal full attention; `local` is sliding-window attention;
`global` is full attention that can consume a Roaring block-sparse mask at
decode (the paper integration, DESIGN.md section 2); `enc` is bidirectional
(encoder-only); `mla` is DeepSeek-V2 multi-head latent attention; `mamba`,
`mlstm`, `slstm` are the SSM/xLSTM mixers.

The pattern structure is what lets the whole stack lower as a
scan-over-layer-groups: parameters of each position in the pattern are
stacked across repeats, so the HLO size is independent of depth.
"""

from __future__ import annotations

import dataclasses

Mixer = str
Ffn = str
BlockKind = tuple[Mixer, Ffn]

MIXERS = ("full", "local", "global", "mla", "mamba", "mlstm", "slstm", "enc")
FFNS = ("mlp", "moe", "none")

ATTN_MIXERS = ("full", "local", "global", "enc")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # layer plan
    prefix: tuple[BlockKind, ...] = ()
    pattern: tuple[BlockKind, ...] = (("full", "mlp"),)
    # attention
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0        # 0 disables
    final_softcap: float = 0.0
    sliding_window: int = 0          # for 'local' mixers
    m_rope_sections: tuple[int, int, int] | None = None
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0              # ff of dense ("mlp") blocks if distinct
    moe_dispatch: str = "scatter"    # scatter | dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    ssm_chunk: int = 128
    xlstm_heads: int = 4
    xlstm_chunk: int = 0          # 0 = sequential scan; >0 = chunkwise-parallel mLSTM
    # norms / embeddings / activations
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_block_norms: bool = False   # gemma2-style extra post-norms
    scale_embed: bool = False        # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = False
    act: str = "swiglu"              # swiglu | geglu | gelu
    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str = "none"           # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0
    frontend_dim: int = 0            # embedding dim fed by the stub
    # roaring integration (paper technique)
    roaring_sparse_global: bool = False
    attn_block_size: int = 128
    sparse_topk_blocks: int = 0   # >0: gather-based sparse decode (per-request cap)
    # numerics / training-perf knobs (hillclimb levers, EXPERIMENTS.md sec Perf)
    pure_dp: bool = False            # small models: replicate params, DP only
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "block"             # none | block
    ce_chunk: int = 0                # 0 = full logits; >0 = chunked CE vocab tile
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    flash_block_skip: bool = True    # skip fully-masked KV blocks (beyond-paper; exact)

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.family in ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
        n_patterned = self.n_layers - len(self.prefix)
        assert n_patterned >= 0 and n_patterned % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers, prefix {len(self.prefix)}, "
            f"pattern {len(self.pattern)}")
        for mixer, ffn in self.prefix + self.pattern:
            assert mixer in MIXERS and ffn in FFNS, (mixer, ffn)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_repeats(self) -> int:
        return (self.n_layers - len(self.prefix)) // len(self.pattern)

    @property
    def layer_kinds(self) -> tuple[BlockKind, ...]:
        return self.prefix + self.pattern * self.n_repeats

    @property
    def is_encoder(self) -> bool:
        return any(m == "enc" for m, _ in self.layer_kinds)

    @property
    def has_attention(self) -> bool:
        return any(m in ATTN_MIXERS or m == "mla" for m, _ in self.layer_kinds)

    @property
    def full_attention_only(self) -> bool:
        """True when every mixer is unbounded-window attention (the archs for
        which long_500k is skipped per the assignment)."""
        mixers = {m for m, _ in self.layer_kinds}
        if not mixers <= {"full", "mla", "enc", "global"}:
            return False
        # 'global' with roaring sparsity is sub-quadratic; plain global isn't
        return not self.roaring_sparse_global

    def params_count(self) -> int:
        """Approximate parameter count N (for the 6*N*D model-FLOPs line)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for mixer, ffn in self.layer_kinds:
            if mixer in ("full", "local", "global", "enc"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * hd * d
            elif mixer == "mla":
                total += d * self.q_lora_rank
                total += self.q_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.qk_rope_dim)
                total += d * (self.kv_lora_rank + self.qk_rope_dim)
                total += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)
                total += self.n_heads * self.v_head_dim * d
            elif mixer == "mamba":
                di = self.ssm_expand * d
                dt = self.ssm_dt_rank or -(-d // 16)
                total += d * 2 * di + di * (dt + 2 * self.ssm_d_state)
                total += dt * di + di * self.ssm_d_state + di * d
            elif mixer == "mlstm":
                di = self.ssm_expand * d
                total += d * 2 * di + 3 * di * di + 2 * di * self.xlstm_heads
                total += di * d
            elif mixer == "slstm":
                dh = d // self.xlstm_heads
                total += 4 * d * d + 4 * self.xlstm_heads * dh * dh
                total += d * (4 * d) // 3 * 2
            if ffn == "mlp":
                ff = self.dense_d_ff or self.d_ff
                mult = 3 if self.act in ("swiglu", "geglu") else 2
                total += mult * d * ff
            elif ffn == "moe":
                ff = self.moe_d_ff or self.d_ff
                total += d * self.n_experts
                total += 3 * self.n_experts * d * ff
                total += 3 * self.n_shared_experts * d * ff
        return total

    def active_params_count(self) -> int:
        """N_active for MoE archs (6*N_active*D)."""
        if self.n_experts == 0:
            return self.params_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        per_expert = 3 * d * ff
        inactive = sum(
            (self.n_experts - self.moe_top_k) * per_expert
            for _, f in self.layer_kinds if f == "moe")
        return self.params_count() - inactive
