"""Model zoo: unified transformer/SSM/hybrid stack (see config.py)."""

from repro.models.config import ModelConfig  # noqa: F401
