"""Dense feed-forward and Mixture-of-Experts layers.

MoE dispatch strategies (EXPERIMENTS.md section Perf levers):

  * "dense"   -- every expert runs on every token, combined with routing
                 weights.  O(E x tokens) FLOPs: the correctness oracle used
                 by smoke tests and the scatter path's property tests.
  * "scatter" -- capacity-bucketed sort-free dispatch: tokens are scattered
                 into (E, capacity, d) buckets via a cumulative-position
                 scatter, experts run one batched einsum, results gather
                 back with routing weights.  O(top_k x tokens) FLOPs.
                 Tokens beyond an expert's capacity are dropped (standard
                 Switch-style behaviour), tracked by `dropped_fraction`.

Roaring integration: per-expert token-id sets are exposed as Roaring
bitmaps by `repro.serve/telemetry` helpers for load-balance analytics
(paper section 5.9 fast counts).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_params(cfg, rng, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.dense_d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    std_in, std_out = d ** -0.5, ff ** -0.5
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": jax.random.normal(k[0], (d, ff), jnp.float32) * std_in,
            "w_up": jax.random.normal(k[1], (d, ff), jnp.float32) * std_in,
            "w_down": jax.random.normal(k[2], (ff, d), jnp.float32) * std_out,
        }
    return {
        "w_in": jax.random.normal(k[0], (d, ff), jnp.float32) * std_in,
        "w_out": jax.random.normal(k[1], (ff, d), jnp.float32) * std_out,
    }


def _act(x, kind):
    if kind == "swiglu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x)


def mlp(x, p, cfg):
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        h = _act(x @ p["w_gate"].astype(dt), cfg.act) * (x @ p["w_up"].astype(dt))
        return h @ p["w_down"].astype(dt)
    return _act(x @ p["w_in"].astype(dt), "gelu") @ p["w_out"].astype(dt)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def moe_params(cfg, rng):
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    k = jax.random.split(rng, 5)
    std_in, std_out = d ** -0.5, ff ** -0.5
    p = {
        "router": jax.random.normal(k[0], (d, e), jnp.float32) * std_in,
        "wg": jax.random.normal(k[1], (e, d, ff), jnp.float32) * std_in,
        "wu": jax.random.normal(k[2], (e, d, ff), jnp.float32) * std_in,
        "wd": jax.random.normal(k[3], (e, ff, d), jnp.float32) * std_out,
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * ff
        ks = jax.random.split(k[4], 3)
        p["shared"] = {
            "w_gate": jax.random.normal(ks[0], (d, sf), jnp.float32) * std_in,
            "w_up": jax.random.normal(ks[1], (d, sf), jnp.float32) * std_in,
            "w_down": jax.random.normal(ks[2], (sf, d), jnp.float32)
            * (sf ** -0.5),
        }
    return p


def _routing(x2, p, cfg):
    """x2: (T, d) -> (topk weights (T, K), topk experts (T, K), aux loss)."""
    logits = (x2.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)              # (T, K)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    e = cfg.n_experts
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / idx.size)
    aux = e * jnp.sum(me * ce)
    return w.astype(x2.dtype), idx, aux


def _expert_ffn(xe, p, cfg):
    """xe: (E, C, d) -> (E, C, d) through each expert's SwiGLU."""
    dt = xe.dtype
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))


def moe(x, p, cfg):
    """x: (B, S, d) -> (y (B, S, d), metrics dict)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    w, idx, aux = _routing(x2, p, cfg)
    t, k = idx.shape
    e = cfg.n_experts

    if cfg.moe_dispatch == "dense":
        # oracle: all experts on all tokens
        ye = _expert_ffn(
            jnp.broadcast_to(x2[None], (e, t, d)).astype(x.dtype), p, cfg)
        onehot = jax.nn.one_hot(idx, e, dtype=x.dtype)        # (T, K, E)
        comb = (onehot * w[..., None]).sum(axis=1)            # (T, E)
        y2 = jnp.einsum("te,etd->td", comb, ye)
        dropped = jnp.float32(0.0)
    else:
        # Dispatch LOCALLY within each data shard: tokens grouped by dp rank
        # scatter into per-group buckets, so the bucket tensor is dp-sharded
        # instead of partial-replicated (which costs an all-reduce of the
        # expert matmul outputs -- EXPERIMENTS.md sec Perf, mixtral cell).
        from repro.dist import ctx
        dpa = ctx.dp_axes()
        sizes = ctx.axis_sizes()
        groups = 1
        for a in dpa:
            groups *= sizes.get(a, 1)
        if groups <= 1 or t % groups != 0:
            groups = 1
        tl = t // groups                                      # local tokens
        cap = int(max(1, round(cfg.capacity_factor * tl * k / e)))
        cap = min(cap, tl)
        xg = x2.reshape(groups, tl, d)
        idxg = idx.reshape(groups, tl, k)

        def dispatch(xl, il):
            flat_e = il.reshape(-1)                           # (Tl*K,)
            onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
            pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                      flat_e[:, None], axis=1)[:, 0]
            keep = pos < cap
            dst = jnp.where(keep, flat_e * cap + pos, e * cap)
            buckets = jnp.zeros((e * cap + 1, d), x.dtype).at[dst].set(
                jnp.repeat(xl, k, axis=0), mode="drop")
            return buckets[:-1].reshape(e, cap, d), keep, \
                jnp.where(keep, flat_e * cap + pos, 0)

        buckets, keep, src = jax.vmap(dispatch)(xg, idxg)     # (G, e, cap, d)
        buckets = ctx.constrain(buckets, {0: dpa, 1: "model"})
        dropped = 1.0 - keep.mean()
        dt_ = x.dtype
        hbk = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buckets,
                                     p["wg"].astype(dt_))) \
            * jnp.einsum("gecd,edf->gecf", buckets, p["wu"].astype(dt_))
        ye = jnp.einsum("gecf,efd->gecd", hbk, p["wd"].astype(dt_))
        # reshard expert outputs to group-local BEFORE the combine gather:
        # an explicit bf16 all-gather over the model axis, instead of the
        # mask + f32 all-reduce GSPMD otherwise derives for a cross-shard
        # take_along_axis (EXPERIMENTS.md sec Perf, deepseek cell)
        ye = ctx.constrain(ye, {0: dpa})
        gathered = ye.reshape(groups, e * cap, d)
        yk = jnp.take_along_axis(gathered, src[..., None], axis=1) \
            * keep[..., None].astype(dt_)                     # (G, Tl*K, d)
        y2 = (yk.reshape(t, k, d) * w[..., None]).sum(axis=1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        dt = x.dtype
        hs = jax.nn.silu(x2 @ sp["w_gate"].astype(dt)) \
            * (x2 @ sp["w_up"].astype(dt))
        y2 = y2 + hs @ sp["w_down"].astype(dt)
    metrics = {"router_aux": aux, "dropped_fraction": dropped,
               "expert_idx": idx.reshape(b, s, k)}
    return y2.reshape(b, s, d), metrics
