"""Unified model: embeds -> (prefix blocks + scanned pattern groups) -> head.

The layer plan comes from ModelConfig.prefix / .pattern (see config.py).
Parameters of each pattern position are stacked over repeats and the stack
is traversed with `lax.scan`, so the lowered HLO is O(len(pattern)) in size
regardless of depth -- essential for compiling 80-layer models in the
multi-pod dry-run.

Three entry points (shapes per the assignment):
  * loss_and_metrics / train-step path  (train_4k)
  * prefill                             (prefill_32k)
  * decode_step                         (decode_32k, long_500k)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mlp as M
from repro.models import ssm as S
from repro.models.config import ModelConfig

ATTN = ("full", "local", "global", "enc")


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def _mixer_params(cfg, mixer, rng):
    if mixer in ATTN:
        return L.attn_params(cfg, rng)
    if mixer == "mla":
        return L.mla_params(cfg, rng)
    if mixer == "mamba":
        return S.mamba_params(cfg, rng)
    if mixer == "mlstm":
        return S.mlstm_params(cfg, rng)
    if mixer == "slstm":
        return S.slstm_params(cfg, rng)
    raise ValueError(mixer)


def block_params(cfg: ModelConfig, kind, rng):
    mixer, ffn = kind
    k = jax.random.split(rng, 2)
    p = {"ln1": L.norm_params(cfg, cfg.d_model),
         "mixer": _mixer_params(cfg, mixer, k[0])}
    if cfg.post_block_norms:
        p["ln1_post"] = L.norm_params(cfg, cfg.d_model)
    if ffn == "mlp":
        p["ln2"] = L.norm_params(cfg, cfg.d_model)
        p["ffn"] = M.mlp_params(cfg, k[1])
        if cfg.post_block_norms:
            p["ln2_post"] = L.norm_params(cfg, cfg.d_model)
    elif ffn == "moe":
        p["ln2"] = L.norm_params(cfg, cfg.d_model)
        p["ffn"] = M.moe_params(cfg, k[1])
        if cfg.post_block_norms:
            p["ln2_post"] = L.norm_params(cfg, cfg.d_model)
    return p


def init_params(cfg: ModelConfig, rng) -> dict:
    ks = jax.random.split(rng, 4 + len(cfg.prefix))
    d = cfg.d_model
    params: dict = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32)
        * d ** -0.5,
        "final_norm": L.norm_params(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            ks[1], (d, cfg.vocab), jnp.float32) * d ** -0.5
    if cfg.frontend != "none":
        fd = cfg.frontend_dim or d
        params["frontend_proj"] = jax.random.normal(
            ks[2], (fd, d), jnp.float32) * fd ** -0.5
    for i, kind in enumerate(cfg.prefix):
        params[f"prefix_{i}"] = block_params(cfg, kind, ks[4 + i])
    # stacked pattern groups
    rep = cfg.n_repeats
    if rep:
        base = jax.random.split(ks[3], len(cfg.pattern))
        pat = []
        for pi, kind in enumerate(cfg.pattern):
            rngs = jax.random.split(base[pi], rep)
            pat.append(jax.vmap(lambda r, kind=kind: block_params(
                cfg, kind, r))(rngs))
        params["pattern"] = tuple(pat)
    return params


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# forward blocks (training)
# ---------------------------------------------------------------------------

def block_train(x, p, cfg: ModelConfig, kind, positions):
    mixer, ffn = kind
    h = L.apply_norm(x, p["ln1"], cfg)
    if mixer in ATTN:
        h = L.attn_train(h, p["mixer"], cfg, mixer, positions)
    elif mixer == "mla":
        h = L.mla_train(h, p["mixer"], cfg, positions)
    elif mixer == "mamba":
        h = S.mamba_train(h, p["mixer"], cfg)
    elif mixer == "mlstm":
        h = S.mlstm_train(h, p["mixer"], cfg)
    elif mixer == "slstm":
        h = S.slstm_train(h, p["mixer"], cfg)
    if cfg.post_block_norms:
        h = L.apply_norm(h, p["ln1_post"], cfg)
    x = x + h
    aux = jnp.float32(0.0)
    if ffn != "none":
        h = L.apply_norm(x, p["ln2"], cfg)
        if ffn == "mlp":
            h = M.mlp(h, p["ffn"], cfg)
        else:
            h, metrics = M.moe(h, p["ffn"], cfg)
            aux = metrics["router_aux"]
        if cfg.post_block_norms:
            h = L.apply_norm(h, p["ln2_post"], cfg)
        x = x + h
    return x, aux


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Returns (x (B, S, d), positions (B, S), label_mask_offset)."""
    dt = jnp.dtype(cfg.compute_dtype)
    parts = []
    if cfg.frontend != "none" and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(dt)
        parts.append(fe @ params["frontend_proj"].astype(dt))
    if "tokens" in batch:
        emb = params["embed"].astype(dt)[batch["tokens"]]
        parts.append(emb)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    from repro.dist import ctx
    x = ctx.constrain(x, {0: ctx.dp_axes()})
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def backbone(params, x, positions, cfg: ModelConfig):
    """Shared trunk: prefix blocks then scanned pattern groups."""
    aux_total = jnp.float32(0.0)
    for i, kind in enumerate(cfg.prefix):
        x, aux = block_train(x, params[f"prefix_{i}"], cfg, kind, positions)
        aux_total = aux_total + aux
    if cfg.n_repeats:
        pattern = cfg.pattern

        def body(carry, layer_params):
            h, aux_sum = carry
            for pi, kind in enumerate(pattern):
                h, aux = block_train(h, layer_params[pi], cfg, kind,
                                     positions)
                aux_sum = aux_sum + aux
            return (h, aux_sum), None

        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), params["pattern"])
    x = L.apply_norm(x, params["final_norm"], cfg)
    return x, aux_total


def _logits(params, x, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def _ce(logits, labels):
    """logits (..., V) fp32-softmaxed CE; labels -1 = masked."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, lse - gold, 0.0)
    return nll.sum(), mask.sum()


def loss_and_metrics(params, batch, cfg: ModelConfig):
    """batch: {'tokens': (B,S)} and/or {'frontend_embeds'}, 'labels': (B,S).
    Returns (loss, metrics)."""
    x, positions = _embed_inputs(params, batch, cfg)
    x, aux = backbone(params, x, positions, cfg)
    labels = batch["labels"]
    if labels.shape[1] != x.shape[1]:  # frontend tokens carry no labels
        pad = x.shape[1] - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)), constant_values=-1)
    if cfg.ce_chunk:
        c = min(cfg.ce_chunk, x.shape[1])
        s = x.shape[1]
        assert s % c == 0
        xs = x.reshape(x.shape[0], s // c, c, -1).swapaxes(0, 1)
        ls = labels.reshape(labels.shape[0], s // c, c).swapaxes(0, 1)

        def body(carry, inp):
            nll_sum, n_sum = carry
            xc, lc = inp
            nll, n = _ce(_logits(params, xc, cfg), lc)
            return (nll_sum + nll, n_sum + n), None

        (nll, n), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                   (xs, ls))
    else:
        nll, n = _ce(_logits(params, x, cfg), labels)
    loss = nll / jnp.maximum(n, 1)
    total = loss + cfg.router_aux_coef * aux
    return total, {"ce_loss": loss, "router_aux": aux, "tokens": n}


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------

def _mixer_state(cfg: ModelConfig, mixer, batch, s_max, dtype):
    if mixer in ATTN:
        return {"k": jnp.zeros((batch, cfg.n_kv_heads, s_max, cfg.hd), dtype),
                "v": jnp.zeros((batch, cfg.n_kv_heads, s_max, cfg.hd), dtype)}
    if mixer == "mla":
        return {"ckv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype)}
    if mixer == "mamba":
        return S.mamba_init_state(cfg, batch, dtype)
    if mixer == "mlstm":
        return S.mlstm_init_state(cfg, batch, dtype)
    if mixer == "slstm":
        return S.slstm_init_state(cfg, batch, dtype)
    raise ValueError(mixer)


def init_decode_state(cfg: ModelConfig, batch: int, s_max: int):
    dt = jnp.dtype(cfg.compute_dtype)
    state = {"pos": jnp.zeros((batch,), jnp.int32)}
    for i, (mixer, _) in enumerate(cfg.prefix):
        state[f"prefix_{i}"] = _mixer_state(cfg, mixer, batch, s_max, dt)
    pat = []
    for (mixer, _) in cfg.pattern:
        one = _mixer_state(cfg, mixer, batch, s_max, dt)
        # batch-major layer stacks (B, R, ...): keeps decode gathers local
        # and contiguous per batch shard (EXPERIMENTS.md sec Perf)
        pat.append(jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[:, None], (a.shape[0], cfg.n_repeats) + a.shape[1:])
            .copy() if cfg.n_repeats else a, one))
    state["pattern"] = tuple(pat)
    return state


def block_decode(x, p, cfg, kind, st, pos, block_mask_words):
    mixer, ffn = kind
    h = L.apply_norm(x, p["ln1"], cfg)
    if mixer in ATTN:
        h, st = L.attn_decode(h, p["mixer"], cfg, mixer, st, pos,
                              block_mask_words)
    elif mixer == "mla":
        h, st = L.mla_decode(h, p["mixer"], cfg, st, pos)
    elif mixer == "mamba":
        h, st = S.mamba_decode(h, p["mixer"], cfg, st)
    elif mixer == "mlstm":
        h, st = S.mlstm_decode(h, p["mixer"], cfg, st)
    elif mixer == "slstm":
        h, st = S.slstm_decode(h, p["mixer"], cfg, st)
    if cfg.post_block_norms:
        h = L.apply_norm(h, p["ln1_post"], cfg)
    x = x + h
    if ffn != "none":
        h = L.apply_norm(x, p["ln2"], cfg)
        if ffn == "mlp":
            h = M.mlp(h[:, None, :], p["ffn"], cfg)[:, 0]
        else:
            h, _ = M.moe(h[:, None, :], p["ffn"], cfg)
            h = h[:, 0]
        if cfg.post_block_norms:
            h = L.apply_norm(h, p["ln2_post"], cfg)
        x = x + h
    return x, st


def decode_step(params, state, tokens, cfg: ModelConfig,
                block_mask_words=None):
    """One decode step.  tokens: (B,) int32; returns (logits (B, V), state).

    For 'global' mixers with cfg.roaring_sparse_global, block_mask_words
    (B, words) uint32 Roaring containers select visible KV blocks -- the
    paper's data structure on the serving hot path."""
    dt = jnp.dtype(cfg.compute_dtype)
    pos = state["pos"]
    x = params["embed"].astype(dt)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    new_state = {"pos": pos + 1}
    for i, kind in enumerate(cfg.prefix):
        x, st = block_decode(x, params[f"prefix_{i}"], cfg, kind,
                             state[f"prefix_{i}"], pos, block_mask_words)
        new_state[f"prefix_{i}"] = st
    if cfg.n_repeats:
        pattern = cfg.pattern

        # Layer-stacked states ride the scan CARRY and are updated in place
        # (token-column scatters for KV caches) instead of being re-stacked
        # as scan outputs -- re-stacking copies the full per-layer cache
        # every step (EXPERIMENTS.md sec Perf, decode restructure).
        def body(carry, inp):
            h, pat_state = carry
            layer_params, i = inp
            pat_state = list(pat_state)
            for pi, kind in enumerate(pattern):
                mixer, ffn = kind
                p = layer_params[pi]
                st = pat_state[pi]
                hn = L.apply_norm(h, p["ln1"], cfg)
                if mixer in ATTN:
                    hn, k_stack, v_stack = L.attn_decode_stacked(
                        hn, p["mixer"], cfg, mixer, st["k"], st["v"], i,
                        pos, block_mask_words)
                    pat_state[pi] = {"k": k_stack, "v": v_stack}
                elif mixer == "mla":
                    hn, ckv, kr = L.mla_decode_stacked(
                        hn, p["mixer"], cfg, st["ckv"], st["kr"], i, pos)
                    pat_state[pi] = {"ckv": ckv, "kr": kr}
                else:
                    st_i = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, i, 1, keepdims=False), st)
                    if mixer == "mamba":
                        hn, st_i = S.mamba_decode(hn, p["mixer"], cfg, st_i)
                    elif mixer == "mlstm":
                        hn, st_i = S.mlstm_decode(hn, p["mixer"], cfg, st_i)
                    elif mixer == "slstm":
                        hn, st_i = S.slstm_decode(hn, p["mixer"], cfg, st_i)
                    pat_state[pi] = jax.tree.map(
                        lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                            full, upd.astype(full.dtype), i, 1), st, st_i)
                if cfg.post_block_norms:
                    hn = L.apply_norm(hn, p["ln1_post"], cfg)
                h = h + hn
                if ffn != "none":
                    hn = L.apply_norm(h, p["ln2"], cfg)
                    if ffn == "mlp":
                        hn = M.mlp(hn[:, None, :], p["ffn"], cfg)[:, 0]
                    else:
                        hn, _ = M.moe(hn[:, None, :], p["ffn"], cfg)
                        hn = hn[:, 0]
                    if cfg.post_block_norms:
                        hn = L.apply_norm(hn, p["ln2_post"], cfg)
                    h = h + hn
            return (h, tuple(pat_state)), None

        (x, pat_state), _ = jax.lax.scan(
            body, (x, state["pattern"]),
            (params["pattern"], jnp.arange(cfg.n_repeats)))
        new_state["pattern"] = pat_state
    else:
        new_state["pattern"] = state["pattern"]
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = _logits(params, x, cfg)
    return logits, new_state


# ---------------------------------------------------------------------------
# prefill (builds the decode state for a whole prompt)
# ---------------------------------------------------------------------------

def _mixer_prefill(x, p, cfg, mixer, positions, s_max, dtype):
    """Returns (mixer output, decode state after the prompt)."""
    b, s, _ = x.shape
    if mixer in ATTN:
        q, k, v = L._project_qkv(x, p, cfg, positions)
        out = L.flash_attention(
            q, k, v, causal=(mixer != "enc"),
            window=cfg.sliding_window if mixer == "local" else 0,
            softcap=cfg.attn_softcap, q_chunk=cfg.attn_q_chunk,
            k_chunk=cfg.attn_k_chunk, block_skip=cfg.flash_block_skip)
        out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        kc = jnp.zeros((b, cfg.n_kv_heads, s_max, cfg.hd), dtype)
        vc = jnp.zeros((b, cfg.n_kv_heads, s_max, cfg.hd), dtype)
        kc = jax.lax.dynamic_update_slice(
            kc, k.transpose(0, 2, 1, 3).astype(dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v.transpose(0, 2, 1, 3).astype(dtype), (0, 0, 0, 0))
        return out, {"k": kc, "v": vc}
    if mixer == "mla":
        out = L.mla_train(x, p, cfg, positions)
        ckv, kr = L._mla_ckv(x, p, cfg, positions)
        ckv_c = jnp.zeros((b, s_max, cfg.kv_lora_rank), dtype)
        kr_c = jnp.zeros((b, s_max, cfg.qk_rope_dim), dtype)
        ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv.astype(dtype),
                                             (0, 0, 0))
        kr_c = jax.lax.dynamic_update_slice(kr_c, kr.astype(dtype), (0, 0, 0))
        return out, {"ckv": ckv_c, "kr": kr_c}
    if mixer == "mamba":
        # the chunked train pass carries the exact decode state
        out, st = S.mamba_train(x, p, cfg, return_state=True)
        st = {"conv": st["conv"].astype(dtype), "h": st["h"]}
        return out, st
    if mixer == "mlstm":
        out, st = S.mlstm_train(x, p, cfg, return_state=True)
        return out, st
    if mixer == "slstm":
        out, st = S.slstm_train(x, p, cfg, return_state=True)
        return out, st
    raise ValueError(mixer)


def _block_prefill(x, p, cfg, kind, positions, s_max, dtype):
    mixer, ffn = kind
    h = L.apply_norm(x, p["ln1"], cfg)
    h, st = _mixer_prefill(h, p["mixer"], cfg, mixer, positions, s_max, dtype)
    if cfg.post_block_norms:
        h = L.apply_norm(h, p["ln1_post"], cfg)
    x = x + h
    if ffn != "none":
        h = L.apply_norm(x, p["ln2"], cfg)
        h = M.mlp(h, p["ffn"], cfg) if ffn == "mlp" \
            else M.moe(h, p["ffn"], cfg)[0]
        if cfg.post_block_norms:
            h = L.apply_norm(h, p["ln2_post"], cfg)
        x = x + h
    return x, st


def prefill(params, batch, cfg: ModelConfig, s_max: int | None = None):
    """Process a prompt; returns (last-position logits, decode state)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x, positions = _embed_inputs(params, batch, cfg)
    b, s = x.shape[0], x.shape[1]
    s_max = s_max or s
    state = {"pos": jnp.full((b,), s, jnp.int32)}
    for i, kind in enumerate(cfg.prefix):
        x, st = _block_prefill(x, params[f"prefix_{i}"], cfg, kind,
                               positions, s_max, dt)
        state[f"prefix_{i}"] = st
    if cfg.n_repeats:
        pattern = cfg.pattern

        def body(h, layer_params):
            sts = []
            for pi, kind in enumerate(pattern):
                h, st = _block_prefill(h, layer_params[pi], cfg, kind,
                                       positions, s_max, dt)
                sts.append(st)
            return h, tuple(sts)

        x, pat_state = jax.lax.scan(body, x, params["pattern"])
        # scan stacks layer-major; decode carries batch-major stacks
        state["pattern"] = jax.tree.map(
            lambda a: jnp.swapaxes(a, 0, 1), pat_state)
    else:
        state["pattern"] = ()
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return logits, state
