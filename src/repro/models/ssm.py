"""State-space / recurrent mixers: Mamba-1 (Jamba), mLSTM and sLSTM (xLSTM).

All three are attention-free mixers with O(1)-per-token decode state -- the
sub-quadratic families that run the `long_500k` shape (DESIGN.md section 8).

Mamba uses a chunked selective scan: `lax.scan` over sequence chunks with an
associative scan inside each chunk, so peak activation memory is
O(B * chunk * d_inner * d_state) instead of O(B * S * ...).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = cfg.ssm_dt_rank or -(-cfg.d_model // 16)
    return di, dt_rank


def mamba_params(cfg, rng):
    d = cfg.d_model
    di, dt_rank = mamba_dims(cfg)
    ds, dc = cfg.ssm_d_state, cfg.ssm_d_conv
    k = jax.random.split(rng, 6)
    return {
        "in_proj": jax.random.normal(k[0], (d, 2 * di), jnp.float32)
        * d ** -0.5,
        "conv_w": jax.random.normal(k[1], (dc, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(k[2], (di, dt_rank + 2 * ds), jnp.float32)
        * di ** -0.5,
        "dt_proj": jax.random.normal(k[3], (dt_rank, di), jnp.float32)
        * dt_rank ** -0.5,
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, ds))
            .copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(k[5], (di, d), jnp.float32) * di ** -0.5,
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, T, di); w: (dc, di); state: (B, dc-1, di)
    carried tail for decode.  Returns (y, new_state)."""
    dc = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(dc))
    new_state = xp[:, -(dc - 1):, :]
    return y + b[None, None, :], new_state


def _selective_scan_chunk(a, bx, h0):
    """a, bx: (B, T, di, ds); h0: (B, di, ds) -> (h_all (B,T,di,ds), h_T)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_all = b_c + a_c * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_train(x, p, cfg, return_state=False):
    """x: (B, S, d) -> (B, S, d) [, final decode state]."""
    b, s, d = x.shape
    di, dt_rank = mamba_dims(cfg)
    ds = cfg.ssm_d_state
    dt_proj = p["dt_proj"].astype(x.dtype)
    xz = x @ p["in_proj"].astype(x.dtype)
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    xi, _ = _causal_conv(xi_raw, p["conv_w"].astype(x.dtype),
                         p["conv_b"].astype(x.dtype))
    xi = jax.nn.silu(xi)
    proj = xi @ p["x_proj"].astype(x.dtype)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ dt_proj
                         + p["dt_bias"].astype(x.dtype))      # (B, S, di)
    bmat = proj[..., dt_rank:dt_rank + ds]                    # (B, S, ds)
    cmat = proj[..., dt_rank + ds:]                           # (B, S, ds)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (di, ds)

    chunk = min(cfg.ssm_chunk, s)
    assert s % chunk == 0
    nch = s // chunk

    def body(h, inp):
        xi_c, dt_c, b_c, c_c = inp                            # (B, T, ...)
        dt32 = dt_c.astype(jnp.float32)
        abar = jnp.exp(dt32[..., None] * a[None, None])       # (B,T,di,ds)
        bx = (dt32 * xi_c.astype(jnp.float32))[..., None] \
            * b_c.astype(jnp.float32)[:, :, None, :]          # (B,T,di,ds)
        h_all, h_t = _selective_scan_chunk(abar, bx, h)
        y = jnp.einsum("btds,bts->btd", h_all,
                       c_c.astype(jnp.float32))               # (B,T,di)
        return h_t, y.astype(x.dtype)

    def to_chunks(t):
        return t.reshape(b, nch, chunk, *t.shape[2:]).swapaxes(0, 1)

    h0 = jnp.zeros((b, di, ds), jnp.float32)
    h_final, ys = jax.lax.scan(
        body, h0, (to_chunks(xi), to_chunks(dt), to_chunks(bmat),
                   to_chunks(cmat)))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + xi * p["D"].astype(x.dtype)[None, None, :]
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        # conv state carries the last (d_conv - 1) *pre-conv* activations
        state = {"conv": xi_raw[:, -(cfg.ssm_d_conv - 1):, :],
                 "h": h_final}
        return out, state
    return out


def mamba_init_state(cfg, batch, dtype):
    di, _ = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
    }


def mamba_decode(x_tok, p, cfg, state):
    """x_tok: (B, d); O(1) state update."""
    b, d = x_tok.shape
    di, dt_rank = mamba_dims(cfg)
    ds = cfg.ssm_d_state
    x = x_tok[:, None, :]
    xz = x @ p["in_proj"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(xi, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype), state["conv"])
    xi = jax.nn.silu(xi)[:, 0]                                # (B, di)
    proj = xi @ p["x_proj"].astype(x.dtype)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))
    bvec = proj[..., dt_rank:dt_rank + ds].astype(jnp.float32)
    cvec = proj[..., dt_rank + ds:].astype(jnp.float32)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    abar = jnp.exp(dt32[..., None] * a[None])                 # (B, di, ds)
    bx = (dt32 * xi.astype(jnp.float32))[..., None] * bvec[:, None, :]
    h = abar * state["h"] + bx
    y = jnp.einsum("bds,bs->bd", h, cvec).astype(x_tok.dtype)
    y = y + xi * p["D"].astype(x_tok.dtype)[None, :]
    y = y * jax.nn.silu(z[:, 0])
    out = y @ p["out_proj"].astype(x_tok.dtype)
    return out, {"conv": conv_state, "h": h}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory)
# ---------------------------------------------------------------------------

def mlstm_params(cfg, rng):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.xlstm_heads
    dh = di // h
    k = jax.random.split(rng, 7)
    std = di ** -0.5
    return {
        "up": jax.random.normal(k[0], (d, 2 * di), jnp.float32) * d ** -0.5,
        "wq": jax.random.normal(k[1], (di, h, dh), jnp.float32) * std,
        "wk": jax.random.normal(k[2], (di, h, dh), jnp.float32) * std,
        "wv": jax.random.normal(k[3], (di, h, dh), jnp.float32) * std,
        "wi": jax.random.normal(k[4], (di, h), jnp.float32) * std,
        "wf": jax.random.normal(k[5], (di, h), jnp.float32) * std,
        "bi": jnp.zeros((h,), jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),   # forget-dominant init
        "ln": jnp.zeros((di,), jnp.float32),
        "down": jax.random.normal(k[6], (di, d), jnp.float32) * di ** -0.5,
    }


def mlstm_init_state(cfg, batch, dtype):
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.xlstm_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_step(state, qkvif):
    """One stabilized mLSTM step (exponential gating, Beck et al. 2024)."""
    q, k, v, i_pre, f_pre = qkvif          # (B,h,dh) x3, (B,h) x2
    C, n, m = state["C"], state["n"], state["m"]
    f_log = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_log + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    C_new = f_g[..., None, None] * C + i_g[..., None, None] \
        * (v[..., :, None] * k[..., None, :])               # (B,h,dh,dh)
    n_new = f_g[..., None] * n + i_g[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)),
                      jnp.exp(-m_new))
    h_out = num / den[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h_out


def _mlstm_inputs(xi, p, cfg):
    h = cfg.xlstm_heads
    q = jnp.einsum("btd,dhk->bthk", xi, p["wq"].astype(xi.dtype)) \
        .astype(jnp.float32)
    k = jnp.einsum("btd,dhk->bthk", xi, p["wk"].astype(xi.dtype)) \
        .astype(jnp.float32) * (q.shape[-1] ** -0.5)
    v = jnp.einsum("btd,dhk->bthk", xi, p["wv"].astype(xi.dtype)) \
        .astype(jnp.float32)
    i_pre = (xi @ p["wi"].astype(xi.dtype)).astype(jnp.float32) \
        + p["bi"][None, None]
    f_pre = (xi @ p["wf"].astype(xi.dtype)).astype(jnp.float32) \
        + p["bf"][None, None]
    return q, k, v, i_pre, f_pre


def mlstm_train(x, p, cfg, return_state=False):
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    xz = x @ p["up"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_inputs(xi, p, cfg)

    chunk = getattr(cfg, "xlstm_chunk", 0)
    if chunk and s % chunk == 0 and s > chunk:
        hs, st = _mlstm_chunked(q, k, v, i_pre, f_pre, cfg, chunk)
    else:
        def body(state, inp):
            return _mlstm_step(state, inp)

        state0 = mlstm_init_state(cfg, b, x.dtype)
        swap = lambda t: t.swapaxes(0, 1)
        st, hs = jax.lax.scan(body, state0,
                              (swap(q), swap(k), swap(v), swap(i_pre),
                               swap(f_pre)))
        hs = hs.swapaxes(0, 1)
    hs = hs.reshape(b, s, di).astype(x.dtype)
    from repro.models.layers import rms_norm
    hs = rms_norm(hs, p["ln"], cfg.norm_eps)
    hs = hs * jax.nn.silu(z)
    out = hs @ p["down"].astype(x.dtype)
    return (out, st) if return_state else out


def _mlstm_chunked(q, k, v, i_pre, f_pre, cfg, chunk):
    """Chunkwise-parallel mLSTM (EXPERIMENTS.md sec Perf, xlstm hillclimb).

    Mathematically identical to the sequential recurrence: the matrix state
    C is updated once per chunk instead of once per token, and the
    within-chunk contribution is an (L, L)-masked attention-like product.
    HBM traffic for the state drops by the chunk length (the sequential
    scan reads+writes C = (B, H, dh, dh) every token).

    Derivation (stabilized, mirroring _mlstm_step exactly):
        F_t     = cumsum(log_sigmoid(f_t))       within the chunk
        m_t     = F_t + cummax(max(m0 - 0, max_{j<=t}(i_j - F_j)))
        C_t     = e^{m0+F_t-m_t} C_0 + sum_{j<=t} e^{i_j+F_t-F_j-m_t} v_j k_j
        h_t     = C_t q_t / max(|n_t q_t|, e^{-m_t})
    """
    b, s, h, dh = q.shape
    n_chunks = s // chunk

    def to_chunks(t):
        return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic, fc = to_chunks(i_pre), to_chunks(f_pre)

    state0 = mlstm_init_state(cfg, b, q.dtype)

    def body(state, inp):
        qt, kt, vt, it, ft = inp                  # (B, L, H, *) / (B, L, H)
        C0, n0, m0 = state["C"], state["n"], state["m"]
        f_log = jax.nn.log_sigmoid(ft)            # (B, L, H)
        F = jnp.cumsum(f_log, axis=1)             # decay from chunk start
        # running stabilizer: m_t = F_t + cummax(max(m0, i_j - F_j))
        g = jnp.maximum(m0[:, None], jax.lax.cummax(it - F, axis=1))
        m = F + g                                 # (B, L, H)
        # inter-chunk weights and within-chunk log-weight matrix
        w0 = jnp.exp(m0[:, None] + F - m)         # (B, L, H)
        D = (it[:, None, :, :] + F[:, :, None, :] - F[:, None, :, :]
             - m[:, :, None, :])                  # (B, L_t, L_j, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        expD = jnp.exp(D)
        A = jnp.einsum("bthd,bjhd->btjh", qt, kt) * expD
        h_num = (w0[..., None] * jnp.einsum("bthd,bhvd->bthv", qt, C0)
                 + jnp.einsum("btjh,bjhv->bthv", A, vt))
        n_t = (w0[..., None] * n0[:, None]
               + jnp.einsum("btjh,bjhd->bthd", expD, kt))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, qt)), jnp.exp(-m))
        h_out = h_num / den[..., None]            # (B, L, H, dh)
        # chunk-end state (t = L-1)
        m_new = m[:, -1]
        wC = jnp.exp(m0 + F[:, -1] - m_new)       # (B, H)
        wj = jnp.exp(it + F[:, -1:] - F - m_new[:, None])   # (B, L, H)
        C_new = wC[..., None, None] * C0 + jnp.einsum(
            "bjh,bjhv,bjhd->bhvd", wj, vt, kt)
        n_new = wC[..., None] * n0 + jnp.einsum("bjh,bjhd->bhd", wj, kt)
        return ({"C": C_new, "n": n_new, "m": m_new},
                h_out.reshape(b, chunk, h * dh))

    st, hs = jax.lax.scan(body, state0, (qc, kc, vc, ic, fc))
    return hs.swapaxes(0, 1).reshape(b, s, h * dh), st


def mlstm_decode(x_tok, p, cfg, state):
    x = x_tok[:, None, :]
    xz = x @ p["up"].astype(x.dtype)
    xi, z = jnp.split(xz, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_inputs(xi, p, cfg)
    state, h_out = _mlstm_step(
        state, (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]))
    b = x_tok.shape[0]
    di = cfg.ssm_expand * cfg.d_model
    hs = h_out.reshape(b, di).astype(x_tok.dtype)
    from repro.models.layers import rms_norm
    hs = rms_norm(hs, p["ln"], cfg.norm_eps)
    hs = hs * jax.nn.silu(z[:, 0])
    return hs @ p["down"].astype(x_tok.dtype), state


def slstm_params(cfg, rng):
    d = cfg.d_model
    h = cfg.xlstm_heads
    dh = d // h
    ff = max(1, (4 * d) // 3)
    k = jax.random.split(rng, 4)
    return {
        "w": jax.random.normal(k[0], (d, 4, h, dh), jnp.float32) * d ** -0.5,
        "r": jax.random.normal(k[1], (4, h, dh, dh), jnp.float32) * dh ** -0.5,
        "b": jnp.zeros((4, h, dh), jnp.float32),
        "up": jax.random.normal(k[2], (d, 2 * ff), jnp.float32) * d ** -0.5,
        "down": jax.random.normal(k[3], (ff, d), jnp.float32) * ff ** -0.5,
    }


def slstm_init_state(cfg, batch, dtype):
    h = cfg.xlstm_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -1e30,
                                                  jnp.float32)}


def _slstm_step(p, state, wx):
    """wx: (B, 4, h, dh) precomputed input contributions."""
    c, n, hprev, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("ghkl,bhl->bghk", p["r"].astype(jnp.float32), hprev)
    pre = wx.astype(jnp.float32) + rec + p["b"][None]
    i_pre, f_pre, z_pre, o_pre = (pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3])
    f_log = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_log + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_log + m - m_new)
    c_new = f_g * c + i_g * jnp.tanh(z_pre)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def slstm_train(x, p, cfg, return_state=False):
    b, s, d = x.shape
    h = cfg.xlstm_heads
    dh = d // h
    wx = jnp.einsum("bsd,dghk->bsghk", x, p["w"].astype(x.dtype))

    def body(state, wx_t):
        return _slstm_step(p, state, wx_t)

    state0 = slstm_init_state(cfg, b, x.dtype)
    st, hs = jax.lax.scan(body, state0, wx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    # post up/down projection (proj factor 4/3, gated)
    u = hs @ p["up"].astype(x.dtype)
    u1, u2 = jnp.split(u, 2, axis=-1)
    out = (jax.nn.gelu(u1) * u2) @ p["down"].astype(x.dtype)
    return (out, st) if return_state else out


def slstm_decode(x_tok, p, cfg, state):
    wx = jnp.einsum("bd,dghk->bghk", x_tok, p["w"].astype(x_tok.dtype))
    state, h_new = _slstm_step(p, state, wx)
    b, d = x_tok.shape
    hs = h_new.reshape(b, d).astype(x_tok.dtype)
    u = hs @ p["up"].astype(x_tok.dtype)
    u1, u2 = jnp.split(u, 2, axis=-1)
    return (jax.nn.gelu(u1) * u2) @ p["down"].astype(x_tok.dtype), state
