"""Shared neural layers: norms, RoPE / M-RoPE, chunked flash attention
(train), decode attention (dense + Roaring block-sparse), and DeepSeek-V2
multi-head latent attention (MLA).

All functions are pure; parameters are plain dicts of jax arrays.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

_NEG = np.float32(-1e30)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return ((1.0 + scale.astype(jnp.float32)) * out).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (scale.astype(jnp.float32) * out
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, params, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rms_norm(x, params["scale"], cfg.norm_eps)


def norm_params(cfg, shape_d):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((shape_d,), jnp.float32),
                "bias": jnp.zeros((shape_d,), jnp.float32)}
    return {"scale": jnp.zeros((shape_d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE (+ multimodal M-RoPE sections, qwen2-vl)
# ---------------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))


def apply_rope(x, positions, theta: float,
               sections: tuple[int, int, int] | None = None):
    """x: (..., S, H, D); positions: (..., S) int32 (text stub: the three
    M-RoPE streams share one position id, making the sectioned rotation
    exactly equivalent to 1-D RoPE while keeping the sectioned layout)."""
    d = x.shape[-1]
    freqs = jnp.asarray(_rope_freqs(d, theta), jnp.float32)  # (d/2,)
    if sections is not None:
        assert sum(sections) == d // 2, (sections, d)
        # each frequency index belongs to a (temporal/height/width) section;
        # with a single position stream the angles coincide with 1-D RoPE.
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]   # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash attention (training / prefill)
# ---------------------------------------------------------------------------

def _block_pairs(nq, qc, nk, kc, causal, window, skip):
    """Static (query-block, kv-block) schedule.  With skip=True only block
    pairs that can contain visible positions are visited (beyond-paper perf
    lever: halves compute for causal, gives O(S*W) for sliding windows)."""
    pairs = []
    for i in range(nq):
        for j in range(nk):
            if skip:
                if causal and j * kc > i * qc + qc - 1:
                    continue  # entirely in the future
                if window and (j * kc + kc - 1) < (i * qc - window + 1):
                    continue  # entirely out of the window
            pairs.append((i, j))
    return (np.asarray([p[0] for p in pairs], np.int32),
            np.asarray([p[1] for p in pairs], np.int32))


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, q_chunk=512, k_chunk=1024, block_skip=False):
    """Memory-bounded attention: O(S * k_chunk) live intermediates.

    q: (B, S, H, D); k, v: (B, S, Hkv, D).  Returns (B, S, H, D).
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]          # value head dim may differ (MLA)
    g = h // hkv
    scale = (d ** -0.5) if scale is None else scale
    qc, kc = min(q_chunk, s), min(k_chunk, s)
    nq, nk = s // qc, s // kc
    assert nq * qc == s and nk * kc == s, (s, qc, kc)

    qr = q.reshape(b, nq, qc, hkv, g, d)
    kr = k.reshape(b, nk, kc, hkv, d)
    vr = v.reshape(b, nk, kc, hkv, dv)

    # keep attention tiles tensor-parallel: without these constraints GSPMD
    # tends to replicate heads through the scan carry, multiplying FLOPs
    from repro.dist import ctx
    dp = ctx.dp_axes()
    plan = ctx.attn_head_plan(hkv, g, qc)
    qdims = {0: dp}
    kdims = {0: dp}
    cdims = {0: dp}           # carry (b, nq, hkv, g, qc[, dv])
    if plan == "hkv":
        qdims[3] = "model"
        kdims[3] = "model"
        cdims[2] = "model"
    elif plan == "g":
        qdims[4] = "model"
        cdims[3] = "model"
    elif plan == "qc":
        qdims[2] = "model"
        cdims[4] = "model"
    if plan != "auto":
        # 'auto': GSPMD splits the model axis jointly over (hkv, g) from the
        # projection's head sharding; constraining here would conflict.
        qr = ctx.constrain(qr, qdims)
        kr = ctx.constrain(kr, kdims)
        vr = ctx.constrain(vr, kdims)

    qi, kj = _block_pairs(nq, qc, nk, kc, causal, window, block_skip)

    m0 = jnp.full((b, nq, hkv, g, qc), _NEG, jnp.float32)
    l0 = jnp.zeros((b, nq, hkv, g, qc), jnp.float32)
    a0 = jnp.zeros((b, nq, hkv, g, qc, dv), jnp.float32)
    if plan != "auto":
        m0 = ctx.constrain(m0, cdims)
        l0 = ctx.constrain(l0, cdims)
        a0 = ctx.constrain(a0, cdims)

    qpos_in = jnp.arange(qc)
    kpos_in = jnp.arange(kc)

    def body(carry, ij):
        m, l, acc = carry
        i, j = ij
        qb = jax.lax.dynamic_index_in_dim(qr, i, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kr, j, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vr, j, 1, keepdims=False)
        sc = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                        preferred_element_type=jnp.float32) * scale
        if softcap:
            sc = softcap * jnp.tanh(sc / softcap)
        qpos = i * qc + qpos_in
        kpos = j * kc + kpos_in
        mask = jnp.ones((qc, kc), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        sc = jnp.where(mask[None, None, None], sc, _NEG)
        mb = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        lb = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        ab = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mb, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(mb - m_new)
        l_new = alpha * lb + p.sum(axis=-1)
        # probabilities drop to the value dtype for the PV matmul (f32
        # accumulation); upcasting the V tile would materialize it in f32
        a_new = alpha[..., None] * ab + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32).astype(jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.asarray(qi), jnp.asarray(kj)))
    l_safe = jnp.where(l > 0, l, 1.0)
    out = acc / l_safe[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    # (b, nq, hkv, g, qc, dv) -> (b, s, h, dv)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, s, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (single new token over a KV cache)
# ---------------------------------------------------------------------------

def decode_attention_dense(q, k_cache, v_cache, kv_len, *,
                           window=0, softcap=0.0, scale=None):
    """q: (B, H, D); caches: (B, Hkv, S, D); kv_len: (B,) -> (B, H, D)."""
    b, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hkv, g, d)
    sc = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    pos = jnp.arange(s)
    valid = pos[None, :] < kv_len[:, None]
    if window:
        valid &= pos[None, :] >= (kv_len[:, None] - window)
    sc = jnp.where(valid[:, None, None, :], sc, _NEG)
    w = jax.nn.softmax(sc, axis=-1)
    # keep the cache in its storage dtype: casting it would materialize the
    # full (B, Hkv, S, D) buffer in f32 (EXPERIMENTS.md sec Perf, decode)
    out = jnp.einsum("bhgs,bhsd->bhgd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attention_roaring(q, k_cache, v_cache, kv_len, block_mask_words,
                             *, block_size=128, scale=None, softcap=0.0):
    """Paper-technique decode path: the Roaring block-visibility kernel."""
    return kops.decode_attention(q, k_cache, v_cache, block_mask_words,
                                 kv_len, block_size=block_size,
                                 sm_scale=scale, softcap=softcap)


def decode_attention_block_gather(q, k_cache, v_cache, kv_len,
                                  block_mask_words, *, block_size=128,
                                  topk=64, scale=None, softcap=0.0):
    """Gather-based Roaring block-sparse decode (portable twin of the Pallas
    kernel): materializes the visible-block id list from the bitset words
    (rank = prefix sum -- the paper's sec 3.1 extraction), gathers only
    those KV blocks, and attends over the gathered window.  HBM traffic
    scales with `topk * block_size` instead of the full cache length.

    q: (B, H, D); caches (B, Hkv, S, D); block_mask_words (B, W) uint32.
    """
    b, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    nblk = s // block_size
    topk = min(topk, nblk)
    scale = (d ** -0.5) if scale is None else scale
    blocks = jnp.arange(nblk)
    vis = ((block_mask_words[:, blocks >> 5]
            >> (blocks & 31).astype(jnp.uint32)) & np.uint32(1)).astype(bool)
    vis &= (blocks[None, :] * block_size) < kv_len[:, None]

    def extract(vis_row):
        rank = jnp.cumsum(vis_row) - 1
        dst = jnp.where(vis_row & (rank < topk), rank, topk)
        idx = jnp.zeros(topk + 1, jnp.int32).at[dst].set(
            blocks.astype(jnp.int32), mode="drop")[:topk]
        n = jnp.minimum(vis_row.sum(), topk)
        return idx, n

    idx, n_vis = jax.vmap(extract)(vis)                 # (B, topk), (B,)
    kb = k_cache.reshape(b, hkv, nblk, block_size, d)
    vb = v_cache.reshape(b, hkv, nblk, block_size, d)
    sel = idx[:, None, :, None, None]
    k_sel = jnp.take_along_axis(kb, jnp.broadcast_to(
        sel, (b, hkv, topk, block_size, d)).astype(jnp.int32), axis=2)
    v_sel = jnp.take_along_axis(vb, jnp.broadcast_to(
        sel, (b, hkv, topk, block_size, d)).astype(jnp.int32), axis=2)
    qg = q.reshape(b, hkv, g, d)
    sc = jnp.einsum("bhgd,bhtsd->bhgts", qg, k_sel,
                    preferred_element_type=jnp.float32) * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    pos = idx[:, :, None] * block_size + jnp.arange(block_size)[None, None]
    valid = (jnp.arange(topk)[None, :, None] < n_vis[:, None, None]) \
        & (pos < kv_len[:, None, None])
    sc = jnp.where(valid[:, None, None], sc, _NEG)
    sc2 = sc.reshape(b, hkv, g, topk * block_size)
    w = jax.nn.softmax(sc2, axis=-1).reshape(b, hkv, g, topk, block_size)
    out = jnp.einsum("bhgts,bhtsd->bhgd", w.astype(v_sel.dtype), v_sel,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention blocks (projection + rope + attention + output)
# ---------------------------------------------------------------------------

def attn_params(cfg, rng):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k = jax.random.split(rng, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k[0], (d, h, hd), jnp.float32) * std,
        "wk": jax.random.normal(k[1], (d, hkv, hd), jnp.float32) * std,
        "wv": jax.random.normal(k[2], (d, hkv, hd), jnp.float32) * std,
        "wo": jax.random.normal(k[3], (h, hd, d), jnp.float32)
        * ((h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(x, p, cfg, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.m_rope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.m_rope_sections)
    return q, k, v


def attn_train(x, p, cfg, mixer, positions):
    """x: (B, S, d) -> (B, S, d).  mixer in full|local|global|enc."""
    q, k, v = _project_qkv(x, p, cfg, positions)
    out = flash_attention(
        q, k, v,
        causal=(mixer != "enc"),
        window=cfg.sliding_window if mixer == "local" else 0,
        softcap=cfg.attn_softcap,
        q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
        block_skip=cfg.flash_block_skip)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attn_decode(x_tok, p, cfg, mixer, cache, pos, block_mask_words=None):
    """x_tok: (B, d); cache: {'k','v'}: (B, Hkv, S, D); pos: (B,) int32.
    Returns (out (B, d), new cache)."""
    dt = x_tok.dtype
    x = x_tok[:, None, :]                                   # (B, 1, d)
    q, k, v = _project_qkv(x, p, cfg, positions=pos[:, None])
    q = q[:, 0]                                             # (B, H, D)
    k_new = k[:, 0]                                         # (B, Hkv, D)
    v_new = v[:, 0]
    kc = _cache_insert(cache["k"], k_new, pos)
    vc = _cache_insert(cache["v"], v_new, pos)
    kv_len = pos + 1
    if mixer == "global" and cfg.roaring_sparse_global \
            and block_mask_words is not None:
        if cfg.sparse_topk_blocks:
            out = decode_attention_block_gather(
                q, kc, vc, kv_len, block_mask_words,
                block_size=cfg.attn_block_size,
                topk=cfg.sparse_topk_blocks, scale=cfg.hd ** -0.5,
                softcap=cfg.attn_softcap)
        else:
            out = decode_attention_roaring(
                q, kc, vc, kv_len, block_mask_words,
                block_size=cfg.attn_block_size, scale=cfg.hd ** -0.5,
                softcap=cfg.attn_softcap)
    else:
        out = decode_attention_dense(
            q, kc, vc, kv_len,
            window=cfg.sliding_window if mixer == "local" else 0,
            softcap=cfg.attn_softcap)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(dt))
    return out, {"k": kc, "v": vc}


def _cache_insert(cache, new, pos):
    """cache: (B, Hkv, S, D); new: (B, Hkv, D); pos: (B,)."""
    b = cache.shape[0]
    return jax.vmap(
        lambda c, n, p_: jax.lax.dynamic_update_slice(
            c, n[:, None, :].astype(c.dtype), (0, p_, 0))
    )(cache, new, pos)


def insert_token_stacked(stack, new, i, pos):
    """Write one token column into a batch-major layer-stacked cache IN
    PLACE.

    stack: (B, R, H, S, D) or (B, R, S, D); new: (B, H, D) / (B, D);
    i: scalar layer index; pos: (B,) positions.  One scatter with a
    token-column window -- the whole point of carrying caches through the
    decode layer-scan instead of re-stacking them as scan outputs
    (EXPERIMENTS.md sec Perf, decode restructure)."""
    b = new.shape[0]
    if stack.ndim == 5:
        hh = stack.shape[2]
        return stack.at[jnp.arange(b)[:, None], i,
                        jnp.arange(hh)[None, :], pos[:, None], :].set(
            new.astype(stack.dtype))
    return stack.at[jnp.arange(b), i, pos, :].set(new.astype(stack.dtype))


def visible_block_ids(block_mask_words, kv_len, n_blocks, block_size, topk):
    """Roaring words -> dense (B, topk) visible-block id list + counts.
    The rank extraction is the paper's sec 3.1 prefix-sum idiom."""
    blocks = jnp.arange(n_blocks)
    vis = ((block_mask_words[:, blocks >> 5]
            >> (blocks & 31).astype(jnp.uint32)) & np.uint32(1)).astype(bool)
    vis &= (blocks[None, :] * block_size) < kv_len[:, None]

    def extract(vis_row):
        rank = jnp.cumsum(vis_row) - 1
        dst = jnp.where(vis_row & (rank < topk), rank, topk)
        idx = jnp.zeros(topk + 1, jnp.int32).at[dst].set(
            blocks.astype(jnp.int32), mode="drop")[:topk]
        return idx, jnp.minimum(vis_row.sum(), topk)

    return jax.vmap(extract)(vis)


def gather_blocks_stacked(stack, layer_i, block_ids, block_size):
    """(B, R, Hkv, S, D) + (B, topk) block ids -> (B, topk, Hkv, bs, D),
    reading ONLY the addressed blocks of layer `layer_i` (a batch-aligned
    lax.gather on the contiguous batch-major stack -- no per-layer slice
    materialization, no transpose, shard-local under dp sharding)."""
    b, r, hkv, s, d = stack.shape
    topk = block_ids.shape[1]
    starts = jnp.stack([
        jnp.broadcast_to(layer_i, (b, topk)).astype(jnp.int32),
        block_ids.astype(jnp.int32) * block_size,
    ], axis=-1)                                   # (B, topk, 2)
    dnums = jax.lax.GatherDimensionNumbers(
        offset_dims=(1, 2, 3),                    # Hkv, bs, D in the output
        collapsed_slice_dims=(0,),
        start_index_map=(0, 2))

    def one(stack_b, starts_b):
        return jax.lax.gather(
            stack_b, starts_b, dnums,
            slice_sizes=(1, hkv, block_size, d),
            mode=jax.lax.GatherScatterMode.CLIP)

    return jax.vmap(one)(stack, starts)


def attn_decode_stacked(x_tok, p, cfg, mixer, k_stack, v_stack, i, pos,
                        block_mask_words=None):
    """Decode step against batch-major stacked caches (B, R, Hkv, S, D); updates
    only the new token column; the roaring-sparse path gathers only the
    visible blocks straight from the stack (paper technique on the decode
    hot path)."""
    dt = x_tok.dtype
    x = x_tok[:, None, :]
    q, k, v = _project_qkv(x, p, cfg, positions=pos[:, None])
    q = q[:, 0]
    k_stack = insert_token_stacked(k_stack, k[:, 0], i, pos)
    v_stack = insert_token_stacked(v_stack, v[:, 0], i, pos)
    kv_len = pos + 1
    sparse = (mixer == "global" and cfg.roaring_sparse_global
              and block_mask_words is not None and cfg.sparse_topk_blocks)
    if sparse:
        b, h, d = q.shape
        hkv = cfg.n_kv_heads
        g = h // hkv
        bs = cfg.attn_block_size
        n_blocks = k_stack.shape[3] // bs  # (B, R, Hkv, S, D)
        topk = min(cfg.sparse_topk_blocks, n_blocks)
        idx, n_vis = visible_block_ids(block_mask_words, kv_len, n_blocks,
                                       bs, topk)
        k_sel = gather_blocks_stacked(k_stack, i, idx, bs)  # (B,t,Hkv,bs,D)
        v_sel = gather_blocks_stacked(v_stack, i, idx, bs)
        qg = q.reshape(b, hkv, g, d)
        sc = jnp.einsum("bhgd,bthsd->bhgts", qg, k_sel,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
        if cfg.attn_softcap:
            sc = cfg.attn_softcap * jnp.tanh(sc / cfg.attn_softcap)
        posns = idx[:, :, None] * bs + jnp.arange(bs)[None, None]
        valid = (jnp.arange(topk)[None, :, None] < n_vis[:, None, None]) \
            & (posns < kv_len[:, None, None])
        sc = jnp.where(valid[:, None, None], sc, _NEG)
        w = jax.nn.softmax(sc.reshape(b, hkv, g, topk * bs), axis=-1) \
            .reshape(b, hkv, g, topk, bs)
        out = jnp.einsum("bhgts,bthsd->bhgd", w.astype(v_sel.dtype), v_sel,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, h, d).astype(dt)
    else:
        kc = jax.lax.dynamic_index_in_dim(k_stack, i, 1, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_stack, i, 1, keepdims=False)
        if mixer == "global" and cfg.roaring_sparse_global \
                and block_mask_words is not None:
            out = decode_attention_roaring(
                q, kc, vc, kv_len, block_mask_words,
                block_size=cfg.attn_block_size, scale=cfg.hd ** -0.5,
                softcap=cfg.attn_softcap)
        else:
            out = decode_attention_dense(
                q, kc, vc, kv_len,
                window=cfg.sliding_window if mixer == "local" else 0,
                softcap=cfg.attn_softcap)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(dt))
    return out, k_stack, v_stack


def mla_decode_stacked(x_tok, p, cfg, ckv_stack, kr_stack, i, pos):
    """Absorbed MLA decode against layer-stacked compressed caches
    (R, B, S, kl) / (R, B, S, rope_d)."""
    x = x_tok[:, None, :]
    q_nope, q_rope = _mla_q(x, p, cfg, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]
    ckv_new, kr_new = _mla_ckv(x, p, cfg, pos[:, None])
    ckv_stack = insert_token_stacked(ckv_stack, ckv_new[:, 0], i, pos)
    kr_stack = insert_token_stacked(kr_stack, kr_new[:, 0], i, pos)
    ckv_c = jax.lax.dynamic_index_in_dim(ckv_stack, i, 1, keepdims=False)
    kr_c = jax.lax.dynamic_index_in_dim(kr_stack, i, 1, keepdims=False)
    dt = x_tok.dtype
    q_c = jnp.einsum("bhn,khn->bhk", q_nope, p["w_uk"].astype(dt))
    sc = jnp.einsum("bhk,bsk->bhs", q_c, ckv_c,
                    preferred_element_type=jnp.float32)
    sc = sc + jnp.einsum("bhr,bsr->bhs", q_rope, kr_c,
                         preferred_element_type=jnp.float32)
    sc = sc * ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5)
    s = ckv_c.shape[1]
    valid = jnp.arange(s)[None, :] < (pos + 1)[:, None]
    sc = jnp.where(valid[:, None, :], sc, _NEG)
    w = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhs,bsk->bhk", w.astype(ckv_c.dtype), ckv_c,
                     preferred_element_type=jnp.float32).astype(dt)
    vout = jnp.einsum("bhk,khv->bhv", ctx, p["w_uv"].astype(dt))
    out = jnp.einsum("bhv,hvd->bd", vout, p["wo"].astype(dt))
    return out, ckv_stack, kr_stack


# ---------------------------------------------------------------------------
# Multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_params(cfg, rng):
    d, h = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    k = jax.random.split(rng, 6)
    std = d ** -0.5
    return {
        "w_dq": jax.random.normal(k[0], (d, ql), jnp.float32) * std,
        "q_ln": jnp.zeros((ql,), jnp.float32),
        "w_uq": jax.random.normal(k[1], (ql, h, nope + rope_d), jnp.float32)
        * (ql ** -0.5),
        "w_dkv": jax.random.normal(k[2], (d, kl + rope_d), jnp.float32) * std,
        "kv_ln": jnp.zeros((kl,), jnp.float32),
        "w_uk": jax.random.normal(k[3], (kl, h, nope), jnp.float32)
        * (kl ** -0.5),
        "w_uv": jax.random.normal(k[4], (kl, h, vd), jnp.float32)
        * (kl ** -0.5),
        "wo": jax.random.normal(k[5], (h, vd, d), jnp.float32)
        * ((h * vd) ** -0.5),
    }


def _mla_q(x, p, cfg, positions):
    cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["w_dq"].astype(x.dtype)),
                  p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, p["w_uq"].astype(x.dtype))
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(x, p, cfg, positions):
    dkv = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"].astype(x.dtype))
    ckv = rms_norm(dkv[..., :cfg.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[:, :, 0]           # (B, S, rope_d)
    return ckv, k_rope


def mla_train(x, p, cfg, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(x, p, cfg, positions)
    ckv, k_rope = _mla_ckv(x, p, cfg, positions)
    k_nope = jnp.einsum("bsk,khn->bshn", ckv, p["w_uk"].astype(x.dtype))
    vfull = jnp.einsum("bsk,khv->bshv", ckv, p["w_uv"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, cfg.qk_rope_dim))], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    # decompressed space is MHA; pad v to qk head width for the shared kernel
    out = flash_attention(q, k, vfull, causal=True, scale=scale,
                          q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
                          block_skip=cfg.flash_block_skip)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))


def mla_decode(x_tok, p, cfg, cache, pos):
    """Absorbed-matrix MLA decode: the cache holds only (ckv, k_rope) --
    the paper('s subject)-sized KV cache advantage of MLA.

    cache: {'ckv': (B, S, kl), 'kr': (B, S, rope_d)}."""
    x = x_tok[:, None, :]
    q_nope, q_rope = _mla_q(x, p, cfg, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]             # (B, H, *)
    ckv_new, kr_new = _mla_ckv(x, p, cfg, pos[:, None])
    ckv_c = jax.vmap(lambda c, n, p_: jax.lax.dynamic_update_slice(
        c, n.astype(c.dtype), (p_, 0)))(cache["ckv"], ckv_new, pos)
    kr_c = jax.vmap(lambda c, n, p_: jax.lax.dynamic_update_slice(
        c, n.astype(c.dtype), (p_, 0)))(cache["kr"], kr_new, pos)
    dt = x_tok.dtype
    q_c = jnp.einsum("bhn,khn->bhk", q_nope, p["w_uk"].astype(dt))
    sc = jnp.einsum("bhk,bsk->bhs", q_c, ckv_c,
                    preferred_element_type=jnp.float32)
    sc = sc + jnp.einsum("bhr,bsr->bhs", q_rope, kr_c,
                         preferred_element_type=jnp.float32)
    sc = sc * ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5)
    s = ckv_c.shape[1]
    valid = jnp.arange(s)[None, :] < (pos + 1)[:, None]
    sc = jnp.where(valid[:, None, :], sc, _NEG)
    w = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhs,bsk->bhk", w, ckv_c.astype(jnp.float32),
                     preferred_element_type=jnp.float32).astype(dt)
    vout = jnp.einsum("bhk,khv->bhv", ctx, p["w_uv"].astype(dt))
    out = jnp.einsum("bhv,hvd->bd", vout, p["wo"].astype(dt))
    return out, {"ckv": ckv_c, "kr": kr_c}
