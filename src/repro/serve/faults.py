"""Deterministic fault injection for the query server.

The server's robustness claims (retry-with-backoff, kernel->host
degradation, deadline enforcement, zero lost tickets) are only testable
if failures can be scripted exactly.  This module provides the three
pieces the tests wire through ``QueryServer``:

* ``FaultInjector`` -- named injection points (``SITES``) consulted by
  the server at every dispatch boundary.  Scripted mode replays an exact
  per-site sequence (fail-once-then-succeed is ``[True, False]``);
  seeded-random mode draws from a private ``random.Random`` so a run is
  reproducible from its seed alone.
* ``FaultError`` subclasses -- the transient failures the injector
  raises, kept distinct from real bugs so the server's catch-all can
  still report unexpected exceptions as such.
* ``FakeClock`` -- a manual clock + sleep pair so deadline and backoff
  tests advance virtual time instead of sleeping in CI.

Injection sites
---------------
``dispatch_raise``   the kernel batch raises mid-dispatch (transient).
``dispatch_hang``    the dispatch stalls; fires as a sleep of the
                     scripted duration, driving deadline overruns.
``slab_mismatch``    the planned slab no longer matches the index
                     generation (concurrent mutation); the server must
                     re-plan, not fail.
``alloc_pressure``   the batch is too large for the allocator; the
                     server must split it, then degrade to the host.
"""

from __future__ import annotations

import random
import time

SITES = ("dispatch_raise", "dispatch_hang", "slab_mismatch",
         "alloc_pressure")


class FaultError(Exception):
    """Base of all injected faults: transient by contract, so the server
    retries these before degrading."""


class DispatchFault(FaultError):
    """Injected kernel-dispatch failure (site ``dispatch_raise``)."""


class SlabMismatch(FaultError):
    """Planned slab went stale mid-batch (site ``slab_mismatch``)."""


class AllocPressure(FaultError):
    """Allocator refused the batch (site ``alloc_pressure``)."""


class SystemClock:
    """Real monotonic time + real sleep (the default outside tests)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class FakeClock:
    """Manual clock: ``sleep`` advances ``now`` instantly and records
    every call, so backoff schedules and deadline overruns are asserted
    without wall-clock delay."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)
        self.sleeps: list[float] = []

    def now(self) -> float:
        return self.t

    def sleep(self, seconds: float) -> None:
        self.sleeps.append(float(seconds))
        self.t += max(0.0, float(seconds))

    def advance(self, seconds: float) -> None:
        self.t += float(seconds)


class FaultInjector:
    """Per-site fault schedule consulted by the server.

    ``fire(site)`` returns the next scripted value for ``site`` --
    falsy for "no fault", ``True`` to fault, a positive float for a
    hang duration -- consuming one schedule entry per call.  A site's
    schedule may be a finite sequence (exhausted -> no more faults) or
    the string ``"always"``.  ``FaultInjector()`` with no arguments
    never fires, so production servers pay one dict lookup per site.
    """

    def __init__(self, script: dict | None = None, *,
                 seed: int | None = None, rates: dict | None = None,
                 hang_s: float = 0.0):
        script = dict(script or {})
        rates = dict(rates or {})
        for site in list(script) + list(rates):
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r}; "
                                 f"sites: {SITES}")
        self._always = {s for s, v in script.items() if v == "always"}
        self._queues = {s: list(v) for s, v in script.items()
                        if v != "always"}
        self._rates = rates
        self._hang_s = float(hang_s)
        self._rng = random.Random(seed)
        self.fired: list[str] = []                # audit log for tests

    @classmethod
    def script(cls, script: dict) -> "FaultInjector":
        """Exact per-site schedules, e.g. fail-once-then-succeed:
        ``FaultInjector.script({"dispatch_raise": [True, False]})``."""
        return cls(script)

    @classmethod
    def random(cls, seed: int, rates: dict,
               hang_s: float = 0.0) -> "FaultInjector":
        """Seeded random faulting: ``rates`` maps site -> probability
        per consultation; ``hang_s`` is the duration when
        ``dispatch_hang`` fires."""
        return cls(seed=seed, rates=rates, hang_s=hang_s)

    def fire(self, site: str):
        if site in self._always:
            self.fired.append(site)
            return True
        q = self._queues.get(site)
        if q:
            v = q.pop(0)
            if v:
                self.fired.append(site)
            return v
        rate = self._rates.get(site, 0.0)
        if rate and self._rng.random() < rate:
            self.fired.append(site)
            return self._hang_s if site == "dispatch_hang" else True
        return False
