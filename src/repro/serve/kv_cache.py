"""Paged KV-cache page allocator with a Roaring free-set (DESIGN.md sec 2).

The allocator's free list over [0, n_pages) is exactly an integer set: we
keep it as a Roaring bitmap, so
  * allocation        = select(0..k) + difference,
  * free              = union,
  * fragmentation     = num_runs vs cardinality (run containers!),
  * defrag planning   = set algebra between per-sequence page sets.
The page *table* (sequence -> ordered page list) stays a plain list since
order matters; set queries (which pages live, which sequences own a page
range) go through bitmaps.
"""

from __future__ import annotations

import numpy as np

from repro.core import RoaringBitmap


class PagedKVAllocator:
    def __init__(self, n_pages: int, page_size: int = 128):
        self.n_pages = n_pages
        self.page_size = page_size
        self.free = RoaringBitmap.from_range(0, n_pages).run_optimize()
        self.tables: dict[int, list[int]] = {}

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return self.free.cardinality

    def fragmentation(self) -> float:
        """1 - (1 / runs-per-free-region); 0 when the free set is one run."""
        if not self.free:
            return 0.0
        runs = sum(c.num_runs() for c in self.free.containers)
        return 1.0 - 1.0 / runs

    # ------------------------------------------------------------------
    def allocate(self, seq_id: int, n_pages: int) -> list[int]:
        if n_pages > self.n_free:
            raise MemoryError(
                f"need {n_pages} pages, {self.n_free} free")
        pages = [self.free.select(i) for i in range(n_pages)]
        taken = RoaringBitmap.from_values(np.asarray(pages, np.uint32))
        self.free = self.free - taken
        self.tables.setdefault(seq_id, []).extend(pages)
        return pages

    def extend(self, seq_id: int, token_count: int) -> list[int]:
        """Grow a sequence to cover token_count tokens."""
        have = len(self.tables.get(seq_id, ())) * self.page_size
        need = max(0, -(-max(token_count - have, 0) // self.page_size))
        return self.allocate(seq_id, need) if need else []

    def release(self, seq_id: int) -> None:
        pages = self.tables.pop(seq_id, [])
        if pages:
            self.free = self.free | RoaringBitmap.from_values(
                np.asarray(pages, np.uint32))
            self.free.run_optimize()

    # ------------------------------------------------------------------
    def pages_of(self, seq_id: int) -> list[int]:
        return list(self.tables.get(seq_id, ()))

    def used_set(self) -> RoaringBitmap:
        from repro.core import complement
        return complement(self.free, self.n_pages)

    def owner_overlap(self, a: int, b: int) -> int:
        """Shared pages between two sequences (prefix sharing telemetry)."""
        sa = RoaringBitmap.from_values(
            np.asarray(self.tables.get(a, []), np.uint32))
        sb = RoaringBitmap.from_values(
            np.asarray(self.tables.get(b, []), np.uint32))
        return sa.and_card(sb)
