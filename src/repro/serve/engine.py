"""Batched serving engine: prefill -> decode loop with Roaring integrations.

Per-request state carries
  * a Roaring block-visibility set (sink + sliding local + pinned blocks)
    rendered to container words for the block-sparse attention kernel,
  * an optional VocabConstraint (constrained decoding),
  * paged-KV bookkeeping via PagedKVAllocator.
Runs on CPU with reduced configs (examples/constrained_serve.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RoaringBitmap
from repro.core.tensor import block_mask_words
from repro.models import transformer as T
from repro.serve.constrained import VocabConstraint
from repro.serve.kv_cache import PagedKVAllocator


@dataclasses.dataclass
class BlockPolicy:
    """Which KV blocks stay visible for long-context decode."""
    sink_blocks: int = 1          # always keep the first blocks
    local_blocks: int = 8         # sliding window of recent blocks
    pinned: RoaringBitmap | None = None   # retrieval-pinned blocks

    def visible_set(self, kv_len: int, block_size: int) -> RoaringBitmap:
        n_blocks = max(1, -(-kv_len // block_size))
        sink = RoaringBitmap.from_range(0, min(self.sink_blocks, n_blocks))
        lo = max(0, n_blocks - self.local_blocks)
        local = RoaringBitmap.from_range(lo, n_blocks)
        vis = sink | local
        if self.pinned is not None:
            vis = vis | self.pinned
        return vis


class Engine:
    def __init__(self, cfg, params, max_seq: int,
                 policy: BlockPolicy | None = None,
                 constraint: VocabConstraint | None = None,
                 page_size: int = 128, greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.policy = policy or BlockPolicy()
        self.constraint = constraint
        self.greedy = greedy
        self.rng = jax.random.key(seed)
        self.allocator = PagedKVAllocator(
            n_pages=max(64, 4 * max_seq // page_size), page_size=page_size)
        self._decode = jax.jit(
            lambda p, st, t, m: T.decode_step(p, st, t, cfg, m))
        self.n_blocks = max(1, max_seq // cfg.attn_block_size)
        self._mask_cache: dict[tuple[int, ...], jax.Array] = {}

    def _mask_words(self, kv_lens: list[int]):
        """Visible-block mask words, cached on the per-request block counts.

        The visible set depends on kv_len only through
        ceil(kv_len / block_size), so consecutive decode steps inside one
        attention block hit the cache instead of rebuilding Roaring sets and
        re-rendering words every token.  (Mutating ``policy.pinned`` in
        place will not invalidate the cache; swap the policy or Engine to
        change pinning mid-stream.)"""
        bs = self.cfg.attn_block_size
        key = tuple(-(-kl // bs) for kl in kv_lens)
        mask = self._mask_cache.get(key)
        if mask is None:
            if len(self._mask_cache) > 512:        # bound decode-long growth
                self._mask_cache.clear()
            sets = [self.policy.visible_set(kl, bs) for kl in kv_lens]
            mask = self._mask_cache[key] = block_mask_words(
                sets, self.n_blocks)
        return mask

    def generate(self, prompts: np.ndarray, max_new_tokens: int) -> np.ndarray:
        """prompts: (B, S0) int32 -> (B, max_new_tokens) int32."""
        b, s0 = prompts.shape
        for i in range(b):
            self.allocator.extend(i, s0)
        logits, state = T.prefill(
            self.params, {"tokens": jnp.asarray(prompts)}, self.cfg,
            s_max=self.max_seq)
        out = np.zeros((b, max_new_tokens), np.int32)
        tok = self._select(logits)
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)
            kv_lens = [s0 + t + 1] * b
            for i in range(b):
                self.allocator.extend(i, kv_lens[i])
            mask = self._mask_words(kv_lens)
            logits, state = self._decode(self.params, state,
                                         jnp.asarray(tok), mask)
            tok = self._select(logits)
        return out

    def _select(self, logits):
        if self.constraint is not None:
            logits = self.constraint.apply(logits)
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

    def release_all(self):
        for sid in list(self.allocator.tables):
            self.allocator.release(sid)
