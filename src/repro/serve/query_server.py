"""Fault-tolerant continuous query server over a warm inverted index.

The paper's adopters (Druid, Pinot, Elasticsearch) serve thousands of
concurrent queries against one shared index; this module is that serving
layer for the repro engine, shaped like an inference server's continuous
batcher: callers ``submit`` queries and get tickets back immediately,
and each engine tick coalesces EVERYTHING queued into one multi-query
slab dispatch per op class (``core.aggregate.execute_plans`` -- a query
id is just another segment coordinate of the segmented-reduce kernel)
plus one vmapped score+select dispatch per (k, metric) similarity class
(``SimilarityEngine.topk_batch`` over the cached candidate slab).

Robustness contract (the point of the module):

* **Admission control** -- the queue is bounded; tickets beyond
  ``max_queue`` resolve immediately with a structured ``OVERLOADED``
  result.  Malformed queries resolve ``INVALID`` at submit time (the
  planner validates at admission, never inside a batch).
* **Deadlines** -- enforced at admission, at batch formation, and after
  dispatch: a ticket that misses its deadline resolves ``DEADLINE``;
  a hung dispatch can overrun but never lose the ticket.
* **Retry with backoff** -- transient dispatch failures retry up to
  ``max_retries`` times with exponential backoff (through the injected
  clock, so tests never sleep).
* **Batch splitting** -- allocator pressure halves the batch and
  retries the halves independently before giving up on the kernel.
* **Graceful degradation** -- a batch that keeps failing reroutes to
  the numpy-only host planner (``execute_plan_host`` / the pruned host
  top-k sweep), which is bit-identical to the kernel path by
  construction; the ticket's telemetry flags ``degraded``.
* **Zero lost tickets** -- every admitted ticket resolves with a value
  or a structured error; no exception escapes ``step``.

Failure handling is scripted/testable through ``serve.faults``.  See
docs/ARCHITECTURE.md ("Serving the index") for the ticket lifecycle and
the failure-handling state diagram.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.core import aggregate
from repro.kernels.ref import METRICS
from repro.serve.faults import (AllocPressure, DispatchFault,
                                FaultInjector, SystemClock)
from repro.serve.telemetry import QueryTelemetry, ServerStats

__all__ = ["Query", "Ticket", "TicketResult", "QueryServer",
           "OK", "OVERLOADED", "INVALID", "DEADLINE", "ERROR"]

BOOLEAN_KINDS = ("and", "or", "xor", "andnot", "threshold")

# ticket terminal statuses
OK = "ok"                 # value holds the query result
OVERLOADED = "overloaded"  # shed at admission: queue full
INVALID = "invalid"       # rejected at admission: malformed query
DEADLINE = "deadline"     # missed its deadline (admission or dispatch)
ERROR = "error"           # unexpected failure after all recovery paths

# nominal admission-queue byte charge for a similarity ticket: one query
# block row -- the real cost is the shared resident slab, already paid
_SIM_BYTES = 8192


@dataclasses.dataclass(frozen=True)
class Query:
    """One query: a boolean aggregate over terms or a similarity top-k.

    ``kind`` is "and" | "or" | "xor" | "andnot" | "threshold" |
    "similar".  For "andnot" the first term is the minuend; "threshold"
    uses ``t``/``weights`` (see ``threshold_many``); "similar" queries
    ``terms[0]`` with ``k``/``metric``."""
    kind: str
    terms: tuple
    t: int = 0
    weights: tuple | None = None
    k: int = 10
    metric: str = "jaccard"

    @classmethod
    def and_(cls, *terms): return cls("and", terms)

    @classmethod
    def or_(cls, *terms): return cls("or", terms)

    @classmethod
    def xor_(cls, *terms): return cls("xor", terms)

    @classmethod
    def andnot(cls, keep, *drops): return cls("andnot", (keep, *drops))

    @classmethod
    def threshold(cls, terms, t, weights=None):
        return cls("threshold", tuple(terms), t,
                   None if weights is None else tuple(weights))

    @classmethod
    def similar(cls, term, k=10, metric="jaccard"):
        return cls("similar", (term,), k=k, metric=metric)


@dataclasses.dataclass
class TicketResult:
    """Terminal outcome: ``status`` is one of the module constants;
    ``value`` is the query result when status is OK (a RoaringBitmap,
    or ``[(term, score)]`` for similarity); ``error`` a diagnostic."""
    status: str
    value: object = None
    error: str = ""

    @property
    def ok(self) -> bool:
        return self.status == OK


class Ticket:
    """Handle returned by ``submit``: resolves exactly once, to a
    ``TicketResult``, with per-query ``QueryTelemetry`` attached."""

    __slots__ = ("id", "query", "deadline", "telemetry", "result",
                 "_plan", "_value", "_error")

    def __init__(self, tid: int, query: Query, deadline: float | None,
                 submitted_at: float):
        self.id = tid
        self.query = query
        self.deadline = deadline                  # absolute clock time
        self.telemetry = QueryTelemetry(submitted_at=submitted_at)
        self.result: TicketResult | None = None
        self._plan = None                         # WidePlan (boolean)
        self._value = None
        self._error: str | None = None

    @property
    def done(self) -> bool:
        return self.result is not None


class QueryServer:
    """Continuous batcher over an ``InvertedIndex``.

    Synchronous and single-threaded by design: ``submit`` enqueues (or
    sheds) and ``step`` runs one engine tick -- form a batch, coalesce,
    dispatch, resolve.  Tests drive ticks directly with a fake clock;
    a production loop is ``while True: server.step()``.

    Parameters: ``backend`` forwards to the kernel wrappers ("pallas" /
    "ref" / None); ``max_queue`` bounds admission; ``max_batch`` /
    ``max_batch_bytes`` bound one tick's coalesced slab; ``max_retries``
    kernel re-attempts before host degradation; ``backoff_s`` base of
    the exponential retry backoff; ``clock`` an object with ``now()`` /
    ``sleep(s)`` (``FakeClock`` in tests); ``faults`` a
    ``serve.faults.FaultInjector``; ``arena`` an optional warm
    ``core.arena.BitmapArena`` (defaults to the index's own, when it has
    one) -- postings stay device-resident across ticks and the
    ``slab_mismatch`` recovery rung revalidates generations (repatching
    only edited rows) instead of dropping the cached slab
    (docs/ARCHITECTURE.md section 6, docs/MEMORY.md); ``mesh`` a 1-D
    ``("wide",)`` mesh -- similarity tickets then coalesce against the
    SHARDED engine (per-shard arena slabs, k-list all-gather, device
    merge) and coalesced BOOLEAN plans dispatch against the shard-local
    arena slabs too (``aggregate._shard_reduce_arena``: resident rows
    gather from each shard's slab inside one jit, partials fold on
    device), with the same recovery ladder: ``slab_mismatch``
    revalidates per shard through the arena (only shards owning dirty
    rows repatch), and the terminal host fallback stays the unsharded,
    jax-free host sweep."""

    def __init__(self, index, *, backend: str | None = None,
                 max_queue: int = 4096, max_batch: int = 1024,
                 max_batch_bytes: int = 256 << 20, max_retries: int = 2,
                 backoff_s: float = 0.005, clock=None, faults=None,
                 arena=None, mesh=None):
        self.index = index
        self.backend = backend
        self.mesh = mesh
        self.arena = arena if arena is not None \
            else getattr(index, "arena", None)
        self.max_queue = int(max_queue)
        self.max_batch = int(max_batch)
        self.max_batch_bytes = int(max_batch_bytes)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self._clock = clock if clock is not None else SystemClock()
        self._faults = faults if faults is not None else FaultInjector()
        self._queue: deque[Ticket] = deque()
        self._stats = ServerStats()
        self._next_id = 0

    # -- admission -------------------------------------------------------

    def submit(self, query: Query, deadline_s: float | None = None
               ) -> Ticket:
        """Admit one query; never raises for query content.

        Returns a ticket that is either queued (``done`` False) or
        already resolved with a structured rejection: ``INVALID`` for
        malformed queries (validated by the planner here, at admission),
        ``DEADLINE`` for an already-expired deadline, ``OVERLOADED``
        when the queue is full (load shedding)."""
        now = self._clock.now()
        t = Ticket(self._next_id, query,
                   None if deadline_s is None else now + deadline_s, now)
        self._next_id += 1
        self._stats.submitted += 1
        try:
            self._admit_plan(t)
        except (ValueError, IndexError, TypeError) as e:
            self._resolve(t, INVALID, error=str(e))
            return t
        if t.deadline is not None and now > t.deadline:
            self._resolve(t, DEADLINE,
                          error="deadline expired at admission")
            return t
        if len(self._queue) >= self.max_queue:
            self._resolve(t, OVERLOADED,
                          error=f"queue full ({self.max_queue})")
            return t
        self._queue.append(t)
        return t

    def _admit_plan(self, t: Ticket) -> None:
        """Validate + plan at admission (planner errors surface here,
        never inside a coalesced batch)."""
        q = t.query
        if q.kind in BOOLEAN_KINDS:
            bms = [self.index._get(x) for x in q.terms]
            if self.arena is not None:
                for bm in bms:
                    if bm.containers:
                        self.arena.adopt(bm)
            t._plan = aggregate.plan_wide(
                q.kind, bms, q.t, q.weights, backend=self.backend,
                arena=self.arena)
        elif q.kind == "similar":
            if q.metric not in METRICS:
                raise ValueError(f"unknown metric {q.metric!r}")
            if len(q.terms) != 1:
                raise ValueError("similar takes exactly one term")
        else:
            raise ValueError(f"unknown query kind {q.kind!r}")

    @property
    def pending(self) -> int:
        return len(self._queue)

    def stats(self) -> ServerStats:
        return dataclasses.replace(self._stats)

    # -- the engine tick -------------------------------------------------

    def step(self) -> int:
        """One tick: form a batch (max-batch / max-bytes policy),
        enforce deadlines at the dispatch boundary, coalesce into one
        dispatch per op class, resolve every ticket taken.  Returns the
        number of tickets resolved.  Never raises: unexpected failures
        resolve their tickets with status ``ERROR``."""
        self._stats.ticks += 1
        if not self._queue:
            return 0
        batch: list[Ticket] = []
        nbytes = 0
        while self._queue and len(batch) < self.max_batch:
            t = self._queue[0]
            b = (t._plan.slab_bytes() if t._plan is not None
                 else _SIM_BYTES)
            if batch and nbytes + b > self.max_batch_bytes:
                break
            self._queue.popleft()
            batch.append(t)
            nbytes += b
        now = self._clock.now()
        live: list[Ticket] = []
        for t in batch:
            if t.deadline is not None and now > t.deadline:
                self._resolve(t, DEADLINE,
                              error="deadline expired in queue")
            else:
                live.append(t)
        if not live:
            return len(batch)
        self._stats.batches += 1
        self._stats.max_batch = max(self._stats.max_batch, len(live))
        for t in live:
            t.telemetry.dispatched_at = now
            t.telemetry.batch_size = len(live)
        if self._faults.fire("slab_mismatch"):
            self._replan(live)
        self._execute(live)
        for t in live:
            if t._error is not None:
                self._resolve(t, ERROR, error=t._error)
            elif t.deadline is not None and \
                    self._clock.now() > t.deadline:
                self._resolve(t, DEADLINE,
                              error="deadline overrun at dispatch")
            else:
                self._resolve(t, OK, value=t._value)
        return len(batch)

    def run_until_idle(self, max_ticks: int = 1_000_000) -> int:
        """Tick until the queue drains; returns tickets resolved."""
        n = 0
        for _ in range(max_ticks):
            if not self._queue:
                break
            n += self.step()
        return n

    # -- dispatch, retry, degrade ---------------------------------------

    def _replan(self, tickets: list[Ticket]) -> None:
        """Slab-generation mismatch: re-plan every boolean ticket from
        the live postings, then carry on -- a mismatch is a re-plan,
        never a failure.

        With a warm arena this rung is INCREMENTAL: registered bitmaps
        revalidate their generation counters and only rows whose
        containers actually changed repatch (one scatter), and the
        similarity engine refreshes in place through the same arena view
        (``_sim_engine``) -- the cached slab is never dropped.  Without
        an arena it falls back to dropping the similarity slab cache
        wholesale."""
        self._stats.replans += 1
        if self.arena is not None:
            self._stats.rows_repatched += self.arena.revalidate()
        else:
            self.index._sim = None
        for t in tickets:
            t.telemetry.replans += 1
            if t.query.kind in BOOLEAN_KINDS:
                self._admit_plan(t)

    def _kernel_batch(self, tickets: list[Ticket]) -> None:
        """One coalesced kernel attempt for the whole batch; raises on
        (injected or real) dispatch failure.  Fault consultation order:
        allocator pressure (before any work), hang (stalls the clock),
        then the dispatch itself."""
        if self._faults.fire("alloc_pressure"):
            raise AllocPressure(f"batch of {len(tickets)} refused")
        hang = self._faults.fire("dispatch_hang")
        if hang:
            self._clock.sleep(float(hang))
        if self._faults.fire("dispatch_raise"):
            raise DispatchFault("injected dispatch failure")
        booleans = [t for t in tickets if t.query.kind in BOOLEAN_KINDS]
        sims = [t for t in tickets if t.query.kind == "similar"]
        if booleans:
            # with a multi-device mesh + arena, coalesced boolean plans
            # dispatch against the shard-local arena slabs
            # (aggregate._shard_reduce_arena); the recovery ladder is
            # unchanged -- slab_mismatch revalidates through the arena,
            # which repatches only the shards owning dirty rows, and the
            # terminal host fallback (_host_batch) stays jax-free
            out = aggregate.execute_plans([t._plan for t in booleans],
                                          backend=self.backend,
                                          mesh=self.mesh)
            for t, bm in zip(booleans, out):
                t._value = bm
        if sims:
            terms, eng = self.index._sim_engine(mesh=self.mesh)
            by_class: dict[tuple, list[Ticket]] = {}
            for t in sims:
                by_class.setdefault((t.query.k, t.query.metric),
                                    []).append(t)
            for (k, metric), group in by_class.items():
                queries = [self._sim_query(t, terms) for t in group]
                res = eng.topk_batch(queries, k, metric,
                                     backend=self.backend)
                for t, (idx, score, _) in zip(group, res):
                    t._value = [(terms[i], float(s))
                                for i, s in zip(idx.tolist(),
                                                score.tolist())]

    def _sim_query(self, t: Ticket, terms: list):
        term = t.query.terms[0]
        if term in self.index.postings:
            return terms.index(term)
        return self.index._get(term)              # unknown: empty query

    def _execute(self, tickets: list[Ticket]) -> None:
        """Dispatch ``tickets`` with the full recovery ladder: retry
        with backoff on transient failure, split on allocator pressure,
        degrade to the host planner when the kernel keeps failing.
        Postcondition: every ticket has ``_value`` or ``_error`` set."""
        attempt = 0
        while True:
            try:
                self._kernel_batch(tickets)
                return
            except AllocPressure:
                self._stats.batch_splits += 1
                for t in tickets:
                    t.telemetry.splits += 1
                if len(tickets) > 1:
                    mid = len(tickets) // 2
                    self._execute(tickets[:mid])
                    self._execute(tickets[mid:])
                    return
                break                             # 1 ticket: degrade
            except Exception:                     # noqa: BLE001
                attempt += 1
                if attempt > self.max_retries:
                    break                         # degrade
                self._stats.dispatch_retries += 1
                for t in tickets:
                    t.telemetry.retries += 1
                self._clock.sleep(self.backoff_s * 2 ** (attempt - 1))
        self._host_batch(tickets)

    def _host_batch(self, tickets: list[Ticket]) -> None:
        """Graceful degradation: resolve each ticket on the numpy-only
        host planner (bit-identical to the kernel path by construction;
        see ``execute_plan_host``).  Per-ticket isolation: one bad query
        cannot take down its batchmates."""
        self._stats.host_fallbacks += 1
        sim_ctx = None
        for t in tickets:
            t.telemetry.degraded = True
            try:
                if t.query.kind in BOOLEAN_KINDS:
                    t._value = aggregate.execute_plan_host(t._plan)
                else:
                    if sim_ctx is None:
                        sim_ctx = self.index._sim_engine()
                    terms, eng = sim_ctx
                    idx, score, _ = eng.topk(
                        self._sim_query(t, terms), t.query.k,
                        t.query.metric, backend="host")
                    t._value = [(terms[i], float(s))
                                for i, s in zip(idx.tolist(),
                                                score.tolist())]
            except Exception as e:                # noqa: BLE001
                t._error = f"{type(e).__name__}: {e}"

    # -- resolution ------------------------------------------------------

    def _resolve(self, t: Ticket, status: str, value=None,
                 error: str = "") -> None:
        t.telemetry.resolved_at = self._clock.now()
        t.result = TicketResult(status, value, error)
        s = self._stats
        if status == OK:
            s.resolved_ok += 1
        elif status == OVERLOADED:
            s.rejected_overloaded += 1
        elif status == INVALID:
            s.rejected_invalid += 1
        elif status == DEADLINE:
            s.deadline_expired += 1
        else:
            s.resolved_error += 1
