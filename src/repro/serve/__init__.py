"""repro.serve -- serving layers over the Roaring engine.

``query_server`` is the fault-tolerant continuous batcher (coalesced
multi-query dispatch, admission control, deadlines, kernel->host
degradation); ``faults`` its deterministic fault-injection harness;
``telemetry`` the per-ticket/server observability records plus the MoE
routing telemetry.
"""

from repro.serve.faults import (AllocPressure, DispatchFault, FakeClock,
                                FaultError, FaultInjector, SlabMismatch,
                                SystemClock)
from repro.serve.query_server import (DEADLINE, ERROR, INVALID, OK,
                                      OVERLOADED, Query, QueryServer,
                                      Ticket, TicketResult)
from repro.serve.telemetry import QueryTelemetry, ServerStats

__all__ = [
    "Query", "QueryServer", "Ticket", "TicketResult",
    "OK", "OVERLOADED", "INVALID", "DEADLINE", "ERROR",
    "FaultError", "DispatchFault", "SlabMismatch", "AllocPressure",
    "FaultInjector", "FakeClock", "SystemClock",
    "QueryTelemetry", "ServerStats",
]
