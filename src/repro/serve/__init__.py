"""repro.serve"""
