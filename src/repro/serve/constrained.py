"""Constrained decoding with Roaring vocabulary masks.

An allowed-token set over a 152 k vocabulary is 3 Roaring chunks; grammar /
lexicon state transitions are set algebra (union of continuations,
intersection with hard filters, difference for banned strings) -- all on the
paper's operations, including the count-only variants for quick feasibility
checks.  At sampling time the active set renders to a dense additive mask.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import RoaringBitmap, to_dense


class VocabConstraint:
    def __init__(self, vocab: int, allowed: RoaringBitmap | None = None):
        self.vocab = vocab
        self.allowed = allowed if allowed is not None \
            else RoaringBitmap.from_range(0, vocab)

    # set algebra over constraints -----------------------------------
    def intersect(self, other: "VocabConstraint") -> "VocabConstraint":
        return VocabConstraint(self.vocab, self.allowed & other.allowed)

    def union(self, other: "VocabConstraint") -> "VocabConstraint":
        return VocabConstraint(self.vocab, self.allowed | other.allowed)

    def ban(self, token_ids) -> "VocabConstraint":
        return VocabConstraint(
            self.vocab,
            self.allowed - RoaringBitmap.from_values(
                np.asarray(token_ids, np.uint32)))

    def feasible(self) -> bool:
        return self.allowed.cardinality > 0   # fast count, never materialize

    def n_allowed(self) -> int:
        return self.allowed.cardinality

    # rendering --------------------------------------------------------
    def dense_mask(self) -> np.ndarray:
        """(V,) float32 additive mask: 0 for allowed, -inf for banned."""
        dense = to_dense(self.allowed, self.vocab)
        return np.where(dense, 0.0, -np.inf).astype(np.float32)

    def apply(self, logits):
        return logits + jnp.asarray(self.dense_mask())


def lexicon_constraint(vocab: int, lexicons: dict[str, np.ndarray],
                       active: list[str]) -> VocabConstraint:
    """Union of the active lexicons' token sets."""
    bms = [RoaringBitmap.from_values(lexicons[name].astype(np.uint32))
           for name in active]
    return VocabConstraint(vocab, RoaringBitmap.or_many(bms)) if bms \
        else VocabConstraint(vocab)
