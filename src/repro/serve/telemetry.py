"""Serving telemetry: per-ticket query timings for the continuous query
server, plus MoE routing telemetry on Roaring sets (paper section 5.9
fast counts).

Query-server side: every resolved ticket carries a ``QueryTelemetry``
(queue time, dispatch latency, retries, degradation flags) and the
server aggregates a running ``ServerStats`` -- the observability
contract the fault-injection tests assert against.

MoE side: per training/serving step, each expert's routed-token-id set
is a Roaring bitmap; load balance, expert overlap (Jaccard), and drift
between steps (symmetric difference) are the paper's count-only
operations -- computed without materializing intermediate sets.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import RoaringBitmap


@dataclasses.dataclass
class QueryTelemetry:
    """Per-ticket timing and failure-handling record, attached to every
    resolved ticket (including structured rejections)."""
    submitted_at: float = 0.0
    dispatched_at: float | None = None      # None: never reached dispatch
    resolved_at: float = 0.0
    batch_size: int = 0                     # tickets in the ticket's batch
    retries: int = 0                        # failed kernel attempts
    splits: int = 0                         # alloc-pressure batch splits
    replans: int = 0                        # slab-mismatch re-plans
    degraded: bool = False                  # resolved on the host path

    @property
    def queue_time(self) -> float:
        """Admission -> dispatch (or rejection) wait."""
        end = (self.dispatched_at if self.dispatched_at is not None
               else self.resolved_at)
        return end - self.submitted_at

    @property
    def latency(self) -> float:
        """Admission -> resolution, the caller-visible total."""
        return self.resolved_at - self.submitted_at


@dataclasses.dataclass
class ServerStats:
    """Monotone counters over a server's lifetime (``QueryServer.stats``
    returns a snapshot copy)."""
    submitted: int = 0
    rejected_overloaded: int = 0
    rejected_invalid: int = 0
    resolved_ok: int = 0
    resolved_error: int = 0
    deadline_expired: int = 0
    ticks: int = 0
    batches: int = 0
    dispatch_retries: int = 0
    batch_splits: int = 0
    replans: int = 0
    rows_repatched: int = 0     # arena rows repatched by replan rungs
    host_fallbacks: int = 0
    max_batch: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def routing_sets(expert_idx: np.ndarray, n_experts: int) -> list[RoaringBitmap]:
    """expert_idx: (tokens, top_k) int -> per-expert token-id bitmaps."""
    flat_tok = np.repeat(np.arange(expert_idx.shape[0], dtype=np.uint32),
                         expert_idx.shape[1])
    flat_e = expert_idx.reshape(-1)
    out = []
    for e in range(n_experts):
        out.append(RoaringBitmap.from_values(flat_tok[flat_e == e]))
    return out


def load_balance_stats(sets: list[RoaringBitmap]) -> dict:
    loads = np.array([bm.cardinality for bm in sets], np.float64)
    total = loads.sum()
    frac = loads / max(total, 1)
    e = len(sets)
    return {
        "max_load_fraction": float(frac.max()),
        "cv": float(loads.std() / max(loads.mean(), 1e-9)),
        "entropy_ratio": float(
            -(frac[frac > 0] * np.log(frac[frac > 0])).sum() / np.log(e)),
    }


def expert_overlap_matrix(sets: list[RoaringBitmap]) -> np.ndarray:
    """Pairwise Jaccard between experts' token sets (fast counts)."""
    e = len(sets)
    out = np.zeros((e, e))
    for i in range(e):
        for j in range(i, e):
            out[i, j] = out[j, i] = sets[i].jaccard(sets[j])
    return out


def routing_drift(prev: list[RoaringBitmap],
                  cur: list[RoaringBitmap]) -> np.ndarray:
    """Per-expert symmetric-difference cardinality between steps,
    normalized by union -- 0 = stable routing, 1 = fully churned."""
    out = np.zeros(len(cur))
    for i, (a, b) in enumerate(zip(prev, cur)):
        union = a.or_card(b)
        out[i] = a.xor_card(b) / union if union else 0.0
    return out
