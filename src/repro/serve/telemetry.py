"""MoE routing telemetry on Roaring sets (paper section 5.9 fast counts).

Per training/serving step, each expert's routed-token-id set is a Roaring
bitmap; load balance, expert overlap (Jaccard), and drift between steps
(symmetric difference) are the paper's count-only operations -- computed
without materializing intermediate sets.
"""

from __future__ import annotations

import numpy as np

from repro.core import RoaringBitmap


def routing_sets(expert_idx: np.ndarray, n_experts: int) -> list[RoaringBitmap]:
    """expert_idx: (tokens, top_k) int -> per-expert token-id bitmaps."""
    flat_tok = np.repeat(np.arange(expert_idx.shape[0], dtype=np.uint32),
                         expert_idx.shape[1])
    flat_e = expert_idx.reshape(-1)
    out = []
    for e in range(n_experts):
        out.append(RoaringBitmap.from_values(flat_tok[flat_e == e]))
    return out


def load_balance_stats(sets: list[RoaringBitmap]) -> dict:
    loads = np.array([bm.cardinality for bm in sets], np.float64)
    total = loads.sum()
    frac = loads / max(total, 1)
    e = len(sets)
    return {
        "max_load_fraction": float(frac.max()),
        "cv": float(loads.std() / max(loads.mean(), 1e-9)),
        "entropy_ratio": float(
            -(frac[frac > 0] * np.log(frac[frac > 0])).sum() / np.log(e)),
    }


def expert_overlap_matrix(sets: list[RoaringBitmap]) -> np.ndarray:
    """Pairwise Jaccard between experts' token sets (fast counts)."""
    e = len(sets)
    out = np.zeros((e, e))
    for i in range(e):
        for j in range(i, e):
            out[i, j] = out[j, i] = sets[i].jaccard(sets[j])
    return out


def routing_drift(prev: list[RoaringBitmap],
                  cur: list[RoaringBitmap]) -> np.ndarray:
    """Per-expert symmetric-difference cardinality between steps,
    normalized by union -- 0 = stable routing, 1 = fully churned."""
    out = np.zeros(len(cur))
    for i, (a, b) in enumerate(zip(prev, cur)):
        union = a.or_card(b)
        out[i] = a.xor_card(b) / union if union else 0.0
    return out
