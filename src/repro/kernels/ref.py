"""Pure-jnp oracles for every Pallas kernel in this package.

Device bitset convention: one Roaring bitset container = 2048 x uint32 words;
bit ``i`` of the container lives in ``words[i >> 5]`` at position ``i & 31``.
(The host path uses 1024 x uint64; the uint32 choice matches the TPU VPU's
32-bit lanes -- see DESIGN.md section 3.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORDS = 2048            # uint32 words per 2^16-bit container
CONTAINER_BITS = 1 << 16
ARRAY_CAP = 4096        # fixed capacity of the array-value slab

_M1 = jnp.uint32(0x55555555)
_M2 = jnp.uint32(0x33333333)
_M4 = jnp.uint32(0x0F0F0F0F)
_H01 = jnp.uint32(0x01010101)


def popcount_u32(v: jax.Array) -> jax.Array:
    """SWAR per-lane popcount of uint32 values -> int32."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> jnp.uint32(1)) & _M1)
    v = (v & _M2) + ((v >> jnp.uint32(2)) & _M2)
    v = (v + (v >> jnp.uint32(4))) & _M4
    return ((v * _H01) >> jnp.uint32(24)).astype(jnp.int32)


def popcount_words(words: jax.Array) -> jax.Array:
    """(..., WORDS) uint32 -> (...,) int32 cardinality (section 4.1.1 oracle)."""
    return popcount_u32(words).sum(axis=-1).astype(jnp.int32)


def bitset_op(a: jax.Array, b: jax.Array, op: str) -> tuple[jax.Array, jax.Array]:
    """(..., WORDS) x2 -> (result words, cardinality).  Section 4.1.2 oracle."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    if op == "and":
        r = a & b
    elif op == "or":
        r = a | b
    elif op == "xor":
        r = a ^ b
    elif op == "andnot":
        r = a & ~b
    else:
        raise ValueError(op)
    return r, popcount_words(r)


def bitset_op_card(a: jax.Array, b: jax.Array, op: str) -> jax.Array:
    """Count-only variant (paper section 5.9): never materializes ``r``
    outside registers."""
    return bitset_op(a, b, op)[1]


PAIR_OPS = ("and", "or", "xor", "andnot")   # index == per-row op id


def bitset_pair_op(a: jax.Array, b: jax.Array,
                   opids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mixed-op batched bitset algebra (section 4.1.2 generalized): one
    dispatch applies a *different* logical op per row.

    a/b: (M, WORDS) uint32; opids: (M,) int32 indexing ``PAIR_OPS``
    (0 and, 1 or, 2 xor, 3 andnot).  Returns (words, cards)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    sel = opids.astype(jnp.int32)[:, None]
    r = jnp.where(sel == 0, a & b,
                  jnp.where(sel == 1, a | b,
                            jnp.where(sel == 2, a ^ b, a & ~b)))
    return r, popcount_words(r)


def bitset_pair_card(a: jax.Array, b: jax.Array,
                     opids: jax.Array) -> jax.Array:
    """Count-only mixed-op batch (the similarity-join hot path: never
    materializes the result words in HBM)."""
    return bitset_pair_op(a, b, opids)[1]


def array_to_bitset(values: jax.Array, card: jax.Array) -> jax.Array:
    """Sorted uint16-valued (N, ARRAY_CAP) int32 arrays (first ``card`` entries
    valid) -> (N, WORDS) uint32 bitsets.  Oracle for the section 3.2 analogue.

    Uses the disjoint-contribution sum trick: values are distinct, so each
    (word, bit) pair is hit at most once and OR == +.
    """
    n = values.shape[0]
    valid = (jnp.arange(ARRAY_CAP)[None, :] < card[:, None])
    word_idx = jnp.where(valid, values >> 5, WORDS)  # out-of-range drops
    bit = jnp.where(valid, jnp.uint32(1) << (values & 31).astype(jnp.uint32),
                    jnp.uint32(0))

    def one(widx, b):
        return jnp.zeros(WORDS, jnp.uint32).at[widx].add(b, mode="drop")

    return jax.vmap(one)(word_idx, bit)


def bitset_set_many(words: jax.Array, values: jax.Array,
                    card: jax.Array) -> tuple[jax.Array, jax.Array]:
    """OR an array container into an existing bitset, tracking the cardinality
    delta via the paper's XOR trick (section 3.2).  Returns (words, delta)."""
    add = array_to_bitset(values, card)
    new = words | add
    delta = popcount_words(words ^ new)
    return new, delta


def bitset_to_array(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(N, WORDS) uint32 -> ((N, ARRAY_CAP) int32 sorted values, (N,) card).

    Oracle for the section 3.1 extraction.  Positions beyond the cardinality
    are padded with CONTAINER_BITS (an impossible value).  Only meaningful
    when card <= ARRAY_CAP (the Roaring array-container invariant); extra
    values are dropped, matching the fixed-capacity device layout.
    """
    n = words.shape[0]
    bit_pos = jnp.arange(CONTAINER_BITS)
    bits = ((words[:, bit_pos >> 5] >> (bit_pos & 31).astype(jnp.uint32))
            & jnp.uint32(1)).astype(jnp.int32)
    csum = jnp.cumsum(bits, axis=-1)
    card = csum[:, -1]
    # value k of the output = first position whose running count is k+1
    targets = jnp.arange(1, ARRAY_CAP + 1)

    def one(cs):
        return jnp.searchsorted(cs, targets, side="left").astype(jnp.int32)

    vals = jax.vmap(one)(csum)
    vals = jnp.where(targets[None, :] <= card[:, None], vals,
                     jnp.int32(CONTAINER_BITS))
    return vals, card.astype(jnp.int32)


def array_intersect_mask(a_vals: jax.Array, a_card: jax.Array,
                         b_vals: jax.Array, b_card: jax.Array) -> tuple[jax.Array, jax.Array]:
    """All-vs-all membership (the pcmpistrm analogue, section 4.2 oracle).

    Inputs: (N, ARRAY_CAP) int32 sorted values + (N,) cards.
    Returns (mask (N, ARRAY_CAP) bool over A's slots, counts (N,) int32).
    """
    va = (jnp.arange(ARRAY_CAP)[None, :] < a_card[:, None])
    vb = (jnp.arange(ARRAY_CAP)[None, :] < b_card[:, None])
    eq = (a_vals[:, :, None] == b_vals[:, None, :]) & vb[:, None, :]
    mask = eq.any(axis=-1) & va
    return mask, mask.sum(axis=-1).astype(jnp.int32)


def array_intersect_count(a_vals: jax.Array, a_card: jax.Array,
                          b_vals: jax.Array, b_card: jax.Array) -> jax.Array:
    """Memory-lean count-only intersection oracle: a vectorized binary
    search per A value (O(M * ARRAY_CAP) memory) instead of the
    ``array_intersect_mask`` all-vs-all cube (O(M * ARRAY_CAP^2)) --
    the count path must scale to planner-sized batches."""
    pad = jnp.int32(CONTAINER_BITS)
    pos = jnp.arange(ARRAY_CAP)[None, :]
    va = pos < a_card[:, None]
    b_sorted = jnp.where(pos < b_card[:, None], b_vals, pad)

    def one(b_row, a_row):
        return jnp.searchsorted(b_row, a_row).astype(jnp.int32)

    idx = jnp.minimum(jax.vmap(one)(b_sorted, a_vals), ARRAY_CAP - 1)
    hit = (jnp.take_along_axis(b_sorted, idx, axis=1) == a_vals) & va
    return hit.sum(axis=-1).astype(jnp.int32)


def array_pair_masks(a_vals: jax.Array, a_card: jax.Array,
                     b_vals: jax.Array, b_card: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Two-sided all-vs-all membership (sections 4.2-4.5 oracle).

    Like ``array_intersect_mask`` but also emits the B-side mask, so one
    dispatch feeds every materializing array-array op: AND keeps A's hits,
    ANDNOT drops them, OR appends B's misses, XOR keeps both sides' misses.
    Returns (mask_a (M, ARRAY_CAP), mask_b (M, ARRAY_CAP), count (M,))."""
    va = (jnp.arange(ARRAY_CAP)[None, :] < a_card[:, None])
    vb = (jnp.arange(ARRAY_CAP)[None, :] < b_card[:, None])
    eq = ((a_vals[:, :, None] == b_vals[:, None, :])
          & va[:, :, None] & vb[:, None, :])
    mask_a = eq.any(axis=-1)
    mask_b = eq.any(axis=1)
    return (mask_a.astype(jnp.int32), mask_b.astype(jnp.int32),
            mask_a.sum(axis=-1).astype(jnp.int32))


def array_bitset_probe(vals: jax.Array, card: jax.Array,
                       words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Vectorized probe of sorted array values against a bitset row (the
    asymmetric intersection of section 4.2: binary search degenerates to a
    direct word fetch + bit test in the bitset domain).

    vals: (M, ARRAY_CAP) int32 sorted uint16-valued (slots >= card ignored);
    card: (M,) int32; words: (M, WORDS) uint32.  Returns
    (mask (M, ARRAY_CAP) int32 over the array's slots, count (M,))."""
    valid = (jnp.arange(ARRAY_CAP)[None, :] < card[:, None])
    widx = jnp.clip(vals >> 5, 0, WORDS - 1)
    w = jnp.take_along_axis(words.astype(jnp.uint32), widx, axis=1)
    bit = (w >> (vals & 31).astype(jnp.uint32)) & jnp.uint32(1)
    mask = jnp.where(valid, bit.astype(jnp.int32), 0)
    return mask, mask.sum(axis=-1).astype(jnp.int32)


METRICS = ("jaccard", "cosine", "containment")   # index == metric id


def similarity_scores(inter: jax.Array, q_card: jax.Array,
                      cards: jax.Array, metric: str) -> jax.Array:
    """Similarity scores from intersection cardinalities, float32.

    All three metrics derive from the AND cardinality by inclusion-
    exclusion ("beyond unions and intersections", Kaser & Lemire):
    jaccard = |A∩B| / |A∪B|, cosine = |A∩B| / sqrt(|A||B|),
    containment = |A∩B| / |A| (the query side).  A zero denominator
    scores 1.0 (the host convention).  The formula is evaluated in
    float32 with a fixed operation order so the device kernel, the jnp
    oracle, and the numpy host twin (core.pairwise._scores_host) produce
    bit-identical scores -- top-k tie ordering depends on it."""
    interf = inter.astype(jnp.float32)
    qc = q_card.astype(jnp.float32)
    oc = cards.astype(jnp.float32)
    if metric == "jaccard":
        denom = qc + oc - interf
    elif metric == "cosine":
        denom = jnp.sqrt(qc * oc)
    elif metric == "containment":
        denom = jnp.broadcast_to(qc, oc.shape)
    else:
        raise ValueError(metric)
    return jnp.where(denom > 0, interf / denom, jnp.float32(1.0))


def topk_select(score: jax.Array, inter: jax.Array,
                k: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Iterative first-max top-k selection (the threshold-refinement
    pass): k rounds of argmax, ties resolved to the LOWEST index --
    exactly the order of a stable host argsort on the negated scores.
    Returns (idx (k,) int32, score (k,) float32, inter (k,) int32)."""
    idxs, scores, inters = [], [], []
    for _ in range(k):
        j = jnp.argmax(score)                   # first occurrence wins
        idxs.append(j.astype(jnp.int32))
        scores.append(score[j])
        inters.append(inter[j].astype(jnp.int32))
        score = score.at[j].set(jnp.float32(-2.0))
    return jnp.stack(idxs), jnp.stack(scores), jnp.stack(inters)


def similarity_topk(rows: jax.Array, row_col: jax.Array, starts: jax.Array,
                    q_words: jax.Array, q_card: jax.Array, cards: jax.Array,
                    exclude: jax.Array, *, metric: str, k: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused similarity scoring + top-k selection oracle (one jit).

    rows:    (N, WORDS) uint32 candidate container rows, candidate-major
             (rows of candidate t occupy starts[t]:starts[t+1]).
    row_col: (N,) int32 column of each row's chunk key in ``q_words``.
    starts:  (T + 1,) int32 per-candidate row offsets.
    q_words: (C, WORDS) uint32 query containers in bitset domain, one row
             per global chunk key (zeros where the query has no container).
    q_card / cards: query / per-candidate (T,) cardinalities, int32.
    exclude: runtime int32 candidate index whose score is forced to -1
             (the query itself in an index join); -1 excludes nothing.

    Returns (idx (k,) int32, score (k,) float32, inter (k,) int32),
    best-first, ties at equal score resolved to the lowest index."""
    rows = rows.astype(jnp.uint32)
    t = starts.shape[0] - 1
    per_row = popcount_words(rows & q_words[row_col])
    # per-segment sum, NOT a global prefix: the grand total of
    # intersection bits across all candidates can overflow int32 even
    # though each candidate's own count cannot
    seg_id = jnp.searchsorted(starts[1:], jnp.arange(per_row.shape[0]),
                              side="right")
    inter = jax.ops.segment_sum(per_row, seg_id, num_segments=t) \
        .astype(jnp.int32)
    score = similarity_scores(inter, q_card, cards, metric)
    score = jnp.where(jnp.arange(t) == exclude, jnp.float32(-1.0), score)
    return topk_select(score, inter, k)


def topk_select_ids(score: jax.Array, inter: jax.Array, gidx: jax.Array,
                    k: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k selection over *labelled* scores: k rounds of (max score,
    LOWEST global id among the maxes), the selected id's entries masked
    to -2.0.

    This is the shard-merge tie rule pinned by the sharded similarity
    path: every entry carries its GLOBAL candidate index ``gidx``, and a
    tie group cut at the k boundary resolves to ascending global index --
    even when the tied entries arrived from different shards.  Applied
    per shard (over local candidates labelled with global ids) and again
    over the all-gathered S*k lists, it reproduces the single-device
    ``topk_select`` order exactly, because both implement the same total
    order (score descending, global index ascending).

    Returns (gidx (k,) int32, score (k,) float32, inter (k,) int32).
    ``gidx`` values may repeat only for padding entries (score < -1);
    duplicates of one id are masked together in a single round."""
    big = jnp.int32(2**31 - 1)
    ids, scores, inters = [], [], []
    for _ in range(k):
        m = jnp.max(score)
        g = jnp.min(jnp.where(score == m, gidx, big))
        hit = (gidx == g) & (score == m)
        ids.append(g.astype(jnp.int32))
        scores.append(m)
        inters.append(jnp.max(jnp.where(hit, inter, 0)).astype(jnp.int32))
        score = jnp.where(hit, jnp.float32(-2.0), score)
    return jnp.stack(ids), jnp.stack(scores), jnp.stack(inters)


def similarity_topk_ids(rows: jax.Array, row_col: jax.Array,
                        starts: jax.Array, q_words: jax.Array,
                        q_card: jax.Array, cards: jax.Array,
                        gidx: jax.Array, n_valid: jax.Array,
                        exclude: jax.Array, *, metric: str, k: int
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard fused score + select: :func:`similarity_topk` over a
    candidate SUBSET labelled with global ids (one shard of a sharded
    similarity dispatch, or any pruned candidate list).

    Differences from the dense oracle: ``gidx`` (T,) int32 carries each
    local slot's GLOBAL candidate index (selection and exclusion key on
    it); ``n_valid`` is a runtime scalar -- slots >= n_valid are layout
    padding and score -2.0 no matter what their padded rows/cards say
    (an all-zero pad row under the cosine/zero-denominator convention
    would otherwise score 1.0 and corrupt the local top-k); ``exclude``
    is a GLOBAL candidate id (scored -1.0 on its owning shard; -1 none).

    Returns (gidx (k,) int32, score (k,) float32, inter (k,) int32),
    best-first, score ties to the lowest GLOBAL index
    (:func:`topk_select_ids`)."""
    rows = rows.astype(jnp.uint32)
    t = starts.shape[0] - 1
    per_row = popcount_words(rows & q_words[row_col])
    seg_id = jnp.searchsorted(starts[1:], jnp.arange(per_row.shape[0]),
                              side="right")
    inter = jax.ops.segment_sum(per_row, seg_id, num_segments=t) \
        .astype(jnp.int32)
    score = similarity_scores(inter, q_card, cards, metric)
    score = jnp.where(gidx == exclude, jnp.float32(-1.0), score)
    score = jnp.where(jnp.arange(t) >= n_valid, jnp.float32(-2.0), score)
    return topk_select_ids(score, inter, gidx, k)


def merge_sorted(a_vals: jax.Array, a_card: jax.Array,
                 b_vals: jax.Array, b_card: jax.Array,
                 cap: int = 2 * ARRAY_CAP) -> tuple[jax.Array, jax.Array]:
    """Branch-free merge of two padded sorted arrays (section 4.3 oracle for
    the sorting-network merger): returns (merged (N, cap) int32 with PAD at
    the tail, total count).  PAD = CONTAINER_BITS."""
    pad = jnp.int32(CONTAINER_BITS)
    a = jnp.where(jnp.arange(a_vals.shape[1])[None] < a_card[:, None],
                  a_vals, pad)
    b = jnp.where(jnp.arange(b_vals.shape[1])[None] < b_card[:, None],
                  b_vals, pad)
    merged = jnp.sort(jnp.concatenate([a, b], axis=-1), axis=-1)[:, :cap]
    return merged, (a_card + b_card).astype(jnp.int32)


def dedup_sorted(merged: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Union-style dedup (section 4.3 store_unique oracle): keep one copy of
    each duplicated value; stable-compacts to the left, PAD at the tail."""
    pad = jnp.int32(CONTAINER_BITS)
    prev = jnp.concatenate(
        [jnp.full((merged.shape[0], 1), -1, merged.dtype), merged[:, :-1]],
        axis=-1)
    keep = (merged != prev) & (merged < pad)
    return _compact(merged, keep)


def xor_dedup_sorted(merged: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric-difference dedup (section 4.5 oracle): drop values that occur
    twice entirely (inputs are sets, so multiplicity is 1 or 2)."""
    pad = jnp.int32(CONTAINER_BITS)
    prev = jnp.concatenate(
        [jnp.full((merged.shape[0], 1), -1, merged.dtype), merged[:, :-1]],
        axis=-1)
    nxt = jnp.concatenate(
        [merged[:, 1:], jnp.full((merged.shape[0], 1), -2, merged.dtype)],
        axis=-1)
    keep = (merged != prev) & (merged != nxt) & (merged < pad)
    return _compact(merged, keep)


def _compact(vals: jax.Array, keep: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stable left-compaction of kept values; the TPU-idiomatic stream
    compaction is a prefix sum + scatter."""
    pad = jnp.int32(CONTAINER_BITS)
    rank = jnp.cumsum(keep.astype(jnp.int32), axis=-1) - 1
    count = jnp.where(keep.any(-1), rank[:, -1] + 1, 0).astype(jnp.int32)
    dst = jnp.where(keep, rank, vals.shape[1])  # dropped -> OOB

    def one(v, d):
        return jnp.full(vals.shape[1], pad, vals.dtype).at[d].set(
            v, mode="drop")

    return jax.vmap(one)(vals, dst), count


# ---------------------------------------------------------------------------
# segmented wide-aggregation oracle (paper sec 5.8 generalized; see
# kernels/segment_ops.py for the Pallas twin)
# ---------------------------------------------------------------------------

def segment_reduce(slab: jax.Array, starts: jax.Array, op: str, *,
                   jmax: int, threshold: int = 0,
                   weights: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Per-segment OR/AND/XOR/ANDNOT/threshold reduction + cardinality.

    slab: (N, WORDS) uint32 rows grouped segment-major; starts: (S + 1,)
    int32 row offsets; jmax: static max segment length.  Returns
    (words (S, WORDS) uint32, cards (S,) int32).  Empty segments reduce to
    zero words / zero cardinality for every op.

    op "andnot" treats each segment's FIRST row as the minuend and the rest
    as subtrahends: row0 & ~(row1 | row2 | ...).  ``weights`` (N,) int32 are
    per-row occurrence weights for op "threshold" (default 1 per row).
    ``threshold`` is a runtime scalar OR a (S,) int32 vector of per-segment
    thresholds (the multi-query coalescing path: every queued T-occurrence
    query becomes one segment group of the same dispatch).
    """
    slab = slab.astype(jnp.uint32)
    starts = starts.astype(jnp.int32)
    n = slab.shape[0]
    seg_len = starts[1:] - starts[:-1]                    # (S,)
    row = starts[:-1, None] + jnp.arange(jmax, dtype=jnp.int32)[None, :]
    valid = row < starts[1:, None]                        # (S, jmax)
    g = slab[jnp.minimum(row, n - 1)]                     # (S, jmax, WORDS)
    if op == "threshold":
        g = jnp.where(valid[..., None], g, jnp.uint32(0))
        if weights is None:
            w = jnp.ones((g.shape[0], jmax), jnp.int32)
        else:
            w = weights.astype(jnp.int32)[jnp.minimum(row, n - 1)]
        w = jnp.where(valid, w, 0)
        t = jnp.asarray(threshold, jnp.int32)
        if t.ndim == 1:
            t = t[:, None]                                # (S, 1) vs (S, WORDS)
        out = jnp.zeros((g.shape[0], WORDS), jnp.uint32)
        for b in range(32):
            cnt = (((g >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.int32)
                   * w[..., None]).sum(axis=1)
            hit = (cnt >= t).astype(jnp.uint32)
            out = out | (hit << jnp.uint32(b))
    elif op == "andnot":
        g = jnp.where(valid[..., None], g, jnp.uint32(0))
        first = g[:, 0]
        rest = jax.lax.reduce(g[:, 1:], jnp.uint32(0),
                              jax.numpy.bitwise_or, dimensions=(1,))
        out = first & ~rest
    else:
        ident = jnp.uint32(0xFFFFFFFF if op == "and" else 0)
        g = jnp.where(valid[..., None], g, ident)
        if op == "or":
            comb = jax.numpy.bitwise_or
        elif op == "and":
            comb = jax.numpy.bitwise_and
        elif op == "xor":
            comb = jax.numpy.bitwise_xor
        else:
            raise ValueError(op)
        out = jax.lax.reduce(g, ident, comb, dimensions=(1,))
    out = jnp.where((seg_len > 0)[:, None], out, jnp.uint32(0))
    return out, popcount_words(out)


def segment_reduce_rows(table: jax.Array, ids: jax.Array, starts: jax.Array,
                        op: str, *, jmax: int, threshold: int = 0,
                        weights: jax.Array | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Resident-slab twin of :func:`segment_reduce`: gather ``ids`` rows
    from a device-resident ``table`` (arena slab, optionally with a staged
    host block appended), then reduce.  Under jit the gather fuses with
    the reduce, so resident rows never round-trip through the host --
    queries move only ``ids``/``starts`` over PCIe (see core/arena.py).
    ``ids`` index ``table`` segment-major; pad ragged segments with id 0
    (the arena's reserved all-zero row)."""
    slab = jnp.take(table.astype(jnp.uint32), ids.astype(jnp.int32), axis=0)
    return segment_reduce(slab, starts, op, jmax=jmax,
                          threshold=threshold, weights=weights)


def gather_rows_dual(table: jax.Array, staged: jax.Array,
                     pos: jax.Array, sidx: jax.Array) -> jax.Array:
    """Two-source row gather: slot ``i`` reads ``table[pos[i]] |
    staged[sidx[i]]``.  Exactly one side of every slot points at a real
    row; the other points at a reserved all-zero row (``table`` row /
    position 0 is the arena's zero row, ``staged`` row 0 is the block's),
    so the OR is exact slot selection -- zero is the OR identity, never a
    blend.  ``table`` may be a sharded assembled per-shard slab
    (``core.arena.ShardSlabs.assembled``): under jit the take lowers to a
    cross-device gather, so resident rows never touch the host."""
    return (jnp.take(table.astype(jnp.uint32), pos.astype(jnp.int32),
                     axis=0)
            | jnp.take(staged.astype(jnp.uint32), sidx.astype(jnp.int32),
                       axis=0))


def segment_reduce_rows_dual(table: jax.Array, staged: jax.Array,
                             pos: jax.Array, sidx: jax.Array,
                             starts: jax.Array, op: str, *, jmax: int,
                             threshold: int = 0,
                             weights: jax.Array | None = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Row-table twin of :func:`segment_reduce_rows` for the arena's
    dual-source layout: resident rows gather from ``table`` by slab
    position (single-device slab, or the sharded assembled layout --
    global position ``(r % S) * cap_s + r // S``), cold rows from a small
    per-call ``staged`` block, via :func:`gather_rows_dual`.  Unlike
    ``segment_reduce_rows`` with an appended host block, the resident
    table is never copied per call.  Pad slots point both indices at the
    zero rows."""
    slab = gather_rows_dual(table, staged, pos, sidx)
    return segment_reduce(slab, starts, op, jmax=jmax,
                          threshold=threshold, weights=weights)


# ---------------------------------------------------------------------------
# bit-sliced occurrence counters (the exchange payload of the sharded
# threshold path: each shard counts locally, counters are all-gathered and
# added bit-sliced, then one comparator pass emits the result words)
# ---------------------------------------------------------------------------

def segment_counters(slab: jax.Array, starts: jax.Array, *, jmax: int,
                     planes: int,
                     weights: jax.Array | None = None) -> jax.Array:
    """Per-segment bit-sliced occurrence counters.

    Counts, for every one of the 2^16 bit positions, the (weighted) number
    of rows of the segment that set it, and returns the counts bit-sliced:
    ``(S, planes, WORDS)`` uint32 where plane ``p`` holds bit ``p`` of each
    position's count.  ``planes`` must satisfy ``max count < 2^planes``.
    """
    slab = slab.astype(jnp.uint32)
    starts = starts.astype(jnp.int32)
    n = slab.shape[0]
    row = starts[:-1, None] + jnp.arange(jmax, dtype=jnp.int32)[None, :]
    valid = row < starts[1:, None]
    g = jnp.where(valid[..., None], slab[jnp.minimum(row, n - 1)],
                  jnp.uint32(0))                          # (S, jmax, WORDS)
    if weights is None:
        w = jnp.ones((g.shape[0], jmax), jnp.int32)
    else:
        w = weights.astype(jnp.int32)[jnp.minimum(row, n - 1)]
    w = jnp.where(valid, w, 0)
    # one expensive (S, jmax, WORDS) reduction per bit position; the plane
    # extraction afterwards is cheap elementwise work
    out = [jnp.zeros((g.shape[0], WORDS), jnp.uint32) for _ in range(planes)]
    for b in range(32):
        cnt = (((g >> jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.int32)
               * w[..., None]).sum(axis=1)
        for p in range(planes):
            bit = ((cnt >> p) & 1).astype(jnp.uint32)
            out[p] = out[p] | (bit << jnp.uint32(b))
    return jnp.stack(out, axis=1)


def bitsliced_add(a: jax.Array, b: jax.Array) -> jax.Array:
    """Ripple-carry add of two bit-sliced counter sets (..., planes, WORDS).

    The result keeps the same number of planes; callers must size ``planes``
    so the true sum never overflows (the sharded planner bounds it by the
    total weight across ALL shards)."""
    planes = a.shape[-2]
    carry = jnp.zeros_like(a[..., 0, :])
    out = []
    for i in range(planes):
        ai, bi = a[..., i, :], b[..., i, :]
        out.append(ai ^ bi ^ carry)
        carry = (ai & bi) | (carry & (ai ^ bi))
    return jnp.stack(out, axis=-2)


def counters_ge(planes_arr: jax.Array, t: jax.Array) -> jax.Array:
    """Bitwise magnitude comparator: positions whose bit-sliced count is
    >= t.  planes_arr: (..., planes, WORDS) uint32; t: runtime int32
    scalar, or a (S,) vector of per-segment thresholds against a
    (S, planes, WORDS) counter set (the coalesced multi-query path).
    Returns (..., WORDS) uint32 result words."""
    full = jnp.uint32(0xFFFFFFFF)
    n_planes = planes_arr.shape[-2]
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 1:
        t = t[:, None]                       # broadcast over the word lanes
    gt = jnp.zeros_like(planes_arr[..., 0, :])
    eq = jnp.full_like(gt, full)
    for i in reversed(range(n_planes)):
        ci = planes_arr[..., i, :]
        tmask = jnp.where((t >> i) & 1 == 1, full, jnp.uint32(0))
        gt = gt | (eq & ci & ~tmask)
        eq = eq & ~(ci ^ tmask)
    return gt | eq


# ---------------------------------------------------------------------------
# Roaring-masked block-sparse attention (decode step) oracle
# ---------------------------------------------------------------------------

def block_sparse_attention_decode(
        q: jax.Array,            # (B, H, D)
        k: jax.Array,            # (B, Hkv, S, D)
        v: jax.Array,            # (B, Hkv, S, D)
        block_mask_words: jax.Array,  # (B, n_blocks/32) uint32 roaring bitset
        kv_len: jax.Array,       # (B,) int32 valid KV length
        block_size: int = 128,
        sm_scale: float | None = None,
        softcap: float = 0.0) -> jax.Array:
    """Reference decode attention where key/value *blocks* are visible only if
    their bit is set in a Roaring bitset container row.  Returns (B, H, D)."""
    b_, h, d = q.shape
    _, hkv, s, _ = k.shape
    n_blocks = s // block_size
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    groups = h // hkv
    qg = q.reshape(b_, hkv, groups, d)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    blk = jnp.arange(s) // block_size
    visible = ((block_mask_words[:, blk >> 5] >> (blk & 31).astype(jnp.uint32))
               & jnp.uint32(1)).astype(bool)
    visible &= jnp.arange(s)[None, :] < kv_len[:, None]
    scores = jnp.where(visible[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows -> zero output
    out = jnp.einsum("bkgs,bksd->bkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b_, h, d).astype(q.dtype)
