"""Device-resident fused top-k similarity kernel.

The paper's fast-count argument (section 5.9: the logical op and the
popcount must happen while the words sit in vector registers) extends to
similarity joins: the *scores* never need to leave the device either.
This module fuses the whole ``InvertedIndex.similar`` hot path --
AND-cardinality scoring of a query bitmap against T candidate bitmaps,
metric evaluation (jaccard / cosine / containment by inclusion-exclusion
over the AND count), and the k-selection -- into ONE engine dispatch, so
only k indices and k scores ever cross back to the host.

Layout (prepared once by ``core.pairwise.SimilarityEngine`` and cached on
device -- the serving contract):

  * ``rows``    (N, WORDS) uint32: every candidate container promoted to
    the bitset domain, candidate-major (candidate t owns rows
    ``starts[t]:starts[t+1]``; ragged, described by scalar-prefetched
    offsets exactly like ``segment_ops``).
  * ``row_col`` (N,) int32: which global chunk key each row belongs to --
    the scoring step ANDs row r with ``q_words[row_col[r]]``, so a query
    that lacks the key contributes zero automatically.
  * ``q_words`` (C, WORDS) uint32: the query's containers scattered over
    the global key columns.  This is the ONLY per-query device transfer
    (C * 8 kB); the candidate slab stays resident.

Two Pallas stages compose inside one jit (one XLA dispatch at runtime):

  1. ``_score_kernel`` -- grid (T, jmax): per-row AND + Harley-Seal
     popcount accumulates each candidate's intersection cardinality in a
     VMEM scalar (the revisited-output pattern of ``segment_ops``); the
     segment's last step evaluates the float32 metric score.
  2. ``_select_kernel`` -- a threshold-refinement pass: k rounds of
     (max, first-index-of-max) over the score vector held in VMEM,
     masking each winner.  Ties at equal score resolve to the LOWEST
     candidate index -- bit-identical to a stable host argsort of the
     negated scores, which is what the host planner runs off-device.

``kernels.ref.similarity_topk`` is the pure-jnp oracle; the score formula
itself lives in ``kernels.ref.similarity_scores`` with a fixed float32
operation order shared by the kernel, the oracle, and the numpy host twin
so all three paths select identically.  See docs/ARCHITECTURE.md
(sections 4.2/5.9 row of the paper map).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.harley_seal import harley_seal_reduce
from repro.kernels.ref import METRICS, WORDS, similarity_scores


def _score_kernel(starts_ref, col_ref, cards_ref, misc_ref, row_ref, q_ref,
                  score_ref, inter_ref, acc_ref, *, metric, jmax):
    t = pl.program_id(0)
    j = pl.program_id(1)
    seg_len = starts_ref[t + 1] - starts_ref[t]
    x = jnp.where(j < seg_len, row_ref[...] & q_ref[...], jnp.uint32(0))
    pc = harley_seal_reduce(x.reshape(1, WORDS // 16, 16))[:, None]

    @pl.when(j == 0)
    def _():
        acc_ref[...] = pc

    @pl.when(j > 0)
    def _():
        acc_ref[...] = acc_ref[...] + pc

    @pl.when(j == jmax - 1)
    def _():
        inter = acc_ref[0, 0]
        # THE score formula (ref.similarity_scores): one definition
        # serves the oracle, the kernel, and (via its numpy twin) the
        # host planner, so tie order can never drift between paths
        s = similarity_scores(inter, misc_ref[0], cards_ref[t], metric)
        s = jnp.where(t == misc_ref[1], jnp.float32(-1.0), s)
        score_ref[...] = s.reshape(1, 1)
        inter_ref[...] = inter.reshape(1, 1)


def _select_kernel(score_ref, inter_ref, idx_ref, sco_ref, int_ref, *, k):
    """Threshold-refinement k-selection: k rounds of (max, first index of
    max) with the winner masked out -- first-max-wins reproduces the
    stable host argsort tie order (lowest index first)."""
    s = score_ref[...]                           # (1, T)
    n = s.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    for i in range(k):
        m = jnp.max(s)
        j = jnp.min(jnp.where(s == m, cols, n))
        hit = cols == j
        idx_ref[0, i] = j
        sco_ref[0, i] = m
        int_ref[0, i] = jnp.sum(jnp.where(hit, inter_ref[...], 0))
        s = jnp.where(hit, jnp.float32(-2.0), s)


@functools.partial(jax.jit,
                   static_argnames=("metric", "k", "jmax", "interpret"))
def similarity_topk(rows: jax.Array, row_col: jax.Array, starts: jax.Array,
                    q_words: jax.Array, q_card: jax.Array, cards: jax.Array,
                    exclude: jax.Array = -1, *, metric: str, k: int,
                    jmax: int, interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused score + k-select over a device-resident candidate slab.

    rows:    (N, WORDS) uint32 candidate container rows, candidate-major.
    row_col: (N,) int32 global-key column of each row (indexes q_words).
    starts:  (T + 1,) int32 per-candidate row offsets (ragged segments).
    q_words: (C, WORDS) uint32 query bitset rows over the global keys.
    q_card:  scalar int32 query cardinality; cards: (T,) int32.
    exclude: runtime int32 candidate index scored -1 (-1: none).
    metric:  "jaccard" | "cosine" | "containment" (static).
    k, jmax: static selection size / max rows per candidate.

    Returns (idx (k,) int32, score (k,) float32, inter (k,) int32),
    best-first, ties to the lowest index.  One dispatch end-to-end.

    Tie order is a PINNED contract: equal scores cut at the k boundary
    resolve to the lowest candidate index, and on the sharded path
    (``similarity_topk_ids`` per shard + ``topk_merge`` over the
    all-gathered k-lists) to the lowest GLOBAL candidate index -- so a
    tie group straddling two shards merges in exactly the order this
    single-device kernel (and the stable host argsort) would emit.
    """
    assert metric in METRICS, metric
    assert k >= 1 and jmax >= 1
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = rows.shape[0]
    t = starts.shape[0] - 1
    starts = starts.astype(jnp.int32)
    misc = jnp.stack([jnp.asarray(q_card, jnp.int32),
                      jnp.asarray(exclude, jnp.int32)])

    def row_index(ti, j, st, col, cd, ms):
        return (jnp.minimum(st[ti] + j, n - 1), 0)

    def q_index(ti, j, st, col, cd, ms):
        return (col[jnp.minimum(st[ti] + j, n - 1)], 0)

    score, inter = pl.pallas_call(
        functools.partial(_score_kernel, metric=metric, jmax=jmax),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(t, jmax),
            in_specs=[pl.BlockSpec((1, WORDS), row_index),
                      pl.BlockSpec((1, WORDS), q_index)],
            out_specs=[
                pl.BlockSpec((1, 1), lambda ti, j, st, col, cd, ms: (ti, 0)),
                pl.BlockSpec((1, 1), lambda ti, j, st, col, cd, ms: (ti, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((1, 1), jnp.int32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((t, 1), jnp.float32),
                   jax.ShapeDtypeStruct((t, 1), jnp.int32)],
        interpret=interpret,
    )(starts, row_col.astype(jnp.int32), cards.astype(jnp.int32), misc,
      rows.astype(jnp.uint32), q_words.astype(jnp.uint32))

    idx, sco, intr = pl.pallas_call(
        functools.partial(_select_kernel, k=k),
        grid=(1,),
        in_specs=[pl.BlockSpec((1, t), lambda i: (0, 0)),
                  pl.BlockSpec((1, t), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (0, 0)),
                   pl.BlockSpec((1, k), lambda i: (0, 0)),
                   pl.BlockSpec((1, k), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, k), jnp.int32),
                   jax.ShapeDtypeStruct((1, k), jnp.float32),
                   jax.ShapeDtypeStruct((1, k), jnp.int32)],
        interpret=interpret,
    )(score.reshape(1, t), inter.reshape(1, t))
    return idx[0], sco[0], intr[0]


# ---------------------------------------------------------------------------
# sharded variants: one shard scores a candidate SUBSET labelled with
# global ids, local k-lists all-gather, and a final ids-select merges.
# Selection keys on (score desc, GLOBAL index asc) at every stage, so the
# merged result is bit-identical to the single-device kernel above --
# including tie groups that straddle shards (docs/ARCHITECTURE.md).
# ---------------------------------------------------------------------------

def _score_ids_kernel(starts_ref, col_ref, cards_ref, gidx_ref, misc_ref,
                      row_ref, q_ref, score_ref, inter_ref, acc_ref, *,
                      metric, jmax):
    t = pl.program_id(0)
    j = pl.program_id(1)
    seg_len = starts_ref[t + 1] - starts_ref[t]
    x = jnp.where(j < seg_len, row_ref[...] & q_ref[...], jnp.uint32(0))
    pc = harley_seal_reduce(x.reshape(1, WORDS // 16, 16))[:, None]

    @pl.when(j == 0)
    def _():
        acc_ref[...] = pc

    @pl.when(j > 0)
    def _():
        acc_ref[...] = acc_ref[...] + pc

    @pl.when(j == jmax - 1)
    def _():
        inter = acc_ref[0, 0]
        s = similarity_scores(inter, misc_ref[0], cards_ref[t], metric)
        # exclusion keys on the GLOBAL id; pad slots (>= n_valid) are
        # forced to -2.0 LAST -- an all-zero pad row would otherwise
        # score 1.0 under the zero-denominator convention
        s = jnp.where(gidx_ref[t] == misc_ref[1], jnp.float32(-1.0), s)
        s = jnp.where(t >= misc_ref[2], jnp.float32(-2.0), s)
        score_ref[...] = s.reshape(1, 1)
        inter_ref[...] = inter.reshape(1, 1)


def _select_ids_kernel(score_ref, inter_ref, gidx_ref, idx_ref, sco_ref,
                       int_ref, *, k):
    """k rounds of (max, lowest GLOBAL id among the maxes): the pinned
    shard-merge tie rule.  Entries of the winning id mask together, so
    identical padding entries cannot occupy more than one round."""
    s = score_ref[...]                           # (1, T)
    g = gidx_ref[...]
    big = jnp.int32(2**31 - 1)
    for i in range(k):
        m = jnp.max(s)
        w = jnp.min(jnp.where(s == m, g, big))
        hit = (g == w) & (s == m)
        idx_ref[0, i] = w
        sco_ref[0, i] = m
        int_ref[0, i] = jnp.max(jnp.where(hit, inter_ref[...], 0))
        s = jnp.where(hit, jnp.float32(-2.0), s)


@functools.partial(jax.jit,
                   static_argnames=("metric", "k", "jmax", "interpret"))
def similarity_topk_ids(rows: jax.Array, row_col: jax.Array,
                        starts: jax.Array, q_words: jax.Array,
                        q_card: jax.Array, cards: jax.Array,
                        gidx: jax.Array, n_valid: jax.Array,
                        exclude: jax.Array = -1, *, metric: str, k: int,
                        jmax: int, interpret: bool | None = None
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused score + k-select over ONE SHARD of a sharded candidate set.

    Layout matches :func:`similarity_topk` with three additions carried
    by ``kernels.ref.similarity_topk_ids`` (the oracle): ``gidx`` (T,)
    int32 global candidate ids (selection/exclusion key on them),
    ``n_valid`` runtime scalar valid-slot count (pad slots score -2.0),
    ``exclude`` a GLOBAL id (-1: none).  Returns (gidx (k,) int32,
    score (k,) float32, inter (k,) int32), ties to the lowest GLOBAL
    index -- the pinned shard-merge tie rule."""
    assert metric in METRICS, metric
    assert k >= 1 and jmax >= 1
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = rows.shape[0]
    t = starts.shape[0] - 1
    starts = starts.astype(jnp.int32)
    misc = jnp.stack([jnp.asarray(q_card, jnp.int32),
                      jnp.asarray(exclude, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])

    def row_index(ti, j, st, col, cd, gi, ms):
        return (jnp.minimum(st[ti] + j, n - 1), 0)

    def q_index(ti, j, st, col, cd, gi, ms):
        return (col[jnp.minimum(st[ti] + j, n - 1)], 0)

    score, inter = pl.pallas_call(
        functools.partial(_score_ids_kernel, metric=metric, jmax=jmax),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(t, jmax),
            in_specs=[pl.BlockSpec((1, WORDS), row_index),
                      pl.BlockSpec((1, WORDS), q_index)],
            out_specs=[
                pl.BlockSpec((1, 1),
                             lambda ti, j, st, col, cd, gi, ms: (ti, 0)),
                pl.BlockSpec((1, 1),
                             lambda ti, j, st, col, cd, gi, ms: (ti, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((1, 1), jnp.int32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((t, 1), jnp.float32),
                   jax.ShapeDtypeStruct((t, 1), jnp.int32)],
        interpret=interpret,
    )(starts, row_col.astype(jnp.int32), cards.astype(jnp.int32),
      gidx.astype(jnp.int32), misc,
      rows.astype(jnp.uint32), q_words.astype(jnp.uint32))
    return topk_merge(score.reshape(-1), inter.reshape(-1), gidx, k,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_merge(score: jax.Array, inter: jax.Array, gidx: jax.Array,
               k: int, *, interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Global top-k merge of labelled k-lists: one select pass over the
    all-gathered (S*k,) score/inter/gidx entries (k log k work, trivial
    next to scoring).  Ties to the lowest GLOBAL index -- bit-identical
    to selecting over the unsharded score vector."""
    assert k >= 1
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m = score.shape[0]
    idx, sco, intr = pl.pallas_call(
        functools.partial(_select_ids_kernel, k=k),
        grid=(1,),
        in_specs=[pl.BlockSpec((1, m), lambda i: (0, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0)),
                  pl.BlockSpec((1, m), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (0, 0)),
                   pl.BlockSpec((1, k), lambda i: (0, 0)),
                   pl.BlockSpec((1, k), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, k), jnp.int32),
                   jax.ShapeDtypeStruct((1, k), jnp.float32),
                   jax.ShapeDtypeStruct((1, k), jnp.int32)],
        interpret=interpret,
    )(score.reshape(1, m).astype(jnp.float32),
      inter.reshape(1, m).astype(jnp.int32),
      gidx.reshape(1, m).astype(jnp.int32))
    return idx[0], sco[0], intr[0]
