"""Fused bitset logical op + cardinality (paper section 4.1.2) as Pallas
TPU kernels.

The paper's point: when aggregating two bitset containers you want the
population count of the result computed *in vector registers*, without a
round-trip through memory and the scalar popcnt instruction.  These kernels
do exactly that -- one pass loads both containers into VMEM, computes
AND/OR/XOR/ANDNOT, runs the Harley-Seal circuit on the result while it is
still resident, and writes words + cardinality (or, for the count-only
"fast count" variants of section 5.9, just the cardinality).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.harley_seal import DEFAULT_BLOCK, harley_seal_reduce
from repro.kernels.ref import WORDS

_OPS = ("and", "or", "xor", "andnot")


def _apply(a, b, op: str):
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    if op == "andnot":
        return a & ~b
    raise ValueError(op)


def _op_kernel(a_ref, b_ref, out_ref, card_ref, *, op):
    r = _apply(a_ref[...], b_ref[...], op)
    out_ref[...] = r
    bn = r.shape[0]
    card_ref[...] = harley_seal_reduce(r.reshape(bn, WORDS // 16, 16))[:, None]


def _card_kernel(a_ref, b_ref, card_ref, *, op):
    r = _apply(a_ref[...], b_ref[...], op)
    bn = r.shape[0]
    card_ref[...] = harley_seal_reduce(r.reshape(bn, WORDS // 16, 16))[:, None]


def _pad(x, block):
    n_pad = (-x.shape[0]) % block
    return jnp.pad(x, ((0, n_pad), (0, 0))) if n_pad else x


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def bitset_op(a: jax.Array, b: jax.Array, op: str, *,
              block: int = DEFAULT_BLOCK,
              interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """(N, WORDS) x2 uint32 -> (result words (N, WORDS), cardinality (N,))."""
    assert op in _OPS, op
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a.shape[0]
    a, b = _pad(a, block), _pad(b, block)
    grid = (a.shape[0] // block,)
    spec = pl.BlockSpec((block, WORDS), lambda i: (i, 0))
    out, card = pl.pallas_call(
        functools.partial(_op_kernel, op=op),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, pl.BlockSpec((block, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((a.shape[0], WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((a.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
    return out[:n], card[:n, 0]


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def bitset_op_card(a: jax.Array, b: jax.Array, op: str, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool | None = None) -> jax.Array:
    """Count-only variant: never materializes the result container in HBM
    (paper section 5.9, e.g. Jaccard index numerators)."""
    assert op in _OPS, op
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a.shape[0]
    a, b = _pad(a, block), _pad(b, block)
    grid = (a.shape[0] // block,)
    spec = pl.BlockSpec((block, WORDS), lambda i: (i, 0))
    card = pl.pallas_call(
        functools.partial(_card_kernel, op=op),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(a, b)
    return card[:n, 0]
