"""Pallas TPU kernels for the paper's compute hot-spots (see DESIGN.md sec 3).

harley_seal       -- vectorized population count            (paper sec 4.1.1)
bitset_ops        -- fused logical op + cardinality         (paper sec 4.1.2)
bitset_convert    -- array->bitset scatter w/ card tracking (paper sec 3.1/3.2)
array_ops         -- all-vs-all sorted-array intersection   (paper sec 4.2/4.4)
pair_ops          -- batched pairwise ops: mixed-op bitset rows + array x
                     bitset probe (paper sec 4.1-4.5, similarity joins)
segment_ops       -- segmented wide OR/AND/XOR/threshold    (paper sec 5.8)
block_sparse_attn -- roaring-masked decode attention        (framework integration)
ops               -- public jit'd wrappers with backend dispatch
ref               -- pure-jnp oracles for all of the above
"""

from repro.kernels import ops, ref  # noqa: F401
