"""Public jit'd wrappers over the Pallas kernels, with a backend switch.

``backend``:
  * "pallas" -- always run the Pallas kernel (interpret=True off-TPU);
  * "ref"    -- always run the pure-jnp oracle (fast under jit on CPU);
  * "auto"   -- Pallas on TPU, oracle elsewhere (default: the oracle *is*
                the correct lowering for CPU tests, and the kernels are the
                TPU target validated in interpret mode by the test suite).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import array_ops as _array_ops
from repro.kernels import bitset_convert as _convert
from repro.kernels import bitset_ops as _bitset_ops
from repro.kernels import block_sparse_attn as _bsa
from repro.kernels import harley_seal as _hs
from repro.kernels import pair_ops as _pair_ops
from repro.kernels import ref
from repro.kernels import segment_ops as _segment_ops
from repro.kernels import topk_ops as _topk_ops

Backend = str
_DEFAULT: Backend = "auto"


def set_default_backend(backend: Backend) -> None:
    global _DEFAULT
    assert backend in ("auto", "pallas", "ref")
    _DEFAULT = backend


def _use_pallas(backend: Backend | None) -> bool:
    b = _DEFAULT if backend is None else backend
    if b == "pallas":
        return True
    if b == "ref":
        return False
    return jax.default_backend() == "tpu"


def prefer_kernel(backend: Backend | None) -> bool:
    """Whether a host planner should route work through the (jit'd)
    kernel wrappers at all, vs staying on its vectorized numpy twins.

    On TPU (or when a backend is forced, e.g. in tests) the fused kernels
    win; on CPU the host paths avoid a device round-trip that the jnp
    reference lowering cannot amortize.  Shared by the wide-aggregation
    and pairwise planners so the two policies can never drift."""
    if backend in ("pallas", "ref"):
        return True
    return jax.default_backend() == "tpu"


def popcount(words: jax.Array, *, backend: Backend | None = None) -> jax.Array:
    if _use_pallas(backend):
        return _hs.popcount(words)
    return ref.popcount_words(words)


def bitset_op(a, b, op: str, *, backend: Backend | None = None):
    if _use_pallas(backend):
        return _bitset_ops.bitset_op(a, b, op)
    return ref.bitset_op(a, b, op)


def bitset_op_card(a, b, op: str, *, backend: Backend | None = None):
    if _use_pallas(backend):
        return _bitset_ops.bitset_op_card(a, b, op)
    return ref.bitset_op_card(a, b, op)


def array_to_bitset(values, card, *, backend: Backend | None = None):
    if _use_pallas(backend):
        return _convert.array_to_bitset(values, card)
    return ref.array_to_bitset(values, card)


def bitset_set_many(words, values, card, *, backend: Backend | None = None):
    if _use_pallas(backend):
        return _convert.bitset_set_many(words, values, card)
    return ref.bitset_set_many(words, values, card)


def bitset_to_array(words):
    """Extraction is a pure-jnp path on all backends (see bitset_convert)."""
    return ref.bitset_to_array(words)


def array_intersect(a_vals, a_card, b_vals, b_card, *,
                    backend: Backend | None = None):
    if _use_pallas(backend):
        return _array_ops.array_intersect(a_vals, a_card, b_vals, b_card)
    return ref.array_intersect_mask(a_vals, a_card, b_vals, b_card)


def array_intersect_card(a_vals, a_card, b_vals, b_card, *,
                         backend: Backend | None = None):
    """Count-only batched sorted-array intersection (N,) int32 -- the
    array x array class of the pairwise similarity-join planner."""
    if _use_pallas(backend):
        return _array_ops.array_intersect_card(a_vals, a_card,
                                               b_vals, b_card)
    return _ref_array_intersect_count(a_vals, a_card, b_vals, b_card)


_ref_array_intersect_count = jax.jit(ref.array_intersect_count)


def array_pair_masks(a_vals, a_card, b_vals, b_card, *,
                     backend: Backend | None = None):
    """Two-sided membership masks + count for a batch of sorted-array
    pairs: one dispatch feeds AND/OR/XOR/ANDNOT materialization."""
    if _use_pallas(backend):
        return _array_ops.array_pair_masks(a_vals, a_card, b_vals, b_card)
    return ref.array_pair_masks(a_vals, a_card, b_vals, b_card)


def array_bitset_probe(vals, card, words, *, backend: Backend | None = None):
    """Batched array x bitset membership probe (mask over the array's
    slots + count per row)."""
    if _use_pallas(backend):
        return _pair_ops.array_bitset_probe(vals, card, words)
    return _ref_array_bitset_probe(vals, card, words)


_ref_array_bitset_probe = jax.jit(ref.array_bitset_probe)


def bitset_pair_op(a, b, opids, *, backend: Backend | None = None):
    """Mixed-op batched bitset algebra: per-row op ids into
    ``ref.PAIR_OPS``; returns (words, cards) in one dispatch."""
    opids = jnp.asarray(opids, jnp.int32)
    if _use_pallas(backend):
        return _pair_ops.bitset_pair_op(a, b, opids)
    return _ref_bitset_pair_op(a, b, opids)


def bitset_pair_card(a, b, opids, *, backend: Backend | None = None):
    """Count-only mixed-op batch (fast counts, paper section 5.9)."""
    opids = jnp.asarray(opids, jnp.int32)
    if _use_pallas(backend):
        return _pair_ops.bitset_pair_card(a, b, opids)
    return _ref_bitset_pair_card(a, b, opids)


_ref_bitset_pair_op = jax.jit(ref.bitset_pair_op)
_ref_bitset_pair_card = jax.jit(ref.bitset_pair_card)


def similarity_topk(rows, row_col, starts, q_words, q_card, cards, *,
                    metric: str, k: int, jmax: int, exclude=-1,
                    backend: Backend | None = None):
    """Fused similarity top-k: score a query against T device-resident
    candidates and select the best k in ONE dispatch (score + select never
    leave the device; only k indices/scores return).  See
    kernels/topk_ops.py for the layout and docs/ARCHITECTURE.md for where
    this sits in the paper map."""
    exclude = jnp.asarray(exclude, jnp.int32)
    if _use_pallas(backend):
        return _topk_ops.similarity_topk(rows, row_col, starts, q_words,
                                         q_card, cards, exclude,
                                         metric=metric, k=k, jmax=jmax)
    return _ref_similarity_topk(rows, row_col, starts, q_words,
                                jnp.asarray(q_card, jnp.int32),
                                cards, exclude, metric=metric, k=k)


_ref_similarity_topk = jax.jit(ref.similarity_topk,
                               static_argnames=("metric", "k"))


def similarity_topk_ids(rows, row_col, starts, q_words, q_card, cards,
                        gidx, *, metric: str, k: int, jmax: int, n_valid,
                        exclude=-1, backend: Backend | None = None):
    """Per-shard fused similarity top-k over a candidate SUBSET labelled
    with global ids (one shard of the sharded ``SimilarityEngine`` path,
    or any pruned candidate list): slots >= ``n_valid`` are padding,
    ``exclude`` is a GLOBAL candidate id, and score ties resolve to the
    lowest GLOBAL index -- see kernels/topk_ops.py for the pinned
    shard-merge tie rule."""
    exclude = jnp.asarray(exclude, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    if _use_pallas(backend):
        return _topk_ops.similarity_topk_ids(
            rows, row_col, starts, q_words, q_card, cards, gidx, n_valid,
            exclude, metric=metric, k=k, jmax=jmax)
    return _ref_similarity_topk_ids(
        rows, row_col, starts, q_words, jnp.asarray(q_card, jnp.int32),
        cards, gidx, n_valid, exclude, metric=metric, k=k)


_ref_similarity_topk_ids = jax.jit(ref.similarity_topk_ids,
                                   static_argnames=("metric", "k"))


def topk_merge(score, inter, gidx, k: int, *,
               backend: Backend | None = None):
    """Merge all-gathered per-shard k-lists to the global top-k on
    device: one ids-select pass over the (S*k,) entries, ties to the
    lowest GLOBAL candidate index (bit-identical to selecting over the
    unsharded score vector)."""
    if _use_pallas(backend):
        return _topk_ops.topk_merge(score, inter, gidx, k)
    return _ref_topk_select_ids(score, inter, gidx, k)


_ref_topk_select_ids = jax.jit(ref.topk_select_ids,
                               static_argnames=("k",))


_ref_segment_reduce = jax.jit(
    ref.segment_reduce, static_argnames=("op", "jmax"))

_ref_segment_counters = jax.jit(
    ref.segment_counters, static_argnames=("jmax", "planes"))


def segment_reduce(slab, starts, op: str, *, jmax: int, threshold: int = 0,
                   weights=None, planes: int | None = None, wbits: int = 1,
                   backend: Backend | None = None):
    """Segmented K-way OR/AND/XOR/ANDNOT/threshold reduce fused with
    cardinality: one dispatch for an arbitrary number of bitmaps (wide
    aggregation, paper section 5.8).  See kernels/segment_ops.py for the
    layout.  ``threshold`` is a runtime scalar (T-sweeps share one
    compilation) or a (S,) per-segment vector (coalesced multi-query
    batches).  ``weights`` (N,) int32 weight threshold rows (``wbits``
    static bit width, ``planes`` static counter width)."""
    t = jnp.asarray(threshold, jnp.int32)
    if weights is not None:
        weights = jnp.asarray(weights, jnp.int32)
    if _use_pallas(backend):
        return _segment_ops.segment_reduce(slab, starts, op, jmax=jmax,
                                           threshold=t, weights=weights,
                                           planes=planes, wbits=wbits)
    return _ref_segment_reduce(slab, starts, op, jmax=jmax, threshold=t,
                               weights=weights)


_ref_segment_reduce_rows = jax.jit(
    ref.segment_reduce_rows, static_argnames=("op", "jmax"))


def segment_reduce_rows(table, ids, starts, op: str, *, jmax: int,
                        threshold: int = 0, weights=None,
                        planes: int | None = None, wbits: int = 1,
                        backend: Backend | None = None):
    """Resident-slab segmented reduce: gather ``ids`` rows from a
    device-resident ``table`` (``core.arena.BitmapArena`` slab, optionally
    with a staged host block appended) on-device, then reduce exactly like
    :func:`segment_reduce`.  Warm arena queries ship only ids/starts/
    threshold over PCIe -- container words stay resident (docs/MEMORY.md).
    Pad ragged segments with id 0, the arena's reserved all-zero row."""
    t = jnp.asarray(threshold, jnp.int32)
    ids = jnp.asarray(ids, jnp.int32)
    if weights is not None:
        weights = jnp.asarray(weights, jnp.int32)
    if _use_pallas(backend):
        return _segment_ops.segment_reduce_rows(
            table, ids, starts, op, jmax=jmax, threshold=t,
            weights=weights, planes=planes, wbits=wbits)
    return _ref_segment_reduce_rows(table, ids, starts, op, jmax=jmax,
                                    threshold=t, weights=weights)


_ref_segment_reduce_rows_dual = jax.jit(
    ref.segment_reduce_rows_dual, static_argnames=("op", "jmax"))


def segment_reduce_rows_dual(table, staged, pos, sidx, starts, op: str, *,
                             jmax: int, threshold: int = 0, weights=None,
                             planes: int | None = None, wbits: int = 1,
                             backend: Backend | None = None):
    """Dual-source resident-slab reduce: slot ``i`` gathers
    ``table[pos[i]] | staged[sidx[i]]`` on-device (exactly one side real,
    the other the reserved zero row) and reduces like
    :func:`segment_reduce`.  ``table`` is the arena's resident slab --
    single-device or the sharded assembled per-shard layout -- and is
    never copied per call; only the small ``staged`` block of cold rows
    crosses PCIe.  See kernels/segment_ops.py."""
    t = jnp.asarray(threshold, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    sidx = jnp.asarray(sidx, jnp.int32)
    if weights is not None:
        weights = jnp.asarray(weights, jnp.int32)
    if _use_pallas(backend):
        return _segment_ops.segment_reduce_rows_dual(
            table, staged, pos, sidx, starts, op, jmax=jmax, threshold=t,
            weights=weights, planes=planes, wbits=wbits)
    return _ref_segment_reduce_rows_dual(table, staged, pos, sidx, starts,
                                         op, jmax=jmax, threshold=t,
                                         weights=weights)


def segment_counters(slab, starts, *, jmax: int, planes: int, weights=None,
                     backend: Backend | None = None):
    """Per-segment bit-sliced occurrence counters (S, planes, WORDS) --
    the exchange payload of the sharded threshold path.  Counter
    computation is a pure-jnp path on all backends: it exists to be
    all-gathered and combined across mesh shards, where XLA's fusion of
    the 32 plane extractions is already the right lowering."""
    del backend
    if weights is not None:
        weights = jnp.asarray(weights, jnp.int32)
    return _ref_segment_counters(slab, starts, jmax=jmax, planes=planes,
                                 weights=weights)


def decode_attention(q, k, v, block_mask_words, kv_len, *,
                     block_size: int = 128, sm_scale=None, softcap: float = 0.0,
                     backend: Backend | None = None):
    if _use_pallas(backend):
        return _bsa.decode_attention(q, k, v, block_mask_words, kv_len,
                                     block_size=block_size, sm_scale=sm_scale,
                                     softcap=softcap)
    return ref.block_sparse_attention_decode(
        q, k, v, block_mask_words, kv_len,
        block_size=block_size, sm_scale=sm_scale, softcap=softcap)
