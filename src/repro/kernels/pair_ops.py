"""Batched pairwise container kernels: the two-by-two analogue of the
segmented wide-aggregation engine.

The paper's central performance contribution is *vectorized two-by-two* set
algebra (sections 4.2-4.5): SIMD intersection, union, difference and
symmetric difference over container pairs.  The host planner
(``repro.core.pairwise``) key-merges a batch of bitmap pairs, buckets the
matched container pairs by type class, and issues ONE dispatch per class
into the kernels here:

  * ``bitset_pair_op`` -- bitset x bitset (section 4.1.2): stacked word
    rows, a logical op *id per row* (so one dispatch can run a mixed-op
    batch), fused with the Harley-Seal cardinality.  ``bitset_pair_card``
    is the count-only twin (section 5.9: the result words never leave
    registers -- the Jaccard / cosine / intersects hot path).
  * ``array_bitset_probe`` -- array x bitset (the asymmetric case of
    section 4.2): each sorted array value probes the bitset row; the
    paper's per-value binary search degenerates to a word fetch + bit test
    in the bitset domain.  On TPU the gather is a one-hot reduction over
    value tiles (the VPU has no vector gather; the one-hot contraction is
    the standard idiom).

Array x array pairs ride ``kernels.array_ops`` (the pcmpistrm analogue),
extended by this PR with a two-sided-mask variant (feeding OR / XOR /
ANDNOT materialization) and a count-only variant.  Run containers stay on
the host planner's interval fast paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.harley_seal import DEFAULT_BLOCK, harley_seal_reduce
from repro.kernels.ref import ARRAY_CAP, WORDS

TILE = 512   # values per probe tile; (TILE, WORDS) one-hot = 4 MB of VMEM


def _mixed_op(a, b, opid):
    """Per-row op select: opid broadcasts (block, 1) against (block, WORDS).
    All four ops are computed and selected -- on the VPU the four logical
    ops cost less than a branch, exactly the paper's branch-free ethos."""
    return jnp.where(opid == 0, a & b,
                     jnp.where(opid == 1, a | b,
                               jnp.where(opid == 2, a ^ b, a & ~b)))


def _pair_op_kernel(opid_ref, a_ref, b_ref, out_ref, card_ref):
    r = _mixed_op(a_ref[...], b_ref[...], opid_ref[...])
    out_ref[...] = r
    bn = r.shape[0]
    card_ref[...] = harley_seal_reduce(r.reshape(bn, WORDS // 16, 16))[:, None]


def _pair_card_kernel(opid_ref, a_ref, b_ref, card_ref):
    r = _mixed_op(a_ref[...], b_ref[...], opid_ref[...])
    bn = r.shape[0]
    card_ref[...] = harley_seal_reduce(r.reshape(bn, WORDS // 16, 16))[:, None]


def _pad_rows(x, block, fill=0):
    n_pad = (-x.shape[0]) % block
    if not n_pad:
        return x
    return jnp.pad(x, ((0, n_pad),) + ((0, 0),) * (x.ndim - 1),
                   constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bitset_pair_op(a: jax.Array, b: jax.Array, opids: jax.Array, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """(M, WORDS) x2 uint32 + (M,) int32 op ids -> (words, cards).

    One dispatch for an arbitrary mixed-op batch of bitset pairs: op id
    ``i`` of row ``r`` selects ``PAIR_OPS[i]`` for that row."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a.shape[0]
    a, b = _pad_rows(a, block), _pad_rows(b, block)
    ops2d = _pad_rows(opids.astype(jnp.int32)[:, None], block)
    grid = (a.shape[0] // block,)
    spec = pl.BlockSpec((block, WORDS), lambda i: (i, 0))
    ospec = pl.BlockSpec((block, 1), lambda i: (i, 0))
    out, card = pl.pallas_call(
        _pair_op_kernel,
        grid=grid,
        in_specs=[ospec, spec, spec],
        out_specs=[spec, ospec],
        out_shape=[
            jax.ShapeDtypeStruct((a.shape[0], WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((a.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(ops2d, a.astype(jnp.uint32), b.astype(jnp.uint32))
    return out[:n], card[:n, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bitset_pair_card(a: jax.Array, b: jax.Array, opids: jax.Array, *,
                     block: int = DEFAULT_BLOCK,
                     interpret: bool | None = None) -> jax.Array:
    """Count-only mixed-op batch: result words stay in registers (paper
    section 5.9) -- the similarity-join inner loop."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a.shape[0]
    a, b = _pad_rows(a, block), _pad_rows(b, block)
    ops2d = _pad_rows(opids.astype(jnp.int32)[:, None], block)
    grid = (a.shape[0] // block,)
    spec = pl.BlockSpec((block, WORDS), lambda i: (i, 0))
    ospec = pl.BlockSpec((block, 1), lambda i: (i, 0))
    card = pl.pallas_call(
        _pair_card_kernel,
        grid=grid,
        in_specs=[ospec, spec, spec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((a.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(ops2d, a.astype(jnp.uint32), b.astype(jnp.uint32))
    return card[:n, 0]


def _probe_kernel(vals_ref, card_ref_in, words_ref, mask_ref, count_ref):
    vals = vals_ref[...]                             # (1, ARRAY_CAP) int32
    words = words_ref[...]                           # (1, WORDS) uint32
    card = card_ref_in[0, 0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, ARRAY_CAP), 1)
    valid = pos < card
    v = jnp.where(valid, vals, 0)
    wcol = jax.lax.broadcasted_iota(jnp.int32, (TILE, WORDS), 1)
    mask = jnp.zeros((1, ARRAY_CAP), jnp.int32)
    for i in range(ARRAY_CAP // TILE):
        vt = jax.lax.dynamic_slice(v, (0, i * TILE), (1, TILE))[0]
        # one-hot word select: each value hits exactly one word, so the
        # masked sum IS the gathered word (no vector gather on the VPU)
        onehot = (wcol == (vt >> 5)[:, None]).astype(jnp.uint32)
        wsel = (onehot * words).sum(axis=-1)         # (TILE,) uint32
        bit = (wsel >> (vt & 31).astype(jnp.uint32)) & jnp.uint32(1)
        mask = jax.lax.dynamic_update_slice(
            mask, bit.astype(jnp.int32)[None, :], (0, i * TILE))
    mask = jnp.where(valid, mask, 0)
    mask_ref[...] = mask
    count_ref[...] = mask.sum(axis=-1, dtype=jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def array_bitset_probe(vals: jax.Array, card: jax.Array,
                       words: jax.Array, *,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Batched array x bitset probe.

    vals: (M, ARRAY_CAP) int32 sorted uint16-valued (slots >= card
    ignored); card: (M,) int32; words: (M, WORDS) uint32 bitset rows.
    Returns (mask (M, ARRAY_CAP) int32 over the array's slots, count (M,)).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = vals.shape[0]
    vspec = pl.BlockSpec((1, ARRAY_CAP), lambda i: (i, 0))
    wspec = pl.BlockSpec((1, WORDS), lambda i: (i, 0))
    cspec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    mask, count = pl.pallas_call(
        _probe_kernel,
        grid=(n,),
        in_specs=[vspec, cspec, wspec],
        out_specs=[vspec, cspec],
        out_shape=[
            jax.ShapeDtypeStruct((n, ARRAY_CAP), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(vals.astype(jnp.int32), card.astype(jnp.int32)[:, None],
      words.astype(jnp.uint32))
    return mask, count[:, 0]
