"""Vectorized sorted-array intersection (paper section 4.2) as a Pallas TPU
kernel: the pcmpistrm analogue.

The paper divides both arrays into blocks and uses the SSE4.1 string-compare
instruction for an all-vs-all equality test between two blocks, stepping
blocks by comparing block maxima (Algorithm 1).  The TPU analogue of the
all-vs-all compare is a broadcast equality outer product on the VPU; the
block-maxima stepping becomes a *skip predicate*: the grid is static, but a
tile pair whose value ranges cannot overlap is skipped with @pl.when, which
on TPU elides the compute exactly like the paper's merge stepping avoids
non-matching block pairs (sortedness makes the ranges available for free).

Output is A-side: a 0/1 membership mask over A's slots plus the intersection
cardinality.  Difference (section 4.4) is the complement of this mask on
valid slots (the paper builds the difference by OR-accumulating intersection
masks and negating).  Union / symmetric difference (sections 4.3/4.5) use the
merge + dedup oracles in ref.py, or the bitset-domain plan in core.tensor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import ARRAY_CAP, CONTAINER_BITS

TILE = 512  # values per compare tile; (TILE, TILE) i32 eq-matrix = 1 MB


def _intersect_kernel(a_ref, a_card_ref, b_ref, b_card_ref,
                      mask_ref, count_ref):
    a = a_ref[...]                                   # (1, ARRAY_CAP)
    b = b_ref[...]
    a_card, b_card = a_card_ref[0, 0], b_card_ref[0, 0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, ARRAY_CAP), 1)
    a_valid = pos < a_card
    b_valid = pos < b_card
    # invalid slots get sentinel values that can never match
    a_v = jnp.where(a_valid, a, np.int32(CONTAINER_BITS))
    b_v = jnp.where(b_valid, b, np.int32(CONTAINER_BITS + 1))

    n_tiles = ARRAY_CAP // TILE
    mask = jnp.zeros((1, ARRAY_CAP), jnp.int32)
    for i in range(n_tiles):
        at = jax.lax.dynamic_slice(a_v, (0, i * TILE), (1, TILE))
        a_min, a_max = at[0, 0], at[0, TILE - 1]
        hit = jnp.zeros((1, TILE), jnp.int32)
        for j in range(n_tiles):
            bt = jax.lax.dynamic_slice(b_v, (0, j * TILE), (1, TILE))
            b_min, b_max = bt[0, 0], bt[0, TILE - 1]
            # Algorithm 1's block-maxima stepping as a skip predicate:
            # sorted tiles whose ranges don't overlap can't match.
            overlap = (a_min <= b_max) & (b_min <= a_max)
            eq_any = jnp.where(
                overlap,
                (at[0, :, None] == bt[0, None, :]).any(axis=-1)
                .astype(jnp.int32)[None, :],
                jnp.zeros((1, TILE), jnp.int32))
            hit = hit | eq_any
        mask = jax.lax.dynamic_update_slice(mask, hit, (0, i * TILE))
    mask_ref[...] = mask
    count_ref[...] = mask.sum(axis=-1, dtype=jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def array_intersect(a_vals: jax.Array, a_card: jax.Array,
                    b_vals: jax.Array, b_card: jax.Array, *,
                    interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Batched sorted-array intersection.

    a_vals/b_vals: (N, ARRAY_CAP) int32 (sorted; slots >= card ignored)
    returns: (mask (N, ARRAY_CAP) int32 over A's slots, count (N,) int32)
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a_vals.shape[0]
    vspec = pl.BlockSpec((1, ARRAY_CAP), lambda i: (i, 0))
    cspec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    mask, count = pl.pallas_call(
        _intersect_kernel,
        grid=(n,),
        in_specs=[vspec, cspec, vspec, cspec],
        out_specs=[vspec, cspec],
        out_shape=[
            jax.ShapeDtypeStruct((n, ARRAY_CAP), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a_vals.astype(jnp.int32), a_card.astype(jnp.int32)[:, None],
      b_vals.astype(jnp.int32), b_card.astype(jnp.int32)[:, None])
    return mask, count[:, 0]


def array_difference(a_vals, a_card, b_vals, b_card, *, interpret=None):
    """Section 4.4: A \\ B = valid slots of A minus the intersection mask."""
    mask, inter = array_intersect(a_vals, a_card, b_vals, b_card,
                                  interpret=interpret)
    valid = (jnp.arange(ARRAY_CAP)[None, :] < a_card[:, None]).astype(jnp.int32)
    keep = valid * (1 - mask)
    return keep, (a_card.astype(jnp.int32) - inter)


def _pair_masks_kernel(a_ref, a_card_ref, b_ref, b_card_ref,
                       mask_a_ref, mask_b_ref, count_ref):
    """Two-sided variant of ``_intersect_kernel``: the same tiled all-vs-all
    compare also accumulates which B slots matched, so one dispatch feeds
    every materializing array-array op (AND keeps A's hits, ANDNOT drops
    them, OR appends B's misses, XOR keeps both sides' misses --
    sections 4.2-4.5)."""
    a = a_ref[...]
    b = b_ref[...]
    a_card, b_card = a_card_ref[0, 0], b_card_ref[0, 0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, ARRAY_CAP), 1)
    a_v = jnp.where(pos < a_card, a, np.int32(CONTAINER_BITS))
    b_v = jnp.where(pos < b_card, b, np.int32(CONTAINER_BITS + 1))

    n_tiles = ARRAY_CAP // TILE
    mask_a = jnp.zeros((1, ARRAY_CAP), jnp.int32)
    mask_b = jnp.zeros((1, ARRAY_CAP), jnp.int32)
    for i in range(n_tiles):
        at = jax.lax.dynamic_slice(a_v, (0, i * TILE), (1, TILE))
        a_min, a_max = at[0, 0], at[0, TILE - 1]
        hit_a = jnp.zeros((1, TILE), jnp.int32)
        for j in range(n_tiles):
            bt = jax.lax.dynamic_slice(b_v, (0, j * TILE), (1, TILE))
            b_min, b_max = bt[0, 0], bt[0, TILE - 1]
            overlap = (a_min <= b_max) & (b_min <= a_max)
            eq = jnp.where(overlap,
                           at[0, :, None] == bt[0, None, :],
                           jnp.zeros((TILE, TILE), jnp.bool_))
            hit_a = hit_a | eq.any(axis=-1).astype(jnp.int32)[None, :]
            bj = jax.lax.dynamic_slice(mask_b, (0, j * TILE), (1, TILE))
            mask_b = jax.lax.dynamic_update_slice(
                mask_b, bj | eq.any(axis=0).astype(jnp.int32)[None, :],
                (0, j * TILE))
        mask_a = jax.lax.dynamic_update_slice(mask_a, hit_a, (0, i * TILE))
    mask_a_ref[...] = mask_a
    mask_b_ref[...] = mask_b
    count_ref[...] = mask_a.sum(axis=-1, dtype=jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def array_pair_masks(a_vals: jax.Array, a_card: jax.Array,
                     b_vals: jax.Array, b_card: jax.Array, *,
                     interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched two-sided sorted-array intersection masks.

    a_vals/b_vals: (N, ARRAY_CAP) int32 (sorted; slots >= card ignored)
    returns: (mask_a (N, ARRAY_CAP), mask_b (N, ARRAY_CAP), count (N,))
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a_vals.shape[0]
    vspec = pl.BlockSpec((1, ARRAY_CAP), lambda i: (i, 0))
    cspec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    mask_a, mask_b, count = pl.pallas_call(
        _pair_masks_kernel,
        grid=(n,),
        in_specs=[vspec, cspec, vspec, cspec],
        out_specs=[vspec, vspec, cspec],
        out_shape=[
            jax.ShapeDtypeStruct((n, ARRAY_CAP), jnp.int32),
            jax.ShapeDtypeStruct((n, ARRAY_CAP), jnp.int32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a_vals.astype(jnp.int32), a_card.astype(jnp.int32)[:, None],
      b_vals.astype(jnp.int32), b_card.astype(jnp.int32)[:, None])
    return mask_a, mask_b, count[:, 0]


def _intersect_card_kernel(a_ref, a_card_ref, b_ref, b_card_ref, count_ref):
    """Count-only intersection (paper section 5.9 applied to the section
    4.2 compare): the membership mask never leaves registers."""
    a = a_ref[...]
    b = b_ref[...]
    a_card, b_card = a_card_ref[0, 0], b_card_ref[0, 0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, ARRAY_CAP), 1)
    a_v = jnp.where(pos < a_card, a, np.int32(CONTAINER_BITS))
    b_v = jnp.where(pos < b_card, b, np.int32(CONTAINER_BITS + 1))
    n_tiles = ARRAY_CAP // TILE
    total = jnp.zeros((), jnp.int32)
    for i in range(n_tiles):
        at = jax.lax.dynamic_slice(a_v, (0, i * TILE), (1, TILE))
        a_min, a_max = at[0, 0], at[0, TILE - 1]
        hit = jnp.zeros((1, TILE), jnp.int32)
        for j in range(n_tiles):
            bt = jax.lax.dynamic_slice(b_v, (0, j * TILE), (1, TILE))
            b_min, b_max = bt[0, 0], bt[0, TILE - 1]
            overlap = (a_min <= b_max) & (b_min <= a_max)
            eq_any = jnp.where(
                overlap,
                (at[0, :, None] == bt[0, None, :]).any(axis=-1)
                .astype(jnp.int32)[None, :],
                jnp.zeros((1, TILE), jnp.int32))
            hit = hit | eq_any
        total = total + hit.sum(dtype=jnp.int32)
    count_ref[...] = total[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def array_intersect_card(a_vals: jax.Array, a_card: jax.Array,
                         b_vals: jax.Array, b_card: jax.Array, *,
                         interpret: bool | None = None) -> jax.Array:
    """Batched count-only sorted-array intersection: (N,) int32 counts."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = a_vals.shape[0]
    vspec = pl.BlockSpec((1, ARRAY_CAP), lambda i: (i, 0))
    cspec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    count = pl.pallas_call(
        _intersect_card_kernel,
        grid=(n,),
        in_specs=[vspec, cspec, vspec, cspec],
        out_specs=cspec,
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(a_vals.astype(jnp.int32), a_card.astype(jnp.int32)[:, None],
      b_vals.astype(jnp.int32), b_card.astype(jnp.int32)[:, None])
    return count[:, 0]
