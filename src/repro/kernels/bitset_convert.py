"""Array-container <-> bitset-container conversion kernels (paper sections
3.1 / 3.2), adapted for TPU.

section 3.2 (x64): set bits of a bitset at indexes given by an array, with
branchless cardinality tracking (`bts` + `sbb`, or the XOR trick).  TPU has
no scatter inside a kernel, but Roaring array containers hold *distinct*
values, so each (word, bit) contribution is disjoint and OR == +:
the scatter becomes a masked compare-and-accumulate over word indexes --
a shape the VPU executes well.  The cardinality delta uses exactly the
paper's XOR trick: popcount(old ^ new).

section 3.1 (bitset -> array extraction, blsi/tzcnt loop): the TPU idiom is a
prefix sum over bit occupancy; it needs a 65536-long cumsum and binary
search, which XLA already fuses well outside a kernel -- see
`repro.kernels.ref.bitset_to_array` (used directly by ops.py; it is the
repack path, not the hot loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.harley_seal import harley_seal_reduce
from repro.kernels.ref import ARRAY_CAP, WORDS

VALUE_TILE = 512  # values processed per inner step: (WORDS, 512) i32 = 4 MB


def _a2b_body(vals, card):
    """(1, ARRAY_CAP) int32 values + scalar card -> (1, WORDS) uint32."""
    valid = jax.lax.broadcasted_iota(jnp.int32, (1, ARRAY_CAP), 1) < card
    widx = jnp.where(valid, vals >> 5, WORDS)          # OOB -> contributes 0
    bit = jnp.where(valid,
                    np.uint32(1) << (vals & 31).astype(jnp.uint32),
                    np.uint32(0))
    wids = jax.lax.broadcasted_iota(jnp.int32, (1, WORDS, 1), 1)
    acc = jnp.zeros((1, WORDS), jnp.uint32)
    for t in range(ARRAY_CAP // VALUE_TILE):
        wv = jax.lax.dynamic_slice(widx, (0, t * VALUE_TILE), (1, VALUE_TILE))
        bv = jax.lax.dynamic_slice(bit, (0, t * VALUE_TILE), (1, VALUE_TILE))
        eq = wids == wv[:, None, :]                    # (1, WORDS, TILE)
        acc = acc + jnp.where(eq, bv[:, None, :],
                              np.uint32(0)).sum(axis=-1, dtype=jnp.uint32)
    return acc


def _a2b_kernel(vals_ref, card_ref, words_ref):
    words_ref[...] = _a2b_body(vals_ref[...], card_ref[0, 0])


def _set_many_kernel(init_ref, vals_ref, card_ref, words_ref, delta_ref):
    """Fused section 3.2: new = old | onehot(values); delta = pc(old ^ new)."""
    old = init_ref[...]
    add = _a2b_body(vals_ref[...], card_ref[0, 0])
    new = old | add
    words_ref[...] = new
    changed = old ^ new
    delta_ref[...] = harley_seal_reduce(
        changed.reshape(1, WORDS // 16, 16))[:, None]


def _specs():
    return dict(
        vals=pl.BlockSpec((1, ARRAY_CAP), lambda i: (i, 0)),
        card=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        words=pl.BlockSpec((1, WORDS), lambda i: (i, 0)),
        delta=pl.BlockSpec((1, 1), lambda i: (i, 0)),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def array_to_bitset(values: jax.Array, card: jax.Array, *,
                    interpret: bool | None = None) -> jax.Array:
    """(N, ARRAY_CAP) int32 sorted values, (N,) cards -> (N, WORDS) uint32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = values.shape[0]
    s = _specs()
    return pl.pallas_call(
        _a2b_kernel,
        grid=(n,),
        in_specs=[s["vals"], s["card"]],
        out_specs=s["words"],
        out_shape=jax.ShapeDtypeStruct((n, WORDS), jnp.uint32),
        interpret=interpret,
    )(values.astype(jnp.int32), card.astype(jnp.int32)[:, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitset_set_many(words: jax.Array, values: jax.Array, card: jax.Array, *,
                    interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """OR an array container into a bitset container, returning
    (new words (N, WORDS), cardinality delta (N,)) -- section 3.2 fused."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = words.shape[0]
    s = _specs()
    new, delta = pl.pallas_call(
        _set_many_kernel,
        grid=(n,),
        in_specs=[s["words"], s["vals"], s["card"]],
        out_specs=[s["words"], s["delta"]],
        out_shape=[
            jax.ShapeDtypeStruct((n, WORDS), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(words, values.astype(jnp.int32), card.astype(jnp.int32)[:, None])
    return new, delta[:, 0]
