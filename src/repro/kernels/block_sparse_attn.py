"""Roaring-masked block-sparse decode attention (Pallas TPU kernel).

This is the framework integration of the paper's data structure: for
long-context serving the set of *visible key blocks* per sequence is an
integer set over [0, seq/block_size) -- exactly one Roaring bitset container
row (a 4096-block universe covers 512 k tokens at block_size 128).  The
kernel walks the KV cache block by block, tests the container bit for each
block (the paper's section 3.2 `bt` primitive), and *skips all compute and
(on TPU) the HBM traffic* for absent blocks via @pl.when -- giving
sub-quadratic attention whose cost scales with the bitmap cardinality, not
the sequence length.

Flash-attention-style online softmax keeps the accumulator in VMEM scratch
across the KV-block grid axis (TPU grids iterate minor-axis sequentially, so
scratch carries state).  GQA is handled by folding query heads into
(kv_head, group) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_SIZE = 128
_NEG = np.float32(-1e30)


def _bsa_kernel(mask_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                acc_ref, m_ref, l_ref, *, block_size, sm_scale, hkv, groups,
                softcap):
    blk = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        m_ref[...] = jnp.full(m_ref.shape, _NEG, m_ref.dtype)
        l_ref[...] = jnp.zeros(l_ref.shape, l_ref.dtype)

    word = mask_ref[0, blk >> 5]
    bit = (word >> (blk & 31).astype(jnp.uint32)) & np.uint32(1)
    kvl = kvlen_ref[0, 0]
    start = blk * block_size

    @pl.when((bit == np.uint32(1)) & (start < kvl))
    def _compute():
        d = q_ref.shape[-1]
        q = q_ref[0].reshape(hkv, groups, d).astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)           # (hkv, bs, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * sm_scale   # (hkv, g, bs)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_size), 2)
        s = jnp.where(pos < kvl, s, _NEG)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)              # (hkv, g, d)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
        m_ref[...] = m_new

    @pl.when(blk == nblk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = acc_ref[...] / safe[..., None]
        out = jnp.where((l > 0)[..., None], out, 0.0)
        o_ref[...] = out.reshape(1, hkv * groups, q_ref.shape[-1]) \
            .astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "sm_scale", "softcap", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     block_mask_words: jax.Array, kv_len: jax.Array, *,
                     block_size: int = DEFAULT_BLOCK_SIZE,
                     sm_scale: float | None = None,
                     softcap: float = 0.0,
                     interpret: bool | None = None) -> jax.Array:
    """Single-token decode attention with a Roaring block-visibility mask.

    q: (B, H, D); k, v: (B, Hkv, S, D); block_mask_words: (B, ceil(S/bs/32))
    uint32 Roaring bitset words; kv_len: (B,) int32.  Returns (B, H, D).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    assert s % block_size == 0, (s, block_size)
    nblk = s // block_size
    groups = h // hkv
    scale = (d ** -0.5) if sm_scale is None else sm_scale
    words = block_mask_words.shape[1]
    assert words * 32 >= nblk, (words, nblk)

    grid = (b, nblk)
    out = pl.pallas_call(
        functools.partial(_bsa_kernel, block_size=block_size,
                          sm_scale=scale, hkv=hkv, groups=groups,
                          softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, words), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, hkv, block_size, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, hkv, block_size, d), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hkv, groups, d), jnp.float32),
            pltpu.VMEM((hkv, groups), jnp.float32),
            pltpu.VMEM((hkv, groups), jnp.float32),
        ],
        interpret=interpret,
    )(block_mask_words, kv_len.astype(jnp.int32)[:, None], q, k, v)
    return out
