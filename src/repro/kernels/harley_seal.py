"""Vectorized Harley-Seal population count (paper section 4.1.1) as a Pallas
TPU kernel.

The paper's AVX2 version streams sixteen 256-bit vectors through a carry-save
adder (CSA) circuit, accumulating into five bit-sliced accumulator vectors
(ones/twos/fours/eights/sixteens) so that the expensive per-byte popcount
runs on 5 vectors instead of 16.  On TPU the VPU register is (8, 128) x 32-bit
= 32768 bits, so one 2^16-bit Roaring bitset container is two vregs; we lay
the 16 CSA circuit inputs along the minor axis of a (block, 128, 16) reshape
and vectorize the identical circuit across lanes.  The op-count saving is the
same as the paper's: 15 CSAs x 5 logical ops per 16 words, then a SWAR
popcount of 5 accumulators instead of 16 (TPU has no vector popcount
instruction, which is precisely the situation the paper's circuit was
designed for).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import WORDS

# numpy scalars stay literals inside Pallas kernel traces
_M1 = np.uint32(0x55555555)
_M2 = np.uint32(0x33333333)
_M4 = np.uint32(0x0F0F0F0F)
_H01 = np.uint32(0x01010101)

DEFAULT_BLOCK = 8  # containers per grid step: 8 x 8 kB = 64 kB of VMEM


def _popcount_u32(v):
    v = v - ((v >> np.uint32(1)) & _M1)
    v = (v & _M2) + ((v >> np.uint32(2)) & _M2)
    v = (v + (v >> np.uint32(4))) & _M4
    return ((v * _H01) >> np.uint32(24)).astype(jnp.int32)


def _csa(a, b, c):
    """Carry-save adder: 3 bits in, (high, low) out -- 5 logical ops."""
    u = a ^ b
    return (a & b) | (u & c), u ^ c


def harley_seal_reduce(x):
    """The 16-input Harley-Seal circuit of the paper's Fig. 3, vectorized.

    x: (..., 16) uint32, the 16 circuit inputs along the last axis.
    Returns int32 popcount summed over all axes except the leading one.
    """
    A = [x[..., i] for i in range(16)]
    twos_a, ones = _csa(A[0], A[1], jnp.zeros_like(A[0]))
    twos_b, ones = _csa(A[2], A[3], ones)
    fours_a, twos = _csa(twos_a, twos_b, jnp.zeros_like(A[0]))
    twos_a, ones = _csa(A[4], A[5], ones)
    twos_b, ones = _csa(A[6], A[7], ones)
    fours_b, twos = _csa(twos_a, twos_b, twos)
    eights_a, fours = _csa(fours_a, fours_b, jnp.zeros_like(A[0]))
    twos_a, ones = _csa(A[8], A[9], ones)
    twos_b, ones = _csa(A[10], A[11], ones)
    fours_a, twos = _csa(twos_a, twos_b, twos)
    twos_a, ones = _csa(A[12], A[13], ones)
    twos_b, ones = _csa(A[14], A[15], ones)
    fours_b, twos = _csa(twos_a, twos_b, twos)
    eights_b, fours = _csa(fours_a, fours_b, fours)
    sixteens, eights = _csa(eights_a, eights_b, jnp.zeros_like(A[0]))
    axes = tuple(range(1, x.ndim - 1))
    total = (16 * _popcount_u32(sixteens)
             + 8 * _popcount_u32(eights)
             + 4 * _popcount_u32(fours)
             + 2 * _popcount_u32(twos)
             + _popcount_u32(ones))
    return total.sum(axis=axes).astype(jnp.int32)


def _popcount_kernel(words_ref, out_ref):
    x = words_ref[...]                       # (bn, WORDS) uint32
    bn = x.shape[0]
    g = x.reshape(bn, WORDS // 16, 16)       # 16 circuit inputs, minor axis
    out_ref[...] = harley_seal_reduce(g)[:, None]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def popcount(words: jax.Array, *, block: int = DEFAULT_BLOCK,
             interpret: bool | None = None) -> jax.Array:
    """(N, WORDS) uint32 bitset containers -> (N,) int32 cardinalities."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = words.shape[0]
    n_pad = (-n) % block
    if n_pad:
        words = jnp.pad(words, ((0, n_pad), (0, 0)))
    grid = (words.shape[0] // block,)
    out = pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, WORDS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((words.shape[0], 1), jnp.int32),
        interpret=interpret,
    )(words)
    return out[:n, 0]
