"""Segmented wide-aggregation kernel: K-way OR/AND/XOR/threshold reductions
over bitset-promoted containers in ONE Pallas dispatch.

The paper's wide union (section 5.8, ``roaring_bitmap_or_many``) keeps an
accumulator container hot while streaming inputs through it; sections 4.1.2
and 5.9 argue the logical op and the population count must both happen while
the words are still in vector registers.  This kernel generalizes that to a
*segmented reduce*: the host planner (``repro.core.aggregate``) stacks every
container that shares a 16-bit chunk key into contiguous rows of an
``(N, WORDS)`` uint32 slab and describes the segments with a row-offset
vector ``starts`` of shape ``(S + 1,)`` (segment ``s`` owns rows
``starts[s]:starts[s+1]``).  One ``pallas_call`` then produces, per segment,
the reduced words *and* the Harley-Seal cardinality -- the popcount runs
exactly once per segment, at finalization, never per accumulation step
(the paper's "lazy" cardinality).

Grid layout: ``(S, jmax)`` where ``jmax`` is the (static) longest segment.
The inner dimension walks a segment's rows; the output block index ignores
it, so the accumulator stays resident in VMEM across the whole segment
(the standard Pallas revisited-output accumulation pattern).  Row offsets
arrive via scalar prefetch so the input index map can address ragged
segments; steps past a segment's end contribute the op identity.

``threshold`` extends the same engine to T-occurrence queries ("Threshold
and Symmetric Functions over Bitmaps", Kaser & Lemire): a bit-sliced
ripple-carry counter (one uint32 plane per counter bit, ``L = ceil(log2(
jmax + 1))`` planes in VMEM scratch) counts how many inputs set each of the
2^16 bits, and finalization runs a bitwise magnitude comparator against
``T`` -- a runtime scalar (scalar prefetch), so threshold sweeps over the
same inputs reuse one compiled kernel.  Per-row integer weights (scalar
prefetch, static bit width) generalize the counter to WEIGHTED threshold
queries via shift-and-add: weight bit ``b`` feeds the row's plane into the
counter at plane ``b``.

``andnot`` runs difference chains ``a - (b1 | b2 | ...)`` as one plan: the
minuend (each segment's first row) parks in the output block while the
subtrahends OR into a VMEM accumulator; the ANDNOT and the popcount fuse
into finalization ("Compressed bitmap indexes: beyond unions and
intersections", Kaser & Lemire).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.harley_seal import harley_seal_reduce
from repro.kernels.ref import WORDS

_FULL = np.uint32(0xFFFFFFFF)

OPS = ("or", "and", "xor", "andnot", "threshold")


def counter_planes(jmax: int) -> int:
    """Bit-sliced counter planes needed to count up to ``jmax`` inputs."""
    return max(1, int(jmax).bit_length())


def _identity(op: str):
    return _FULL if op == "and" else np.uint32(0)


def _combine(acc, x, op: str):
    if op == "or":
        return acc | x
    if op == "and":
        return acc & x
    if op == "xor":
        return acc ^ x
    raise ValueError(op)


def _finalize(words, card_ref, out_ref, seg_len):
    """Mask empty segments to zero and emit words + lazy popcount."""
    r = jnp.where(seg_len > 0, words, jnp.uint32(0))
    out_ref[...] = r
    card_ref[...] = harley_seal_reduce(r.reshape(1, WORDS // 16, 16))[:, None]


def _reduce_kernel(starts_ref, t_ref, w_ref, slab_ref, out_ref, card_ref, *,
                   op, jmax):
    s = pl.program_id(0)
    j = pl.program_id(1)
    seg_len = starts_ref[s + 1] - starts_ref[s]
    x = jnp.where(j < seg_len, slab_ref[...], _identity(op))

    @pl.when(j == 0)
    def _():
        out_ref[...] = x

    @pl.when(j > 0)
    def _():
        out_ref[...] = _combine(out_ref[...], x, op)

    @pl.when(j == jmax - 1)
    def _():
        _finalize(out_ref[...], card_ref, out_ref, seg_len)


def _andnot_kernel(starts_ref, t_ref, w_ref, slab_ref, out_ref, card_ref,
                   rest_ref, *, jmax):
    """Fused difference chain: row0 & ~(row1 | row2 | ...).

    The minuend (row 0) parks in ``out_ref`` while the subtrahends OR into
    the ``rest_ref`` VMEM accumulator; finalization masks and popcounts in
    the same pass (the planner's "OR-reduce the subtrahends, then ANDNOT
    finalize" contract)."""
    s = pl.program_id(0)
    j = pl.program_id(1)
    seg_len = starts_ref[s + 1] - starts_ref[s]
    x = jnp.where(j < seg_len, slab_ref[...], jnp.uint32(0))

    @pl.when(j == 0)
    def _():
        out_ref[...] = x
        rest_ref[...] = jnp.zeros_like(rest_ref)

    @pl.when(j > 0)
    def _():
        rest_ref[...] = rest_ref[...] | x

    @pl.when(j == jmax - 1)
    def _():
        _finalize(out_ref[...] & ~rest_ref[...], card_ref, out_ref, seg_len)


def _threshold_kernel(starts_ref, t_ref, w_ref, slab_ref, out_ref, card_ref,
                      cnt_ref, *, jmax, planes, wbits, n_rows):
    s = pl.program_id(0)
    j = pl.program_id(1)
    seg_len = starts_ref[s + 1] - starts_ref[s]

    @pl.when(j == 0)
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    # shift-and-add of one weighted input bit-plane into the bit-sliced
    # counter: weight bit b contributes the row's plane at counter plane b
    # (wbits == 1 degenerates to the unweighted ripple-carry add)
    x = jnp.where(j < seg_len, slab_ref[...], jnp.uint32(0))
    w = w_ref[jnp.minimum(starts_ref[s] + j, n_rows - 1)]
    for b in range(wbits):
        carry = jnp.where((w >> b) & 1 == 1, x, jnp.uint32(0))
        for i in range(b, planes):
            ci = cnt_ref[i]
            cnt_ref[i] = ci ^ carry
            carry = ci & carry

    @pl.when(j == jmax - 1)
    def _():
        # bitwise magnitude comparator: count >= T, MSB first.  T arrives at
        # runtime (scalar prefetch) PER SEGMENT, so threshold sweeps share
        # one compile and coalesced multi-query batches carry each query's
        # own T; its bit i becomes an all-ones/all-zeros lane mask.
        t = t_ref[s]
        gt = jnp.zeros((1, WORDS), jnp.uint32)
        eq = jnp.full((1, WORDS), _FULL)
        for i in reversed(range(planes)):
            ci = cnt_ref[i]
            tmask = jnp.where((t >> i) & 1 == 1, _FULL,
                              jnp.uint32(0))
            gt = gt | (eq & ci & ~tmask)
            eq = eq & ~(ci ^ tmask)
        _finalize(gt | eq, card_ref, out_ref, seg_len)


@functools.partial(jax.jit,
                   static_argnames=("op", "jmax", "planes", "wbits",
                                    "interpret"))
def segment_reduce(slab: jax.Array, starts: jax.Array, op: str, *,
                   jmax: int, threshold=0, weights: jax.Array | None = None,
                   planes: int | None = None, wbits: int = 1,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Segmented K-way reduction fused with cardinality.

    slab:   (N, WORDS) uint32 bitset-promoted container rows, segment-major.
    starts: (S + 1,) int32 row offsets; segment s covers rows
            starts[s]:starts[s+1] (empty segments allowed -> card 0).
    op:     "or" | "and" | "xor" | "andnot" | "threshold".  "andnot" treats
            each segment's first row as the minuend: row0 & ~OR(rest).
    jmax:   static upper bound on segment length (>= max(diff(starts))).
    threshold: T for op="threshold"; a runtime scalar (sweeping T over the
            same inputs reuses one compilation) or a (S,) int32 vector of
            per-segment thresholds -- the multi-query coalescing path,
            where every queued T-occurrence query contributes its own
            segments to one dispatch.
    weights: (N,) int32 per-row occurrence weights for op="threshold"
            (default: 1 per row).  ``wbits`` is the static bit width of the
            largest weight and ``planes`` the static counter width; both
            must satisfy max-per-segment-total-weight < 2^planes and
            t < 2^planes.

    Returns (words (S, WORDS) uint32, cards (S,) int32).
    """
    assert op in OPS, op
    assert jmax >= 1
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = slab.shape[0]
    s = starts.shape[0] - 1
    starts = starts.astype(jnp.int32)
    # (S,) per-segment thresholds; a scalar T broadcasts to every segment
    tval = jnp.broadcast_to(
        jnp.asarray(threshold, jnp.int32).reshape(-1), (s,))
    if weights is None:
        wval = jnp.ones((n,), jnp.int32)
    else:
        wval = weights.astype(jnp.int32)

    def row_index(si, j, st, tv, wv):
        return (jnp.minimum(st[si] + j, n - 1), 0)

    out_specs = [pl.BlockSpec((1, WORDS), lambda si, j, st, tv, wv: (si, 0)),
                 pl.BlockSpec((1, 1), lambda si, j, st, tv, wv: (si, 0))]
    out_shape = [jax.ShapeDtypeStruct((s, WORDS), jnp.uint32),
                 jax.ShapeDtypeStruct((s, 1), jnp.int32)]
    if op == "threshold":
        if planes is None:
            planes = counter_planes(jmax)
        kernel = functools.partial(_threshold_kernel, jmax=jmax,
                                   planes=planes, wbits=wbits, n_rows=n)
        scratch = [pltpu.VMEM((planes, 1, WORDS), jnp.uint32)]
    elif op == "andnot":
        kernel = functools.partial(_andnot_kernel, jmax=jmax)
        scratch = [pltpu.VMEM((1, WORDS), jnp.uint32)]
    else:
        kernel = functools.partial(_reduce_kernel, op=op, jmax=jmax)
        scratch = []
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(s, jmax),
        in_specs=[pl.BlockSpec((1, WORDS), row_index)],
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    words, card = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(starts, tval, wval, slab.astype(jnp.uint32))
    return words, card[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("op", "jmax", "planes", "wbits",
                                    "interpret"))
def segment_reduce_rows(table: jax.Array, ids: jax.Array, starts: jax.Array,
                        op: str, *, jmax: int, threshold=0,
                        weights: jax.Array | None = None,
                        planes: int | None = None, wbits: int = 1,
                        interpret: bool | None = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Resident-slab entry point: :func:`segment_reduce` over rows gathered
    from a device-resident ``table`` (a ``core.arena.BitmapArena`` slab,
    optionally with a per-call staged host block appended).

    ``ids`` (R,) int32 index ``table`` segment-major; pad ragged segments
    with id 0, the arena's reserved all-zero row (the op identity handling
    inside :func:`segment_reduce` masks padding anyway).  The gather runs
    on-device, so warm queries ship only ``ids``/``starts``/``threshold``
    over PCIe -- container words never leave the device.  See
    docs/MEMORY.md for the transfer accounting.
    """
    slab = jnp.take(table.astype(jnp.uint32), ids.astype(jnp.int32), axis=0)
    return segment_reduce(slab, starts, op, jmax=jmax, threshold=threshold,
                          weights=weights, planes=planes, wbits=wbits,
                          interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("op", "jmax", "planes", "wbits",
                                    "interpret"))
def segment_reduce_rows_dual(table: jax.Array, staged: jax.Array,
                             pos: jax.Array, sidx: jax.Array,
                             starts: jax.Array, op: str, *, jmax: int,
                             threshold=0,
                             weights: jax.Array | None = None,
                             planes: int | None = None, wbits: int = 1,
                             interpret: bool | None = None
                             ) -> tuple[jax.Array, jax.Array]:
    """Dual-source row-table entry point: each slot gathers
    ``table[pos] | staged[sidx]`` on-device (exactly one side of every
    slot is a real row, the other the reserved all-zero row -- OR is
    exact slot selection), then reduces with the Pallas segment kernel.

    ``table`` is a resident arena slab -- the single-device ``(cap,
    WORDS)`` layout or the sharded assembled per-shard layout
    (``core.arena.ShardSlabs.assembled``, global position
    ``(r % S) * cap_s + r // S``) -- and is NEVER copied per call;
    ``staged`` is the small per-call block of cold host rows (row 0
    zero).  Warm queries ship only ``pos``/``sidx``/``starts`` over
    PCIe."""
    slab = (jnp.take(table.astype(jnp.uint32), pos.astype(jnp.int32),
                     axis=0)
            | jnp.take(staged.astype(jnp.uint32), sidx.astype(jnp.int32),
                       axis=0))
    return segment_reduce(slab, starts, op, jmax=jmax, threshold=threshold,
                          weights=weights, planes=planes, wbits=wbits,
                          interpret=interpret)
