"""Distributed layout: mesh/axis context (ctx) + name-pattern parameter
sharding rules (sharding).  See README.md in this directory for the
spec-rule grammar and the mesh-context API."""

from repro.dist import ctx, sharding  # noqa: F401
