"""Mesh / axis context shared by the models, the wide aggregates and the
dry-run.

One mesh source of truth:

  * ``activate(mesh)`` makes a mesh current for model-side sharding
    constraints (``constrain`` / ``dp_axes`` / ``axis_sizes``) AND for
    jax's resource env, so ``with_sharding_constraint`` with bare
    ``PartitionSpec``s works on jax versions with or without
    ``jax.set_mesh``;
  * ``install_wide_mesh()`` builds ``launch.mesh.make_wide_mesh`` and
    installs it as the default mesh of every wide bitmap aggregate
    (``core.aggregate.set_default_mesh`` stores through :func:`set_wide_mesh`
    here, so the two never disagree).

Everything degrades to a no-op off-mesh: single-device tests, examples and
the serve engine call the same code paths with no mesh active and get the
identity behaviour back.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"
WIDE_AXIS = "wide"

_PURE_DP = False
_ACTIVE_MESH = None     # set by activate(); jax's resource env is fallback
_WIDE_MESH = None       # storage behind core.aggregate.set_default_mesh


# ---------------------------------------------------------------------------
# pure-dp switch (configs with pure_dp=True ignore the model axis entirely)
# ---------------------------------------------------------------------------

def set_pure_dp(flag: bool) -> None:
    """Treat every mesh axis (except ``wide``) as data-parallel: the model
    axis is never assigned to weights, activations or head plans."""
    global _PURE_DP
    _PURE_DP = bool(flag)


def pure_dp() -> bool:
    return _PURE_DP


# ---------------------------------------------------------------------------
# current mesh
# ---------------------------------------------------------------------------

def _resource_mesh():
    """The mesh jax itself considers current (``with mesh:`` blocks), or
    None.  Read at trace time, so jitted model code sees the mesh the
    dry-run lowers under.  The resource env is a private surface that has
    moved across jax versions -- fail soft to off-mesh (identity
    behaviour) rather than hard on an upgrade."""
    try:
        from jax._src import mesh as mesh_lib
        env = mesh_lib.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        return None
    return None if env.empty else env


def current_mesh():
    """The explicitly activated mesh, else jax's resource-env mesh, else
    None (off-mesh: every helper degrades to a no-op)."""
    return _ACTIVE_MESH if _ACTIVE_MESH is not None else _resource_mesh()


@contextlib.contextmanager
def activate(mesh):
    """Make ``mesh`` current for this context AND for jax's sharding
    machinery (``jax.set_mesh`` when available, the classic ``with mesh:``
    resource env otherwise)."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        if hasattr(jax, "set_mesh"):
            with jax.set_mesh(mesh):
                yield mesh
        else:
            with mesh:
                yield mesh
    finally:
        _ACTIVE_MESH = prev


def axis_sizes_of(mesh) -> dict:
    """{axis name: size} for any mesh-shaped object exposing
    ``.axis_names`` / ``.devices`` -- the one derivation shared by ctx
    and the sharding rules."""
    return dict(zip(mesh.axis_names, tuple(mesh.devices.shape)))


def dp_axes_of(mesh, pure_dp: bool) -> tuple:
    """Axes a batch dim shards over on ``mesh``: every axis except
    ``model`` / ``wide`` (all but ``wide`` under pure-dp)."""
    excl = {WIDE_AXIS} if pure_dp else {WIDE_AXIS, MODEL_AXIS}
    return tuple(a for a in mesh.axis_names if a not in excl)


def axis_sizes() -> dict:
    """{axis name: size} of the current mesh ({} off-mesh)."""
    m = current_mesh()
    return {} if m is None else axis_sizes_of(m)


def dp_axes() -> tuple:
    """:func:`dp_axes_of` on the current mesh.  Off-mesh the conventional
    ``("data",)`` is returned -- harmless, because :func:`constrain` is a
    no-op there."""
    m = current_mesh()
    if m is None:
        return ("data",)
    return dp_axes_of(m, _PURE_DP)


def model_axis_size() -> int:
    if _PURE_DP:
        return 1
    return int(axis_sizes().get(MODEL_AXIS, 1))


# ---------------------------------------------------------------------------
# model-side helpers
# ---------------------------------------------------------------------------

def attn_head_plan(hkv: int, g: int, qc: int) -> str:
    """Which flash-attention tile dim carries the model axis.

    ``"hkv"`` / ``"g"`` / ``"qc"`` name the dim to constrain; ``"auto"``
    leaves GSPMD to split the model axis jointly over (hkv, g) from the
    projection's head sharding; ``"dp"`` constrains only the batch dim
    (pure-dp, size-1 model axis, or nothing divides)."""
    ms = model_axis_size()
    if ms <= 1:
        return "dp"
    if hkv % ms == 0:
        return "hkv"
    if g % ms == 0:
        return "g"
    if (hkv * g) % ms == 0:
        return "auto"
    if qc % ms == 0:
        return "qc"
    return "dp"


def constrain(x, dims: dict):
    """``with_sharding_constraint`` x with {dim index: axis | axes tuple}.

    Off-mesh this is the identity.  Axes absent from the current mesh are
    dropped (model code names ``"model"`` unconditionally; a wide-only or
    data-only mesh simply ignores it), as are axes whose size does not
    divide the dim (GSPMD would pad; mid-model that is never worth it)
    and axes already claimed by a lower dim (under pure-dp ``dp_axes()``
    includes the model axis, so a call constraining both the batch dim
    and an explicit ``"model"`` dim must not duplicate it)."""
    m = current_mesh()
    if m is None:
        return x
    sizes = axis_sizes_of(m)
    entries: list = [None] * x.ndim
    used: set = set()
    for d in sorted(dims):
        ax = dims[d]
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        axes = tuple(a for a in axes if a in sizes and a not in used)
        n = 1
        for a in axes:
            n *= sizes[a]
        if not axes or (n > 1 and x.shape[d] % n != 0):
            continue
        used.update(axes)
        entries[d] = axes[0] if len(axes) == 1 else axes
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


# ---------------------------------------------------------------------------
# wide-aggregation mesh (shared with core.aggregate)
# ---------------------------------------------------------------------------

def set_wide_mesh(mesh) -> None:
    """Install (or clear, with None) the default mesh for every wide
    bitmap aggregate.  ``core.aggregate.set_default_mesh`` delegates here,
    so model code and bitmap code read one mesh state."""
    global _WIDE_MESH
    _WIDE_MESH = mesh


def wide_mesh():
    return _WIDE_MESH


def install_wide_mesh(n: int | None = None):
    """Build ``launch.mesh.make_wide_mesh(n)`` and install it as the wide
    aggregation default; returns the mesh.  A 1-device mesh is safe: the
    aggregates fall back to the single-dispatch path."""
    from repro.launch.mesh import make_wide_mesh
    mesh = make_wide_mesh(n)
    set_wide_mesh(mesh)
    return mesh


def resolve_wide(mesh):
    """Resolve a wide-aggregation mesh request to ``(mesh, size, axis)``.

    ``mesh=None`` falls back to the installed :func:`wide_mesh`; no mesh
    anywhere resolves to ``(None, 1, None)`` -- the single-device
    identity every sharded code path (``core.aggregate``,
    ``core.pairwise.SimilarityEngine``, ``serve.QueryServer``) degrades
    to.  A resolved mesh must be 1-D (one shard axis): the wide paths
    round-robin rows over a single axis, and a silent flatten of a 2-D
    mesh would scramble the shard <-> device mapping the arena's
    per-shard slabs key on."""
    if mesh is None:
        mesh = wide_mesh()
    if mesh is None:
        return None, 1, None
    names = getattr(mesh, "axis_names", None)
    if names is None:
        # opaque mesh-shaped stand-in (tests install sentinels): pass it
        # through untouched, size-1 -- callers that only need the mesh
        # identity keep working, sharded paths degrade to single-device
        return mesh, 1, None
    if len(names) != 1:
        raise ValueError(
            f"wide sharding needs a 1-D mesh; got axes {names!r}")
    import numpy as np
    return mesh, int(np.prod(mesh.devices.shape)), names[0]
