"""Name-pattern parameter sharding rules.

``RULES`` is an ordered list of ``(regex, dims)``.  The regex is searched
against the dotted parameter path (see :func:`path_str`); ``dims`` gives,
for each dim of the UNSTACKED leaf shape, a priority tuple of candidate
mesh axes (or None for always-replicated).  Resolution walks dims left to
right and assigns the first candidate axis that

  (a) exists in the mesh,
  (b) is not already used by an earlier dim of the same spec, and
  (c) divides the dim size exactly;

otherwise the dim stays replicated.  That single first-fit rule encodes
every fallback in one place: a 2-head KV projection drops the model axis,
an 8-expert MoE on a 16-way model axis falls through to tensor-parallel on
the ff dim, and ``pure_dp=True`` removes the model axis from every
candidate list.

Params under a scanned ``pattern.<i>.`` stack carry a leading repeats dim,
which is always replicated (the scan traverses it).  Params matching no
rule -- or matching with an unexpected rank -- are fully replicated.

Explicit ``overrides`` ({regex: PartitionSpec}) win over the rules and are
validated strictly: a spec axis that does not divide its dim raises a
ValueError naming the param, the dim and the mesh axis sizes.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.ctx import MODEL_AXIS, axis_sizes_of, dp_axes_of

DATA = ("data",)
MODEL = ("model",)

# (regex searched in the dotted path, per-dim candidate axes for the
# unstacked shape).  Order matters only where patterns overlap.
RULES: list[tuple[str, tuple]] = [
    # attention / mlstm projections (d|di, H, hd): FSDP on dim0, TP heads
    (r"mixer\.(wq|wk|wv)$", (DATA, MODEL, None)),
    (r"mixer\.wo$", (MODEL, None, DATA)),
    (r"mixer\.(bq|bk|bv)$", (MODEL, None)),
    # MLA low-rank factors
    (r"mixer\.w_dq$", (DATA, MODEL)),
    (r"mixer\.w_dkv$", (DATA, None)),
    (r"mixer\.(w_uq|w_uk|w_uv)$", (DATA, MODEL, None)),
    # SSM / xLSTM mixers
    (r"mixer\.(in_proj|up)$", (DATA, MODEL)),
    (r"mixer\.(out_proj|down)$", (MODEL, DATA)),
    (r"mixer\.x_proj$", (MODEL, None)),
    (r"mixer\.dt_proj$", (None, MODEL)),
    (r"mixer\.conv_w$", (None, MODEL)),
    (r"mixer\.(wi|wf)$", (DATA, MODEL)),
    (r"mixer\.w$", (DATA, None, MODEL, None)),    # slstm (d, 4, h, dh)
    (r"mixer\.r$", (None, MODEL, None, None)),    # slstm (4, h, dh, dh)
    # dense FFN (also MoE shared experts via ffn.shared.*)
    (r"ffn(\.shared)?\.(w_gate|w_up|w_in)$", (DATA, MODEL)),
    (r"ffn(\.shared)?\.(w_down|w_out)$", (MODEL, DATA)),
    (r"ffn\.router$", (DATA, None)),
    # MoE expert stacks: expert-parallel over the model axis when the
    # expert count divides it, else tensor-parallel on the ff dim (the
    # first-fit resolver realises the fallback)
    (r"ffn\.(wg|wu)$", (MODEL, DATA, MODEL)),     # (E, d, ff)
    (r"ffn\.wd$", (MODEL, MODEL, DATA)),          # (E, ff, d)
    # embeddings / head / frontend
    (r"^embed$", (DATA, MODEL)),
    (r"^lm_head$", (DATA, MODEL)),
    (r"^frontend_proj$", (DATA, MODEL)),
]

_STACKED = re.compile(r"(^|\.)pattern\.\d+\.")


# ---------------------------------------------------------------------------
# paths
# ---------------------------------------------------------------------------

def path_str(path) -> str:
    """Dotted string for a jax key path: dict keys, sequence indices and
    attr names join with '.' -- 'pattern.0.mixer.wq'.  Stable across
    save/load, so checkpoints key their manifests on it."""
    tu = jax.tree_util
    parts = []
    for k in path:
        if isinstance(k, tu.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, tu.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, tu.GetAttrKey):
            parts.append(str(k.name))
        elif isinstance(k, tu.FlattenedIndexKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return ".".join(parts)


# ---------------------------------------------------------------------------
# spec resolution
# ---------------------------------------------------------------------------

# works for jax.sharding.Mesh and any stand-in exposing
# .axis_names/.devices (tests use a FakeMesh; no device access needed)
_axis_sizes = axis_sizes_of


def _resolve(dims, shape, sizes, pure_dp):
    used, out = set(), []
    for cands, n in zip(dims, shape):
        pick = None
        for ax in (cands or ()):
            if pure_dp and ax == MODEL_AXIS:
                continue
            sz = sizes.get(ax)
            if not sz or ax in used or n % sz:
                continue
            pick = ax
            used.add(ax)
            break
        out.append(pick)
    return out


def _check_spec(path: str, shape, spec, sizes) -> None:
    """Strict validation for explicit specs: every named axis must exist
    and divide its dim; raises a ValueError naming the offender."""
    if len(spec) > len(shape):
        raise ValueError(
            f"param {path!r}: spec {spec} has rank {len(spec)} but the "
            f"param has rank {len(shape)} (shape {tuple(shape)})")
    seen: set = set()
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        dup = seen.intersection(axes)
        if dup:
            raise ValueError(
                f"param {path!r}: spec {spec} maps mesh axis "
                f"{sorted(dup)[0]!r} to more than one dim")
        seen.update(axes)
        n = 1
        for a in axes:
            if a not in sizes:
                raise ValueError(
                    f"param {path!r}: spec axis {a!r} is not a mesh axis "
                    f"(mesh has {tuple(sizes)!r})")
            n *= sizes[a]
        if n > 1 and shape[i] % n:
            raise ValueError(
                f"param {path!r}: dim {i} (size {shape[i]}) is not "
                f"divisible by mesh axes {axes!r} (total size {n}); "
                f"adjust the mesh shape or the spec")


def spec_for_param(path: str, shape, mesh, *, pure_dp: bool = False,
                   overrides: dict | None = None) -> P:
    """PartitionSpec for one parameter, resolved from RULES (see module
    docstring).  ``overrides`` maps path regexes to explicit specs, which
    are validated strictly (non-divisible dims raise)."""
    sizes = _axis_sizes(mesh)
    shape = tuple(shape)
    if overrides:
        for pat, spec in overrides.items():
            if re.search(pat, path):
                _check_spec(path, shape, spec, sizes)
                return spec
    stacked = bool(_STACKED.search(path))
    for pat, dims in RULES:
        if re.search(pat, path):
            if len(shape) != len(dims) + (1 if stacked else 0):
                break           # rank mismatch: leave replicated
            body = shape[1:] if stacked else shape
            entries = _resolve(dims, body, sizes, pure_dp)
            if stacked:
                entries = [None] + entries
            return P(*entries)
    return P()                  # no rule -> fully replicated


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def param_shardings(tree, mesh, *, pure_dp: bool = False,
                    overrides: dict | None = None):
    """NamedSharding tree for a parameter (or optimizer-moment) pytree."""
    def leaf(path, l):
        spec = spec_for_param(path_str(path), tuple(l.shape), mesh,
                              pure_dp=pure_dp, overrides=overrides)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, tree)


# ---------------------------------------------------------------------------
# batch / decode-state shardings
# ---------------------------------------------------------------------------

def data_axes(mesh, *, pure_dp: bool = False) -> tuple:
    """Axes a batch dim shards over: all but model/wide (all but wide
    under pure-dp) -- same derivation ``ctx.dp_axes`` applies to the
    current mesh."""
    return dp_axes_of(mesh, pure_dp)


def _batch_spec(path: str, shape, axes, sizes) -> P:
    if not shape or not axes:
        return P()
    n = 1
    for a in axes:
        n *= sizes[a]
    if n > 1 and shape[0] % n:
        raise ValueError(
            f"batch dim 0 of {path!r} (size {shape[0]}) is not divisible "
            f"by the data-parallel mesh axes {axes!r} (total size {n}); "
            f"pick a global batch that is a multiple of {n}")
    lead = axes[0] if len(axes) == 1 else axes
    return P(lead, *([None] * (len(shape) - 1)))


def batch_shardings(tree, mesh, *, pure_dp: bool = False):
    """Shard dim 0 of every batch leaf over the data-parallel axes; a
    non-divisible batch raises immediately with the axis sizes spelled
    out (silently replicating a batch is never what anyone wants)."""
    axes = data_axes(mesh, pure_dp=pure_dp)
    sizes = _axis_sizes(mesh)

    def leaf(path, l):
        return NamedSharding(
            mesh, _batch_spec(path_str(path), tuple(l.shape), axes, sizes))
    return jax.tree_util.tree_map_with_path(leaf, tree)


def decode_state_shardings(tree, mesh, *, pure_dp: bool = False):
    """Decode caches: batch dim 0 over the data axes; attention KV-cache
    leaves ('k'/'v') additionally put the model axis on their head dim
    when it divides (dim 1 unstacked, dim 2 for batch-major layer stacks,
    which have rank 5).  MLA caches ('ckv'/'kr') have no head dim -- the
    latent is shared across heads -- so only their batch dim shards."""
    axes = data_axes(mesh, pure_dp=pure_dp)
    sizes = _axis_sizes(mesh)
    msz = sizes.get(MODEL_AXIS, 0)

    def leaf(path, l):
        ps = path_str(path)
        shape = tuple(l.shape)
        spec = _batch_spec(ps, shape, axes, sizes)
        name = ps.rsplit(".", 1)[-1]
        if (not pure_dp and msz > 1 and name in ("k", "v")
                and len(shape) in (4, 5)):
            hd = 1 if len(shape) == 4 else 2
            if shape[hd] % msz == 0:
                entries = list(spec) + [None] * (len(shape) - len(spec))
                entries[hd] = MODEL_AXIS
                spec = P(*entries)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(leaf, tree)
