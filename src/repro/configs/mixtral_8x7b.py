"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) ff=14336 vocab=32000,
8 experts top-2, sliding-window attention [arXiv:2401.04088].

SWA makes decode cost O(window) per token -- natively sub-quadratic, so
long_500k runs (DESIGN.md sec 8)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000,
        pattern=(("local", "moe"),),
        n_experts=8, moe_top_k=2, moe_d_ff=14336,
        sliding_window=4096,
        rope_theta=1e6,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-reduced", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512,
        pattern=(("local", "moe"),),
        n_experts=4, moe_top_k=2, moe_d_ff=256,
        sliding_window=64,
        attn_q_chunk=64, attn_k_chunk=64,
    )
