"""Assigned architectures x input shapes (see the assignment block).

Each ``repro.configs.<arch_id>`` module exposes ``config()`` (the exact
published configuration) and ``reduced()`` (a small same-family config for
CPU smoke tests).  This package adds the shape grid, applicability rules
(DESIGN.md section 8) and ShapeDtypeStruct input specs for the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCH_IDS = (
    "qwen2_vl_72b",
    "gemma2_27b",
    "stablelm_3b",
    "qwen2_5_3b",
    "qwen3_14b",
    "deepseek_v2_236b",
    "mixtral_8x7b",
    "xlstm_350m",
    "jamba_v01_52b",
    "hubert_xlarge",
)

# CLI-friendly aliases (--arch qwen2-vl-72b etc.)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({"qwen2.5-3b": "qwen2_5_3b", "jamba-v0.1-52b": "jamba_v01_52b"})


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced() if reduced else mod.config()


def all_configs(reduced: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# applicability (DESIGN.md section 8 / assignment skip rules)
# ---------------------------------------------------------------------------

def applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    spec = SHAPES[shape]
    if cfg.is_encoder and spec.step == "decode":
        return False, "encoder-only architecture has no decode step"
    if shape == "long_500k" and cfg.full_attention_only:
        return False, ("pure full-attention architecture: long_500k needs "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""


def grid(reduced: bool = False):
    """All 40 (arch, shape) cells with applicability annotations."""
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a, reduced)
        for s in SHAPES:
            ok, why = applicable(cfg, s)
            cells.append((a, s, ok, why))
    return cells


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Model *data* inputs for the given shape's step function."""
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    if spec.step == "train":
        batch = {}
        s_text = s - cfg.n_frontend_tokens
        if cfg.frontend == "none":
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        elif cfg.frontend == "vision_stub":
            fd = cfg.frontend_dim or cfg.d_model
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, fd), jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
            batch["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
        else:  # audio_stub: pure embedding input
            fd = cfg.frontend_dim or cfg.d_model
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, s, fd), jnp.bfloat16)
            batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return batch
    if spec.step == "prefill":
        batch = {}
        if cfg.frontend == "audio_stub":
            fd = cfg.frontend_dim or cfg.d_model
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, s, fd), jnp.bfloat16)
        elif cfg.frontend == "vision_stub":
            fd = cfg.frontend_dim or cfg.d_model
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, fd), jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct(
                (b, s - cfg.n_frontend_tokens), i32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return batch
    # decode: one new token over a seq_len-deep KV/state cache
    out = {"tokens": jax.ShapeDtypeStruct((b,), i32)}
    if cfg.roaring_sparse_global and cfg.has_attention:
        n_blocks = s // cfg.attn_block_size
        out["block_mask_words"] = jax.ShapeDtypeStruct(
            (b, max(1, (n_blocks + 31) // 32)), jnp.uint32)
    return out


def decode_state_specs(cfg: ModelConfig, shape: str):
    from repro.models import transformer as T
    spec = SHAPES[shape]
    return jax.eval_shape(
        lambda: T.init_decode_state(cfg, spec.global_batch, spec.seq_len))
