"""hubert-xlarge [audio]: 48L d=1280 16H ff=5120 vocab=504 encoder-only
(w2v2 arch) [arXiv:2106.07447; unverified tier].

Encoder-only: decode_32k and long_500k are skipped per the assignment; the
audio frontend is a STUB (input_specs feeds precomputed 512-dim conv-frame
embeddings).  Training is masked-unit prediction over the 504-unit
codebook."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab=504,
        pattern=(("enc", "mlp"),),
        norm="layernorm", norm_eps=1e-5, act="gelu",
        frontend="audio_stub", frontend_dim=512,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-reduced", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=64,
        pattern=(("enc", "mlp"),),
        norm="layernorm", norm_eps=1e-5, act="gelu",
        frontend="audio_stub", frontend_dim=48,
        attn_q_chunk=64, attn_k_chunk=64,
    )
