"""qwen2.5-3b [dense]: 36L d=2048 16H (GQA kv=2) ff=11008 vocab=151936,
GQA + QKV bias, tied embeddings [hf:Qwen/Qwen2.5]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab=151936,
        pattern=(("full", "mlp"),),
        rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-reduced", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512,
        pattern=(("full", "mlp"),),
        rope_theta=1e6, qkv_bias=True, tie_embeddings=True,
        attn_q_chunk=64, attn_k_chunk=64,
    )
