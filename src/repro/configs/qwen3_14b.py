"""qwen3-14b [dense]: 40L d=5120 40H (GQA kv=8) ff=17408 vocab=151936,
qk_norm + GQA [hf:Qwen/Qwen3].

A beyond-paper `+roaring-sparse` variant (roaring_sparse_global=True on the
full-attention mixers promoted to 'global') is dry-run as a demo of applying
the paper's block-mask technique to a full-attention arch -- see
EXPERIMENTS.md sec Perf."""

import dataclasses

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936, head_dim=128,
        pattern=(("full", "mlp"),),
        rope_theta=1e6, qk_norm=True,
    )


def roaring_sparse_variant() -> ModelConfig:
    base = config()
    return dataclasses.replace(
        base, name="qwen3-14b+roaring-sparse",
        pattern=(("global", "mlp"),), roaring_sparse_global=True)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-reduced", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, head_dim=32,
        pattern=(("full", "mlp"),),
        rope_theta=1e6, qk_norm=True,
        attn_q_chunk=64, attn_k_chunk=64,
    )
