"""xlstm-350m [ssm]: 24L d=1024 4 heads vocab=50304, alternating
mLSTM / sLSTM blocks, no FFN (d_ff=0) [arXiv:2405.04517; unverified tier].

Attention-free: the paper's block-mask technique is inapplicable at the
attention layer (DESIGN.md sec 8 Arch-applicability); the data-pipeline /
constrained-decoding Roaring integrations still apply.  O(1) decode state
-> long_500k runs."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        pattern=(("mlstm", "none"), ("slstm", "none")),
        xlstm_heads=4, ssm_expand=2,
        xlstm_chunk=64,   # chunkwise-parallel mLSTM (EXPERIMENTS.md sec Perf)
        pure_dp=True,     # 350M params: TP would cost more than it saves
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-reduced", family="ssm",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=512,
        pattern=(("mlstm", "none"), ("slstm", "none")),
        xlstm_heads=4, ssm_expand=2,
    )
