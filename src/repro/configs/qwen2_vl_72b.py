"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) ff=29568 vocab=152064,
M-RoPE, dynamic resolution [arXiv:2409.12191].  Vision frontend is a STUB
per the assignment: input_specs feeds precomputed patch embeddings."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        pattern=(("full", "mlp"),),
        rope_theta=1e6, qkv_bias=True,
        m_rope_sections=(16, 24, 24),
        frontend="vision_stub", n_frontend_tokens=256, frontend_dim=1280,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-reduced", family="vlm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, head_dim=32,
        pattern=(("full", "mlp"),),
        rope_theta=1e6, qkv_bias=True,
        m_rope_sections=(4, 6, 6),
        frontend="vision_stub", n_frontend_tokens=8, frontend_dim=48,
        attn_q_chunk=64, attn_k_chunk=64,
    )
