"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) ff=36864 vocab=256000,
local+global alternating attention, logit softcaps [arXiv:2408.00118].

Long-context note (DESIGN.md sec 8): local layers are natively sliding-window;
global layers consume Roaring block-sparse masks at decode, making long_500k
sub-quadratic -- the paper-technique integration path."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
        d_ff=36864, vocab=256000, head_dim=128,
        pattern=(("local", "mlp"), ("global", "mlp")),
        rope_theta=10000.0,
        attn_softcap=50.0, final_softcap=30.0,
        sliding_window=4096,
        post_block_norms=True, scale_embed=True,
        tie_embeddings=True, act="geglu",
        roaring_sparse_global=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-reduced", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, head_dim=32,
        pattern=(("local", "mlp"), ("global", "mlp")),
        attn_softcap=50.0, final_softcap=30.0,
        sliding_window=64,
        post_block_norms=True, scale_embed=True,
        tie_embeddings=True, act="geglu",
        roaring_sparse_global=True,
        attn_q_chunk=64, attn_k_chunk=64,
    )
