"""stablelm-3b [dense]: 32L d=2560 32H (MHA kv=32) ff=6912 vocab=50304
[hf:stabilityai/stablelm; unverified tier].  LayerNorm, standard RoPE."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304,
        pattern=(("full", "mlp"),),
        norm="layernorm", norm_eps=1e-5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-reduced", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=512,
        pattern=(("full", "mlp"),),
        norm="layernorm", norm_eps=1e-5,
        attn_q_chunk=64, attn_k_chunk=64,
    )
