"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) ff=14336 vocab=65536,
Mamba + attention 1:7 interleave, 16-expert top-2 MoE every other layer
[arXiv:2403.19887].

The single attention layer per 8-layer period is a 'global' mixer consuming
Roaring block-sparse masks at decode; mamba layers carry O(1) state ->
long_500k runs sub-quadratically (DESIGN.md sec 8)."""

from repro.models.config import ModelConfig

_PERIOD = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ("global", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536,
        pattern=_PERIOD,
        n_experts=16, moe_top_k=2, moe_d_ff=14336,
        ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
        roaring_sparse_global=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-reduced", family="hybrid",
        n_layers=8, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512,
        pattern=_PERIOD,
        n_experts=4, moe_top_k=2, moe_d_ff=256,
        ssm_d_state=8, ssm_d_conv=4, ssm_expand=2,
        roaring_sparse_global=True,
        attn_q_chunk=64, attn_k_chunk=64,
    )
