"""deepseek-v2-236b [moe]: 60L d=5120 128H ff(expert)=1536 vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed experts top-6; first layer dense
[arXiv:2405.04434]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab=102400,
        prefix=(("mla", "mlp"),),
        pattern=(("mla", "moe"),),
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=160, moe_top_k=6, n_shared_experts=2,
        moe_d_ff=1536, dense_d_ff=12288,
        rope_theta=10000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-reduced", family="moe",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=512,
        prefix=(("mla", "mlp"),),
        pattern=(("mla", "moe"),),
        q_lora_rank=48, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=8, moe_top_k=2, n_shared_experts=1,
        moe_d_ff=64, dense_d_ff=256,
        attn_q_chunk=64, attn_k_chunk=64,
    )
