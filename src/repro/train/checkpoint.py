"""Fault-tolerant checkpointing: atomic, versioned, checksummed, async.

Design points for 1000-node operation (DESIGN.md section 6):
  * atomic publish -- write to `step_XXXX.tmp/`, fsync, rename; a crash
    mid-save can never corrupt the latest visible checkpoint;
  * content checksums -- every leaf's sha256 is recorded in the manifest and
    verified on restore; a corrupt checkpoint falls back to the previous one
    (restore_with_retry);
  * async save -- the pytree is snapshotted to host memory synchronously
    (cheap) and written by a background thread so the train loop never
    blocks on storage;
  * mesh-shape independence -- leaves are saved as full (unsharded) arrays,
    so restore works onto ANY mesh: this is what elastic re-scaling
    (train/elastic.py) relies on.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    from repro.dist.sharding import path_str
    return [(path_str(p), np.asarray(v)) for p, v in flat[0]], flat[1]


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None,
             async_: bool = False):
        """Snapshot to host memory now; write atomically (optionally in the
        background)."""
        leaves, _ = _flatten(tree)          # device->host copy happens here
        if async_:
            self.wait()                      # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, leaves, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, leaves, extra: dict):
        try:
            tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
            final = os.path.join(self.dir, f"step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "extra": extra, "leaves": {}}
            arrays = {}
            for i, (path, arr) in enumerate(leaves):
                key = f"leaf_{i:05d}"
                arrays[key] = arr
                manifest["leaves"][key] = {
                    "path": path, "shape": list(arr.shape),
                    "dtype": str(arr.dtype), "sha": _sha(arr)}
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic publish
            self._gc()
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, tree_like, strict_checksum: bool = True):
        """Restore into the structure of `tree_like` (shapes must match).
        Returns (tree, extra)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        by_path = {}
        for key, meta in manifest["leaves"].items():
            arr = data[key]
            if strict_checksum and _sha(arr) != meta["sha"]:
                raise IOError(f"checksum mismatch in {d}: {meta['path']}")
            by_path[meta["path"]] = arr
        flat = jax.tree_util.tree_flatten_with_path(tree_like)
        from repro.dist.sharding import path_str
        leaves = []
        for p, ref in flat[0]:
            ps = path_str(p)
            if ps not in by_path:
                raise KeyError(f"checkpoint missing leaf {ps}")
            arr = by_path[ps]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {ps}: ckpt {arr.shape} vs "
                    f"model {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
        return jax.tree_util.tree_unflatten(flat[1], leaves), \
            manifest["extra"]

    def restore_with_retry(self, tree_like):
        """Restore the newest valid checkpoint, falling back across corrupt
        versions (node-failure survival path).  Returns
        (step, tree, extra) or None."""
        for step in reversed(self.all_steps()):
            try:
                tree, extra = self.restore(step, tree_like)
                return step, tree, extra
            except Exception:
                continue
        return None
