"""The training loop: data -> jit step -> metrics -> checkpoints, with
fault-tolerance wiring (resume, straggler policy hooks, pipeline state).
Runs end-to-end on CPU with reduced configs (examples/train_tiny_lm.py)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import RoaringDataPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import train_step as TS
from repro.train.checkpoint import CheckpointManager


class Trainer:
    def __init__(self, cfg, opt_cfg: adamw.AdamWConfig,
                 pipeline: RoaringDataPipeline,
                 ckpt_dir: str, ckpt_every: int = 50,
                 async_ckpt: bool = True, seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.pipeline = pipeline
        self.ckpt = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.async_ckpt = async_ckpt
        self.params = T.init_params(cfg, jax.random.key(seed))
        self.opt_state = adamw.init_state(self.params)
        self.step = 0
        self._jit_step = jax.jit(TS.make_train_step(cfg, opt_cfg))
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def maybe_resume(self) -> bool:
        """Restore the newest valid checkpoint if present (crash recovery)."""
        found = self.ckpt.restore_with_retry(
            {"params": self.params, "opt": self.opt_state})
        if found is None:
            return False
        step, tree, extra = found
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        if "pipeline" in extra:
            import base64
            st = dict(extra["pipeline"])
            st["seen"] = base64.b64decode(st["seen"])
            st["keep"] = base64.b64decode(st["keep"])
            self.pipeline.load_state_dict(st)
        return True

    def _save(self):
        import base64
        pstate = self.pipeline.state_dict()
        pstate["seen"] = base64.b64encode(pstate["seen"]).decode()
        pstate["keep"] = base64.b64encode(pstate["keep"]).decode()
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"pipeline": pstate},
                       async_=self.async_ckpt)

    # ------------------------------------------------------------------
    def train(self, n_steps: int, log_every: int = 10) -> list[dict]:
        for _ in range(n_steps):
            batch_np = self.pipeline.next_batch()
            batch = {"tokens": jnp.asarray(batch_np["tokens"]),
                     "labels": jnp.asarray(batch_np["labels"])}
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at {self.step}")
            self.step += 1
            rec = {"step": self.step, "loss": loss,
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]),
                   "sec": time.monotonic() - t0}
            self.history.append(rec)
            if self.step % log_every == 0:
                print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                      f"gnorm {rec['grad_norm']:.3f} lr {rec['lr']:.2e} "
                      f"{rec['sec'] * 1e3:.0f} ms")
            if self.step % self.ckpt_every == 0:
                self._save()
        self.ckpt.wait()
        return self.history
