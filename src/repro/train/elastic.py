"""Elastic scaling: replan the mesh when hosts join/leave, re-shard state.

Checkpoints store full (unsharded) arrays (train/checkpoint.py), so
re-sharding after a topology change is: plan a new mesh from the surviving
chip count, rebuild NamedShardings with the same rules engine, and
device_put the restored pytree -- no format migration.  `plan_mesh` keeps
the model axis fixed (TP degree is a property of the model, not the fleet)
and gives the remainder to data/pod axes, dropping stragglers to the
largest usable power-of-two-friendly shape.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    used_chips: int
    idle_chips: int


def plan_mesh(available_chips: int, model_parallel: int = 16,
              chips_per_pod: int = 256) -> MeshPlan:
    """Largest usable mesh with a fixed model axis."""
    if available_chips < model_parallel:
        raise ValueError(
            f"need >= {model_parallel} chips for TP={model_parallel}")
    if available_chips >= 2 * chips_per_pod:
        pods = available_chips // chips_per_pod
        data = chips_per_pod // model_parallel
        shape = (pods, data, model_parallel)
        names = ("pod", "data", "model")
    else:
        data = available_chips // model_parallel
        shape = (data, model_parallel)
        names = ("data", "model")
    used = int(np.prod(shape))
    return MeshPlan(shape, names, used, available_chips - used)


def make_mesh_from_plan(plan: MeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    return jax.make_mesh(plan.shape, plan.axis_names,
                         devices=devices[:plan.used_chips])


def reshard(tree, shardings):
    """Place a (host or differently-sharded) pytree onto new shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)


def rebatch_plan(global_batch: int, old_dp: int, new_dp: int) -> dict:
    """Keep the global batch (approximately) constant across elastic events
    by adjusting the per-replica microbatch, adding gradient accumulation
    when the new replica count would otherwise need a bigger-than-before
    microbatch (memory-safe).  The effective batch rounds UP to the nearest
    achievable size; it never shrinks."""
    old_per = max(1, global_batch // max(old_dp, 1))
    accum = 1
    while True:
        per = -(-global_batch // (new_dp * accum))   # ceil
        if per <= old_per or accum >= global_batch:
            break
        accum += 1
    return {"per_replica_batch": per, "grad_accum": accum,
            "effective_batch": per * new_dp * accum}
