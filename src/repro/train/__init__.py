"""repro.train"""
