"""The jit-compiled training step: loss -> grads -> clip -> AdamW update.

This is the function the multi-pod dry-run lowers for every train_4k cell.
Signature kept flat so in_shardings/out_shardings line up 1:1:

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import adamw


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig):
    def loss_fn(params, batch):
        loss, metrics = T.loss_and_metrics(params, batch, cfg)
        return loss, metrics

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "ce_loss": metrics["ce_loss"].astype(jnp.float32),
            "router_aux": metrics["router_aux"].astype(jnp.float32),
            "grad_norm": opt_metrics["grad_norm"],
            "lr": opt_metrics["lr"],
        }
        return params, opt_state, out_metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = T.loss_and_metrics(params, batch, cfg)
        return {"loss": loss.astype(jnp.float32),
                "tokens": metrics["tokens"]}
    return eval_step
