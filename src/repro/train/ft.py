"""Failure detection and straggler mitigation (host-side control plane).

On a real 1000-node fleet these run on the coordinator; the logic is pure
and unit-tested here:

  * HeartbeatMonitor -- hosts report heartbeats; a host silent for longer
    than `timeout_s` is declared failed, triggering elastic replanning
    (train/elastic.py) + checkpoint restore (train/checkpoint.py).
  * StragglerPolicy  -- tracks per-host step durations with an EWMA; hosts
    slower than `ratio` x the fleet median for `patience` consecutive steps
    are flagged.  The mitigation is deadline-skip: the flagged host's
    microbatch is dropped for the step and the gradient denominator is
    adjusted (`scale_for_skipped`), which bounds step latency by the
    non-straggler max -- the standard large-fleet trick.
"""

from __future__ import annotations

import dataclasses
import statistics


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 30.0
    _last: dict = dataclasses.field(default_factory=dict)

    def beat(self, host: str, now: float):
        self._last[host] = now

    def failed_hosts(self, now: float) -> list[str]:
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive_hosts(self, now: float) -> list[str]:
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout_s)


@dataclasses.dataclass
class StragglerPolicy:
    ratio: float = 1.8          # flag when slower than ratio x median
    patience: int = 3           # for this many consecutive steps
    ewma: float = 0.5
    _dur: dict = dataclasses.field(default_factory=dict)
    _strikes: dict = dataclasses.field(default_factory=dict)

    def observe(self, host: str, step_seconds: float):
        prev = self._dur.get(host)
        self._dur[host] = step_seconds if prev is None else \
            self.ewma * step_seconds + (1 - self.ewma) * prev

    def stragglers(self) -> list[str]:
        if len(self._dur) < 2:
            return []
        med = statistics.median(self._dur.values())
        out = []
        for host, d in self._dur.items():
            if d > self.ratio * med:
                self._strikes[host] = self._strikes.get(host, 0) + 1
            else:
                self._strikes[host] = 0
            if self._strikes.get(host, 0) >= self.patience:
                out.append(host)
        return sorted(out)

    @staticmethod
    def scale_for_skipped(n_total: int, n_skipped: int) -> float:
        """Gradient rescale when skipping stragglers' microbatches: the mean
        over contributing shards stays unbiased."""
        contributing = max(n_total - n_skipped, 1)
        return n_total / contributing
