"""Analytics scenario: an inverted index over synthetic postings lists --
the paper's home application (Druid/Lucene-style predicate algebra).

    PYTHONPATH=src python examples/analytics_index.py
"""

import time

import numpy as np

from repro.core import RoaringBitmap
from repro.data.index import InvertedIndex
from repro.data.synth import TABLE3, generate_dataset


def main():
    rng = np.random.default_rng(1)
    n_docs, n_terms = 20_000, 120
    zipf = (1.0 / np.arange(1, n_terms + 1)) ** 0.8
    zipf /= zipf.sum()
    docs = [[f"t{t}" for t in rng.choice(n_terms, size=rng.integers(5, 30),
                                         p=zipf, replace=False)]
            for _ in range(n_docs)]
    t0 = time.perf_counter()
    idx = InvertedIndex().build(docs).optimize()
    print(f"indexed {n_docs} docs / {len(idx.postings)} terms "
          f"in {time.perf_counter() - t0:.2f}s, "
          f"{idx.memory_bytes() / 1024:.0f} kB of postings")

    q = ("t0", "t1", "t2")
    t0 = time.perf_counter()
    hits_and = idx.query_and(*q)
    hits_or = idx.query_or(*q)
    dt = (time.perf_counter() - t0) * 1e3
    print(f"AND({q}) = {hits_and.cardinality} docs; "
          f"OR = {hits_or.cardinality} docs  [{dt:.2f} ms]")
    print(f"jaccard(t0, t1) = {idx.jaccard('t0', 't1'):.4f} "
          "(count-only, never materialized)")
    # difference chain: one fused plan, the union of the dropped postings
    # is never materialized
    excl = idx.query_andnot("t0", "t1", "t2", "t3")
    print(f"t0 AND NOT (t1 OR t2 OR t3) = {excl.cardinality} docs")

    # T-occurrence query: documents matching at least T of K terms, answered
    # by the segmented wide-aggregation kernel in a single dispatch (the
    # threshold function of Kaser & Lemire); T is a runtime scalar, so the
    # whole sweep shares one compiled kernel
    terms = [f"t{i}" for i in range(8)]
    for t_min in (2, 4, 6):
        hits = idx.query_threshold(terms, t_min)
        print(f">= {t_min} of {len(terms)} terms: {hits.cardinality} docs")
    t0 = time.perf_counter()
    for t_min in (2, 4, 6):
        idx.query_threshold(terms, t_min)
    dt = (time.perf_counter() - t0) * 1e3
    print(f"three warm threshold sweeps over K={len(terms)} terms "
          f"in {dt:.2f} ms (one kernel dispatch each)")

    # weighted variant: rare terms score higher; same counter circuit
    weights = [3 if i >= 4 else 1 for i in range(len(terms))]
    hits = idx.query_threshold(terms, 6, weights=weights)
    print(f"weighted score >= 6 over {len(terms)} terms "
          f"(rare terms x3): {hits.cardinality} docs")

    # top-k similarity: "which terms co-occur most with t0?"  The first
    # call builds the SimilarityEngine's candidate slab (every posting
    # list promoted to bitset rows, cached across queries); each query is
    # then ONE fused score+select dispatch on kernel backends, or a
    # bound-pruned popcount sweep on CPU -- candidates whose cardinality
    # bound cannot reach the running k-th score are never touched.  All
    # three metrics derive from the AND count by inclusion-exclusion.
    t0 = time.perf_counter()
    top = idx.similar("t0", top_k=5)                   # builds the slab
    build_ms = (time.perf_counter() - t0) * 1e3
    print("top-5 jaccard neighbours of t0: "
          + ", ".join(f"{t}={s:.4f}" for t, s in top))
    t0 = time.perf_counter()
    for term in ("t0", "t1", "t2", "t3"):
        idx.similar(term, top_k=5, metric="cosine")
    warm_ms = (time.perf_counter() - t0) * 1e3
    print(f"similar(): slab build+query {build_ms:.2f} ms, then 4 warm "
          f"cosine queries in {warm_ms:.2f} ms (cached slab, one "
          "dispatch each on kernel backends)")

    # device-resident arena (core/arena.py, docs/MEMORY.md): promote the
    # postings ONCE into a warm slab, then every query moves only row
    # ids and results -- never container payloads.  A postings edit
    # repatches just the affected rows (one scatter) instead of
    # rebuilding the slab.
    from repro.core.arena import BitmapArena

    warm = InvertedIndex(arena=BitmapArena()).build(docs).optimize()
    warm.arena.adopt_many(warm.postings.values())   # promote whole index
    hits = warm.query_or(*q)                        # uploads once
    st = warm.arena.stats
    up0, staged0 = st.rows_uploaded, st.host_rows_staged
    t0 = time.perf_counter()
    for _ in range(5):
        assert warm.query_or(*q) == hits
    dt = (time.perf_counter() - t0) * 1e3
    print(f"arena: {warm.arena.n_rows} resident rows; 5 warm OR queries "
          f"in {dt:.2f} ms, rows uploaded since warm: "
          f"{st.rows_uploaded - up0}, staged: "
          f"{st.host_rows_staged - staged0}")     # both 0: zero-transfer
    warm.add_document(n_docs, ["t0", "t5"])       # postings edit
    warm.query_or(*q)                             # revalidates lazily
    print(f"one document added: {st.rows_patched} row(s) repatched via "
          f"one scatter (vs re-uploading all {warm.arena.n_rows} rows); "
          f"OR result now {warm.query_or(*q).cardinality} docs")

    # sharded similarity (docs/ARCHITECTURE.md "Sharded similarity
    # top-k"): hand similar() a 1-D ("wide",) mesh and the arena
    # round-robins its rows into per-shard slabs -- each device scores
    # its own candidates with the fused kernel, all-gathers only the
    # k-lists, and merges to the global top-k on device.  Warm sharded
    # queries move only ids over PCIe; every per-shard ArenaStats
    # counter below stays flat across re-queries.  On a 1-device mesh
    # (plain CI) the engine degrades to the single-device path -- same
    # results, so this walkthrough runs anywhere.  Force shards with
    # XLA_FLAGS=--xla_force_host_platform_device_count=4.
    import jax

    from repro.launch.mesh import make_wide_mesh

    n_dev = min(4, jax.device_count())
    mesh = make_wide_mesh(n_dev)
    top = warm.similar("t0", top_k=5, mesh=mesh)      # builds shard slabs
    assert [t for t, _ in top] == \
        [t for t, _ in warm.similar("t0", top_k=5)]   # bit-identical
    if n_dev > 1:
        shards = warm.arena.shard_slabs(mesh)
        up0 = [s.rows_uploaded for s in shards.stats]
        warm.similar("t1", top_k=5, metric="cosine", mesh=mesh)  # warm
        n_rows = warm.arena.n_rows
        for s, stat in enumerate(shards.stats):
            owned = (n_rows - s + n_dev - 1) // n_dev  # rows r%S == s
            print(f"shard {s}: rows={owned} "
                  f"uploaded={stat.rows_uploaded} "
                  f"patched={stat.rows_patched} "
                  f"gathers={stat.device_gathers}")
        moved = sum(s.rows_uploaded for s in shards.stats) - sum(up0)
        print(f"sharded similar() over {n_dev} devices: warm re-query "
              f"moved {moved} container rows host->device (ids only)")
    else:
        print("sharded similar(): 1 visible device -- degraded to the "
              "single-device path (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=4 to shard)")

    # save / mmap / serve (docs/FORMAT.md): stream the postings into a
    # frozen snapshot archive on disk, then cold-start a server from it.
    # Opening maps the file read-only -- posting lists are numpy views
    # over the mapped buffer, materialized lazily on first touch -- so
    # the open cost is one entry-table scan, not a full parse.
    import os
    import tempfile

    from repro.data.index import load_index
    from repro.data.pipeline import StreamingIndexBuilder

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "analytics.snap")
        t0 = time.perf_counter()
        builder = StreamingIndexBuilder(path, segment_bytes=1 << 20)
        for doc_id, doc_terms in enumerate(docs):
            builder.add_document(doc_id, doc_terms)
        builder.finalize()
        dt = (time.perf_counter() - t0) * 1e3
        print(f"streamed {n_docs} docs into {path.split('/')[-1]} "
              f"({os.path.getsize(path) / 1024:.0f} kB) in {dt:.0f} ms")

        # serve lazily: only the 3 queried posting lists materialize
        t0 = time.perf_counter()
        served = load_index(path)                 # mmap, zero parse
        lazy_hits = served.query_or(*q)
        dt = (time.perf_counter() - t0) * 1e3
        assert lazy_hits == hits_or
        print(f"mmap open + first OR query in {dt:.2f} ms "
              f"(lazy: {len(q)} of {len(served.postings)} posting "
              "lists materialized)")

        # or serve device-warm: one batched promotion of the whole
        # snapshot into an arena slab; sync() performs the single
        # host->device transfer the promotion staged
        served_warm = load_index(path, arena=BitmapArena())
        served_warm.arena.sync()
        st = served_warm.arena.stats
        print(f"arena cold-start: rows_uploaded = {st.rows_uploaded} "
              "(whole snapshot, one bulk transfer)")
        up0 = st.rows_uploaded
        assert served_warm.query_or(*q) == hits_or
        print(f"first query after promotion: rows uploaded since = "
              f"{st.rows_uploaded - up0} (already device-resident)")

    # run the same predicates over a Table-3 twin dataset
    sets, universe = generate_dataset(TABLE3[0], seed=0)[:50], \
        TABLE3[0].universe
    bms = [RoaringBitmap.from_values(s).run_optimize() for s in sets]
    wide = RoaringBitmap.or_many(bms)
    print(f"census twin: union of 50 postings lists -> "
          f"{wide.cardinality} ids at {wide.bits_per_value():.2f} bits/value")


if __name__ == "__main__":
    main()
