"""Quickstart: Roaring bitmaps on host and device in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import RoaringBitmap, serialize, deserialize
from repro.core.tensor import RoaringTensor


def main():
    rng = np.random.default_rng(0)

    # --- host path: the paper's data structure -------------------------
    a = RoaringBitmap.from_values(rng.integers(0, 1 << 24, 500_000))
    b = RoaringBitmap.from_range(1 << 20, (1 << 20) + 2_000_000)
    b = b.run_optimize()
    print("a:", a)
    print("b:", b)
    print("|a & b| =", a.and_card(b), " (count-only, sec 5.9)")
    print("jaccard =", round(a.jaccard(b), 5))
    u = a | b
    print("union:", u, f"-> {u.bits_per_value():.2f} bits/value "
          f"(uncompressed bitset would be "
          f"{(1 << 24) / u.cardinality:.1f})")
    wire = serialize(u)
    assert deserialize(wire) == u
    print(f"serialized: {len(wire)} bytes")

    # --- device path: batched, jit-compiled set algebra ----------------
    xs = [RoaringBitmap.from_values(rng.integers(0, 1 << 19, 50_000))
          for _ in range(8)]
    ys = [RoaringBitmap.from_values(rng.integers(0, 1 << 19, 50_000))
          for _ in range(8)]
    tx = RoaringTensor.from_bitmaps(xs, capacity=10)
    ty = RoaringTensor.from_bitmaps(ys, capacity=10)

    @jax.jit
    def batched_jaccard(x, y):
        return x.jaccard(y)

    print("batched device jaccard:",
          np.round(np.asarray(batched_jaccard(tx, ty)), 4))

    # --- the Pallas kernel layer (validated in interpret mode on CPU) --
    from repro.kernels.harley_seal import popcount
    import jax.numpy as jnp
    words = jnp.asarray(
        rng.integers(0, 1 << 32, (4, 2048), dtype=np.uint32))
    print("harley-seal popcount:", np.asarray(
        popcount(words, interpret=True)))


if __name__ == "__main__":
    main()
