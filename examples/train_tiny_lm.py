"""End-to-end driver: train a ~10M-param qwen2.5-family model for a few
hundred steps on CPU with the full production substrate -- Roaring data
pipeline, AdamW, async atomic checkpoints, crash resume.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
"""

import argparse
import dataclasses

import numpy as np

import repro.configs as C
from repro.data.pipeline import RoaringDataPipeline, quality_filter
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = C.get_config("qwen2_5_3b", reduced=True)
    cfg = dataclasses.replace(cfg, d_model=256, n_layers=4, d_ff=1024,
                              vocab=2048, n_heads=8, n_kv_heads=2)
    print(f"model: {cfg.name} ~{cfg.params_count() / 1e6:.1f}M params")

    rng = np.random.default_rng(0)
    scores = rng.random(4096)
    pipe = RoaringDataPipeline(
        n_docs=4096, seq_len=128, batch_size=16, vocab=cfg.vocab, seed=0,
        filters={"quality": quality_filter(scores, 0.2)})
    print(f"pipeline: {pipe.keep.cardinality}/4096 docs pass the "
          "roaring quality filter")

    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                      weight_decay=0.01)
    tr = Trainer(cfg, opt, pipe, args.ckpt_dir, ckpt_every=50)
    if args.resume and tr.maybe_resume():
        print(f"resumed from step {tr.step}")
    hist = tr.train(args.steps, log_every=20)
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    print(f"loss: {first:.3f} -> {last:.3f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
