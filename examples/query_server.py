"""Continuous query serving: a warm ``QueryServer`` coalescing a mixed
boolean + similarity workload into per-op-class slab dispatches, with
admission control, deadlines, and fault-injected degradation to the
bit-identical host planner.

    PYTHONPATH=src python examples/query_server.py
"""

import numpy as np

from repro.data.index import InvertedIndex
from repro.serve import (OK, FaultInjector, Query, QueryServer)


def main():
    rng = np.random.default_rng(3)
    n_terms = 48
    vocab = [f"t{i}" for i in range(n_terms)]
    docs = [[vocab[j] for j in
             rng.choice(n_terms, size=int(rng.integers(3, 12)),
                        replace=False)]
            for _ in range(5_000)]
    ix = InvertedIndex().build(docs)
    print(f"indexed {ix.n_docs} docs / {len(ix.postings)} terms")

    # -- a healthy tick: 32 mixed queries coalesce into one batch -------
    srv = QueryServer(ix, backend="ref")
    queries = []
    for i in range(32):
        kind = ("and", "or", "xor", "threshold")[i % 4]
        terms = tuple(vocab[j] for j in rng.choice(n_terms, 3,
                                                   replace=False))
        if i % 8 == 7:
            queries.append(Query.similar(terms[0], k=5))
        elif kind == "threshold":
            queries.append(Query.threshold(terms, 2))
        else:
            queries.append(Query(kind, terms))
    tickets = [srv.submit(q) for q in queries]
    srv.run_until_idle()
    st = srv.stats()
    assert all(t.result.status == OK for t in tickets)
    lat = max(t.telemetry.latency for t in tickets)
    print(f"served {st.resolved_ok} queries in {st.batches} batch(es), "
          f"max latency {lat * 1e3:.1f} ms")

    # the coalesced results are bit-identical to direct execution
    probe = tickets[1]
    assert probe.result.value == ix.query_or(*probe.query.terms)
    print("spot check vs direct execution: identical")

    # -- admission control: queries past their deadline never dispatch --
    tight = QueryServer(ix, backend="ref", max_queue=4)
    late = tight.submit(Query.or_(vocab[0]), deadline_s=-1.0)
    shed = [tight.submit(Query.or_(v)) for v in vocab[:8]]
    tight.run_until_idle()
    n_shed = sum(t.result.status == "overloaded" for t in shed)
    print(f"deadline at admission -> {late.result.status}; "
          f"queue of 4 shed {n_shed} of 8 submits")

    # -- scripted faults: dispatch fails once, retry succeeds; a second
    # server fails always and degrades to the host planner -------------
    flaky = QueryServer(ix, backend="ref",
                        faults=FaultInjector.script(
                            {"dispatch_raise": [True]}))
    t = flaky.submit(Query.and_(vocab[0], vocab[1]))
    flaky.run_until_idle()
    print(f"fail-once: status={t.result.status} "
          f"retries={t.telemetry.retries} degraded={t.telemetry.degraded}")

    broken = QueryServer(ix, backend="ref",
                         faults=FaultInjector.script(
                             {"dispatch_raise": "always"}))
    t = broken.submit(Query.and_(vocab[0], vocab[1]))
    broken.run_until_idle()
    assert t.result.value == ix.query_and(vocab[0], vocab[1])
    print(f"fail-always: status={t.result.status} "
          f"degraded={t.telemetry.degraded} (host result bit-identical)")


if __name__ == "__main__":
    main()
