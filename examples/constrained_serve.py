"""Serving driver: batched generation with Roaring-powered features --
block-sparse long-context attention policy, constrained decoding, paged KV
accounting.

    PYTHONPATH=src python examples/constrained_serve.py
"""

import jax
import numpy as np

import repro.configs as C
from repro.core import RoaringBitmap
from repro.models import transformer as T
from repro.serve.constrained import lexicon_constraint
from repro.serve.engine import BlockPolicy, Engine


def main():
    rng = np.random.default_rng(0)
    cfg = C.get_config("gemma2_27b", reduced=True)   # local+global+roaring
    params = T.init_params(cfg, jax.random.key(0))

    # constraint: only "digits" and "ops" lexicons allowed
    lexicons = {"digits": np.arange(16, dtype=np.uint32),
                "ops": np.arange(100, 110, dtype=np.uint32)}
    constraint = lexicon_constraint(cfg.vocab, lexicons, ["digits", "ops"])
    print(f"constraint allows {constraint.n_allowed()}/{cfg.vocab} tokens "
          f"({len(constraint.allowed.containers)} roaring containers)")

    policy = BlockPolicy(sink_blocks=1, local_blocks=4,
                         pinned=RoaringBitmap.from_values([2]))
    eng = Engine(cfg, params, max_seq=512, policy=policy,
                 constraint=constraint)
    prompts = rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=24)
    print("generated (all tokens in the allowed set):")
    for row in out:
        assert all(int(t) in set(np.concatenate(list(lexicons.values()))
                                 .tolist()) for t in row)
        print("  ", row.tolist())
    alloc = eng.allocator
    print(f"paged KV: {alloc.n_pages - alloc.n_free}/{alloc.n_pages} pages "
          f"in use, fragmentation={alloc.fragmentation():.2f}")
    eng.release_all()
    print(f"released: {alloc.n_free}/{alloc.n_pages} free")


if __name__ == "__main__":
    main()
