"""End-to-end system behaviour: the paper's data structure doing real work
inside the framework (train + index + serve in one scenario)."""

import dataclasses

import numpy as np
import pytest

import repro.configs as C
from repro.core import RoaringBitmap
from repro.data.index import InvertedIndex
from repro.data.pipeline import RoaringDataPipeline
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer


@pytest.mark.slow
def test_end_to_end_scenario(tmp_path, rng):
    # 1. corpus + inverted index (the paper's motivating application)
    vocab_terms = [f"t{i}" for i in range(50)]
    docs = [[vocab_terms[i] for i in rng.choice(50, rng.integers(3, 12),
                                                replace=False)]
            for _ in range(300)]
    idx = InvertedIndex().build(docs).optimize()
    hits = idx.query_and("t1", "t2")
    want = {i for i, d in enumerate(docs) if "t1" in d and "t2" in d}
    assert set(hits.to_array().tolist()) == want

    # 2. the index drives the training-data filter
    keep = idx.query_or("t1", "t2", "t3")
    cfg = C.get_config("qwen2_5_3b", reduced=True)
    cfg = dataclasses.replace(cfg, remat="none")
    pipe = RoaringDataPipeline(
        n_docs=300, seq_len=16, batch_size=4, vocab=cfg.vocab, seed=0,
        filters={"terms": keep})
    assert pipe.keep.cardinality == keep.cardinality
    tr = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                 pipe, str(tmp_path / "ck"), ckpt_every=100,
                 async_ckpt=False)
    hist = tr.train(6, log_every=100)
    assert all(np.isfinite(h["loss"]) for h in hist)
    served_ids = set()
    for _ in range(3):
        served_ids |= set(pipe.next_batch()["doc_ids"].tolist())
    assert served_ids <= set(keep.to_array().tolist())

    # 3. serve with a roaring vocab constraint from the same machinery
    from repro.serve.constrained import VocabConstraint
    from repro.serve.engine import BlockPolicy, Engine
    allowed = RoaringBitmap.from_values(np.arange(16, dtype=np.uint32))
    eng = Engine(cfg, tr.params, max_seq=64,
                 policy=BlockPolicy(sink_blocks=1, local_blocks=2),
                 constraint=VocabConstraint(cfg.vocab, allowed))
    out = eng.generate(rng.integers(0, cfg.vocab, (2, 4)).astype(np.int32),
                       max_new_tokens=4)
    assert (out < 16).all()
