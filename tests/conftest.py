import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here; smoke tests
# and benchmarks must see the single real CPU device.  Only launch/dryrun.py
# fakes 512 devices (in its own process).


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
