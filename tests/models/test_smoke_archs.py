"""Per-arch smoke tests (assignment requirement): reduced same-family
configs, one forward/train step on CPU, asserting shapes + no NaNs, plus a
decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T


def make_batch(cfg, rng, b=2, s=64):
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.frontend_dim)), jnp.bfloat16)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    elif cfg.frontend == "vision_stub":
        nf = cfg.n_frontend_tokens
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((b, nf, cfg.frontend_dim)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s - nf)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s - nf)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_forward_and_train_step(arch, rng):
    cfg = C.get_config(arch, reduced=True)
    params = T.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: T.loss_and_metrics(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one gradient step moves the loss
    grads = jax.jit(jax.grad(
        lambda p, b: T.loss_and_metrics(p, b, cfg)[0]))(params, batch)
    gn = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                            for g in jax.tree.leaves(grads))))
    assert np.isfinite(gn) and gn > 0, arch
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = jax.jit(
        lambda p, b: T.loss_and_metrics(p, b, cfg))(params2, batch)
    assert float(loss2) < float(loss), (arch, float(loss), float(loss2))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_decode_step_shapes(arch, rng):
    cfg = C.get_config(arch, reduced=True)
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step (per assignment)")
    b, s_max = 2, 64
    params = T.init_params(cfg, jax.random.key(0))
    state = T.init_decode_state(cfg, b, s_max)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32)
    mask = jnp.full((b, 1), 0xFFFFFFFF, jnp.uint32)
    logits, state = jax.jit(
        lambda p, st, t: T.decode_step(p, st, t, cfg, mask))(
        params, state, toks)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert int(state["pos"][0]) == 1


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_full_config_instantiates(arch):
    """FULL configs are exercised via the dry-run; here we only check the
    published numbers are wired up correctly."""
    cfg = C.get_config(arch)
    assert cfg.n_layers == len(cfg.layer_kinds)
    n = cfg.params_count()
    expected = {
        "qwen2_vl_72b": 72e9, "gemma2_27b": 27e9, "stablelm_3b": 2.8e9,
        "qwen2_5_3b": 3.1e9, "qwen3_14b": 14.8e9, "deepseek_v2_236b": 236e9,
        "mixtral_8x7b": 47e9, "xlstm_350m": 0.35e9, "jamba_v01_52b": 52e9,
        "hubert_xlarge": 0.96e9,
    }[arch]
    assert 0.55 * expected < n < 1.6 * expected, (arch, n, expected)
    if cfg.n_experts:
        assert cfg.active_params_count() < cfg.params_count()
