"""Unit tests for the decode-restructure utilities (EXPERIMENTS.md sec Perf):
token-column scatter insert, roaring block-id extraction, stacked block
gather."""

import jax.numpy as jnp
import numpy as np

from repro.models.layers import (gather_blocks_stacked, insert_token_stacked,
                                 visible_block_ids)


def test_insert_token_stacked_5d(rng):
    b, r, h, s, d = 3, 4, 2, 16, 8
    stack = jnp.asarray(rng.standard_normal((b, r, h, s, d)), jnp.float32)
    new = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    pos = jnp.asarray([0, 5, 15], jnp.int32)
    out = np.asarray(insert_token_stacked(stack, new, 2, pos))
    want = np.asarray(stack).copy()
    for bi in range(b):
        want[bi, 2, :, int(pos[bi]), :] = np.asarray(new)[bi]
    assert np.array_equal(out, want)


def test_insert_token_stacked_4d(rng):
    b, r, s, d = 2, 3, 8, 4
    stack = jnp.zeros((b, r, s, d), jnp.float32)
    new = jnp.ones((b, d), jnp.float32)
    out = np.asarray(insert_token_stacked(stack, new, 1, jnp.asarray([2, 7])))
    assert out[0, 1, 2].sum() == 4 and out[1, 1, 7].sum() == 4
    assert out.sum() == 8  # nothing else touched


def test_visible_block_ids(rng):
    n_blocks, bs, topk = 64, 16, 8
    words = np.zeros((2, 2), np.uint32)
    sel0 = [0, 3, 40, 63]
    sel1 = list(range(20))           # more than topk
    for s_ in sel0:
        words[0, s_ >> 5] |= np.uint32(1) << np.uint32(s_ & 31)
    for s_ in sel1:
        words[1, s_ >> 5] |= np.uint32(1) << np.uint32(s_ & 31)
    kvl = jnp.asarray([n_blocks * bs, 5 * bs], jnp.int32)
    idx, n = visible_block_ids(jnp.asarray(words), kvl, n_blocks, bs, topk)
    idx, n = np.asarray(idx), np.asarray(n)
    assert n[0] == 4 and idx[0, :4].tolist() == sel0
    # row 1 is truncated by kv_len (blocks 0..4) then by topk
    assert n[1] == 5 and idx[1, :5].tolist() == [0, 1, 2, 3, 4]


def test_gather_blocks_stacked_matches_take(rng):
    b, r, hkv, s, d, bs = 2, 3, 2, 64, 4, 16
    stack = jnp.asarray(rng.standard_normal((b, r, hkv, s, d)), jnp.float32)
    ids = jnp.asarray([[0, 2, 3], [1, 1, 0]], jnp.int32)
    got = np.asarray(gather_blocks_stacked(stack, 1, ids, bs))
    st = np.asarray(stack)
    for bi in range(b):
        for t in range(3):
            blk = int(ids[bi, t])
            want = st[bi, 1, :, blk * bs:(blk + 1) * bs, :]
            assert np.array_equal(got[bi, t], want), (bi, t)


def test_pure_dp_specs():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import spec_for_param

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)
    m = FakeMesh()
    assert spec_for_param("prefix_0.mixer.wq", (4096, 32, 128), m) == \
        P("data", "model", None)
    assert spec_for_param("prefix_0.mixer.wq", (4096, 32, 128), m,
                          pure_dp=True) == P("data", None, None)
