"""Decode-vs-forward and prefill-vs-decode logit consistency (fp32)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T

ARCHS = ["gemma2_27b", "deepseek_v2_236b", "jamba_v01_52b", "xlstm_350m"]


def full_logits(params, tokens, cfg):
    x, positions = T._embed_inputs(params, {"tokens": tokens}, cfg)
    x, _ = T.backbone(params, x, positions, cfg)
    return T._logits(params, x, cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = C.get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              capacity_factor=1000.0)
    params = T.init_params(cfg, jax.random.key(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    ref = np.asarray(full_logits(params, toks, cfg))
    mask = jnp.full((B, 1), 0xFFFFFFFF, jnp.uint32)

    state = T.init_decode_state(cfg, B, S)
    step = jax.jit(lambda p, st, t: T.decode_step(p, st, t, cfg, mask))
    outs = []
    for t in range(S):
        logits, state = step(params, state, toks[:, t])
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, ref, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch, rng):
    cfg = C.get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              capacity_factor=1000.0)
    params = T.init_params(cfg, jax.random.key(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    ref = np.asarray(full_logits(params, toks, cfg))
    mask = jnp.full((B, 1), 0xFFFFFFFF, jnp.uint32)
    half = S // 2
    pl, state = T.prefill(params, {"tokens": toks[:, :half]}, cfg, s_max=S)
    np.testing.assert_allclose(np.asarray(pl), ref[:, half - 1],
                               atol=2e-3, rtol=2e-3)
    step = jax.jit(lambda p, st, t: T.decode_step(p, st, t, cfg, mask))
    cur = [np.asarray(pl)]
    for t in range(half, S - 1):
        logits, state = step(params, state, toks[:, t])
        cur.append(np.asarray(logits))
    dec = np.stack(cur, axis=1)
    np.testing.assert_allclose(dec, ref[:, half - 1:S - 1],
                               atol=2e-3, rtol=2e-3)
