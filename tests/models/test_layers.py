"""Layer-level unit tests: flash attention vs naive, RoPE, MoE dispatch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import layers as L
from repro.models import mlp as M


def naive_attention(q, k, v, causal=True, window=0, softcap=0.0, scale=None):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = (d ** -0.5) if scale is None else scale
    qr = q.reshape(b, s, hkv, g, d).astype(np.float32)
    sc = np.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(np.float32)) * scale
    if softcap:
        sc = softcap * np.tanh(sc / softcap)
    mask = np.ones((s, s), bool)
    if causal:
        mask &= np.tril(np.ones((s, s), bool))
    if window:
        qpos = np.arange(s)
        mask &= (qpos[:, None] - qpos[None, :]) < window
    sc = np.where(mask, sc, -1e30)
    w = np.exp(sc - sc.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = np.einsum("bhgqk,bkhd->bhgqd", w, v.astype(np.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)


@pytest.mark.parametrize("causal,window,softcap,block_skip", [
    (True, 0, 0.0, False), (True, 0, 0.0, True),
    (True, 32, 0.0, True), (False, 0, 0.0, False),
    (True, 0, 20.0, False), (True, 16, 0.0, False),
])
def test_flash_vs_naive(rng, causal, window, softcap, block_skip):
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    q = rng.standard_normal((b, s, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    got = np.asarray(L.flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        window=window, softcap=softcap, q_chunk=32, k_chunk=64,
        block_skip=block_skip))
    want = naive_attention(q, k, v, causal, window, softcap)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_block_skip_same_result(rng):
    """The beyond-paper causal block-skip is a pure FLOP optimization."""
    b, s, h, d = 1, 256, 2, 16
    q = rng.standard_normal((b, s, h, d)).astype(np.float32)
    k = rng.standard_normal((b, s, h, d)).astype(np.float32)
    v = rng.standard_normal((b, s, h, d)).astype(np.float32)
    a1 = L.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           causal=True, q_chunk=32, k_chunk=32,
                           block_skip=False)
    a2 = L.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                           causal=True, q_chunk=32, k_chunk=32,
                           block_skip=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               atol=1e-6, rtol=1e-6)


def test_block_pairs_counts():
    qi, kj = L._block_pairs(8, 64, 8, 64, causal=True, window=0, skip=True)
    assert len(qi) == 8 * 9 // 2          # lower triangle of blocks
    qi, kj = L._block_pairs(8, 64, 8, 64, causal=True, window=0, skip=False)
    assert len(qi) == 64
    qi, kj = L._block_pairs(8, 64, 8, 64, causal=True, window=64, skip=True)
    assert len(qi) == 8 + 7               # diagonal band


def test_rope_relative_shift(rng):
    """RoPE: scores depend only on relative positions."""
    d = 32
    x = rng.standard_normal((1, 2, 1, d)).astype(np.float32)
    r1 = L.apply_rope(jnp.asarray(x), jnp.asarray([[3, 7]]), 10000.0)
    r2 = L.apply_rope(jnp.asarray(x), jnp.asarray([[103, 107]]), 10000.0)
    s1 = float(jnp.einsum("d,d->", r1[0, 0, 0], r1[0, 1, 0]))
    s2 = float(jnp.einsum("d,d->", r2[0, 0, 0], r2[0, 1, 0]))
    assert abs(s1 - s2) < 1e-4
    # M-RoPE with equal position streams == 1-D RoPE (text stub contract)
    r3 = L.apply_rope(jnp.asarray(x), jnp.asarray([[3, 7]]), 10000.0,
                      sections=(4, 6, 6))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r3), atol=1e-6)


def test_moe_scatter_matches_dense(rng):
    cfg = C.get_config("mixtral_8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32",
                              capacity_factor=1000.0)
    p = M.moe_params(cfg, jax.random.key(0))
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)) * 0.3,
                    jnp.float32)
    y_scatter, m1 = M.moe(x, p, cfg)
    y_dense, m2 = M.moe(x, p, dataclasses.replace(cfg,
                                                  moe_dispatch="dense"))
    np.testing.assert_allclose(np.asarray(y_scatter), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)
    assert float(m1["dropped_fraction"]) == 0.0


def test_moe_capacity_drops(rng):
    cfg = C.get_config("mixtral_8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    p = M.moe_params(cfg, jax.random.key(0))
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.bfloat16)
    y, m = M.moe(x, p, cfg)
    assert float(m["dropped_fraction"]) > 0.0
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_mla_cache_is_compressed():
    cfg = C.get_config("deepseek_v2_236b")
    from repro.models import transformer as T
    state = jax.eval_shape(lambda: T.init_decode_state(cfg, 1, 1024))
    mla = state["pattern"][0]
    # compressed cache: kv_lora + rope dims, NOT n_heads * head_dim * 2
    ckv_bytes = np.prod(mla["ckv"].shape) * 2
    full_bytes = 1024 * cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim
                                       + cfg.v_head_dim) * 2 * 59
    assert ckv_bytes < full_bytes / 20
