"""Container-level algorithms vs python-set ground truth (paper secs 3-4)."""

import numpy as np
import pytest

from repro.core import containers as C


def mk_array(rng, n):
    n = min(n, C.ARRAY_MAX)  # array-container invariant (paper sec 1)
    return C.ArrayContainer(np.sort(rng.choice(65536, n, replace=False))
                            .astype(np.uint16))


def mk_bitset(rng, n):
    vals = np.sort(rng.choice(65536, n, replace=False)).astype(np.uint16)
    return C.BitsetContainer(C.positions_to_bitset(vals), n)


def mk_run(rng, n):
    vals = np.sort(rng.choice(65536, n, replace=False)).astype(np.uint16)
    return C.RunContainer(C.runs_from_sorted_values(vals))


@pytest.mark.parametrize("op", ["and", "or", "xor", "andnot"])
@pytest.mark.parametrize("mk_x", [mk_array, mk_bitset, mk_run])
@pytest.mark.parametrize("mk_y", [mk_array, mk_bitset, mk_run])
def test_ops_all_kind_pairs(rng, op, mk_x, mk_y):
    for nx, ny in [(50, 5000), (3000, 3000), (6000, 100), (6000, 8000)]:
        x, y = mk_x(rng, nx), mk_y(rng, ny)
        sx = set(x.to_array_values().tolist())
        sy = set(y.to_array_values().tolist())
        want = {"and": sx & sy, "or": sx | sy, "xor": sx ^ sy,
                "andnot": sx - sy}[op]
        fn, card_fn = C.OPS[op]
        got = fn(x, y)
        assert set(got.to_array_values().tolist()) == want
        assert got.card == len(want)
        assert card_fn(x, y) == len(want)
        # result-kind policy: array <= 4096 < bitset
        if got.card and got.card <= C.ARRAY_MAX:
            assert got.kind in ("array",)
        elif got.card:
            assert got.kind == "bitset"


def test_conversions_roundtrip(rng):
    for n in [0, 1, 100, 4096, 4097, 30000, 65536]:
        vals = np.sort(rng.choice(65536, n, replace=False)).astype(np.uint16)
        bs = C.positions_to_bitset(vals)
        assert np.array_equal(C.bitset_to_positions(bs), vals)
        runs = C.runs_from_sorted_values(vals)
        rc = C.RunContainer(runs)
        assert np.array_equal(rc.to_array_values(), vals)
        assert np.array_equal(rc.to_bitset().words, bs)
        assert rc.card == n


def test_bitset_set_clear_flip_cardinality(rng):
    words = np.zeros(C.BITSET_WORDS, np.uint64)
    a = np.sort(rng.choice(65536, 5000, replace=False)).astype(np.uint16)
    b = np.sort(rng.choice(65536, 5000, replace=False)).astype(np.uint16)
    assert C.bitset_set_many(words, a) == 5000
    # setting the same bits again changes nothing (paper XOR trick)
    assert C.bitset_set_many(words, a) == 0
    delta = C.bitset_set_many(words, b)
    assert delta == len(set(b.tolist()) - set(a.tolist()))
    cleared = C.bitset_clear_many(words, a)
    assert cleared == 5000
    # words now hold exactly b \ a
    assert C.popcount_words(words) == len(set(b.tolist())
                                          - set(a.tolist()))
    # flipping b clears b\a and sets b&a
    C.bitset_flip_many(words, b)
    assert C.popcount_words(words) == len(set(b.tolist())
                                          & set(a.tolist()))


def test_num_runs(rng):
    vals = np.array([1, 2, 3, 10, 11, 40, 65535], np.uint16)
    assert C.ArrayContainer(vals).num_runs() == 4
    assert C.BitsetContainer(C.positions_to_bitset(vals)).num_runs() == 4
    # cross-word run: 63,64,65 is ONE run
    vals = np.array([63, 64, 65], np.uint16)
    assert C.BitsetContainer(C.positions_to_bitset(vals)).num_runs() == 1


def test_optimize_picks_smallest(rng):
    # a full range is cheapest as one run
    full = C.RunContainer(np.array([[0, 65535]], np.int32))
    opt = C.optimize(full.to_bitset())
    assert isinstance(opt, C.RunContainer)
    assert opt.memory_bytes() < 16
    # scattered values stay array
    sparse = mk_array(rng, 100)
    assert isinstance(C.optimize(sparse), C.ArrayContainer)
    # dense random stays bitset
    dense = mk_bitset(rng, 30000)
    assert isinstance(C.optimize(dense), C.BitsetContainer)


def test_galloping_matches_merge(rng):
    small = np.sort(rng.choice(65536, 10, replace=False)).astype(np.uint16)
    big = np.sort(rng.choice(65536, 30000, replace=False)).astype(np.uint16)
    want = np.intersect1d(small, big)
    assert np.array_equal(C.array_intersect(small, big), want)
    assert np.array_equal(C.array_intersect(big, small), want)
    wantd = np.setdiff1d(small, big)
    assert np.array_equal(C.array_difference(small, big), wantd)
