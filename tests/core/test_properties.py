"""Hypothesis property tests: the system's set-algebra invariants.

Skipped (not errored) when hypothesis is missing: CI installs it via
requirements-ci.txt, but minimal local images may not have it and a
collection error would mask the rest of the tier-1 suite under ``-x``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (RoaringBitmap, complement, deserialize, flip_range,
                        serialize)

value_sets = st.lists(st.integers(0, 1 << 20), min_size=0, max_size=2000)
small_sets = st.lists(st.integers(0, 1 << 18), min_size=0, max_size=500)


def bm(values):
    return RoaringBitmap.from_values(np.asarray(values, np.uint32)) \
        if values else RoaringBitmap()


@settings(max_examples=60, deadline=None)
@given(value_sets, value_sets)
def test_union_commutative(a, b):
    assert bm(a) | bm(b) == bm(b) | bm(a)


@settings(max_examples=60, deadline=None)
@given(value_sets, value_sets, value_sets)
def test_intersection_associative(a, b, c):
    assert (bm(a) & bm(b)) & bm(c) == bm(a) & (bm(b) & bm(c))


@settings(max_examples=60, deadline=None)
@given(value_sets, value_sets, value_sets)
def test_distributive(a, b, c):
    assert bm(a) & (bm(b) | bm(c)) == (bm(a) & bm(b)) | (bm(a) & bm(c))


@settings(max_examples=60, deadline=None)
@given(small_sets, small_sets)
def test_de_morgan(a, b):
    n = 1 << 18
    lhs = complement(bm(a) | bm(b), n)
    rhs = complement(bm(a), n) & complement(bm(b), n)
    assert lhs == rhs


@settings(max_examples=60, deadline=None)
@given(value_sets, value_sets)
def test_inclusion_exclusion(a, b):
    x, y = bm(a), bm(b)
    assert (x | y).cardinality == \
        x.cardinality + y.cardinality - x.and_card(y)
    assert (x ^ y).cardinality == \
        x.cardinality + y.cardinality - 2 * x.and_card(y)
    assert (x - y).cardinality == x.cardinality - x.and_card(y)


@settings(max_examples=60, deadline=None)
@given(value_sets)
def test_serde_roundtrip(a):
    x = bm(a).run_optimize()
    assert deserialize(serialize(x)) == x


@settings(max_examples=60, deadline=None)
@given(value_sets)
def test_container_invariants(a):
    x = bm(a)
    for c in x.containers:
        assert c.card > 0, "no empty containers stored (paper sec 2.2)"
        if c.kind == "array":
            assert c.card <= 4096
            v = c.values
            assert np.all(v[1:] > v[:-1]), "sorted distinct"
        elif c.kind == "bitset":
            assert c.card > 4096
    assert x.keys == sorted(x.keys)


@settings(max_examples=40, deadline=None)
@given(value_sets)
def test_run_optimize_preserves_and_bounds(a):
    x = bm(a)
    y = x.copy().run_optimize()
    assert x == y
    for c in y.containers:
        if c.kind == "run":
            assert c.num_runs() <= 2047
            # run must beat both alternatives (paper's size rule)
            assert c.memory_bytes() <= min(2 * c.card, 8192)
    assert y.memory_bytes() <= x.memory_bytes()


@settings(max_examples=40, deadline=None)
@given(small_sets, st.integers(0, 1 << 18), st.integers(0, 1 << 18))
def test_flip_range_involution(a, lo, hi):
    lo, hi = min(lo, hi), max(lo, hi)
    x = bm(a)
    assert flip_range(flip_range(x, lo, hi), lo, hi) == x


@settings(max_examples=40, deadline=None)
@given(value_sets)
def test_rank_select_inverse(a):
    x = bm(a)
    n = x.cardinality
    for i in {0, n // 2, n - 1} - {-1}:
        if 0 <= i < n:
            assert x.rank(x.select(i)) == i + 1
