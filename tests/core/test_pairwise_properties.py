"""Hypothesis property sweeps for the pairwise planner.

Skipped (not errored) when hypothesis is missing, mirroring
test_properties.py: CI installs it via requirements-ci.txt.

The invariant under test is the acceptance contract: the class-batched
planner (``pairwise.merge_one`` / ``pairwise_card``) is bit-identical to
the seed scalar two-by-two path across ALL container-type pairings --
including empty bitmaps, full chunks, run-heavy inputs, and the 4096/4097
array<->bitset boundary."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from pairwise_oracle import seed_and_card, seed_merge  # noqa: E402

from repro.core import RoaringBitmap  # noqa: E402
from repro.core import containers as C  # noqa: E402
from repro.core import pairwise  # noqa: E402


# a chunk spec: (key, kind) where kind picks the container shape
chunk = st.tuples(
    st.integers(0, 7),                                  # chunk key
    st.sampled_from(["array", "dense", "run", "full", "boundary"]),
    st.integers(0, 2 ** 32 - 1),                        # shape seed
)


def build(chunks):
    parts = []
    for key, kind, seed in chunks:
        rng = np.random.default_rng(seed)
        base = key << 16
        if kind == "array":
            parts.append(base + rng.choice(
                1 << 16, int(rng.integers(1, 2000)), replace=False))
        elif kind == "dense":
            parts.append(base + rng.choice(
                1 << 16, int(rng.integers(4097, 30000)), replace=False))
        elif kind == "run":
            lo = int(rng.integers(0, 1 << 15))
            parts.append(np.arange(base + lo,
                                   base + lo + int(rng.integers(64, 20000))))
        elif kind == "full":
            parts.append(np.arange(base, base + (1 << 16)))
        else:                                           # boundary
            parts.append(base + rng.choice(
                1 << 16, 4096 + int(rng.integers(0, 2)), replace=False))
    if not parts:
        return RoaringBitmap()
    vals = np.unique(np.concatenate(parts)).astype(np.uint32)
    return RoaringBitmap.from_values(vals).run_optimize()


bitmap_specs = st.lists(chunk, min_size=0, max_size=6)


@settings(max_examples=25, deadline=None)
@given(bitmap_specs, bitmap_specs,
       st.sampled_from(["and", "or", "xor", "andnot"]))
def test_merge_one_bit_identical_to_seed(ca, cb, op):
    a, b = build(ca), build(cb)
    got = pairwise.merge_one(a, b, op)
    want = seed_merge(a, b, op)
    assert got == want
    for c in got.containers:
        assert c.card > 0
        if c.kind == "array":
            assert c.card <= C.ARRAY_MAX


@settings(max_examples=25, deadline=None)
@given(bitmap_specs, bitmap_specs,
       st.sampled_from(["and", "or", "xor", "andnot"]))
def test_pairwise_card_matches_inclusion_exclusion(ca, cb, op):
    a, b = build(ca), build(cb)
    got = int(pairwise.pairwise_card(op, [(a, b)])[0])
    inter = seed_and_card(a, b)
    cx, cy = a.cardinality, b.cardinality
    want = {"and": inter, "or": cx + cy - inter,
            "xor": cx + cy - 2 * inter, "andnot": cx - inter}[op]
    assert got == want
    assert got == seed_merge(a, b, op).cardinality


@settings(max_examples=15, deadline=None)
@given(st.lists(bitmap_specs, min_size=0, max_size=4))
def test_jaccard_matrix_matches_scalar(specs):
    bms = [build(s) for s in specs]
    got = pairwise.jaccard_matrix(bms)
    for i, x in enumerate(bms):
        for j, y in enumerate(bms):
            want = 1.0 if i == j else x.jaccard(y)
            assert abs(got[i, j] - want) < 1e-12
