"""Cross-backend differential harness for the wide aggregates.

Seeded randomized sweeps over container-kind mixes x op x K x mesh size,
asserting BIT-IDENTITY across three independent executions of the same
plan:

  * the numpy host twin (``aggregate.execute_plan_host`` -- no jax at
    all, arena rows resolved through the authoritative host mirror);
  * the single-device kernel path (``execute_plans`` without a mesh);
  * the sharded path (``execute_plans(mesh=)``) -- both the arena route
    (resident rows gathered from per-shard slabs inside one jit,
    ``_shard_reduce_arena``) and the arena-less staged route
    (``_shard_reduce``).

The tier-1 process sees exactly one CPU device (tests/conftest.py), so
mesh sizes 2/4 run in subprocesses launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
tests-multidevice CI job runs them too); mesh size 1 exercises the
transparent fallback in-process.  The sweeps deliberately include empty
segments (chunks held by fewer bitmaps than shards), all-run inputs
(host sweep only -- the sharded plan must still agree), threshold ties
(T exactly attainable), and weighted thresholds.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import RoaringBitmap
from repro.core import aggregate
from repro.core.arena import BitmapArena

SRC = str(Path(__file__).resolve().parents[2] / "src")

# the sweep body is shared by the in-process 1-device test and the
# subprocess multi-device tests: everything below is jax-import-safe
# only AFTER the device count is forced, hence the string template
_SWEEP = '''
import numpy as np

from repro.core import RoaringBitmap
from repro.core import aggregate
from repro.core.arena import BitmapArena

CHUNK = 1 << 16


def _mixed_bitmap(rng, mix, shared):
    """One bitmap of the requested container-kind mix.  ``shared`` is a
    dense block present in EVERY bitmap of the sweep: it pins threshold
    ties (occurrence count == K exactly) and guarantees AND stays
    non-empty on the kernel path."""
    parts = [shared]
    if mix in ("array", "mixed"):
        parts.append(rng.integers(0, 4 * CHUNK, 2500, dtype=np.uint32))
    if mix in ("bitset", "mixed"):
        base = int(rng.integers(0, 3)) * CHUNK
        parts.append(base + rng.integers(0, 2 * CHUNK, 45000,
                                         dtype=np.uint32))
    if mix in ("run", "mixed"):
        lo = int(rng.integers(0, 2 * CHUNK))
        parts.append(np.arange(lo, lo + int(rng.integers(5000, 30000)),
                               dtype=np.uint32))
    return RoaringBitmap.from_values(
        np.unique(np.concatenate(parts)).astype(np.uint32))


def _check(plan, expect, name):
    """One plan, three executions, all bit-identical."""
    host = aggregate.execute_plan_host(plan)
    assert host == expect, f"host twin diverged: {name}"
    got = aggregate.execute_plans([plan], mesh=MESH)[0]
    assert got == expect, f"sharded diverged: {name}"


def sweep(seed, mix, k, arenas=("arena",)):
    rng = np.random.default_rng(seed)
    shared = (5 * CHUNK + rng.integers(0, CHUNK, 9000,
                                       dtype=np.uint32)).astype(np.uint32)
    bms = [_mixed_bitmap(rng, mix, shared) for _ in range(k)]
    # empty-segment coverage: one dense chunk held by exactly TWO
    # bitmaps, so meshes wider than 2 see shards with no rows of it
    pair = 9 * CHUNK + rng.integers(0, CHUNK, 30000, dtype=np.uint32)
    bms[0] |= RoaringBitmap.from_values(np.unique(pair))
    bms[1] |= RoaringBitmap.from_values(np.unique(pair[::2]))
    arena = BitmapArena()
    arena.adopt_many(bms[::2])          # half resident, half cold
    weights = [int(x) for x in rng.integers(1, 8, k)]
    cases = [("or", 0, None), ("xor", 0, None), ("and", 0, None),
             ("andnot", 0, None),
             ("threshold", max(2, k // 2), None),
             ("threshold", k, None),                  # tie: count == K
             ("threshold", sum(weights), weights),    # weighted tie
             ("threshold", sum(weights) // 2, weights)]
    for op, t, w in cases:
        args = (bms[0], bms[1:]) if op == "andnot" else (bms,)
        single = getattr(aggregate, f"{op}_many")(
            *args, **({"t": t, "weights": w} if op == "threshold" else {}))
        for ar_name in arenas:
            ar = arena if ar_name == "arena" else None
            seq = [bms[0], *bms[1:]] if op == "andnot" else bms
            plan = aggregate.plan_wide(op, seq, t, w, arena=ar)
            _check(plan, single, f"{mix} {op} t={t} seed={seed} "
                                 f"arena={ar is not None}")
    return bms, arena, weights


def extras(bms, arena, weights, k, rng):
    # all-run inputs: the host interval sweep resolves everything, the
    # sharded plan must still agree (and the results must be non-empty)
    runs = []
    for _ in range(k):
        lo = int(rng.integers(0, 3 * CHUNK))
        runs.append(RoaringBitmap.from_values(
            np.arange(lo, lo + 40000, dtype=np.uint32)))
    for op in ("or", "and", "xor"):
        single = getattr(aggregate, f"{op}_many")(runs)
        assert getattr(aggregate, f"{op}_many")(runs, mesh=MESH) == single
    assert aggregate.or_many(runs).cardinality > 0

    # coalesced multi-plan batch (non-power-of-two plan count): mixed
    # ops share per-segment thresholds in one sharded dispatch
    plans = [aggregate.plan_wide("threshold", bms, t, arena=arena)
             for t in (2, 3, k)]
    plans.append(aggregate.plan_wide("or", bms, arena=arena))
    plans.append(aggregate.plan_wide("threshold", bms, sum(weights) // 2,
                                     weights, arena=arena))
    exp = aggregate.execute_plans(plans)
    got = aggregate.execute_plans(plans, mesh=MESH)
    hst = [aggregate.execute_plan_host(p) for p in plans]
    for g, e, h in zip(got, exp, hst):
        assert g == e == h


def run_all():
    for mix in ("array", "bitset", "run", "mixed"):
        bms, arena, weights = sweep(11, mix, k=6)
    extras(bms, arena, weights, 6, np.random.default_rng(99))
    # K < shards: some shards hold no rows of any segment; the staged
    # (arena-less) sharded route rides along here -- one small sweep,
    # its broad coverage lives in test_sharded.py
    sweep(42, "mixed", k=3, arenas=("arena", "none"))
'''

_SUBPROCESS_BODY = '''
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={d} "
    + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
from jax.experimental import mesh_utils
from jax.sharding import Mesh

assert jax.device_count() == {d}, jax.device_count()
MESH = Mesh(mesh_utils.create_device_mesh(({d},)), ("wide",))
''' + _SWEEP + '''
run_all()
print("DIFFERENTIAL_OK")
'''


def _run_subprocess(devices: int) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c",
         _SUBPROCESS_BODY.replace("{d}", str(devices))],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.parametrize("devices", [2, 4])
def test_differential_sharded(devices):
    """host twin == single-device kernel == sharded, at 2 and 4 forced
    host devices, across the full container-kind x op sweep."""
    assert "DIFFERENTIAL_OK" in _run_subprocess(devices)


def test_differential_one_device_mesh():
    """Mesh size 1 must transparently take the single-dispatch path and
    still match the host twin (same sweep, in-process)."""
    import jax
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    mesh = Mesh(mesh_utils.create_device_mesh(
        (1,), devices=jax.devices()[:1]), ("wide",))
    ns = {"MESH": mesh, "RoaringBitmap": RoaringBitmap,
          "aggregate": aggregate, "BitmapArena": BitmapArena,
          "np": np}
    exec(compile(_SWEEP, "<sweep>", "exec"), ns)   # noqa: S102
    ns["sweep"](11, "mixed", k=5)
    ns["sweep"](42, "mixed", k=3, arenas=("arena", "none"))
