"""Sharded multi-device wide aggregation.

The tier-1 process sees exactly one CPU device (tests/conftest.py pins
that), so the real multi-device runs happen in subprocesses launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``: each one builds an
N-way ``jax.sharding`` mesh via ``jax.experimental.mesh_utils`` and
asserts the sharded plans are bit-identical to the single-device plans.
In-process tests cover the 1-device fallback and the host-side shard
planner directly.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import RoaringBitmap
from repro.core import aggregate

SRC = str(Path(__file__).resolve().parents[2] / "src")

_SUBPROCESS_BODY = """
import os, sys
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={d} "
    + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax
from jax.experimental import mesh_utils
from jax.sharding import Mesh

from repro.core import RoaringBitmap
from repro.core import aggregate
from repro.core.tensor import RoaringTensor

assert jax.device_count() == {d}, jax.device_count()
mesh = Mesh(mesh_utils.create_device_mesh(({d},)), ("wide",))

rng = np.random.default_rng(0xC0FFEE)
def bm(v):
    return RoaringBitmap.from_values(np.asarray(v, np.uint32))

k = 7
bms = []
for i in range(k):
    parts = [rng.integers(0, 1 << 18, 3000, dtype=np.uint32)]
    lo = int(rng.integers(0, 1 << 17))
    parts.append(np.arange(lo, lo + 50000, dtype=np.uint32))
    bms.append(bm(np.unique(np.concatenate(parts))))

# AND's dense-segment path needs every chunk's smallest container to be a
# bitset (arrays anchor the host fast path): 120k values over 2^18 gives
# ~26k per chunk, and a 4-way intersection stays non-empty (~2k/chunk)
dense = [bm(np.unique(rng.integers(0, 1 << 18, 120000, dtype=np.uint32)))
         for _ in range(4)]
assert all(c.kind == "bitset" for d in dense for c in d.containers)

checks = [
    ("or", aggregate.or_many(bms), aggregate.or_many(bms, mesh=mesh)),
    ("xor", aggregate.xor_many(bms), aggregate.xor_many(bms, mesh=mesh)),
    ("threshold", aggregate.threshold_many(bms, 3),
     aggregate.threshold_many(bms, 3, mesh=mesh)),
    ("threshold_w",
     aggregate.threshold_many(bms, 9, weights=[1, 2, 3, 1, 2, 3, 4]),
     aggregate.threshold_many(bms, 9, weights=[1, 2, 3, 1, 2, 3, 4],
                              mesh=mesh)),
    ("andnot", aggregate.andnot_many(bms[0], bms[1:]),
     aggregate.andnot_many(bms[0], bms[1:], mesh=mesh)),
    ("and", aggregate.and_many(dense), aggregate.and_many(dense,
                                                          mesh=mesh)),
]
for name, single, sharded in checks:
    assert single == sharded, name
    assert single.cardinality > 0, name

# mixed kinds: AND goes through host fast paths + sweep; the sharded plan
# must agree even when the intersection is empty
assert aggregate.and_many(bms) == aggregate.and_many(bms, mesh=mesh)

rt = RoaringTensor.from_bitmaps(bms)
assert rt.reduce_or(mesh=mesh).to_bitmaps()[0] == \\
    rt.reduce_or().to_bitmaps()[0]

aggregate.set_default_mesh(mesh)
try:
    assert RoaringBitmap.or_many(bms) == checks[0][1]
finally:
    aggregate.set_default_mesh(None)
print("SHARDED_OK")
"""


def _run_subprocess(devices: int) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_BODY.format(d=devices)],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.parametrize("devices", [2, 4])
def test_sharded_matches_single_device(devices):
    """or/xor/threshold/weighted-threshold/andnot are bit-identical on a
    forced multi-device CPU mesh (the acceptance contract)."""
    assert "SHARDED_OK" in _run_subprocess(devices)


def test_one_device_mesh_falls_back(rng):
    """A 1-device mesh must transparently use the single-dispatch path."""
    import jax
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    mesh = Mesh(mesh_utils.create_device_mesh(
        (1,), devices=jax.devices()[:1]), ("wide",))
    bms = [RoaringBitmap.from_values(
        rng.integers(0, 1 << 19, 20000, dtype=np.uint32)) for _ in range(4)]
    assert aggregate.or_many(bms, mesh=mesh) == aggregate.or_many(bms)
    assert aggregate.threshold_many(bms, 2, mesh=mesh) == \
        aggregate.threshold_many(bms, 2)
    dense = [RoaringBitmap.from_values(
        rng.integers(0, 1 << 18, 120000, dtype=np.uint32))
        for _ in range(3)]
    assert aggregate.and_many(dense, mesh=mesh) == \
        aggregate.and_many(dense)


def test_shard_plan_partition():
    """Every row lands on exactly one shard (minuend excepted: replicated
    for andnot), segment structure is identical across shards, and weights
    follow their rows."""
    sizes = [5, 1, 0, 7]
    wts = [[2, 3, 4, 5, 6], [7], [], [1, 2, 3, 4, 5, 6, 7]]
    ids, w, starts = aggregate._shard_plan(sizes, 3, "threshold", wts)
    seen = []
    base = {0: 0, 1: 5, 2: 6, 3: 6}
    for dev in range(3):
        assert len(starts[dev]) == len(sizes) + 1
        for si in range(len(sizes)):
            rows = ids[dev][starts[dev][si]:starts[dev][si + 1]]
            assert all(base[si] <= r < base[si] + sizes[si] for r in rows)
            for r, wr in zip(rows, w[dev][starts[dev][si]:
                                          starts[dev][si + 1]]):
                assert wr == wts[si][r - base[si]]
        seen.extend(ids[dev])
    assert sorted(seen) == list(range(13))        # exact partition

    ids, w, starts = aggregate._shard_plan([4], 3, "andnot", None)
    all_rows = [ids[d] for d in range(3)]
    assert all(rows[0] == 0 for rows in all_rows)  # minuend replicated
    subs = sorted(r for rows in all_rows for r in rows[1:])
    assert subs == [1, 2, 3]                       # subtrahends partitioned
