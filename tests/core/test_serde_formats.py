"""Cross-format serde properties: RJ02 <-> portable <-> frozen.

The PR-8 contract (docs/FORMAT.md): every format round-trips every
container kind bit-identically; the portable layout matches CRoaring's
RoaringFormatSpec byte-for-byte (golden vectors below were hand-packed
from the spec); frozen deserialization is PURE VIEWS over the source
buffer -- zero payload copies, asserted via ``np.shares_memory`` on
every container of every kind; and single-byte corruption of the
portable structural header raises ValueError (the portable format has
no checksum, so sorted-key payload flips are detected-or-different,
never a crash -- see FORMAT.md section 4).
"""

import numpy as np
import pytest

from repro.core import (
    RoaringBitmap, deserialize, deserialize_frozen, deserialize_portable,
    read_snapshot, serialize, serialize_frozen, serialize_portable,
    serialized_size_bytes, write_snapshot,
)
from repro.core.serde import sniff_format
from test_serde import _mixed_bitmap, bm

FORMATS = {
    "rj02": (serialize, deserialize),
    "portable": (serialize_portable, deserialize_portable),
    "frozen": (serialize_frozen, deserialize_frozen),
}


def _edge_bitmaps():
    full = RoaringBitmap.from_range(0, 1 << 16).run_optimize()
    return {
        "empty": RoaringBitmap(),
        "single": bm([0]),
        "top": bm([0xFFFFFFFF]),
        "full_chunk": full,
        "boundary_4096": bm(range(4096)),
        "boundary_4097": bm(range(4097)),
        "run_heavy": bm(list(range(10, 500)) + list(range(60000, 65536))
                        ).run_optimize(),
    }


# -- round trips -------------------------------------------------------

@pytest.mark.parametrize("fmt", list(FORMATS))
@pytest.mark.parametrize("trial", range(8))
def test_roundtrip_mixed(rng, fmt, trial):
    ser, de = FORMATS[fmt]
    x = _mixed_bitmap(rng, n_chunks=int(rng.integers(1, 6)))
    y = de(ser(x))
    assert y == x
    assert [c.kind for c in y.containers] == [c.kind for c in x.containers]


@pytest.mark.parametrize("fmt", list(FORMATS))
def test_roundtrip_edges(fmt):
    ser, de = FORMATS[fmt]
    for name, x in _edge_bitmaps().items():
        assert de(ser(x)) == x, (fmt, name)


@pytest.mark.parametrize("trial", range(4))
def test_cross_format_chain(rng, trial):
    """rj02 -> portable -> frozen -> rj02 loses nothing."""
    x = _mixed_bitmap(rng)
    y = deserialize(serialize(x))
    z = deserialize_portable(serialize_portable(y))
    w = deserialize_frozen(serialize_frozen(z))
    assert deserialize(serialize(w)) == x


@pytest.mark.parametrize("fmt", list(FORMATS))
def test_size_is_exact(rng, fmt):
    ser, _ = FORMATS[fmt]
    for x in [*_edge_bitmaps().values(), _mixed_bitmap(rng)]:
        assert serialized_size_bytes(x, format=fmt) == len(ser(x))


def test_bitmap_methods_and_sniff(rng):
    x = _mixed_bitmap(rng)
    for fmt in FORMATS:
        buf = x.serialize(fmt)
        assert sniff_format(buf) == fmt
        assert RoaringBitmap.deserialize(buf) == x           # auto
        assert RoaringBitmap.deserialize(buf, format=fmt) == x
    with pytest.raises(ValueError):
        x.serialize("msgpack")
    with pytest.raises(ValueError):
        RoaringBitmap.deserialize(b"????????", format="auto")


# -- CRoaring golden vectors (hand-packed from RoaringFormatSpec) ------

def test_portable_golden_no_run():
    # {1,2,3}: no-run cookie 12346, 1 container, offset header, array
    want = bytes.fromhex("3a300000" "01000000"       # cookie, n
                         "0000" "0200"               # key 0, card-1
                         "10000000"                  # offset = 16
                         "010002000300")             # 1,2,3
    assert serialize_portable(bm([1, 2, 3])) == want
    assert deserialize_portable(want) == bm([1, 2, 3])


def test_portable_golden_run():
    # [0,100): run cookie 12347 | (n-1)<<16, run-flag bitmap, 1 run
    x = RoaringBitmap.from_range(0, 100).run_optimize()
    want = bytes.fromhex("3b300000" "01"             # cookie+n-1, flags
                         "0000" "6300"               # key 0, card-1
                         "0100" "0000" "6300")       # 1 run: 0 len 99
    assert serialize_portable(x) == want
    assert deserialize_portable(want) == x


def test_portable_bitset_at_most_4096_written_as_array():
    """Writers must canonicalize: a bitset holding <= 4096 values would
    be mis-read as an array (kind is inferred from cardinality)."""
    from repro.core.builder import from_dense
    dense = np.zeros(1 << 16, bool)
    dense[:4096] = True
    x = from_dense(dense)                 # arrives as a bitset container
    y = deserialize_portable(serialize_portable(x))
    assert y == x and y.containers[0].kind == "array"


# -- frozen zero-copy contract ----------------------------------------

def test_frozen_views_share_memory_all_kinds(rng):
    """THE acceptance assertion: every deserialized container payload
    aliases the source buffer (no per-container copy), is read-only,
    and bitset cardinality comes from the directory (no payload read
    needed to construct)."""
    x = _mixed_bitmap(rng, n_chunks=5)
    buf = np.frombuffer(serialize_frozen(x), np.uint8)
    y = deserialize_frozen(buf)
    kinds = set()
    for c in y.containers:
        kinds.add(c.kind)
        payload = (c.words if c.kind == "bitset" else
                   c.values if c.kind == "array" else c.runs)
        assert np.shares_memory(payload, buf), c.kind
        assert not payload.flags.writeable
    assert kinds == {"array", "bitset", "run"}
    assert y == x


def test_frozen_backed_bitmap_safe_to_mutate(rng):
    """Frozen views are copy-on-write through the public mutators: the
    source buffer must stay byte-identical after edits."""
    x = _mixed_bitmap(rng)
    raw = serialize_frozen(x)
    buf = np.frombuffer(raw, np.uint8)
    y = deserialize_frozen(buf)
    y.add(12345)
    y.remove(next(iter(x)))
    y.run_optimize()
    assert bytes(buf) == raw
    assert deserialize_frozen(buf) == x


def test_frozen_vs_eager_bit_identity(rng):
    """A frozen-backed bitmap and its eager twin agree on every op."""
    a_f = deserialize_frozen(serialize_frozen(_mixed_bitmap(rng)))
    b = _mixed_bitmap(rng)
    a_e = deserialize(serialize(a_f))
    assert (a_f & b) == (a_e & b)
    assert (a_f | b) == (a_e | b)
    assert (a_f ^ b) == (a_e ^ b)
    assert (a_f - b) == (a_e - b)
    assert a_f.and_card(b) == a_e.and_card(b)
    assert serialize(a_f) == serialize(a_e)


# -- portable corruption sweep (FORMAT.md section 4) -------------------

def test_portable_single_byte_flip_sweep(rng):
    """No checksum in the portable layout, so the honest contract is:
    every single-byte flip either raises ValueError or yields a bitmap
    that differs from the original -- NEVER a crash or a silent
    bit-identical lie."""
    x = _mixed_bitmap(rng)
    payload = bytes(serialize_portable(x))
    positions = rng.choice(len(payload), size=min(len(payload), 256),
                           replace=False)
    for pos in positions.tolist():
        corrupt = bytearray(payload)
        corrupt[pos] ^= int(rng.integers(1, 256))
        try:
            y = deserialize_portable(bytes(corrupt))
        except ValueError:
            continue
        assert y != x, f"silent corruption at byte {pos}"


def test_portable_structural_bytes_always_raise(rng):
    """Flips in the cookie, container count, or offset header are
    always DETECTED (not merely different)."""
    x = _mixed_bitmap(rng)
    base = serialize_portable(x)
    for pos in (0, 1, 2, 3):                         # cookie / count
        corrupt = bytearray(base)
        corrupt[pos] ^= 0xFF
        with pytest.raises(ValueError):
            deserialize_portable(bytes(corrupt))
    with pytest.raises(ValueError):
        deserialize_portable(base[:len(base) - 1])   # truncated tail
    with pytest.raises(ValueError):
        deserialize_portable(base + b"\x00")         # trailing garbage


# -- snapshot archive --------------------------------------------------

def test_snapshot_roundtrip(rng, tmp_path):
    named = {"a": _mixed_bitmap(rng), "b": bm([7]), "empty": RoaringBitmap()}
    p = tmp_path / "x.snap"
    write_snapshot(p, named, meta=1234)
    for mmap in (True, False):
        snap = read_snapshot(p, mmap=mmap)
        assert snap.meta == 1234
        assert set(snap.bitmaps) == set(named)
        for k in named:
            assert snap.bitmaps[k] == named[k]
    with open(p, "rb") as f:
        assert sniff_format(f.read()) == "snapshot"


def test_snapshot_bad_magic(tmp_path):
    p = tmp_path / "bad.snap"
    p.write_bytes(b"NOTASNAP" + b"\x00" * 24)
    with pytest.raises(ValueError, match="magic"):
        read_snapshot(p)
