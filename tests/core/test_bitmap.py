"""RoaringBitmap vs python sets (randomized) + serde + rank/select."""

import numpy as np
import pytest

from repro.core import (RoaringBitmap, deserialize, serialize,
                        serialized_size_bytes)


def rand_bm(rng, n, hi=1 << 20):
    vals = rng.integers(0, hi, n).astype(np.uint32)
    return RoaringBitmap.from_values(vals), set(vals.tolist())


@pytest.mark.parametrize("na,nb", [(100, 100), (10_000, 200_000),
                                   (200_000, 10_000), (150_000, 150_000)])
def test_algebra_vs_sets(rng, na, nb):
    a, sa = rand_bm(rng, na)
    b, sb = rand_bm(rng, nb)
    assert set((a & b).to_array().tolist()) == sa & sb
    assert set((a | b).to_array().tolist()) == sa | sb
    assert set((a ^ b).to_array().tolist()) == sa ^ sb
    assert set((a - b).to_array().tolist()) == sa - sb
    assert a.and_card(b) == len(sa & sb)
    assert a.or_card(b) == len(sa | sb)
    assert a.xor_card(b) == len(sa ^ sb)
    assert a.andnot_card(b) == len(sa - sb)
    if sa | sb:
        assert abs(a.jaccard(b) - len(sa & sb) / len(sa | sb)) < 1e-12


def test_add_remove_contains(rng):
    bm = RoaringBitmap()
    ref = set()
    for v in rng.integers(0, 1 << 18, 3000).tolist():
        bm.add(v)
        ref.add(v)
    assert bm.cardinality == len(ref)
    for v in list(ref)[:1000]:
        bm.remove(v)
        ref.discard(v)
    assert set(bm.to_array().tolist()) == ref
    probes = rng.integers(0, 1 << 18, 500).tolist()
    for p in probes:
        assert (p in bm) == (p in ref)
    got = bm.contains_many(np.asarray(probes, np.uint32))
    assert np.array_equal(got, np.array([p in ref for p in probes]))


def test_bitset_to_array_demotion_on_remove(rng):
    # paper: Roaring tracks cardinality so deleting from a bitset container
    # can demote it to an array container (BitMagic can't, sec 2.2)
    vals = rng.choice(1 << 16, 5000, replace=False).astype(np.uint32)
    bm = RoaringBitmap.from_values(vals)
    assert bm.containers[0].kind == "bitset"
    for v in sorted(vals.tolist())[:904]:
        bm.remove(v)
    assert bm.containers[0].kind == "array"
    assert bm.cardinality == 4096


def test_rank_select_roundtrip(rng):
    bm, ref = rand_bm(rng, 50_000)
    sa = sorted(ref)
    for i in [0, 1, len(sa) // 3, len(sa) - 1]:
        assert bm.select(i) == sa[i]
        assert bm.rank(sa[i]) == i + 1
    assert bm.min() == sa[0] and bm.max() == sa[-1]
    with pytest.raises(IndexError):
        bm.select(len(sa))


def test_rank_select_all_kinds_and_boundaries(rng):
    # every container kind behind the prefix-cached rank/select
    parts = [rng.choice(1 << 16, 3000, replace=False),
             (1 << 16) + rng.choice(1 << 16, 30000, replace=False),
             np.arange(3 << 16, (3 << 16) + 50000)]
    vals = np.unique(np.concatenate(parts)).astype(np.uint32)
    bm = RoaringBitmap.from_values(vals).run_optimize()
    assert {c.kind for c in bm.containers} == {"array", "bitset", "run"}
    sa = np.sort(vals)
    for i in [0, 2999, 3000, 17000, len(sa) - 1]:
        assert bm.select(i) == int(sa[i])
        assert bm.rank(int(sa[i])) == i + 1
    # rank of absent values, chunk gaps, and past-the-end
    for v in [0, (1 << 16) - 1, (2 << 16) + 7, (3 << 16) + 50000, 1 << 22]:
        assert bm.rank(v) == int(np.searchsorted(sa, v, side="right"))
    assert bm.rank(int(sa[0]) - 1) == 0 if sa[0] else True


def test_rank_select_cache_invalidation(rng):
    """add/remove/run_optimize must invalidate the cumulative-cardinality
    prefix cache (paper section 6 navigation)."""
    vals = np.unique(rng.integers(0, 1 << 19, 20_000,
                                  dtype=np.uint32))
    bm = RoaringBitmap.from_values(vals)
    n = bm.cardinality                      # builds the cache
    assert bm.rank(1 << 20) == n
    new = int(vals[-1]) + 5
    bm.add(new)
    assert bm.cardinality == n + 1
    assert bm.max() == new
    assert bm.rank(1 << 20) == n + 1
    bm.remove(new)
    assert bm.cardinality == n
    assert bm.select(n - 1) == int(vals[-1])
    bm.run_optimize()
    assert bm.rank(int(vals[0])) == 1
    # adding a value in a NEW chunk shifts every later prefix entry
    bm.add(0) if 0 not in bm else None
    assert bm.select(0) == bm.min()


def test_serde_roundtrip_all_kinds(rng):
    bm, _ = rand_bm(rng, 100_000)
    bm = bm | RoaringBitmap.from_range(1 << 21, (1 << 21) + 300_000)
    bm.run_optimize()
    kinds = {c.kind for c in bm.containers}
    assert "run" in kinds
    assert deserialize(serialize(bm)) == bm
    # serialized ~= in-memory (paper sec 5.4)
    assert abs(serialized_size_bytes(bm) - bm.memory_bytes()) \
        < 0.1 * bm.memory_bytes() + 64


def test_wide_union(rng):
    bms, refs = zip(*[rand_bm(rng, 5000, 1 << 22) for _ in range(30)])
    wide = RoaringBitmap.or_many(list(bms))
    want = set().union(*refs)
    assert set(wide.to_array().tolist()) == want
    inter = RoaringBitmap.and_many(list(bms))
    assert set(inter.to_array().tolist()) == set.intersection(*refs)


def test_from_range_runs():
    bm = RoaringBitmap.from_range(10, 200_000)
    assert all(c.kind == "run" for c in bm.containers)
    assert bm.cardinality == 199_990
    assert 9 not in bm and 10 in bm and 199_999 in bm and 200_000 not in bm


def test_memory_bytes_ordering(rng):
    # roaring <= uncompressed bitset for sparse data
    bm, ref = rand_bm(rng, 1000, 1 << 26)
    bitset_bytes = (1 << 26) // 8
    assert bm.memory_bytes() < bitset_bytes / 100


def test_version_bumps_on_every_observable_mutation(rng):
    """Mutation-counter audit (serving caches revalidate against
    ``_version``): any observable change through a mutating API must
    change ``_version``, across every container-kind transition a
    seeded random workload can drive.  The full mutator surface is
    ``add`` / ``remove`` / ``run_optimize`` -- the set operators return
    new bitmaps -- so stale SimilarityEngine slabs are impossible."""
    bm = RoaringBitmap.from_values(
        rng.choice(1 << 18, size=6000, replace=False).astype(np.uint32))
    seen = set(bm.to_array().tolist())
    for _ in range(400):
        v = int(rng.integers(0, 1 << 18))
        before = (bm._version, bm.cardinality)
        if rng.random() < 0.5:
            changed = v not in seen
            bm.add(v)
            seen.add(v)
        else:
            changed = v in seen
            bm.remove(v)
            seen.discard(v)
        assert bm.cardinality == len(seen)
        if changed:
            assert bm._version != before[0], \
                "observable mutation left _version unchanged"
    v0 = bm._version
    bm.run_optimize()                         # repacks containers
    assert bm._version != v0
    assert set(bm.to_array().tolist()) == seen


def test_version_survives_copy_isolation(rng):
    """Mutating a copy must never be observable through the original
    (copy-on-write contract backing zero-copy wide aggregation)."""
    bm = RoaringBitmap.from_values(np.arange(10000, dtype=np.uint32))
    cp = bm.copy()
    v0 = bm._version
    cp.add(200_000)
    cp.remove(5)
    assert bm._version == v0
    assert 5 in bm and 200_000 not in bm
