"""Device-resident top-k similarity engine: edge cases the kernel must
preserve (ISSUE 5 satellite coverage).

The contract under test: ``InvertedIndex.similar`` / ``SimilarityEngine
.topk`` return bit-identical results on the pruned host path and on the
fused kernel path (backend="ref"/"pallas") -- including tie ordering at
the k boundary -- and the kernel path is ONE engine dispatch."""

import numpy as np
import pytest

from repro.core import RoaringBitmap
from repro.core.pairwise import METRICS, SimilarityEngine, _scores_host
from repro.data.index import InvertedIndex


def build_index(rng, n_terms=20, n_docs=600):
    hi = min(9, n_terms + 1)
    docs = [[f"t{t}" for t in rng.choice(n_terms, rng.integers(2, hi),
                                         replace=False)]
            for _ in range(n_docs)]
    return InvertedIndex().build(docs)


def brute_force(idx, term, k, metric):
    """Numpy oracle: float32 scores over every other term + stable
    argsort -- the definition the engine must reproduce exactly."""
    q = idx.postings.get(term, RoaringBitmap())
    terms = [t for t in idx.postings if t != term]
    inter = np.array([q.and_card(idx.postings[t]) for t in terms],
                     np.int64)
    cards = np.array([idx.postings[t].cardinality for t in terms],
                     np.int64)
    score = _scores_host(inter, q.cardinality, cards, metric)
    order = np.argsort(-score, kind="stable")[:k]
    return [(terms[i], float(score[i])) for i in order.tolist()]


@pytest.mark.parametrize("metric", METRICS)
def test_similar_matches_numpy_oracle(rng, metric):
    idx = build_index(rng)
    for term in ("t0", "t7", "t19"):
        want = brute_force(idx, term, 6, metric)
        assert idx.similar(term, 6, metric) == want
        assert idx.similar(term, 6, metric, backend="ref") == want
    assert idx.similar("t3", 6, metric, backend="pallas") == \
        brute_force(idx, "t3", 6, metric)


def test_score_ties_at_k_boundary(rng):
    """Duplicate posting lists produce exact score ties; ties must order
    by term insertion index on every backend."""
    base = rng.integers(0, 5000, 800, dtype=np.uint32)
    docs_of = {"q": base,
               "a": base[:500], "b": base[:500], "c": base[:500],
               "d": base[:500], "e": base[:100]}
    idx = InvertedIndex()
    for t, vals in docs_of.items():
        idx.postings[t] = RoaringBitmap.from_values(vals)
    idx.n_docs = 5000
    # a..d tie exactly; k=2 cuts through the tie group
    got = idx.similar("q", top_k=2)
    assert [t for t, _ in got] == ["a", "b"]
    assert got[0][1] == got[1][1]
    for backend in ("ref", "pallas"):
        assert idx.similar("q", top_k=2, backend=backend) == got
    got4 = idx.similar("q", top_k=4)
    assert [t for t, _ in got4] == ["a", "b", "c", "d"]
    assert idx.similar("q", top_k=4, backend="ref") == got4


def test_k_larger_than_candidate_set(rng):
    idx = build_index(rng, n_terms=7)
    got = idx.similar("t0", top_k=100)
    assert len(got) == len(idx.postings) - 1
    assert got == idx.similar("t0", top_k=100, backend="ref")
    assert [t for t, _ in got] == \
        [t for t, _ in brute_force(idx, "t0", 100, "jaccard")]
    assert idx.similar("t0", top_k=0) == []


def test_empty_term_and_empty_index(rng):
    idx = build_index(rng, n_terms=8)
    # unknown term: queries as an empty posting list, scores still total
    got = idx.similar("nope", top_k=3)
    assert len(got) == 3 and all(s == 0.0 for _, s in got)
    assert got == idx.similar("nope", top_k=3, backend="ref")
    # containment with an empty query: zero denominator scores 1.0
    c = idx.similar("nope", top_k=3, metric="containment")
    assert all(s == 1.0 for _, s in c)
    assert c == idx.similar("nope", top_k=3, metric="containment",
                            backend="ref")
    assert InvertedIndex().similar("x", top_k=5) == []


def test_engine_bitmap_query_and_all_empty(rng):
    bms = [RoaringBitmap.from_values(
        rng.integers(0, 1 << 17, 3000, dtype=np.uint32))
        for _ in range(6)]
    eng = SimilarityEngine(bms)
    q = RoaringBitmap.from_values(
        rng.integers(0, 1 << 17, 3000, dtype=np.uint32))
    idx_h, sc_h, in_h = eng.topk(q, 4)
    idx_r, sc_r, in_r = eng.topk(q, 4, backend="ref")
    assert np.array_equal(idx_h, idx_r)
    assert np.array_equal(sc_h, sc_r)
    assert np.array_equal(in_h, in_r)
    for i, inter in zip(idx_h.tolist(), in_h.tolist()):
        assert inter == q.and_card(bms[i])
    # member query excludes itself
    idx_m, _, _ = eng.topk(2, 10)
    assert 2 not in idx_m.tolist() and idx_m.size == 5
    # out-of-range member indices raise instead of slicing garbage
    # (negative python indexing would silently mix candidates)
    for bad in (-1, -2, len(bms)):
        with pytest.raises(IndexError):
            eng.topk(bad, 3)
    # an engine of empty bitmaps never dispatches and never crashes
    eng0 = SimilarityEngine([RoaringBitmap(), RoaringBitmap()])
    i0, s0, n0 = eng0.topk(RoaringBitmap(), 5)
    assert i0.size == 2 and np.all(n0 == 0)


def test_similar_is_one_dispatch(rng, monkeypatch):
    """The acceptance contract: score + select execute as ONE engine
    dispatch on kernel backends; the host path issues none."""
    from repro.kernels import ops as kops
    calls = []
    for name in ("similarity_topk", "bitset_pair_card", "bitset_pair_op",
                 "array_intersect_card", "array_bitset_probe",
                 "array_pair_masks", "bitset_op_card", "segment_reduce"):
        real = getattr(kops, name)

        def spy(*a, _real=real, _name=name, **k):
            calls.append(_name)
            return _real(*a, **k)

        monkeypatch.setattr(kops, name, spy)
    idx = build_index(rng)
    for backend in ("ref", "pallas"):
        calls.clear()
        idx.similar("t0", top_k=5, backend=backend)
        assert calls == ["similarity_topk"], (backend, calls)
    calls.clear()
    idx.similar("t1", top_k=5)                   # host path
    assert calls == [], calls


def test_engine_cache_invalidation(rng):
    idx = build_index(rng, n_terms=6)
    idx.similar("t0", top_k=3)
    assert idx._sim is not None
    idx.add_document(idx.n_docs, ["t0", "t5"])
    assert idx._sim is None                      # mutation drops the slab
    # rebuilt engine answers for the NEW postings, not the stale slab
    assert idx.similar("t0", top_k=3) == \
        brute_force(idx, "t0", 3, "jaccard")
    # direct edits of the public postings dict are caught by the
    # snapshot revalidation (no index-API call involved)
    idx.postings["clone"] = RoaringBitmap.from_values(
        idx.postings["t0"].to_array())
    got = idx.similar("t0", top_k=1)
    assert got[0] == ("clone", 1.0)
    idx.postings["t1"].add(5_000_000)            # in-place point update
    assert idx.similar("t1", top_k=3) == \
        brute_force(idx, "t1", 3, "jaccard")
    # content change that preserves BOTH object identity and
    # cardinality: caught by the bitmap mutation counter
    idx2 = InvertedIndex()
    idx2.postings["a"] = RoaringBitmap.from_values([0, 1])
    idx2.postings["b"] = RoaringBitmap.from_values([0, 1])
    idx2.n_docs = 10
    assert idx2.similar("a", 1)[0] == ("b", 1.0)
    idx2.postings["b"].remove(0)
    idx2.postings["b"].remove(1)
    idx2.postings["b"].add(2)
    idx2.postings["b"].add(3)
    assert idx2.similar("a", 1)[0][1] == 0.0


def test_pruning_never_changes_results(rng):
    """The bound-pruning planner must be invisible: heavy cardinality
    skew (the prunable regime) still matches the unpruned oracle."""
    bms = []
    for r in range(24):
        size = max(20, int(60_000 / (r + 1) ** 2))
        bms.append(RoaringBitmap.from_values(
            rng.integers(0, 1 << 18, size, dtype=np.uint32)))
    eng = SimilarityEngine(bms)
    for qi in (0, 5, 23):
        for metric in METRICS:
            idx_h, sc_h, in_h = eng.topk(qi, 6, metric)
            idx_r, sc_r, in_r = eng.topk(qi, 6, metric, backend="ref")
            assert np.array_equal(idx_h, idx_r), (qi, metric)
            assert np.array_equal(sc_h, sc_r), (qi, metric)
            assert np.array_equal(in_h, in_r), (qi, metric)


@pytest.mark.parametrize("metric", sorted(METRICS))
def test_topk_batch_matches_per_query_topk(rng, metric):
    """The server's similarity coalescing path: a vmapped batch on the
    kernel backend and the host loop must both equal per-query ``topk``
    exactly (indices, float32 scores, intersections)."""
    cands = [RoaringBitmap.from_values(
        rng.choice(1 << 17, int(rng.integers(30, 3000)),
                   replace=False).astype(np.uint32)) for _ in range(25)]
    eng = SimilarityEngine(cands)
    queries = [0, 7, 24,
               RoaringBitmap.from_values(
                   rng.choice(1 << 17, 500,
                              replace=False).astype(np.uint32)),
               RoaringBitmap()]
    for backend in ("ref", None, "host"):
        got = eng.topk_batch(queries, 6, metric, backend=backend)
        for q, (gi, gs, gn) in zip(queries, got):
            wi, ws, wn = eng.topk(q, 6, metric, backend=backend)
            assert np.array_equal(gi, wi)
            assert np.array_equal(gs, ws)
            assert np.array_equal(gn, wn)


def test_topk_batch_edge_cases(rng):
    eng = SimilarityEngine([RoaringBitmap.from_values(
        np.arange(100, dtype=np.uint32))])
    # member query of a 1-candidate engine: nothing left after exclusion
    out = eng.topk_batch([0], 5, backend="ref")
    assert out[0][0].size == 0
    assert eng.topk_batch([], 5, backend="ref") == []
    with pytest.raises(ValueError):
        eng.topk_batch([0], 5, metric="bogus", backend="ref")
    with pytest.raises(IndexError):
        eng.topk_batch([3], 5, backend="ref")
