"""RoaringTensor (device layout) vs the host RoaringBitmap oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RoaringBitmap
from repro.core.tensor import RoaringTensor, block_mask_words


@pytest.fixture
def pairs(rng):
    def rand(n, hi):
        return RoaringBitmap.from_values(
            rng.integers(0, hi, n).astype(np.uint32))
    a = [rand(30000, 1 << 19), rand(400, 1 << 18),
         RoaringBitmap.from_range(5000, 180_000).run_optimize(),
         RoaringBitmap()]
    b = [rand(15000, 1 << 19), RoaringBitmap.from_range(0, 90_000),
         rand(70000, 1 << 18), rand(100, 1 << 16)]
    return a, b


def test_roundtrip(pairs):
    a, _ = pairs
    t = RoaringTensor.from_bitmaps(a, capacity=8)
    assert t.to_bitmaps() == a
    assert np.array_equal(np.asarray(t.cardinality()),
                          [x.cardinality for x in a])


@pytest.mark.parametrize("op,hop", [("__and__", "__and__"),
                                    ("__or__", "__or__"),
                                    ("__xor__", "__xor__"),
                                    ("andnot", "andnot")])
def test_binary_ops(pairs, op, hop):
    a, b = pairs
    ta = RoaringTensor.from_bitmaps(a, capacity=8)
    tb = RoaringTensor.from_bitmaps(b, capacity=8)
    got = getattr(ta, op)(tb).to_bitmaps()
    want = [getattr(x, hop)(y) for x, y in zip(a, b)]
    assert got == want


def test_count_only(pairs):
    a, b = pairs
    ta = RoaringTensor.from_bitmaps(a, capacity=8)
    tb = RoaringTensor.from_bitmaps(b, capacity=8)
    assert np.array_equal(np.asarray(ta.and_card(tb)),
                          [x.and_card(y) for x, y in zip(a, b)])
    assert np.array_equal(np.asarray(ta.xor_card(tb)),
                          [x.xor_card(y) for x, y in zip(a, b)])
    np.testing.assert_allclose(
        np.asarray(ta.jaccard(tb)),
        [x.jaccard(y) for x, y in zip(a, b)], rtol=1e-6)


def test_contains(pairs, rng):
    a, _ = pairs
    ta = RoaringTensor.from_bitmaps(a, capacity=8)
    q = rng.integers(0, 1 << 19, (len(a), 200)).astype(np.uint32)
    got = np.asarray(ta.contains(jnp.asarray(q)))
    for i, bmx in enumerate(a):
        assert np.array_equal(got[i], bmx.contains_many(q[i])), i


def test_run_optimize_device(pairs):
    a, _ = pairs
    ta = RoaringTensor.from_bitmaps(a, capacity=8).run_optimize()
    assert ta.to_bitmaps() == a
    # the dense range must become a run container on device too
    kinds = np.asarray(ta.kinds)
    assert (kinds == 3).any()
    # packed bytes parity with host run_optimize
    host = [x.copy().run_optimize().memory_bytes() for x in a]
    assert np.asarray(ta.packed_nbytes()).tolist() == host


def test_jit_composition(pairs):
    a, b = pairs
    ta = RoaringTensor.from_bitmaps(a, capacity=8)
    tb = RoaringTensor.from_bitmaps(b, capacity=8)

    @jax.jit
    def f(x, y):
        return ((x & y) | (x ^ y)).cardinality()   # == |x ∪ y|

    want = [(x | y).cardinality for x, y in zip(a, b)]
    assert np.asarray(f(ta, tb)).tolist() == want


def test_block_mask_words():
    bm = RoaringBitmap.from_values([0, 5, 31, 32, 100])
    w = np.asarray(block_mask_words([bm], 128))
    assert w.shape == (1, 4)
    assert int(w[0, 0]) == (1 | (1 << 5) | (1 << 31))
    assert int(w[0, 1]) == 1
    assert int(w[0, 3]) == (1 << 4)
