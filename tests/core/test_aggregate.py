"""Wide-aggregation planner vs a Python-set oracle.

Property-style tests (seeded rng sweeps; hypothesis is not available in this
environment) across adversarial distributions: dense runs, sparse arrays,
the 4096/4097 array<->bitset boundary, disjoint key ranges, and the K=0/K=1
edges.  Every op is checked against functools.reduce over Python sets and
threshold against an occurrence Counter."""

import operator
from collections import Counter
from functools import reduce

import numpy as np
import pytest

from repro.core import RoaringBitmap
from repro.core import aggregate


def bm(values):
    return RoaringBitmap.from_values(np.asarray(list(values), np.uint32))


# ---------------------------------------------------------------------------
# adversarial input distributions
# ---------------------------------------------------------------------------

def dense_runs(rng, k):
    """Heavily overlapping intervals -> run/bitset containers."""
    out = []
    for _ in range(k):
        parts = []
        for _ in range(int(rng.integers(1, 4))):
            lo = int(rng.integers(0, 1 << 18))
            parts.append(np.arange(lo, lo + int(rng.integers(1, 70000)),
                                   dtype=np.uint32))
        out.append(np.unique(np.concatenate(parts)))
    return out


def sparse_arrays(rng, k):
    """Small scattered arrays across many chunks."""
    return [rng.integers(0, 1 << 20, int(rng.integers(1, 500)),
                         dtype=np.uint32) for _ in range(k)]


def boundary_4096(rng, k):
    """Exactly 4096 / 4097 values inside one chunk: the array<->bitset
    result-kind boundary."""
    out = []
    for i in range(k):
        n = 4096 + (i % 2)
        out.append(rng.choice(1 << 16, n, replace=False).astype(np.uint32))
    return out


def disjoint_keys(rng, k):
    """Each bitmap owns its own key range -> all singleton groups."""
    return [(np.uint32(i << 16) +
             rng.integers(0, 1 << 16, int(rng.integers(1, 3000)),
                          dtype=np.uint32))
            for i in range(k)]


def mixed(rng, k):
    """Runs + arrays + bitsets overlapping in the same chunks."""
    gens = [dense_runs, sparse_arrays, boundary_4096]
    return [gens[i % len(gens)](rng, 1)[0] for i in range(k)]


DISTS = [dense_runs, sparse_arrays, boundary_4096, disjoint_keys, mixed]


def _check_invariants(r):
    assert r.keys == sorted(r.keys)
    for c in r.containers:
        assert c.card > 0
        if c.kind == "array":
            assert c.card <= 4096
            assert np.all(np.diff(c.values.astype(np.int64)) > 0)
        elif c.kind == "run":
            assert c.num_runs() <= 2047


@pytest.mark.parametrize("dist", DISTS, ids=lambda d: d.__name__)
@pytest.mark.parametrize("k", [2, 3, 7])
def test_wide_ops_vs_set_oracle(rng, dist, k):
    vals = dist(rng, k)
    bms = [bm(v) for v in vals]
    sets = [set(v.tolist()) for v in vals]
    for name, wide, op in [("or", RoaringBitmap.or_many, operator.or_),
                           ("and", RoaringBitmap.and_many, operator.and_),
                           ("xor", RoaringBitmap.xor_many, operator.xor)]:
        want = sorted(reduce(op, sets))
        got = wide(bms)
        assert got.to_array().tolist() == want, (name, dist.__name__, k)
        _check_invariants(got)


@pytest.mark.parametrize("dist", DISTS, ids=lambda d: d.__name__)
@pytest.mark.parametrize("k,t", [(3, 2), (5, 3), (7, 7), (4, 1)])
def test_threshold_vs_counter_oracle(rng, dist, k, t):
    vals = dist(rng, k)
    bms = [bm(v) for v in vals]
    cnt = Counter()
    for v in vals:
        cnt.update(set(v.tolist()))
    want = sorted(x for x, c in cnt.items() if c >= t)
    got = RoaringBitmap.threshold_many(bms, t)
    assert got.to_array().tolist() == want, (dist.__name__, k, t)
    _check_invariants(got)


def test_wide_matches_pairwise(rng):
    """The planner must agree with the two-by-two merge operators."""
    for _ in range(5):
        bms = [bm(rng.integers(0, 1 << 19, int(rng.integers(0, 20000)),
                               dtype=np.uint32)) for _ in range(4)]
        assert RoaringBitmap.or_many(bms) == reduce(operator.or_, bms)
        assert RoaringBitmap.and_many(bms) == reduce(operator.and_, bms)
        assert RoaringBitmap.xor_many(bms) == reduce(operator.xor, bms)


def test_threshold_endpoints(rng):
    """T=1 is union, T=K intersection, T>K empty, T<1 rejected."""
    bms = [bm(rng.integers(0, 1 << 18, 5000, dtype=np.uint32))
           for _ in range(5)]
    assert RoaringBitmap.threshold_many(bms, 1) == RoaringBitmap.or_many(bms)
    assert RoaringBitmap.threshold_many(bms, 5) == RoaringBitmap.and_many(bms)
    assert not RoaringBitmap.threshold_many(bms, 6)
    with pytest.raises(ValueError):
        RoaringBitmap.threshold_many(bms, 0)


def test_k0_and_k1_edges(rng):
    for wide in (RoaringBitmap.or_many, RoaringBitmap.and_many,
                 RoaringBitmap.xor_many):
        assert wide([]).cardinality == 0
    assert RoaringBitmap.threshold_many([], 1).cardinality == 0
    x = bm(rng.integers(0, 1 << 20, 10000, dtype=np.uint32))
    for wide in (RoaringBitmap.or_many, RoaringBitmap.and_many,
                 RoaringBitmap.xor_many):
        assert wide([x]) == x
    assert RoaringBitmap.threshold_many([x], 1) == x
    assert RoaringBitmap.threshold_many([x], 2).cardinality == 0


def test_full_chunk_or_short_circuit():
    """A full 2^16 chunk in any input forces a full result chunk."""
    a = RoaringBitmap.from_range(0, 1 << 16)
    b = bm([5, 70000])
    r = RoaringBitmap.or_many([a, b, b])
    assert r.cardinality == (1 << 16) + 1
    assert r.containers[0].card == 1 << 16


def test_and_empty_key_early_exit():
    """Disjoint key sets make AND exit before touching containers."""
    a = bm(range(0, 1000))
    c = bm(range(1 << 17, (1 << 17) + 1000))
    assert RoaringBitmap.and_many([a, c, a]).cardinality == 0


def test_aggregate_duplicates_of_same_bitmap(rng):
    """The same bitmap object repeated K times: OR/AND are idempotent and
    XOR follows parity."""
    x = bm(rng.integers(0, 1 << 19, 30000, dtype=np.uint32))
    assert RoaringBitmap.or_many([x, x, x]) == x
    assert RoaringBitmap.and_many([x, x, x]) == x
    assert RoaringBitmap.xor_many([x, x, x]) == x
    assert RoaringBitmap.xor_many([x, x]).cardinality == 0
    assert RoaringBitmap.threshold_many([x, x, x], 3) == x


def test_planner_module_direct_backend(rng):
    """The planner accepts an explicit backend and the ref backend agrees
    with the default dispatch."""
    vals = [rng.integers(0, 1 << 18, 20000, dtype=np.uint32)
            for _ in range(3)]
    bms = [bm(v) for v in vals]
    assert aggregate.or_many(bms, backend="ref") == \
        RoaringBitmap.or_many(bms)
    assert aggregate.threshold_many(bms, 2, backend="ref") == \
        RoaringBitmap.threshold_many(bms, 2)


def test_result_mutation_does_not_corrupt_inputs(rng):
    """Pass-through keys share containers zero-copy; point updates on the
    result must copy-on-write instead of corrupting the inputs."""
    vals = rng.choice(1 << 16, 10000, replace=False).astype(np.uint32) \
        + np.uint32(3 << 16)
    a = bm(vals)                          # single bitset container, key 3
    b = bm([1, 2])
    want = a.to_array().copy()
    u = RoaringBitmap.or_many([a, b])
    u.add(int((3 << 16) + 1))
    u.remove(int(want[0]))
    assert np.array_equal(a.to_array(), want)


def test_tensor_reduce_or_matches_host(rng):
    from repro.core.tensor import RoaringTensor
    bms = [bm(rng.integers(0, 1 << 19, int(rng.integers(1, 15000)),
                           dtype=np.uint32)) for _ in range(5)]
    rt = RoaringTensor.from_bitmaps(bms)
    assert rt.reduce_or().to_bitmaps()[0] == RoaringBitmap.or_many(bms)


@pytest.mark.parametrize("dist", DISTS, ids=lambda d: d.__name__)
@pytest.mark.parametrize("k", [1, 3, 6])
def test_andnot_many_vs_set_oracle(rng, dist, k):
    """a - (b1 | ... | bk) against the Python-set oracle and the pairwise
    two-by-two chain, across every adversarial distribution."""
    vals = dist(rng, k + 1)
    a, subs = bm(vals[0]), [bm(v) for v in vals[1:]]
    want = sorted(set(vals[0].tolist()) -
                  set().union(*(set(v.tolist()) for v in vals[1:])))
    got = RoaringBitmap.andnot_many(a, subs)
    assert got.to_array().tolist() == want, (dist.__name__, k)
    _check_invariants(got)
    assert got == reduce(operator.sub, [a] + subs)


def test_andnot_many_edges(rng):
    a = bm(rng.integers(0, 1 << 19, 20000, dtype=np.uint32))
    assert RoaringBitmap.andnot_many(a, []) == a
    assert RoaringBitmap.andnot_many(a, [a]).cardinality == 0
    assert RoaringBitmap.andnot_many(RoaringBitmap(), [a]).cardinality == 0
    # a full subtrahend chunk wipes the minuend's chunk entirely
    full = RoaringBitmap.from_range(0, 1 << 16)
    r = RoaringBitmap.andnot_many(bm([5, 70000]), [full])
    assert r.to_array().tolist() == [70000]
    # empty subtrahends are no-ops
    assert RoaringBitmap.andnot_many(a, [RoaringBitmap()] * 3) == a


@pytest.mark.parametrize("dist", DISTS, ids=lambda d: d.__name__)
@pytest.mark.parametrize("k,t", [(3, 4), (5, 7), (4, 2)])
def test_threshold_weighted_vs_counter_oracle(rng, dist, k, t):
    """Weighted T-occurrence against a weighted Counter oracle."""
    vals = dist(rng, k)
    bms = [bm(v) for v in vals]
    w = [int(x) for x in rng.integers(1, 6, k)]
    cnt = Counter()
    for v, wi in zip(vals, w):
        for x in set(v.tolist()):
            cnt[x] += wi
    want = sorted(x for x, c in cnt.items() if c >= t)
    got = RoaringBitmap.threshold_many(bms, t, weights=w)
    assert got.to_array().tolist() == want, (dist.__name__, k, t, w)
    _check_invariants(got)


def test_threshold_weight_one_degenerates(rng):
    """weights=[1]*k must agree with the unweighted plan exactly."""
    for dist in DISTS:
        vals = dist(rng, 4)
        bms = [bm(v) for v in vals]
        for t in (1, 2, 4):
            assert RoaringBitmap.threshold_many(bms, t, weights=[1] * 4) \
                == RoaringBitmap.threshold_many(bms, t), (dist.__name__, t)


def test_threshold_weighted_edges(rng):
    bms = [bm(rng.integers(0, 1 << 18, 5000, dtype=np.uint32))
           for _ in range(3)]
    w = [5, 3, 2]
    # t above the total weight is empty without touching containers
    assert RoaringBitmap.threshold_many(bms, 11, weights=w).cardinality == 0
    # t == total weight is the intersection
    assert RoaringBitmap.threshold_many(bms, 10, weights=w) == \
        RoaringBitmap.and_many(bms)
    # t == 1 is the union
    assert RoaringBitmap.threshold_many(bms, 1, weights=w) == \
        RoaringBitmap.or_many(bms)
    # a single heavy bitmap can satisfy t alone
    got = RoaringBitmap.threshold_many(bms, 5, weights=w)
    for x in bms[0].to_array()[:100].tolist():
        assert x in got
    with pytest.raises(ValueError):
        RoaringBitmap.threshold_many(bms, 2, weights=[1, 2])   # wrong len
    with pytest.raises(ValueError):
        RoaringBitmap.threshold_many(bms, 2, weights=[1, 0, 2])  # w < 1


def test_index_query_andnot_chain(rng):
    from repro.data.index import InvertedIndex
    docs = [[f"t{t}" for t in rng.choice(10, rng.integers(1, 5),
                                         replace=False)]
            for _ in range(200)]
    idx = InvertedIndex().build(docs)
    got = idx.query_andnot("t0", "t1", "t2")
    for d in range(len(docs)):
        want = "t0" in docs[d] and "t1" not in docs[d] and \
            "t2" not in docs[d]
        assert (d in got) == want, d


def test_index_query_threshold(rng):
    from repro.data.index import InvertedIndex
    docs = [[f"t{t}" for t in rng.choice(20, rng.integers(1, 8),
                                         replace=False)]
            for _ in range(300)]
    idx = InvertedIndex().build(docs)
    terms = [f"t{i}" for i in range(6)]
    got = idx.query_threshold(terms, 3)
    for d in range(len(docs)):
        n_match = sum(t in docs[d] for t in terms)
        assert (d in got) == (n_match >= 3)


# ---------------------------------------------------------------------------
# multi-query planning (plan_wide / execute_plans / execute_plan_host)
# ---------------------------------------------------------------------------

def _random_query(rng, dist):
    """One random wide query as (op, bitmaps, t, weights)."""
    k = int(rng.integers(2, 7))
    vals = dist(rng, k)
    bms = [bm(v) for v in vals]
    op = ["or", "and", "xor", "andnot", "threshold"][int(rng.integers(5))]
    t, w = 0, None
    if op == "threshold":
        t = int(rng.integers(1, k + 1))
        if rng.random() < 0.5:
            w = [int(x) for x in rng.integers(1, 5, k)]
    return op, bms, t, w


def _direct(op, bms, t, w, backend):
    if op == "or":
        return aggregate.or_many(bms, backend=backend)
    if op == "and":
        return aggregate.and_many(bms, backend=backend)
    if op == "xor":
        return aggregate.xor_many(bms, backend=backend)
    if op == "andnot":
        return aggregate.andnot_many(bms[0], bms[1:], backend=backend)
    return aggregate.threshold_many(bms, t, weights=w, backend=backend)


@pytest.mark.parametrize("seed", [0, 1])
def test_execute_plans_coalesced_bit_identical(seed):
    """N queries coalesced into one dispatch per op class must equal N
    direct executions exactly -- container kinds included (a query id is
    just another segment coordinate)."""
    rng = np.random.default_rng(seed)
    dists = [dense_runs, sparse_arrays, boundary_4096, disjoint_keys]
    queries = [_random_query(rng, dists[i % 4]) for i in range(12)]
    plans = [aggregate.plan_wide(op, b, t, w, backend="ref")
             for op, b, t, w in queries]
    batch = aggregate.execute_plans(plans, backend="ref")
    for got, (op, b, t, w) in zip(batch, queries):
        want = _direct(op, b, t, w, "ref")
        assert got == want, op
        assert [c.kind for c in got.containers] == \
               [c.kind for c in want.containers], op


@pytest.mark.parametrize("seed", [3, 4])
def test_execute_plan_host_is_bit_identical_and_jax_free(seed):
    """The degradation path: numpy-only execution of a plan matches the
    kernel dispatch bit for bit (same rows, same repack)."""
    rng = np.random.default_rng(seed)
    dists = [dense_runs, sparse_arrays, boundary_4096, disjoint_keys]
    for i in range(8):
        op, b, t, w = _random_query(rng, dists[i % 4])
        host = aggregate.execute_plan_host(
            aggregate.plan_wide(op, b, t, w, backend="ref"))
        want = _direct(op, b, t, w, "ref")
        assert host == want, op
        assert [c.kind for c in host.containers] == \
               [c.kind for c in want.containers], op


def test_per_segment_thresholds_share_one_dispatch(rng):
    """Threshold queries with DIFFERENT t values coalesce into one
    dispatch via the kernel's per-segment threshold vector."""
    k = 6
    vals = dense_runs(rng, k)
    bms = [bm(v) for v in vals]
    plans = [aggregate.plan_wide("threshold", bms, t, backend="ref")
             for t in range(2, k + 1)]
    batch = aggregate.execute_plans(plans, backend="ref")
    sets = [set(np.concatenate(vals).tolist()) for _ in range(1)]
    counts = Counter()
    for v in vals:
        counts.update(v.tolist())
    for t, got in zip(range(2, k + 1), batch):
        want = {x for x, c in counts.items() if c >= t}
        assert set(got.to_array().tolist()) == want, t


def test_plan_wide_validates_at_admission():
    with pytest.raises(ValueError, match="threshold"):
        aggregate.plan_wide("threshold", [bm([1])], 0)
    with pytest.raises(ValueError, match="weight"):
        aggregate.plan_wide("threshold", [bm([1]), bm([2])], 1,
                            weights=[1])
    with pytest.raises(ValueError, match="minuend"):
        aggregate.plan_wide("andnot", [])
    with pytest.raises(ValueError, match="unknown wide op"):
        aggregate.plan_wide("nand", [bm([1])])


def test_plan_slab_bytes_accounting(rng):
    a = bm(np.arange(0, 50000, dtype=np.uint32))          # bitset/run mix
    b_ = bm(np.arange(25000, 70000, dtype=np.uint32))
    plan = aggregate.plan_wide("or", [a, b_], backend="ref")
    assert plan.slab_bytes() == \
        sum(len(r) for r in plan.seg_rows) * 8192
    empty = aggregate.plan_wide("or", [], backend="ref")
    assert empty.slab_bytes() == 0
