"""Sharded similarity top-k over per-shard arena slabs.

The tier-1 process sees exactly one CPU device (tests/conftest.py pins
that), so the real multi-device runs happen in subprocesses launched
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``, mirroring
tests/core/test_sharded.py.  The subprocess asserts the full tentpole
contract: bit-identical results (indices, float32 scores, intersection
counts -- including tie order) against a cold single-device engine for
member / bitmap / unknown-term queries across every metric, tie groups
straddling shard boundaries at the k cut, warm re-queries moving ZERO
container rows host->device (per-shard ``ArenaStats``), single-row
single-shard repatch on :meth:`SimilarityEngine.refresh`, a seeded
mutation-query interleave, batched parity, ``InvertedIndex.similar``
wiring, and the query server's ``slab_mismatch`` recovery rung against
a sharded engine.

In-process tests cover the new per-shard kernel primitives
(``similarity_topk_ids`` / ``topk_merge``) on the ref and Pallas
interpret backends, the 1-device mesh fallback, and the arena
requirement.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[2] / "src")

_SUBPROCESS_BODY = """
import os, sys
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={d} "
    + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax
from jax.experimental import mesh_utils
from jax.sharding import Mesh

from repro.core import BitmapArena, RoaringBitmap
from repro.core.pairwise import SimilarityEngine
from repro.data.index import InvertedIndex
from repro.serve import FaultInjector, Query, QueryServer

assert jax.device_count() == {d}, jax.device_count()
S = {d}
mesh = Mesh(mesh_utils.create_device_mesh((S,)), ("wide",))

rng = np.random.default_rng(0xB17)
def bm(v):
    return RoaringBitmap.from_values(np.asarray(np.unique(v), np.uint32))

bms = []
for i in range(41):
    n = int(rng.integers(0, 6000))
    bms.append(bm(rng.choice(300_000, size=n, replace=False)))
bms.append(RoaringBitmap())                     # empty candidate

def check(a, b, ctx):
    for x, y, part in zip(a, b, ("idx", "score", "inter")):
        assert np.array_equal(x, y), (ctx, part, x, y)

arena = BitmapArena()
eng = SimilarityEngine(bms, arena=arena, mesh=mesh)
cold = SimilarityEngine(bms, arena=BitmapArena())
qbm = bm(rng.choice(300_000, size=4000, replace=False))
empty_q = RoaringBitmap()

# 1. bit-identity: member / bitmap / empty queries, every metric, k sweep
for metric in ("jaccard", "cosine", "containment"):
    for query in (0, 7, len(bms) - 1, qbm, empty_q):
        for k in (1, 5, len(bms)):
            check(cold.topk(query, k, metric, backend="ref"),
                  eng.topk(query, k, metric), (metric, k))

# 2. tie group straddling shards: identical posting lists at consecutive
# global indices (homes t % S cycle through every shard) and k cutting
# inside the group -- the winners must be the LOWEST global indices, in
# ascending order, on both paths
tie_vals = rng.choice(300_000, size=500, replace=False)
ties = [bm(tie_vals) for _ in range(2 * S + 1)]   # spans all shards twice
tied = ties + bms[:9]
tarena = BitmapArena()
teng = SimilarityEngine(tied, arena=tarena, mesh=mesh)
tcold = SimilarityEngine(tied, arena=BitmapArena())
for k in (2, S, 2 * S):                           # cuts inside the group
    got = teng.topk(bm(tie_vals), k, "jaccard")
    want = tcold.topk(bm(tie_vals), k, "jaccard", backend="ref")
    check(want, got, ("tie", k))
    assert got[0].tolist() == list(range(k))      # lowest global indices
    assert np.all(got[1][:k] == got[1][0])        # one tie group

# 3. warm re-queries move ZERO container rows host->device
shards = arena.shard_slabs(mesh)
up0 = [s.rows_uploaded for s in shards.stats]
g0 = [s.device_gathers for s in shards.stats]
for metric in ("jaccard", "cosine"):
    eng.topk(3, 10, metric)
    eng.topk(qbm, 10, metric)
assert [s.rows_uploaded for s in shards.stats] == up0
assert all(g1 > g for g1, g in zip(
    (s.device_gathers for s in shards.stats), g0))
assert arena.stats.rows_uploaded == 0             # single-dev slab unused

# 4. refresh(): one container edit repatches exactly ONE row on exactly
# ONE shard
bms[5].add(299_999)
assert eng.refresh()
p0 = [s.rows_patched for s in shards.stats]
got = eng.topk(5, 7, "jaccard")                   # flush happens lazily
deltas = [b - a for a, b in zip(p0,
                                (s.rows_patched for s in shards.stats))]
assert sum(deltas) == 1 and max(deltas) == 1, deltas
check(SimilarityEngine(bms, arena=BitmapArena()).topk(
    5, 7, "jaccard", backend="ref"), got, "refresh")

# 5. seeded mutation-query interleave vs a cold single-device engine
for step in range(12):
    t = int(rng.integers(0, len(bms) - 1))
    bms[t].add(int(rng.integers(0, 1 << 20)))
    eng.refresh()
    q = int(rng.integers(0, len(bms))) if step % 2 else qbm
    k = int(rng.integers(1, 12))
    metric = ("jaccard", "cosine", "containment")[step % 3]
    check(SimilarityEngine(bms, arena=BitmapArena()).topk(
        q, k, metric, backend="ref"),
        eng.topk(q, k, metric), ("interleave", step))

# 6. batched parity
batch = [0, 1, qbm, len(bms) - 1]
wants = SimilarityEngine(bms, arena=BitmapArena()).topk_batch(
    batch, 6, "jaccard", backend="ref")
for want, got in zip(wants, eng.topk_batch(batch, 6, "jaccard")):
    check(want, got, "batch")

# 7. InvertedIndex.similar(mesh=) + QueryServer slab_mismatch recovery
docs = [[f"t{{j}}" for j in rng.choice(50, rng.integers(2, 12))]
        for _ in range(3000)]
cold_ix = InvertedIndex().build(docs)
warm_ix = InvertedIndex(arena=BitmapArena()).build(docs)
assert warm_ix.similar("t1", 8, mesh=mesh) == cold_ix.similar("t1", 8)
assert warm_ix.similar("t1", 8, "cosine", mesh=mesh) == \\
    cold_ix.similar("t1", 8, "cosine")
assert warm_ix.similar("absent", 8, mesh=mesh) == \\
    cold_ix.similar("absent", 8)

faults = FaultInjector.script({{"slab_mismatch": [True]}})
srv = QueryServer(warm_ix, backend="ref", faults=faults, mesh=mesh)
ref_srv = QueryServer(cold_ix, backend="ref")
qs = [Query.similar("t2", 5), Query.similar("t7", 3, metric="cosine")]
ta = [srv.submit(q) for q in qs]
tb = [ref_srv.submit(q) for q in qs]
srv.run_until_idle()
ref_srv.run_until_idle()
for a, b in zip(ta, tb):
    assert a.result.ok and a.result.value == b.result.value
assert srv.stats().replans == 1
print("TOPK_SHARDED_OK")
"""


def _run_subprocess(devices: int) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_BODY.format(d=devices)],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.parametrize("devices", [2, 4])
def test_sharded_topk_matches_single_device(devices):
    """The tentpole contract on a forced multi-device CPU mesh:
    bit-identical results including tie order, warm zero-PCIe, per-shard
    refresh accounting, server recovery."""
    assert "TOPK_SHARDED_OK" in _run_subprocess(devices)


# ---------------------------------------------------------------------------
# in-process: kernel primitives + 1-device degradation
# ---------------------------------------------------------------------------

def _tiny_case(rng):
    import jax.numpy as jnp
    from repro.kernels.ref import WORDS
    T, C = 6, 4
    rows_per = [1, 2, 0, 3, 1, 2]
    starts = np.zeros(T + 1, np.int32)
    starts[1:] = np.cumsum(rows_per)
    rows = rng.integers(0, 2 ** 32, size=(int(starts[-1]), WORDS),
                        dtype=np.uint32)
    row_col = rng.integers(0, C, size=(rows.shape[0],), dtype=np.int32)
    q_words = rng.integers(0, 2 ** 32, size=(C, WORDS), dtype=np.uint32)
    cards = np.array([max(1, int(np.unpackbits(np.ascontiguousarray(
        rows[starts[t]:starts[t + 1]]).view(np.uint8)).sum()))
        for t in range(T)], np.int32)
    q_card = int(np.unpackbits(q_words.view(np.uint8)).sum())
    gidx = np.array([3, 9, 12, 20, 27, 33], np.int32)
    return (jnp.asarray(rows), jnp.asarray(row_col), jnp.asarray(starts),
            jnp.asarray(q_words), q_card, jnp.asarray(cards),
            jnp.asarray(gidx))


@pytest.mark.parametrize("metric", ["jaccard", "cosine", "containment"])
def test_similarity_topk_ids_ref_pallas_parity(metric, rng):
    """The per-shard fused kernel agrees bit-for-bit with the jnp oracle
    on the interpret backend, across padding and exclusion masks."""
    from repro.kernels import ops as kops
    rows, col, starts, q, qc, cards, gidx = _tiny_case(rng)
    for n_valid in (6, 4):
        for exclude in (-1, 9):
            out = {}
            for be in ("ref", "pallas"):
                out[be] = kops.similarity_topk_ids(
                    rows, col, starts, q, qc, cards, gidx, metric=metric,
                    k=3, jmax=4, n_valid=n_valid, exclude=exclude,
                    backend=be)
            for a, b in zip(out["ref"], out["pallas"]):
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_topk_merge_tie_rule():
    """Merged k-lists resolve equal scores to the LOWEST global index --
    the pinned shard-boundary contract (both backends)."""
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    score = jnp.asarray(np.array([.5, .9, .9, .1, .9, .5], np.float32))
    inter = jnp.asarray(np.array([5, 9, 9, 1, 9, 5], np.int32))
    gidx = jnp.asarray(np.array([40, 31, 7, 2, 19, 3], np.int32))
    for be in ("ref", "pallas"):
        idx, sco, itr = kops.topk_merge(score, inter, gidx, 4, backend=be)
        assert np.asarray(idx).tolist() == [7, 19, 31, 3]
        assert np.array_equal(np.asarray(sco),
                              np.array([.9, .9, .9, .5], np.float32))
        assert np.asarray(itr).tolist() == [9, 9, 9, 5]


def test_one_device_mesh_degrades(rng):
    """A 1-device mesh must fall back to the single-device engine (and
    an opaque/absent mesh never shards)."""
    import jax
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    from repro.core import BitmapArena, RoaringBitmap
    from repro.core.pairwise import SimilarityEngine
    mesh = Mesh(mesh_utils.create_device_mesh(
        (1,), devices=jax.devices()[:1]), ("wide",))
    bms = [RoaringBitmap.from_values(np.unique(
        rng.integers(0, 1 << 18, 2000, dtype=np.uint32)))
        for _ in range(9)]
    eng = SimilarityEngine(bms, arena=BitmapArena(), mesh=mesh)
    assert eng._mesh is None                      # degraded
    plain = SimilarityEngine(bms)
    for part_a, part_b in zip(eng.topk(2, 4), plain.topk(2, 4)):
        assert np.array_equal(part_a, part_b)


def test_sharded_engine_requires_arena():
    """mesh= with >1 shard and no arena must refuse loudly, engine and
    index both."""
    from repro.core.pairwise import SimilarityEngine
    from repro.data.index import InvertedIndex

    class _FakeDevs:
        shape = (2,)

        def reshape(self, *_):
            return [None, None]

    class _FakeMesh:
        axis_names = ("wide",)
        devices = _FakeDevs()

    with pytest.raises(ValueError, match="arena"):
        SimilarityEngine([], mesh=_FakeMesh())
    ix = InvertedIndex().build([["a", "b"], ["b"]])
    with pytest.raises(ValueError, match="arena"):
        ix.similar("a", 2, mesh=_FakeMesh())
