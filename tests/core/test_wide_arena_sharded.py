"""Warm-path regression tests for sharded wide aggregates on arena slabs.

The tentpole claim is an accounting one: once every operand row is
resident in its shard's slab, repeated sharded ``or/and/xor/andnot/
threshold_many`` move ZERO container rows over PCIe -- the host only
ships segment ids and positions, and each shard gathers its rows from
its own device-local slab inside the jit.  These tests pin that claim
with per-shard ``ArenaStats``:

  * warm repeats of every wide op keep each shard's ``rows_uploaded``
    and the arena's ``host_rows_staged`` exactly flat, while per-shard
    ``device_gathers`` keeps growing (the work really ran on device);
  * a single bitmap edit followed by ``adopt`` repatches exactly ONE
    row on exactly ONE shard -- the incremental CoW scatter stays
    shard-local instead of rebroadcasting slabs;
  * cold (never-adopted) operands ride the staged side of the dual-
    source gather and are counted as ``host_rows_staged``, never as
    slab uploads.

Multi-device meshes need forced host devices before jax imports, so the
body runs in subprocesses (mirroring tests/core/test_topk_sharded.py);
the tests-multidevice CI job runs these too.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[2] / "src")

_SUBPROCESS_BODY = '''
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={d} "
    + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import jax
from jax.experimental import mesh_utils
from jax.sharding import Mesh

from repro.core import RoaringBitmap
from repro.core import aggregate as agg
from repro.core.arena import BitmapArena

assert jax.device_count() == {d}, jax.device_count()
mesh = Mesh(mesh_utils.create_device_mesh(({d},)), ("wide",))

rng = np.random.default_rng(0xA11)


def rand_bm(n, hi=1 << 18):
    return RoaringBitmap.from_values(
        rng.choice(hi, size=n, replace=False).astype(np.int64))


bms = [rand_bm(int(rng.integers(1000, 60000))) for _ in range(11)]
w = [int(x) for x in rng.integers(1, 8, 11)]
arena = BitmapArena()
arena.adopt_many(bms)

OPS = [("or",), ("and",), ("xor",), ("andnot",),
       ("threshold", 4, None), ("threshold", 13, w)]


def run_all():
    out = []
    for op, *rest in OPS:
        if op == "andnot":
            out.append(agg.andnot_many(bms[0], bms[1:], mesh=mesh,
                                       arena=arena))
        elif op == "threshold":
            t, ww = rest
            out.append(agg.threshold_many(bms, t, weights=ww, mesh=mesh,
                                          arena=arena))
        else:
            out.append(getattr(agg, op + "_many")(bms, mesh=mesh,
                                                  arena=arena))
    return out


# --- 1. warm repeats: zero PCIe rows, per shard -------------------------
first = run_all()                      # builds slabs, uploads everything
shards = arena.shard_slabs(mesh)
up0 = [s.rows_uploaded for s in shards.stats]
rp0 = [s.rows_patched for s in shards.stats]
g0 = [s.device_gathers for s in shards.stats]
staged0 = arena.stats.host_rows_staged
assert sum(up0) > 0                    # the cold start really uploaded

for _ in range(2):
    again = run_all()
    assert [s.rows_uploaded for s in shards.stats] == up0, \\
        "warm sharded aggregate uploaded rows"
    assert [s.rows_patched for s in shards.stats] == rp0, \\
        "warm sharded aggregate repatched rows"
    assert arena.stats.host_rows_staged == staged0, \\
        "warm sharded aggregate staged host rows"
    assert all(r == f for r, f in zip(again, first))
g1 = [s.device_gathers for s in shards.stats]
assert all(b > a for a, b in zip(g0, g1)), (g0, g1)
# the single-device slab never entered the picture
assert arena.stats.rows_uploaded == 0
print("WARM_OK")

# --- 2. one edit -> exactly one shard repatches one row -----------------
bms[3].add(123456)
arena.adopt(bms[3])
run_all()
deltas = [s.rows_patched - rp0[i] for i, s in enumerate(shards.stats)]
assert sum(deltas) == 1 and max(deltas) == 1, deltas
# a repatch recrosses PCIe once, on that one shard only (uploads count it)
updel = [s.rows_uploaded - up0[i] for i, s in enumerate(shards.stats)]
assert updel == deltas, (updel, deltas)
up0 = [s.rows_uploaded for s in shards.stats]
assert agg.or_many(bms, mesh=mesh, arena=arena) == agg.or_many(bms)
print("REPATCH_OK")

# --- 3. cold operands stage, never upload -------------------------------
cold = rand_bm(50000)
up1 = [s.rows_uploaded for s in shards.stats]
st1 = arena.stats.host_rows_staged
got = agg.or_many(bms + [cold], mesh=mesh, arena=arena)
assert got == agg.or_many(bms + [cold])
assert [s.rows_uploaded for s in shards.stats] == up1, \\
    "cold operand leaked into a shard slab"
assert arena.stats.host_rows_staged > st1, \\
    "cold operand was not accounted as staged"
print("COLD_OK")
'''


def _run_subprocess(devices: int) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c",
         _SUBPROCESS_BODY.replace("{d}", str(devices))],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.parametrize("devices", [2, 4])
def test_warm_sharded_aggregates_zero_pcie_rows(devices):
    """Repeated sharded wide aggregates keep every shard's
    ``rows_uploaded``/``rows_patched`` and the arena's
    ``host_rows_staged`` flat; one edit repatches exactly one shard;
    cold operands stage instead of uploading."""
    out = _run_subprocess(devices)
    assert "WARM_OK" in out
    assert "REPATCH_OK" in out
    assert "COLD_OK" in out
