"""Pairwise planner vs the seed two-by-two path.

Seeded-rng sweeps (the hypothesis twin lives in
test_pairwise_properties.py) across every container-type pairing --
array/bitset/run x array/bitset/run, empty, full-chunk, and the 4096/4097
boundary -- asserting bit-identity of the class-batched planner against a
frozen copy of the seed scalar ``_merge`` / ``and_card``, plus the
dispatch-count contract: a batch of M pairs issues O(container-type
classes) kernel dispatches, not O(M)."""

import numpy as np
import pytest

from pairwise_oracle import seed_and_card, seed_merge

from repro.core import RoaringBitmap
from repro.core import pairwise


# ---------------------------------------------------------------------------
# distributions: every chunk kind, plus empty / full / boundary chunks
# ---------------------------------------------------------------------------

def bm(values):
    return RoaringBitmap.from_values(np.asarray(list(values), np.uint32))


def mixed_kinds(rng, n_chunks=24):
    """Chunks drawn from {absent, sparse array, dense bitset, runs,
    full, 4096/4097 boundary} -- every pairing occurs across two draws."""
    parts = []
    for c in range(n_chunks):
        base = c << 16
        r = rng.random()
        if r < 0.18:
            continue                                   # absent chunk
        if r < 0.38:                                   # sparse array
            parts.append(base + rng.choice(
                1 << 16, int(rng.integers(1, 3000)), replace=False))
        elif r < 0.58:                                 # dense bitset
            parts.append(base + rng.choice(
                1 << 16, int(rng.integers(5000, 40000)), replace=False))
        elif r < 0.78:                                 # runs
            lo = int(rng.integers(0, 1 << 15))
            parts.append(np.arange(base + lo,
                                   base + lo
                                   + int(rng.integers(64, 30000))))
        elif r < 0.88:                                 # full chunk
            parts.append(np.arange(base, base + (1 << 16)))
        else:                                          # array/bitset edge
            parts.append(base + rng.choice(
                1 << 16, 4096 + int(rng.integers(0, 2)), replace=False))
    if not parts:
        parts = [np.asarray([0], np.int64)]
    vals = np.unique(np.concatenate(parts)).astype(np.uint32)
    return RoaringBitmap.from_values(vals).run_optimize()


OPS = ("and", "or", "xor", "andnot")


@pytest.mark.parametrize("backend", [None, "ref"])
def test_merge_one_matches_seed(rng, backend):
    for _ in range(4):
        a, b = mixed_kinds(rng), mixed_kinds(rng)
        for op in OPS:
            got = pairwise.merge_one(a, b, op, backend=backend)
            want = seed_merge(a, b, op)
            assert got == want, (op, backend)
            for c in got.containers:
                assert c.card > 0
                if c.kind == "array":
                    assert c.card <= 4096
                    assert np.all(np.diff(
                        c.values.astype(np.int64)) > 0)


def test_merge_edges(rng):
    e = RoaringBitmap()
    a = mixed_kinds(rng)
    assert (a & e).cardinality == 0
    assert (a | e) == a
    assert (e - a).cardinality == 0
    assert (a - e) == a
    assert (a ^ a).cardinality == 0
    assert (a & a) == a
    full = RoaringBitmap.from_range(0, 1 << 18)
    assert (a | full).cardinality >= full.cardinality
    assert seed_merge(a, full, "andnot") == (a - full)


@pytest.mark.parametrize("backend", [None, "ref"])
@pytest.mark.parametrize("op", OPS)
def test_pairwise_card_matches_seed(rng, backend, op):
    bms = [mixed_kinds(rng, n_chunks=8) for _ in range(6)]
    pairs = [(bms[i], bms[j]) for i in range(6) for j in range(i, 6)]
    got = pairwise.pairwise_card(op, pairs, backend=backend)
    for g, (x, y) in zip(got.tolist(), pairs):
        inter = seed_and_card(x, y)
        cx, cy = x.cardinality, y.cardinality
        want = {"and": inter, "or": cx + cy - inter,
                "xor": cx + cy - 2 * inter, "andnot": cx - inter}[op]
        assert g == want


def test_pairwise_card_mixed_ops_and_edges(rng):
    bms = [mixed_kinds(rng, n_chunks=6) for _ in range(4)]
    pairs = [(bms[i], bms[j]) for i in range(4) for j in range(4)]
    ops = [OPS[k % 4] for k in range(len(pairs))]
    got = pairwise.pairwise_card(ops, pairs)
    for g, (x, y), op in zip(got.tolist(), pairs, ops):
        inter = seed_and_card(x, y)
        cx, cy = x.cardinality, y.cardinality
        want = {"and": inter, "or": cx + cy - inter,
                "xor": cx + cy - 2 * inter, "andnot": cx - inter}[op]
        assert g == want
    assert pairwise.pairwise_card("and", []).size == 0
    e = RoaringBitmap()
    assert pairwise.pairwise_card("or", [(e, e)])[0] == 0
    assert pairwise.pairwise_card(
        "and", [(bms[0], bms[0])])[0] == bms[0].cardinality
    with pytest.raises(ValueError):
        pairwise.pairwise_card("nand", pairs)
    with pytest.raises(ValueError):
        pairwise.pairwise_card(["and"], pairs)


def test_and_card_public_surface(rng):
    a, b = mixed_kinds(rng), mixed_kinds(rng)
    assert a.and_card(b) == seed_and_card(a, b)
    assert a.or_card(b) == (a | b).cardinality
    assert a.xor_card(b) == (a ^ b).cardinality
    assert a.andnot_card(b) == (a - b).cardinality
    # the tiny-pair host fallback
    x, y = bm([1, 2, 3]), bm([2, 3, 4, 1 << 17])
    assert x.and_card(y) == 2


def test_jaccard_matrix(rng):
    bms = [mixed_kinds(rng, n_chunks=6) for _ in range(8)]
    bms.append(RoaringBitmap())                       # empty row
    got = RoaringBitmap.jaccard_matrix(bms)
    n = len(bms)
    assert got.shape == (n, n)
    for i in range(n):
        for j in range(n):
            want = bms[i].jaccard(bms[j]) if i != j else 1.0
            assert abs(got[i, j] - want) < 1e-12, (i, j)
    assert np.array_equal(got, got.T)
    assert RoaringBitmap.jaccard_matrix([]).shape == (0, 0)
    assert RoaringBitmap.jaccard_matrix([bms[0]]).shape == (1, 1)


def test_dispatch_count_is_per_class_not_per_pair(rng, monkeypatch):
    """M pairs of mixed-kind bitmaps must issue O(container-type classes)
    kernel dispatches (the acceptance contract), not O(pairs)."""
    from repro.kernels import ops as kops
    calls = []
    for name in ("bitset_pair_card", "array_intersect_card",
                 "array_bitset_probe", "bitset_pair_op",
                 "array_pair_masks", "bitset_op_card"):
        real = getattr(kops, name)

        def spy(*a, _real=real, _name=name, **k):
            calls.append(_name)
            return _real(*a, **k)

        monkeypatch.setattr(kops, name, spy)
    bms = [mixed_kinds(rng, n_chunks=5) for _ in range(24)]
    pairs = [(x, y) for i, x in enumerate(bms) for y in bms[i + 1:]]
    assert len(pairs) == 24 * 23 // 2
    got = pairwise.pairwise_card("and", pairs, backend="ref")
    assert len(calls) <= 3, calls                     # one per class, max
    for g, (x, y) in zip(got.tolist(), pairs):
        assert g == seed_and_card(x, y)


def test_index_similar(rng):
    from repro.data.index import InvertedIndex
    docs = [[f"t{t}" for t in rng.choice(12, rng.integers(1, 6),
                                         replace=False)]
            for _ in range(400)]
    idx = InvertedIndex().build(docs)
    got = idx.similar("t0", top_k=5)
    assert len(got) == 5
    # scores are float32 by contract (so the host and the fused device
    # kernel select bit-identically); compare against a float64 oracle
    # at float32 tolerance
    want = sorted(((t, idx.jaccard("t0", t)) for t in idx.postings
                   if t != "t0"), key=lambda kv: -kv[1])[:5]
    assert [t for t, _ in got] == [t for t, _ in want] or \
        [round(s, 6) for _, s in got] == [round(s, 6) for _, s in want]
    for (t, s), (wt, ws) in zip(got, want):
        assert abs(s - ws) < 1e-6
    contain = idx.similar("t0", top_k=3, metric="containment")
    q = idx.postings["t0"]
    for t, s in contain:
        assert abs(s - q.and_card(idx.postings[t]) / q.cardinality) < 1e-6
    with pytest.raises(ValueError):
        idx.similar("t0", metric="dice")


def test_tensor_pairwise_card(rng):
    from repro.core.tensor import RoaringTensor
    a_bms = [bm(rng.integers(0, 1 << 18, 20000, dtype=np.uint32))
             for _ in range(4)]
    b_bms = [bm(rng.integers(0, 1 << 18, 20000, dtype=np.uint32))
             for _ in range(4)]
    ta = RoaringTensor.from_bitmaps(a_bms, capacity=4)
    tb = RoaringTensor.from_bitmaps(b_bms, capacity=4)
    ops = ["and", "or", "xor", "andnot"]
    got = np.asarray(ta.pairwise_card(tb, ops))
    for i, op in enumerate(ops):
        x, y = a_bms[i], b_bms[i]
        inter = seed_and_card(x, y)
        cx, cy = x.cardinality, y.cardinality
        want = {"and": inter, "or": cx + cy - inter,
                "xor": cx + cy - 2 * inter, "andnot": cx - inter}[op]
        assert int(got[i]) == want, op
    uniform = np.asarray(ta.pairwise_card(tb, "and"))
    assert np.array_equal(uniform, np.asarray(ta.and_card(tb)))
    with pytest.raises(ValueError):
        ta.pairwise_card(tb, ["and"])


def test_tensor_pairwise_card_gather(rng):
    """Index-array pair selection happens on device (no host pair-list
    bridge): arbitrary / repeated rows, one mixed-op dispatch."""
    from repro.core.tensor import RoaringTensor
    a_bms = [bm(rng.integers(0, 1 << 18, 15000, dtype=np.uint32))
             for _ in range(4)]
    b_bms = [bm(rng.integers(0, 1 << 18, 15000, dtype=np.uint32))
             for _ in range(3)]
    ta = RoaringTensor.from_bitmaps(a_bms, capacity=4)
    tb = RoaringTensor.from_bitmaps(b_bms, capacity=4)
    lhs = np.array([0, 0, 3, 2, 1, 0])
    rhs = np.array([1, 2, 0, 2, 1, 0])
    ops = ["and", "or", "xor", "andnot", "and", "or"]
    got = np.asarray(ta.pairwise_card(tb, ops, lhs_idx=lhs, rhs_idx=rhs))
    for g, i, j, op in zip(got.tolist(), lhs.tolist(), rhs.tolist(), ops):
        x, y = a_bms[i], b_bms[j]
        inter = seed_and_card(x, y)
        cx, cy = x.cardinality, y.cardinality
        want = {"and": inter, "or": cx + cy - inter,
                "xor": cx + cy - 2 * inter, "andnot": cx - inter}[op]
        assert g == want, op
    # take() composes with everything batch-shaped
    sub = ta.take(np.array([2, 0]))
    assert np.array_equal(np.asarray(sub.cardinality()),
                          np.asarray(ta.cardinality())[[2, 0]])
    # concrete out-of-range indices raise instead of silently filling
    for bad in ([-1], [4], [0, 99]):
        with pytest.raises(IndexError):
            ta.take(np.array(bad))
    with pytest.raises(IndexError):
        ta.pairwise_card(tb, "and", lhs_idx=np.array([0, 9]),
                         rhs_idx=np.array([0, 0]))
    # mismatched pair row counts without index arrays must raise
    with pytest.raises(ValueError):
        ta.pairwise_card(tb, "and")
    with pytest.raises(ValueError):
        ta.pairwise_card(tb, ["and", "or"], lhs_idx=lhs, rhs_idx=rhs)


def test_result_containers_canonical(rng):
    """Planner results must obey the seed result-kind policy: binary ops
    materialize array (card <= 4096) or bitset, never runs; pass-through
    containers keep their kind."""
    a, b = mixed_kinds(rng), mixed_kinds(rng)
    common = set(a.keys) & set(b.keys)
    for op in OPS:
        got = pairwise.merge_one(a, b, op)
        want = seed_merge(a, b, op)
        for k, c, wc in zip(got.keys, got.containers,
                            want.containers):
            if k in common:
                assert c.kind == wc.kind, (op, k)
