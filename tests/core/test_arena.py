"""Device-resident bitmap arena: lifecycle, bit-identity, and the
zero-transfer contract.

The arena's correctness claim is structural (container identity, not
generation counters, gates row reuse), so the tests here hammer exactly
the places that could silently go wrong: adopt/patch/free accounting,
every wide op with and without an arena across mixed container kinds,
seeded mutation/query interleaving against the cold host path, the
warm-query ZERO host->device row transfer assertion, the single-row
peel fix (resident singletons must stay on device), SimilarityEngine
arena views with in-place refresh, and the query server's generation-
revalidating ``slab_mismatch`` rung."""

import numpy as np
import pytest

from repro.core import BitmapArena, RoaringBitmap
from repro.core import aggregate
from repro.core import containers as C
from repro.core.pairwise import SimilarityEngine
from repro.core.tensor import RoaringTensor
from repro.data.index import InvertedIndex
from repro.serve.faults import FaultInjector
from repro.serve.query_server import Query, QueryServer


def bm(values):
    return RoaringBitmap.from_values(np.asarray(list(values), np.uint32))


def mixed_bitmaps(rng, k=8):
    """Array/bitset/run mix across overlapping chunk keys."""
    out = []
    for i in range(k):
        kind = ("array", "bitset", "run")[i % 3]
        if kind == "array":
            out.append(bm(rng.choice(1 << 18, 300, replace=False)))
        elif kind == "bitset":
            out.append(bm(rng.choice(1 << 17, 30000, replace=False)))
        else:
            starts = rng.choice(1 << 17, 20) & ~np.uint32(0)
            vals = np.unique(np.concatenate(
                [np.arange(s, s + 400) for s in starts]))
            out.append(bm(vals))
    return out


# ---------------------------------------------------------------------------
# lifecycle: adopt / lookup / patch / free
# ---------------------------------------------------------------------------

class TestLifecycle:
    def test_adopt_and_lookup_content(self):
        rng = np.random.default_rng(0)
        bms = mixed_bitmaps(rng)
        arena = BitmapArena(capacity=2)          # forces growth
        n = arena.adopt_many(bms)
        assert n == sum(len(b.containers) for b in bms) or n > 0
        for b in bms:
            assert arena.resident(b)
            for c in b.containers:
                rid = arena.lookup(c)
                assert rid is not None and rid > 0
                assert np.array_equal(arena.host_row(rid),
                                      C.container_words64(c))
        # row 0 is the reserved all-zero padding target
        assert not arena.host_row(0).any()
        # warm re-adopt is a no-op
        assert arena.adopt_many(bms) == 0

    def test_incremental_patch_is_minimal(self):
        rng = np.random.default_rng(1)
        bms = mixed_bitmaps(rng)
        arena = BitmapArena()
        arena.adopt_many(bms)
        arena.device_slab()
        up0 = arena.stats.rows_uploaded
        # one value added to one container -> exactly one row repatches
        bms[1].add(3)                            # bitset container edit
        changed = arena.adopt(bms[1])
        assert changed == 1
        arena.device_slab()
        assert arena.stats.rows_uploaded == up0 + 1
        assert arena.stats.rows_patched == 1
        # the device slab matches the host mirror after the patch
        dev = np.asarray(arena.device_slab())[: arena._n]
        host = arena._host[: arena._n].view(np.uint32).reshape(-1, 2048)
        assert np.array_equal(dev, host)

    def test_copy_on_write_patch(self):
        """In-flight consumers keep the pre-patch slab (functional
        update allocates a fresh device buffer)."""
        arena = BitmapArena()
        b = bm(range(70000, 90000))
        arena.adopt(b)
        slab_before = arena.device_slab()
        snapshot = np.asarray(slab_before).copy()
        b.add(1)                                  # new chunk 0 row
        arena.adopt(b)
        slab_after = arena.device_slab()
        assert slab_after is not slab_before
        assert np.array_equal(np.asarray(slab_before), snapshot)

    def test_release_and_row_reuse(self):
        arena = BitmapArena()
        a = bm(range(100))
        arena.adopt(a)
        rows = arena.n_rows
        # removing the only chunk frees its row
        for v in range(100):
            a.remove(v)
        arena.adopt(a)
        assert arena.n_rows == rows - 1
        assert arena.stats.rows_freed == 1
        # a new adoption reuses the freed row
        b = bm(range(50))
        arena.adopt(b)
        assert arena.n_rows == rows
        rid = arena.lookup(b.containers[0])
        assert np.array_equal(arena.host_row(rid),
                              C.container_words64(b.containers[0]))
        arena.release(a)
        arena.release(b)
        assert arena.n_rows == 1                 # only the zero row left

    def test_shared_container_refcount(self):
        """Two bitmaps sharing a container object share one row."""
        a = bm(range(5000, 9000))
        shared = a.containers[0]
        b = RoaringBitmap([0], [shared])
        arena = BitmapArena()
        arena.adopt(a)
        rows = arena.n_rows
        arena.adopt(b)
        assert arena.n_rows == rows              # no second promotion
        arena.release(a)
        assert arena.lookup(shared) is not None  # b still holds the row
        arena.release(b)
        assert arena.lookup(shared) is None


# ---------------------------------------------------------------------------
# bulk frozen adoption (PR 8): one batched conversion, one transfer
# ---------------------------------------------------------------------------

class TestAdoptFrozen:
    def _frozen_twins(self, rng, k=9):
        """(eager bitmaps, frozen view-backed deserialized twins)."""
        from repro.core import deserialize_frozen, serialize_frozen
        bms = [b.run_optimize() for b in mixed_bitmaps(rng, k)]
        froz = [deserialize_frozen(serialize_frozen(b)) for b in bms]
        return bms, froz

    def test_bulk_rows_match_per_container_promotion(self):
        rng = np.random.default_rng(5)
        bms, froz = self._frozen_twins(rng)
        bulk, eager = BitmapArena(), BitmapArena()
        n_bulk = bulk.adopt_frozen(froz)
        eager.adopt_many(bms)
        assert n_bulk == sum(len(b.containers) for b in froz)
        assert bulk.n_rows == eager.n_rows
        for b in froz:
            assert bulk.resident(b)
            for c in b.containers:
                rid = bulk.lookup(c)
                assert np.array_equal(bulk.host_row(rid),
                                      C.container_words64(c))

    def test_upload_accounting_and_warm_requery(self):
        """Cold start = exactly ONE slab upload; the first and every
        later query move zero additional rows."""
        rng = np.random.default_rng(6)
        bms, froz = self._frozen_twins(rng)
        arena = BitmapArena()
        arena.adopt_frozen(froz)
        arena.sync()
        up0 = arena.stats.rows_uploaded
        assert up0 == arena._n                   # one bulk upload
        want = RoaringBitmap.or_many(bms)
        for _ in range(2):
            got = aggregate.or_many(froz, backend="ref", arena=arena)
            assert got == want
            assert arena.stats.rows_uploaded == up0
        # re-adopting the same snapshot is a no-op
        assert arena.adopt_frozen(froz) == 0

    def test_batched_after_slab_exists_is_one_scatter(self):
        rng = np.random.default_rng(7)
        bms, froz = self._frozen_twins(rng, k=4)
        arena = BitmapArena()
        arena.adopt_frozen(froz[:2])
        arena.sync()
        patched0 = arena.stats.rows_patched
        arena.adopt_frozen(froz[2:])             # second wave
        arena.sync()
        n_new = sum(len(b.containers) for b in froz[2:])
        assert arena.stats.rows_patched == patched0 + n_new
        got = aggregate.xor_many(froz, backend="ref", arena=arena)
        assert got == RoaringBitmap.xor_many(bms)

    def test_single_bitmap_and_shared_rows(self):
        arena = BitmapArena()
        a = bm(range(5000, 9000))
        assert arena.adopt_frozen(a) == 1        # single-bitmap form
        shared = a.containers[0]
        b = RoaringBitmap([0], [shared])
        assert arena.adopt_frozen([b]) == 0      # row already resident
        arena.release(a)
        assert arena.lookup(shared) is not None  # refcounted by b
        arena.release(b)
        assert arena.lookup(shared) is None


# ---------------------------------------------------------------------------
# wide ops: bit-identity with and without an arena
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [None, "ref"])
class TestWideOpParity:
    def test_all_ops(self, backend):
        rng = np.random.default_rng(2)
        bms = mixed_bitmaps(rng)
        arena = BitmapArena()
        arena.adopt_many(bms)
        assert aggregate.or_many(bms, backend=backend, arena=arena) == \
            aggregate.or_many(bms, backend=backend)
        assert aggregate.xor_many(bms, backend=backend, arena=arena) == \
            aggregate.xor_many(bms, backend=backend)
        assert aggregate.and_many(bms[:4], backend=backend,
                                  arena=arena) == \
            aggregate.and_many(bms[:4], backend=backend)
        assert aggregate.andnot_many(bms[1], bms[2:6], backend=backend,
                                     arena=arena) == \
            aggregate.andnot_many(bms[1], bms[2:6], backend=backend)
        for t in (2, 3, len(bms)):
            assert aggregate.threshold_many(
                bms, t, backend=backend, arena=arena) == \
                aggregate.threshold_many(bms, t, backend=backend)

    def test_weighted_threshold(self, backend):
        rng = np.random.default_rng(3)
        bms = mixed_bitmaps(rng, 6)
        w = [1, 3, 2, 1, 5, 2]
        arena = BitmapArena()
        arena.adopt_many(bms)
        for t in (3, 7):
            assert aggregate.threshold_many(
                bms, t, weights=w, backend=backend, arena=arena) == \
                aggregate.threshold_many(bms, t, weights=w,
                                         backend=backend)

    def test_cold_containers_stage_correctly(self, backend):
        """Bitmaps never adopted still compute correctly through an
        arena-planned dispatch (mixed resident + staged rows)."""
        rng = np.random.default_rng(4)
        bms = mixed_bitmaps(rng)
        arena = BitmapArena()
        arena.adopt_many(bms[:4])                # half resident, half cold
        assert aggregate.or_many(bms, backend=backend, arena=arena) == \
            aggregate.or_many(bms, backend=backend)
        assert aggregate.threshold_many(
            bms, 3, backend=backend, arena=arena) == \
            aggregate.threshold_many(bms, 3, backend=backend)

    def test_execute_plans_mixed_arenas(self, backend):
        """Coalesced plan batches only group plans sharing an arena."""
        rng = np.random.default_rng(5)
        bms = mixed_bitmaps(rng)
        arena = BitmapArena()
        arena.adopt_many(bms)
        plans = [
            aggregate.plan_wide("or", bms[:5], backend=backend,
                                arena=arena),
            aggregate.plan_wide("or", bms[3:], backend=backend),
            aggregate.plan_wide("threshold", bms, 2, backend=backend,
                                arena=arena),
        ]
        got = aggregate.execute_plans(plans, backend=backend)
        assert got[0] == aggregate.or_many(bms[:5], backend=backend)
        assert got[1] == aggregate.or_many(bms[3:], backend=backend)
        assert got[2] == aggregate.threshold_many(bms, 2,
                                                  backend=backend)

    def test_execute_plan_host_resolves_ids(self, backend):
        """The server's host-degradation twin resolves arena row ids
        through the HOST mirror (no jax) and stays bit-identical."""
        rng = np.random.default_rng(6)
        bms = mixed_bitmaps(rng)
        arena = BitmapArena()
        arena.adopt_many(bms)
        plan = aggregate.plan_wide("or", bms, backend=backend,
                                   arena=arena)
        assert aggregate.execute_plan_host(plan) == \
            aggregate.or_many(bms, backend=backend)


# ---------------------------------------------------------------------------
# the zero-transfer contract + the single-row peel fix
# ---------------------------------------------------------------------------

def dense_postings(n, seed=29):
    """Single-chunk dense bitsets (the serving-shaped worst case for
    per-call staging)."""
    rng = np.random.default_rng(seed)
    return [bm(rng.choice(1 << 16, 20000, replace=False))
            for _ in range(n)]


class TestZeroTransfer:
    def test_warm_requery_moves_no_rows(self):
        bms = dense_postings(16)
        arena = BitmapArena()
        arena.adopt_many(bms)
        first = aggregate.or_many(bms, backend="ref", arena=arena)
        uploaded = arena.stats.rows_uploaded
        staged = arena.stats.host_rows_staged
        for _ in range(3):
            again = aggregate.or_many(bms, backend="ref", arena=arena)
            assert again == first
        # the dispatch-count contract: warm re-queries perform ZERO
        # host->device row transfers and stage no host rows
        assert arena.stats.rows_uploaded == uploaded
        assert arena.stats.host_rows_staged == staged == 0
        assert arena.stats.device_gathers >= 4

    def test_peel_keeps_resident_singletons_on_device(self):
        """A single-row segment whose row is arena-resident must NOT
        fall back to the host popcount peel (the PR 4 peel bypassed a
        warm arena); host-ndarray singletons still peel."""
        b = bm(np.arange(0, 50000, 3))           # one dense chunk 0 bitset
        arena = BitmapArena()
        arena.adopt(b)
        rid = arena.lookup(b.containers[0])
        arena.device_slab()
        up0 = arena.stats.rows_uploaded
        out = aggregate._dispatch([0], [[rid]], "or", 0, "ref",
                                  arena=arena)
        assert arena.stats.rows_uploaded == up0      # nothing re-staged
        assert arena.stats.host_rows_staged == 0
        assert arena.stats.device_gathers == 1       # device path taken
        got = RoaringBitmap([0], [out[0]])
        assert got == b
        # the host twin still peels (no dispatch)
        g0 = arena.stats.device_gathers
        row = C.container_words64(b.containers[0])
        out2 = aggregate._dispatch([0], [[row]], "or", 0, "ref",
                                   arena=arena)
        assert RoaringBitmap([0], [out2[0]]) == b
        assert arena.stats.device_gathers == g0      # peeled on host


# ---------------------------------------------------------------------------
# seeded mutation/query interleaving vs the cold host path
# ---------------------------------------------------------------------------

class TestMutationQueryInterleaving:
    @pytest.mark.parametrize("seed", [7, 19, 43])
    def test_arena_index_tracks_cold_index(self, seed):
        rng = np.random.default_rng(seed)
        docs = [[f"t{j}" for j in rng.choice(24, rng.integers(2, 8))]
                for _ in range(2000)]
        cold = InvertedIndex().build(docs)
        warm = InvertedIndex(arena=BitmapArena()).build(docs)
        terms = [f"t{j}" for j in range(24)]
        for step in range(40):
            action = rng.integers(0, 5)
            if action == 0:                      # add a document
                doc = int(rng.integers(0, 4000))
                ts = [terms[j] for j in rng.choice(24, 3)]
                cold.add_document(doc, ts)
                warm.add_document(doc, ts)
            elif action == 1:                    # point removal
                t = terms[int(rng.integers(0, 24))]
                if cold.postings.get(t) and len(cold.postings[t]):
                    v = cold.postings[t].to_array()[0]
                    cold.postings[t].remove(int(v))
                    warm.postings[t].remove(int(v))
            elif action == 2:                    # run_optimize sweep
                cold.optimize()
                warm.optimize()
            qt = [terms[j] for j in rng.choice(24, 4, replace=False)]
            assert cold.query_and(*qt[:2]) == warm.query_and(*qt[:2])
            assert cold.query_or(*qt) == warm.query_or(*qt)
            assert cold.query_xor(*qt[:3]) == warm.query_xor(*qt[:3])
            assert cold.query_threshold(qt, 2) == \
                warm.query_threshold(qt, 2)
            assert cold.query_andnot(qt[0], *qt[1:3]) == \
                warm.query_andnot(qt[0], *qt[1:3])
            if step % 10 == 0:
                assert cold.similar(qt[0], 5) == warm.similar(qt[0], 5)

    def test_warm_index_requery_zero_transfer(self):
        rng = np.random.default_rng(8)
        docs = [[f"t{j}" for j in rng.choice(16, 6, replace=False)]
                for _ in range(30000)]            # dense bitset postings
        ix = InvertedIndex(arena=BitmapArena()).build(docs)
        want = ix.query_or("t0", "t1", "t2", "t3")
        up = ix.arena.stats.rows_uploaded
        staged = ix.arena.stats.host_rows_staged
        for _ in range(3):
            assert ix.query_or("t0", "t1", "t2", "t3") == want
            assert len(ix.query_and("t0", "t1"))
        assert ix.arena.stats.rows_uploaded == up
        assert ix.arena.stats.host_rows_staged == staged


# ---------------------------------------------------------------------------
# SimilarityEngine arena views
# ---------------------------------------------------------------------------

class TestEngineArenaView:
    def test_parity_and_refresh(self):
        rng = np.random.default_rng(9)
        bms = [bm(rng.choice(1 << 17, 4000 + 300 * i, replace=False))
               for i in range(10)]
        arena = BitmapArena()
        cold = SimilarityEngine(bms)
        warm = SimilarityEngine(bms, arena=arena)
        assert np.array_equal(cold.rows, warm.rows)
        q = bm(rng.choice(1 << 17, 2500, replace=False))
        for backend in (None, "ref"):
            for query in (3, q):
                a = cold.topk(query, 5, "jaccard", backend=backend)
                b = warm.topk(query, 5, "jaccard", backend=backend)
                assert all(np.array_equal(x, y) for x, y in zip(a, b))
        # refresh: only the edited row repatches; results track a fresh
        # engine bit for bit
        warm._device()
        up0 = arena.stats.rows_uploaded
        bms[2].add(1 << 18)                      # new chunk: exactly 1 row
        assert warm.refresh() is True
        assert warm.refresh() is False
        warm._device()
        assert arena.stats.rows_uploaded == up0 + 1
        fresh = SimilarityEngine(bms)
        a = fresh.topk(q, 5, backend="ref")
        b = warm.topk(q, 5, backend="ref")
        assert all(np.array_equal(x, y) for x, y in zip(a, b))

    def test_refresh_requires_arena(self):
        eng = SimilarityEngine([bm(range(10))])
        with pytest.raises(ValueError):
            eng.refresh()

    def test_index_preserves_engine_across_mutation(self):
        rng = np.random.default_rng(10)
        docs = [[f"t{j}" for j in rng.choice(12, 4, replace=False)]
                for _ in range(1500)]
        ix = InvertedIndex(arena=BitmapArena()).build(docs)
        before = ix._sim_engine()[1]
        ix.add_document(9000, ["t1", "t2"])      # existing terms only
        after = ix._sim_engine()[1]
        assert after is before                   # refreshed in place
        cold = InvertedIndex().build(docs)
        cold.add_document(9000, ["t1", "t2"])
        assert cold.similar("t1", 5) == ix.similar("t1", 5)
        ix.add_document(9001, ["brand_new"])     # term set changed
        assert ix._sim_engine()[1] is not before


# ---------------------------------------------------------------------------
# query server: generation revalidation replaces whole-slab drops
# ---------------------------------------------------------------------------

class TestServerRevalidation:
    def _indices(self, seed=11):
        rng = np.random.default_rng(seed)
        docs = [[f"t{j}" for j in rng.choice(40, rng.integers(2, 10))]
                for _ in range(4000)]
        cold = InvertedIndex().build(docs)
        warm = InvertedIndex(arena=BitmapArena()).build(docs)
        return cold, warm

    def test_slab_mismatch_repatches_rows(self):
        cold_ix, warm_ix = self._indices()
        faults = FaultInjector.script({"slab_mismatch": [True]})
        srv = QueryServer(warm_ix, backend="ref", faults=faults)
        ref = QueryServer(cold_ix, backend="ref")
        assert srv.arena is warm_ix.arena        # picked up from the index
        qs = [Query.and_("t1", "t2"), Query.or_("t3", "t4", "t5"),
              Query.threshold(("t1", "t2", "t3"), 2),
              Query.similar("t2", 5)]
        ta = [srv.submit(q) for q in qs]
        tb = [ref.submit(q) for q in qs]
        eng = warm_ix._sim_engine()[1]
        # concurrent mutation between admission and dispatch
        warm_ix.postings["t1"].add(4999)
        cold_ix.postings["t1"].add(4999)
        srv.run_until_idle()
        ref.run_until_idle()
        for a, b in zip(ta, tb):
            assert a.result.ok and b.result.ok
            assert a.result.value == b.result.value
        st = srv.stats()
        assert st.replans == 1
        assert st.rows_repatched >= 1            # incremental, not a drop
        assert warm_ix._sim_engine()[1] is eng   # engine never dropped

    def test_no_arena_keeps_drop_semantics(self):
        cold_ix, _ = self._indices(12)
        faults = FaultInjector.script({"slab_mismatch": [True]})
        srv = QueryServer(cold_ix, backend="ref", faults=faults)
        t = srv.submit(Query.similar("t1", 3))
        cold_ix._sim_engine()
        assert cold_ix._sim is not None
        srv.run_until_idle()
        assert t.result.ok
        assert srv.stats().replans == 1
        assert srv.stats().rows_repatched == 0


# ---------------------------------------------------------------------------
# RoaringTensor bridge
# ---------------------------------------------------------------------------

class TestTensorBridge:
    def test_to_arena_roundtrip(self):
        rng = np.random.default_rng(13)
        bms = mixed_bitmaps(rng, 5)
        rt = RoaringTensor.from_bitmaps(bms)
        arena, twins = rt.to_arena()
        assert len(twins) == 5
        for orig, twin in zip(bms, twins):
            assert orig == twin
            assert arena.resident(twin)
        assert aggregate.or_many(twins, backend="ref", arena=arena) == \
            aggregate.or_many(bms, backend="ref")
