"""Frozen copy of the seed (pre-planner) scalar two-by-two path.

Shared by test_pairwise.py and test_pairwise_properties.py as the
bit-identity oracle the class-batched planner is checked against.  Kept
independent of repro.core.bitmap._merge on purpose: the planner now backs
that method, so the oracle must not route through it."""

from repro.core import RoaringBitmap
from repro.core import containers as C


def seed_merge(a, b, op):
    """The seed RoaringBitmap._merge: scalar key-merge, one container op
    per matched key."""
    fn = C.OPS[op][0]
    keys, conts = [], []
    i = j = 0
    na, nb = len(a.keys), len(b.keys)
    while i < na and j < nb:
        ka, kb = a.keys[i], b.keys[j]
        if ka == kb:
            c = fn(a.containers[i], b.containers[j])
            if c.card:
                keys.append(ka)
                conts.append(c)
            i += 1
            j += 1
        elif ka < kb:
            if op in ("or", "xor", "andnot"):
                keys.append(ka)
                conts.append(a.containers[i])
            i += 1
        else:
            if op in ("or", "xor"):
                keys.append(kb)
                conts.append(b.containers[j])
            j += 1
    if op in ("or", "xor", "andnot"):
        while i < na:
            keys.append(a.keys[i])
            conts.append(a.containers[i])
            i += 1
    if op in ("or", "xor"):
        while j < nb:
            keys.append(b.keys[j])
            conts.append(b.containers[j])
            j += 1
    return RoaringBitmap(keys, conts)


def seed_and_card(a, b):
    """The seed RoaringBitmap.and_card: scalar key-merge fast count."""
    cnt = 0
    i = j = 0
    while i < len(a.keys) and j < len(b.keys):
        ka, kb = a.keys[i], b.keys[j]
        if ka == kb:
            cnt += C.container_and_card(a.containers[i], b.containers[j])
            i += 1
            j += 1
        elif ka < kb:
            i += 1
        else:
            j += 1
    return cnt


def seed_op_card(a, b, op):
    """Seed count for any op by inclusion-exclusion over seed_and_card."""
    inter = seed_and_card(a, b)
    ca, cb = a.cardinality, b.cardinality
    return {"and": inter, "or": ca + cb - inter,
            "xor": ca + cb - 2 * inter, "andnot": ca - inter}[op]
