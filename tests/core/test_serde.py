"""Serialization round-trip and corruption handling.

Property-style seeded sweeps (hypothesis is not installed in this image;
the strategy mix is hand-rolled) over bitmaps whose containers cover every
kind combination -- run/array/bitset mixes, the 4096/4097 boundary, full
chunks -- plus truncation at every structural boundary, which must raise a
clear ValueError rather than a bare struct/buffer error.
"""

import numpy as np
import pytest

from repro.core import RoaringBitmap, deserialize, serialize
from repro.core.serde import MAGIC


def bm(values):
    return RoaringBitmap.from_values(np.asarray(list(values), np.uint32))


def _mixed_bitmap(rng, n_chunks=4):
    """A bitmap mixing array, bitset, and run containers across chunks."""
    parts = []
    for i in range(n_chunks):
        base = np.uint32(int(rng.integers(0, 64)) << 16)
        style = rng.integers(0, 4)
        if style == 0:                               # sparse array
            vals = rng.integers(0, 1 << 16, int(rng.integers(1, 400)),
                                dtype=np.uint32)
        elif style == 1:                             # dense bitset
            vals = rng.choice(1 << 16, int(rng.integers(4097, 20000)),
                              replace=False).astype(np.uint32)
        elif style == 2:                             # runs
            lo = int(rng.integers(0, 1 << 15))
            vals = np.arange(lo, lo + int(rng.integers(100, 30000)),
                             dtype=np.uint32)
        else:                                        # 4096/4097 boundary
            vals = rng.choice(1 << 16, 4096 + int(rng.integers(0, 2)),
                              replace=False).astype(np.uint32)
        parts.append(base + vals)
    return bm(np.concatenate(parts)).run_optimize()


@pytest.mark.parametrize("trial", range(12))
def test_roundtrip_mixed_kinds(rng, trial):
    x = _mixed_bitmap(rng, n_chunks=int(rng.integers(1, 6)))
    assert deserialize(serialize(x)) == x


def test_roundtrip_edges(rng):
    assert deserialize(serialize(RoaringBitmap())) == RoaringBitmap()
    one = bm([0])
    assert deserialize(serialize(one)) == one
    full = RoaringBitmap.from_range(0, 1 << 16).run_optimize()
    assert deserialize(serialize(full)) == full
    top = bm([0xFFFFFFFF])
    assert deserialize(serialize(top)) == top


def test_roundtrip_preserves_kinds(rng):
    x = _mixed_bitmap(rng)
    y = deserialize(serialize(x))
    assert [c.kind for c in y.containers] == [c.kind for c in x.containers]
    assert y.keys == x.keys


@pytest.mark.parametrize("trial", range(6))
def test_truncation_every_boundary_raises_value_error(rng, trial):
    """Truncating a valid payload anywhere must raise ValueError with a
    useful message -- not struct.error, not a silent short read."""
    x = _mixed_bitmap(rng)
    payload = serialize(x)
    cuts = sorted({1, 3, 4, 6, 8, len(payload) // 2, len(payload) - 1})
    for cut in cuts:
        with pytest.raises(ValueError):
            deserialize(payload[:cut])


def test_truncation_message_is_clear(rng):
    payload = serialize(_mixed_bitmap(rng))
    # a truncated body fails the checksum before any structural parse
    with pytest.raises(ValueError, match="checksum mismatch"):
        deserialize(payload[:len(payload) - 1])
    with pytest.raises(ValueError, match="header"):
        deserialize(MAGIC)                    # magic only, no crc/count


def _refresh_crc(payload: bytearray) -> bytes:
    """Recompute the RJ02 checksum so structural validation (not the
    CRC) is what rejects a hand-corrupted payload."""
    import struct
    import zlib
    payload[4:8] = struct.pack("<I", zlib.crc32(bytes(payload[8:])))
    return bytes(payload)


def test_bad_magic_and_bad_kind():
    with pytest.raises(ValueError, match="magic"):
        deserialize(b"XXXX" + b"\x00" * 12)
    x = bm([1, 2, 3])
    payload = bytearray(serialize(x))
    # kinds live right after the 2-byte key directory (header is
    # magic 4 + crc 4 + count 4, one key here)
    payload[12 + 2] = 9
    with pytest.raises(ValueError, match="kind"):
        deserialize(_refresh_crc(payload))


def test_checksum_guards_structural_fields():
    """Any bare byte flip -- even one that would still parse -- is
    caught by the CRC before structural validation runs."""
    payload = bytearray(serialize(bm([1, 2, 3])))
    payload[12] ^= 0xFF                       # flip a key byte
    with pytest.raises(ValueError, match="checksum mismatch"):
        deserialize(bytes(payload))


def test_empty_buffer():
    with pytest.raises(ValueError):
        deserialize(b"")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_byte_flip_sweep_always_value_error(seed):
    """Robustness contract: ANY single-byte corruption of a valid
    payload must raise ValueError -- never crash, hang, or return a
    silently-wrong bitmap.  The CRC layer guarantees single-byte flips
    are always detected (CRC-32 catches every burst <= 32 bits)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    x = _mixed_bitmap(rng)
    payload = bytes(serialize(x))
    positions = rng.choice(len(payload), size=min(len(payload), 200),
                           replace=False)
    for pos in positions.tolist():
        flip = int(rng.integers(1, 256))      # never a no-op flip
        corrupt = bytearray(payload)
        corrupt[pos] ^= flip
        with pytest.raises(ValueError):
            deserialize(bytes(corrupt))


def test_structural_validation_behind_valid_crc(rng):
    """Defense in depth: payloads with a VALID checksum but broken
    structure (built wrong, not damaged in flight) still raise."""
    import struct

    x = _mixed_bitmap(rng)
    base = serialize(x)
    n = struct.unpack_from("<I", base, 8)[0]
    # unsorted keys: swap the first two directory entries
    if n >= 2:
        p = bytearray(base)
        p[12:14], p[14:16] = p[14:16], p[12:14]
        with pytest.raises(ValueError):
            deserialize(_refresh_crc(p))
    # trailing garbage past the last payload byte
    p = bytearray(base + b"\x00\x07")
    with pytest.raises(ValueError, match="trailing"):
        deserialize(_refresh_crc(p))


def test_errors_carry_offset_and_container_index(rng):
    """PR-8 contract: every truncation/validation ValueError names the
    byte offset it fired at, and container-level failures name the
    container index -- pinned here so messages stay actionable."""
    import re
    import struct

    x = _mixed_bitmap(rng)
    payload = serialize(x)
    # truncation anywhere reports a byte offset; header truncation also
    # says how many bytes remained (body cuts fail the CRC first)
    for cut in (3, 10, len(payload) // 2):
        with pytest.raises(ValueError, match=r"byte offset \d+") as ei:
            deserialize(payload[:cut])
        if cut < 12:
            assert re.search(r"only \d+ remain", str(ei.value))
    # checksum failure points at the crc field
    with pytest.raises(ValueError, match="crc field at byte offset 4"):
        p = bytearray(payload)
        p[-1] ^= 1
        deserialize(bytes(p))
    # bad kind names the container index AND the directory offset
    p = bytearray(serialize(bm([1, 2, 3])))
    p[12 + 2] = 9
    with pytest.raises(
            ValueError,
            match=r"kind 9 for container 0 .*byte offset 14"):
        deserialize(_refresh_crc(p))
    # second-container failure reports index 1, not 0
    two = bm([5, (1 << 16) + 1, (1 << 16) + 9])
    p = bytearray(serialize(two))
    n = struct.unpack_from("<I", p, 8)[0]
    assert n == 2
    p[12 + 2 * n + 1] = 9                  # kind byte of container 1
    with pytest.raises(ValueError, match="container 1"):
        deserialize(_refresh_crc(p))


def test_bitset_card_cross_check(rng):
    """A bitset whose stored cardinality disagrees with its popcount is
    rejected (that mismatch is exactly a 'silently wrong' bitmap)."""
    import struct

    vals = rng.choice(1 << 16, size=5000, replace=False).astype(np.uint32)
    x = bm(vals.tolist())                     # one bitset container
    assert x.containers[0].kind == "bitset"
    p = bytearray(serialize(x))
    # cards directory entry (one container): magic4+crc4+n4+key2+kind1
    struct.pack_into("<H", p, 15, 4999 - 1)
    with pytest.raises(ValueError, match="popcount"):
        deserialize(_refresh_crc(p))
