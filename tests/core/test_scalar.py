"""Scalar twins == vectorized implementations (the sec 5.10 ablation's
correctness precondition)."""

import numpy as np

from repro.core import containers as C
from repro.core import scalar as S


def test_popcount(rng):
    words = rng.integers(0, 1 << 64, 128, dtype=np.uint64)
    assert S.bitset_popcount(words) == int(np.bitwise_count(words).sum())


def test_bitset_ops(rng):
    a = rng.integers(0, 1 << 64, C.BITSET_WORDS, dtype=np.uint64)
    b = rng.integers(0, 1 << 64, C.BITSET_WORDS, dtype=np.uint64)
    for op, f in [("and", np.bitwise_and), ("or", np.bitwise_or),
                  ("xor", np.bitwise_xor), ("andnot", lambda x, y: x & ~y)]:
        words, card = S.bitset_op(a, b, op)
        assert np.array_equal(words, f(a, b))
        assert card == int(np.bitwise_count(f(a, b)).sum())


def test_array_ops(rng):
    a = np.sort(rng.choice(65536, 800, replace=False)).astype(np.uint16)
    b = np.sort(rng.choice(65536, 1200, replace=False)).astype(np.uint16)
    assert np.array_equal(S.intersect(a, b), np.intersect1d(a, b))
    assert np.array_equal(S.union(a, b), np.union1d(a, b))
    assert np.array_equal(S.difference(a, b), np.setdiff1d(a, b))
    assert np.array_equal(S.symmetric_difference(a, b), np.setxor1d(a, b))


def test_extraction_and_set_many(rng):
    vals = np.sort(rng.choice(65536, 2000, replace=False)).astype(np.uint16)
    words = C.positions_to_bitset(vals)
    assert np.array_equal(S.bitset_to_positions(words), vals)
    w2 = np.zeros(C.BITSET_WORDS, np.uint64)
    assert S.bitset_set_many(w2, vals) == 2000
    assert S.bitset_set_many(w2, vals) == 0
    assert np.array_equal(w2, words)
