"""Batched pairwise kernels (interpret=True) vs ref oracles vs numpy.

Covers the three planner classes: mixed-op bitset rows (op id per row),
two-sided array masks / count-only intersect, and the array x bitset
probe."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.array_ops import array_intersect_card, array_pair_masks
from repro.kernels.pair_ops import (
    array_bitset_probe, bitset_pair_card, bitset_pair_op,
)

_NP_OPS = [np.bitwise_and, np.bitwise_or, np.bitwise_xor,
           lambda x, y: x & ~y]


@pytest.mark.parametrize("n", [1, 5, 13])
def test_bitset_pair_op_mixed_ops(rng, n):
    a = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    b = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    opids = rng.integers(0, 4, n).astype(np.int32)
    want = np.stack([_NP_OPS[o](a[i], b[i])
                     for i, o in enumerate(opids.tolist())])
    want_c = np.bitwise_count(want).sum(axis=1)
    w, c = bitset_pair_op(jnp.asarray(a), jnp.asarray(b),
                          jnp.asarray(opids), interpret=True)
    assert np.array_equal(np.asarray(w), want)
    assert np.array_equal(np.asarray(c), want_c)
    c2 = bitset_pair_card(jnp.asarray(a), jnp.asarray(b),
                          jnp.asarray(opids), interpret=True)
    assert np.array_equal(np.asarray(c2), want_c)
    # oracle agreement
    ow, oc = ref.bitset_pair_op(jnp.asarray(a), jnp.asarray(b),
                                jnp.asarray(opids))
    assert np.array_equal(np.asarray(ow), want)
    assert np.array_equal(np.asarray(oc), want_c)


def test_bitset_pair_op_edge_patterns():
    pats = np.array([[0] * 2048, [0xFFFFFFFF] * 2048,
                     [0xFFFFFFFF] * 2048, [1] + [0] * 2047], np.uint32)
    other = np.array([[0xFFFFFFFF] * 2048, [0] * 2048,
                      [0xFFFFFFFF] * 2048, [1] + [0] * 2047], np.uint32)
    opids = np.array([1, 3, 2, 0], np.int32)   # or, andnot, xor, and
    want = np.stack([_NP_OPS[o](pats[i], other[i])
                     for i, o in enumerate(opids.tolist())])
    w, c = bitset_pair_op(jnp.asarray(pats), jnp.asarray(other),
                          jnp.asarray(opids), interpret=True)
    assert np.array_equal(np.asarray(w), want)
    assert np.array_equal(np.asarray(c), np.bitwise_count(want).sum(1))


@pytest.mark.parametrize("cards", [
    [(0, 5), (10, 4000), (3000, 3000), (4096, 1), (1, 1)],
    [(4096, 4096), (0, 0), (2048, 2048)],
])
def test_array_pair_masks_kernel(rng, cards):
    n = len(cards)
    A = np.zeros((n, 4096), np.int32)
    B = np.zeros((n, 4096), np.int32)
    avs, bvs = [], []
    for i, (ca, cb) in enumerate(cards):
        av = np.sort(rng.choice(65536, ca, replace=False)).astype(np.int32)
        bv = np.sort(rng.choice(65536, cb, replace=False)).astype(np.int32)
        A[i, :ca] = av
        B[i, :cb] = bv
        avs.append(av)
        bvs.append(bv)
    ac = np.array([c[0] for c in cards])
    bc = np.array([c[1] for c in cards])
    for fn in (array_pair_masks,
               lambda *a, **k: ref.array_pair_masks(*a)):
        ma, mb, cnt = fn(jnp.asarray(A), jnp.asarray(ac),
                         jnp.asarray(B), jnp.asarray(bc), interpret=True)
        ma, mb, cnt = np.asarray(ma), np.asarray(mb), np.asarray(cnt)
        for i, (ca, cb) in enumerate(cards):
            want = np.intersect1d(avs[i], bvs[i])
            assert cnt[i] == want.size
            assert np.array_equal(avs[i][ma[i, :ca].astype(bool)], want)
            assert np.array_equal(bvs[i][mb[i, :cb].astype(bool)], want)
            assert not ma[i, ca:].any() and not mb[i, cb:].any()
    cnt2 = array_intersect_card(jnp.asarray(A), jnp.asarray(ac),
                                jnp.asarray(B), jnp.asarray(bc),
                                interpret=True)
    assert np.array_equal(np.asarray(cnt2), cnt)


@pytest.mark.parametrize("cards", [[0, 1, 100, 4096], [2048]])
def test_array_bitset_probe_kernel(rng, cards):
    n = len(cards)
    vals = np.zeros((n, 4096), np.int32)
    vlists = []
    for i, c in enumerate(cards):
        v = np.sort(rng.choice(65536, c, replace=False)).astype(np.int32)
        vals[i, :c] = v
        vlists.append(v)
    words = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    got_m, got_c = array_bitset_probe(jnp.asarray(vals),
                                      jnp.asarray(cards),
                                      jnp.asarray(words), interpret=True)
    ref_m, ref_c = ref.array_bitset_probe(jnp.asarray(vals),
                                          jnp.asarray(cards),
                                          jnp.asarray(words))
    assert np.array_equal(np.asarray(got_m), np.asarray(ref_m))
    assert np.array_equal(np.asarray(got_c), np.asarray(ref_c))
    for i, c in enumerate(cards):
        v = vlists[i]
        want = (((words[i][v >> 5] >> (v & 31).astype(np.uint32)) & 1)
                .astype(np.int32) if c else np.zeros(0, np.int32))
        assert np.array_equal(np.asarray(got_m)[i, :c], want)
        assert int(np.asarray(got_c)[i]) == int(want.sum())
        assert not np.asarray(got_m)[i, c:].any()
