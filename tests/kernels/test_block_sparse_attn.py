"""Roaring block-sparse decode attention kernel vs oracle: shape/dtype sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.block_sparse_attn import decode_attention


def make_case(rng, b, h, hkv, d, s, bs, density, dtype):
    nblk = s // bs
    q = rng.standard_normal((b, h, d)).astype(dtype)
    k = (rng.standard_normal((b, hkv, s, d)) * 0.3).astype(dtype)
    v = rng.standard_normal((b, hkv, s, d)).astype(dtype)
    words = max(1, (nblk + 31) // 32)
    mask = np.zeros((b, words), np.uint32)
    for i in range(b):
        nsel = int(round(density * nblk))
        sel = rng.choice(nblk, nsel, replace=False)
        for s_ in sel:
            mask[i, s_ >> 5] |= np.uint32(1) << np.uint32(s_ & 31)
    kvl = rng.integers(1, s + 1, b).astype(np.int32)
    return q, k, v, mask, kvl


@pytest.mark.parametrize("b,h,hkv,d,s,bs", [
    (2, 8, 2, 64, 1024, 128),
    (1, 4, 4, 128, 512, 128),
    (3, 16, 8, 64, 1024, 256),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_vs_oracle(rng, b, h, hkv, d, s, bs, dtype):
    np_dtype = np.float32 if dtype == np.float32 else np.float32
    q, k, v, mask, kvl = make_case(rng, b, h, hkv, d, s, bs, 0.5, np_dtype)
    args = [jnp.asarray(q, dtype), jnp.asarray(k, dtype),
            jnp.asarray(v, dtype), jnp.asarray(mask), jnp.asarray(kvl)]
    got = np.asarray(decode_attention(*args, block_size=bs,
                                      interpret=True), np.float32)
    want = np.asarray(ref.block_sparse_attention_decode(
        *args, block_size=bs), np.float32)
    tol = 2e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


def test_empty_mask_returns_zeros(rng):
    q, k, v, mask, kvl = make_case(rng, 2, 4, 2, 64, 512, 128, 0.5,
                                   np.float32)
    mask[:] = 0
    got = np.asarray(decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(mask),
        jnp.asarray(kvl), block_size=128, interpret=True))
    assert np.allclose(got, 0.0)


def test_full_mask_equals_dense(rng):
    q, k, v, mask, kvl = make_case(rng, 2, 8, 4, 64, 512, 128, 1.0,
                                   np.float32)
    mask[:] = 0xFFFFFFFF
    got = np.asarray(decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(mask),
        jnp.asarray(kvl), block_size=128, interpret=True))
    # dense reference softmax over valid positions
    scale = 64 ** -0.5
    for i in range(2):
        L = int(kvl[i])
        qg = q[i].reshape(4, 2, 64)
        sc = np.einsum("kgd,ksd->kgs", qg, k[i][:, :L]) * scale
        w = np.exp(sc - sc.max(-1, keepdims=True))
        w /= w.sum(-1, keepdims=True)
        want = np.einsum("kgs,ksd->kgd", w, v[i][:, :L]).reshape(8, 64)
        np.testing.assert_allclose(got[i], want, atol=2e-5, rtol=2e-5)


def test_softcap(rng):
    q, k, v, mask, kvl = make_case(rng, 1, 4, 4, 32, 256, 128, 1.0,
                                   np.float32)
    mask[:] = 0xFFFFFFFF
    a = [jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(mask),
         jnp.asarray(kvl)]
    got = np.asarray(decode_attention(*a, block_size=128, softcap=5.0,
                                      interpret=True))
    want = np.asarray(ref.block_sparse_attention_decode(
        *a, block_size=128, softcap=5.0))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    plain = np.asarray(decode_attention(*a, block_size=128, interpret=True))
    assert np.abs(plain - got).max() > 1e-5
