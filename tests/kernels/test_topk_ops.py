"""Pallas fused top-k similarity kernel vs the pure-jnp oracle.

Random candidate slabs (ragged segments, empty candidates, exclusion,
every metric) must produce identical (idx, score, inter) triples from
``topk_ops.similarity_topk`` (interpret mode) and ``ref.similarity_topk``
-- including first-max tie ordering, which the selection contract rides
on."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels import topk_ops

WORDS = ref.WORDS


def random_case(rng, t, c, density=0.02):
    """Ragged candidate slab: each candidate owns 0..4 rows, each row a
    sparse bitset over one of ``c`` key columns."""
    rows, row_col, starts = [], [], [0]
    cards = []
    for _ in range(t):
        n_rows = int(rng.integers(0, 5))
        card = 0
        for _ in range(n_rows):
            w = (rng.random((WORDS,)) < density).astype(np.uint32)
            w = w * rng.integers(1, 1 << 32, WORDS, dtype=np.uint32)
            rows.append(w)
            row_col.append(int(rng.integers(0, c)))
            card += int(np.bitwise_count(w).sum())
        starts.append(len(rows))
        cards.append(card)
    q = (rng.random((c, WORDS)) < density * 2).astype(np.uint32) \
        * rng.integers(1, 1 << 32, (c, WORDS), dtype=np.uint32)
    q_card = int(np.bitwise_count(q).sum())
    rows = np.stack(rows) if rows else np.zeros((1, WORDS), np.uint32)
    row_col = np.asarray(row_col, np.int32) if row_col else \
        np.zeros(1, np.int32)
    return (jnp.asarray(rows), jnp.asarray(row_col),
            jnp.asarray(np.asarray(starts, np.int32)), jnp.asarray(q),
            q_card, jnp.asarray(np.asarray(cards, np.int32)))


@pytest.mark.parametrize("metric", ref.METRICS)
def test_kernel_matches_oracle(rng, metric):
    for trial in range(3):
        t, c = 12 + trial * 5, 4
        rows, row_col, starts, q, q_card, cards = random_case(rng, t, c)
        jmax = max(1, int(np.diff(np.asarray(starts)).max()))
        for exclude in (-1, 3):
            ki, ks, kn = topk_ops.similarity_topk(
                rows, row_col, starts, q, jnp.int32(q_card), cards,
                jnp.int32(exclude), metric=metric, k=5, jmax=jmax,
                interpret=True)
            oi, os_, on = ref.similarity_topk(
                rows, row_col, starts, q, jnp.int32(q_card), cards,
                jnp.int32(exclude), metric=metric, k=5)
            assert np.array_equal(np.asarray(ki), np.asarray(oi))
            assert np.array_equal(np.asarray(ks), np.asarray(os_))
            assert np.array_equal(np.asarray(kn), np.asarray(on))
            assert exclude not in np.asarray(ki).tolist() or exclude == -1


def test_oracle_inter_and_tie_order(rng):
    """The oracle itself: inter equals a hand loop; exact ties order by
    ascending candidate index (the stable-argsort contract)."""
    rows, row_col, starts, q, q_card, cards = random_case(rng, 10, 3)
    oi, os_, on = ref.similarity_topk(rows, row_col, starts, q,
                                      jnp.int32(q_card), cards,
                                      jnp.int32(-1), metric="jaccard",
                                      k=10)
    rows_np = np.asarray(rows)
    q_np = np.asarray(q)
    st = np.asarray(starts)
    col = np.asarray(row_col)
    want_inter = []
    for t in range(10):
        tot = 0
        for r in range(st[t], st[t + 1]):
            tot += int(np.bitwise_count(rows_np[r] & q_np[col[r]]).sum())
        want_inter.append(tot)
    for i, n in zip(np.asarray(oi).tolist(), np.asarray(on).tolist()):
        assert n == want_inter[i]
    sc = np.asarray(os_)
    idx = np.asarray(oi)
    for a, b in zip(range(len(sc) - 1), range(1, len(sc))):
        assert sc[a] > sc[b] or (sc[a] == sc[b] and idx[a] < idx[b])


def test_empty_segments_score_zero(rng):
    """Candidates with no rows (empty bitmaps) must score from
    inter = 0, not garbage, on both paths."""
    rows = jnp.asarray((rng.random((3, WORDS)) < 0.05)
                       .astype(np.uint32))
    row_col = jnp.asarray(np.zeros(3, np.int32))
    starts = jnp.asarray(np.asarray([0, 0, 3, 3], np.int32))  # t0/t2 empty
    q = rows[:1]
    cards = jnp.asarray(np.asarray(
        [0, int(np.bitwise_count(np.asarray(rows)).sum()), 0], np.int32))
    q_card = int(np.bitwise_count(np.asarray(q)).sum())
    ki, ks, kn = topk_ops.similarity_topk(
        rows, row_col, starts, q, jnp.int32(q_card), cards,
        jnp.int32(-1), metric="jaccard", k=3, jmax=4, interpret=True)
    oi, os_, on = ref.similarity_topk(
        rows, row_col, starts, q, jnp.int32(q_card), cards,
        jnp.int32(-1), metric="jaccard", k=3)
    assert np.array_equal(np.asarray(ki), np.asarray(oi))
    assert np.array_equal(np.asarray(ks), np.asarray(os_))
    assert np.asarray(kn).tolist()[1:] == [0, 0]   # the empty candidates
