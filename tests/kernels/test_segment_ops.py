"""Segmented wide-aggregation kernel (interpret=True) vs the jnp oracle and
numpy ground truth: ragged segments, empty segments, threshold counters."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.segment_ops import counter_planes, segment_reduce

WORDS = ref.WORDS


def _np_reduce(slab, starts, op, t=0):
    s = starts.size - 1
    out = np.zeros((s, WORDS), np.uint32)
    for i in range(s):
        rows = slab[starts[i]:starts[i + 1]]
        if rows.shape[0] == 0:
            continue
        if op == "threshold":
            for b in range(32):
                cnt = ((rows >> np.uint32(b)) & 1).sum(axis=0)
                out[i] |= np.uint32(1 << b) * (cnt >= t)
        else:
            f = {"or": np.bitwise_or, "and": np.bitwise_and,
                 "xor": np.bitwise_xor}[op]
            out[i] = f.reduce(rows, axis=0)
    return out


def _segments(rng, n, s):
    cuts = np.sort(rng.choice(n + 1, s - 1, replace=True))
    return np.concatenate(([0], cuts, [n])).astype(np.int32)


@pytest.mark.parametrize("op", ["or", "and", "xor"])
@pytest.mark.parametrize("n,s", [(7, 3), (16, 1), (24, 9)])
def test_segment_reduce_vs_oracle(rng, op, n, s):
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    starts = _segments(rng, n, s)
    jmax = max(1, int(np.diff(starts).max()))
    want = _np_reduce(slab, starts, op)
    want_c = np.bitwise_count(want).sum(axis=1)
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts), op,
                            jmax=jmax, interpret=True)
    ow, oc = ref.segment_reduce(jnp.asarray(slab), jnp.asarray(starts), op,
                                jmax=jmax)
    assert np.array_equal(np.asarray(kw), want)
    assert np.array_equal(np.asarray(kc), want_c)
    assert np.array_equal(np.asarray(ow), want)
    assert np.array_equal(np.asarray(oc), want_c)


def test_segment_reduce_empty_and_overlong_segments(rng):
    """Empty segments reduce to zero for every op (even AND, whose step
    identity is all-ones); jmax may exceed the longest segment."""
    slab = rng.integers(0, 1 << 32, (5, WORDS), dtype=np.uint32)
    starts = np.array([0, 0, 3, 3, 5], np.int32)
    for op in ("or", "and", "xor"):
        kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts), op,
                                jmax=8, interpret=True)
        want = _np_reduce(slab, starts, op)
        assert np.array_equal(np.asarray(kw), want)
        assert int(np.asarray(kc)[0]) == 0 and int(np.asarray(kc)[2]) == 0


@pytest.mark.parametrize("t", [1, 2, 4, 7])
def test_segment_threshold_vs_oracle(rng, t):
    n, s = 21, 4
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    # adversarial extra: rows with identical words to stack exact counts
    slab[3] = slab[4] = slab[5]
    starts = np.array([0, 7, 7, 14, 21], np.int32)
    jmax = 8
    want = _np_reduce(slab, starts, "threshold", t)
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                            "threshold", jmax=jmax, threshold=t,
                            interpret=True)
    ow, oc = ref.segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                                "threshold", jmax=jmax, threshold=t)
    assert np.array_equal(np.asarray(kw), want)
    assert np.array_equal(np.asarray(ow), want)
    want_c = np.bitwise_count(want).sum(axis=1)
    assert np.array_equal(np.asarray(kc), want_c)
    assert np.array_equal(np.asarray(oc), want_c)


def test_threshold_equals_or_and():
    """T=1 over K rows == OR; T=K == AND (symmetric-function endpoints)."""
    rng = np.random.default_rng(5)
    slab = rng.integers(0, 1 << 32, (6, WORDS), dtype=np.uint32)
    starts = np.array([0, 6], np.int32)
    a = jnp.asarray(slab)
    st = jnp.asarray(starts)
    w_or, _ = segment_reduce(a, st, "or", jmax=6, interpret=True)
    w_and, _ = segment_reduce(a, st, "and", jmax=6, interpret=True)
    w_t1, _ = segment_reduce(a, st, "threshold", jmax=6, threshold=1,
                             interpret=True)
    w_t6, _ = segment_reduce(a, st, "threshold", jmax=6, threshold=6,
                             interpret=True)
    assert np.array_equal(np.asarray(w_t1), np.asarray(w_or))
    assert np.array_equal(np.asarray(w_t6), np.asarray(w_and))


def test_counter_planes():
    assert counter_planes(1) == 1
    assert counter_planes(2) == 2
    assert counter_planes(3) == 2
    assert counter_planes(4) == 3
    assert counter_planes(64) == 7
