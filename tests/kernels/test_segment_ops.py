"""Segmented wide-aggregation kernel (interpret=True) vs the jnp oracle and
numpy ground truth: ragged segments, empty segments, threshold counters."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.segment_ops import counter_planes, segment_reduce

WORDS = ref.WORDS


def _np_reduce(slab, starts, op, t=0, w=None):
    s = starts.size - 1
    out = np.zeros((s, WORDS), np.uint32)
    for i in range(s):
        rows = slab[starts[i]:starts[i + 1]]
        if rows.shape[0] == 0:
            continue
        if op == "threshold":
            ws = np.ones(rows.shape[0], np.int64) if w is None else \
                w[starts[i]:starts[i + 1]].astype(np.int64)
            for b in range(32):
                cnt = (((rows >> np.uint32(b)) & 1) * ws[:, None]).sum(axis=0)
                out[i] |= np.uint32(1 << b) * (cnt >= t)
        elif op == "andnot":
            rest = np.bitwise_or.reduce(rows[1:], axis=0) \
                if rows.shape[0] > 1 else np.zeros(WORDS, np.uint32)
            out[i] = rows[0] & ~rest
        else:
            f = {"or": np.bitwise_or, "and": np.bitwise_and,
                 "xor": np.bitwise_xor}[op]
            out[i] = f.reduce(rows, axis=0)
    return out


def _segments(rng, n, s):
    cuts = np.sort(rng.choice(n + 1, s - 1, replace=True))
    return np.concatenate(([0], cuts, [n])).astype(np.int32)


@pytest.mark.parametrize("op", ["or", "and", "xor"])
@pytest.mark.parametrize("n,s", [(7, 3), (16, 1), (24, 9)])
def test_segment_reduce_vs_oracle(rng, op, n, s):
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    starts = _segments(rng, n, s)
    jmax = max(1, int(np.diff(starts).max()))
    want = _np_reduce(slab, starts, op)
    want_c = np.bitwise_count(want).sum(axis=1)
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts), op,
                            jmax=jmax, interpret=True)
    ow, oc = ref.segment_reduce(jnp.asarray(slab), jnp.asarray(starts), op,
                                jmax=jmax)
    assert np.array_equal(np.asarray(kw), want)
    assert np.array_equal(np.asarray(kc), want_c)
    assert np.array_equal(np.asarray(ow), want)
    assert np.array_equal(np.asarray(oc), want_c)


def test_segment_reduce_empty_and_overlong_segments(rng):
    """Empty segments reduce to zero for every op (even AND, whose step
    identity is all-ones); jmax may exceed the longest segment."""
    slab = rng.integers(0, 1 << 32, (5, WORDS), dtype=np.uint32)
    starts = np.array([0, 0, 3, 3, 5], np.int32)
    for op in ("or", "and", "xor"):
        kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts), op,
                                jmax=8, interpret=True)
        want = _np_reduce(slab, starts, op)
        assert np.array_equal(np.asarray(kw), want)
        assert int(np.asarray(kc)[0]) == 0 and int(np.asarray(kc)[2]) == 0


@pytest.mark.parametrize("t", [1, 2, 4, 7])
def test_segment_threshold_vs_oracle(rng, t):
    n, s = 21, 4
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    # adversarial extra: rows with identical words to stack exact counts
    slab[3] = slab[4] = slab[5]
    starts = np.array([0, 7, 7, 14, 21], np.int32)
    jmax = 8
    want = _np_reduce(slab, starts, "threshold", t)
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                            "threshold", jmax=jmax, threshold=t,
                            interpret=True)
    ow, oc = ref.segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                                "threshold", jmax=jmax, threshold=t)
    assert np.array_equal(np.asarray(kw), want)
    assert np.array_equal(np.asarray(ow), want)
    want_c = np.bitwise_count(want).sum(axis=1)
    assert np.array_equal(np.asarray(kc), want_c)
    assert np.array_equal(np.asarray(oc), want_c)


def test_threshold_equals_or_and():
    """T=1 over K rows == OR; T=K == AND (symmetric-function endpoints)."""
    rng = np.random.default_rng(5)
    slab = rng.integers(0, 1 << 32, (6, WORDS), dtype=np.uint32)
    starts = np.array([0, 6], np.int32)
    a = jnp.asarray(slab)
    st = jnp.asarray(starts)
    w_or, _ = segment_reduce(a, st, "or", jmax=6, interpret=True)
    w_and, _ = segment_reduce(a, st, "and", jmax=6, interpret=True)
    w_t1, _ = segment_reduce(a, st, "threshold", jmax=6, threshold=1,
                             interpret=True)
    w_t6, _ = segment_reduce(a, st, "threshold", jmax=6, threshold=6,
                             interpret=True)
    assert np.array_equal(np.asarray(w_t1), np.asarray(w_or))
    assert np.array_equal(np.asarray(w_t6), np.asarray(w_and))


def test_counter_planes():
    assert counter_planes(1) == 1
    assert counter_planes(2) == 2
    assert counter_planes(3) == 2
    assert counter_planes(4) == 3
    assert counter_planes(64) == 7


@pytest.mark.parametrize("n,s", [(7, 3), (9, 1), (16, 5)])
def test_segment_andnot_vs_oracle(rng, n, s):
    """Fused difference chain: row0 & ~(OR of the rest), including
    single-row segments (nothing subtracted) and empty segments."""
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    starts = _segments(rng, n, s)
    jmax = max(1, int(np.diff(starts).max()))
    want = _np_reduce(slab, starts, "andnot")
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                            "andnot", jmax=jmax, interpret=True)
    ow, oc = ref.segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                                "andnot", jmax=jmax)
    want_c = np.bitwise_count(want).sum(axis=1)
    assert np.array_equal(np.asarray(kw), want)
    assert np.array_equal(np.asarray(kc), want_c)
    assert np.array_equal(np.asarray(ow), want)
    assert np.array_equal(np.asarray(oc), want_c)


def test_segment_andnot_self_and_empty(rng):
    """a - a == 0; a - nothing == a; empty segment -> zero."""
    slab = rng.integers(0, 1 << 32, (4, WORDS), dtype=np.uint32)
    slab[1] = slab[0]
    starts = np.array([0, 2, 3, 3, 4], np.int32)
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                            "andnot", jmax=4, interpret=True)
    kw, kc = np.asarray(kw), np.asarray(kc)
    assert not kw[0].any() and kc[0] == 0          # a & ~a
    assert np.array_equal(kw[1], slab[2])          # lone minuend
    assert not kw[2].any() and kc[2] == 0          # empty segment


@pytest.mark.parametrize("t", [2, 5, 11])
def test_segment_threshold_weighted_vs_oracle(rng, t):
    """Weighted counters via shift-and-add: per-row integer weights, with
    exact-count collisions from duplicated rows."""
    n, s = 14, 3
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    slab[4] = slab[3]                              # stack exact counts
    starts = np.array([0, 6, 6, 14], np.int32)
    w = rng.integers(1, 8, n).astype(np.int32)
    jmax = 8
    totals = [int(w[starts[i]:starts[i + 1]].sum())
              for i in range(starts.size - 1)]
    planes = max(counter_planes(max(totals)), int(t).bit_length())
    wbits = int(w.max()).bit_length()
    want = _np_reduce(slab, starts, "threshold", t, w)
    want_c = np.bitwise_count(want).sum(axis=1)
    kw, kc = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                            "threshold", jmax=jmax, threshold=t,
                            weights=jnp.asarray(w), planes=planes,
                            wbits=wbits, interpret=True)
    ow, oc = ref.segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                                "threshold", jmax=jmax, threshold=t,
                                weights=jnp.asarray(w))
    assert np.array_equal(np.asarray(kw), want)
    assert np.array_equal(np.asarray(kc), want_c)
    assert np.array_equal(np.asarray(ow), want)
    assert np.array_equal(np.asarray(oc), want_c)


def test_weight_one_degenerates_to_unweighted(rng):
    """All-ones weights must produce bit-identical output to the
    unweighted counter circuit."""
    n = 12
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    starts = np.array([0, 5, 12], np.int32)
    for t in (1, 3, 5):
        kw0, kc0 = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                                  "threshold", jmax=8, threshold=t,
                                  interpret=True)
        kw1, kc1 = segment_reduce(jnp.asarray(slab), jnp.asarray(starts),
                                  "threshold", jmax=8, threshold=t,
                                  weights=jnp.ones(n, jnp.int32),
                                  interpret=True)
        assert np.array_equal(np.asarray(kw0), np.asarray(kw1))
        assert np.array_equal(np.asarray(kc0), np.asarray(kc1))


def test_segment_counters_exchange_roundtrip(rng):
    """The sharded-threshold contract: counters from disjoint row splits,
    bit-slice-added, then compared, must equal the one-shot threshold."""
    n = 10
    slab = rng.integers(0, 1 << 32, (n, WORDS), dtype=np.uint32)
    starts = np.array([0, 4, 10], np.int32)
    w = rng.integers(1, 5, n).astype(np.int32)
    planes = counter_planes(int(max(w[0:4].sum(), w[4:10].sum()) * 2))
    # split each segment's rows into even/odd halves (zero-padded rows
    # keep the segment structure identical on both "shards")
    halves = []
    for par in (0, 1):
        h = slab.copy()
        hw = w.copy()
        for i in range(starts.size - 1):
            rows = np.arange(starts[i], starts[i + 1])
            drop = rows[(rows - starts[i]) % 2 != par]
            h[drop] = 0
            hw[drop] = 1                          # weight of a zero row
        halves.append(ref.segment_counters(
            jnp.asarray(h), jnp.asarray(starts), jmax=8, planes=planes,
            weights=jnp.asarray(hw)))
    tot = ref.bitsliced_add(halves[0], halves[1])
    for t in (1, 4, 9):
        got = np.asarray(ref.counters_ge(tot, jnp.int32(t)))
        want = _np_reduce(slab, starts, "threshold", t, w)
        assert np.array_equal(got, want), t
