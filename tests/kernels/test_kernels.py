"""Pallas kernels (interpret=True) vs pure-jnp oracles: shape/dtype sweeps.

Every kernel is validated against its ref.py oracle AND against numpy
ground truth where applicable, per the deliverable's requirement.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.array_ops import array_difference, array_intersect
from repro.kernels.bitset_convert import array_to_bitset, bitset_set_many
from repro.kernels.bitset_ops import bitset_op, bitset_op_card
from repro.kernels.harley_seal import popcount


@pytest.mark.parametrize("n", [1, 3, 8, 17])
def test_harley_seal_popcount(rng, n):
    w = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    want = np.bitwise_count(w).sum(axis=1)
    assert np.array_equal(np.asarray(popcount(jnp.asarray(w),
                                              interpret=True)), want)
    assert np.array_equal(np.asarray(ref.popcount_words(jnp.asarray(w))),
                          want)


def test_harley_seal_edge_patterns():
    pats = np.array([[0] * 2048, [0xFFFFFFFF] * 2048,
                     [0x80000001] * 2048, [1] + [0] * 2047], np.uint32)
    want = np.bitwise_count(pats).sum(axis=1)
    assert np.array_equal(
        np.asarray(popcount(jnp.asarray(pats), interpret=True)), want)


@pytest.mark.parametrize("op,f", [
    ("and", np.bitwise_and), ("or", np.bitwise_or),
    ("xor", np.bitwise_xor), ("andnot", lambda x, y: x & ~y)])
@pytest.mark.parametrize("n", [2, 9])
def test_bitset_op_kernel(rng, op, f, n):
    a = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    b = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    want_w = f(a, b)
    want_c = np.bitwise_count(want_w).sum(axis=1)
    rw, rc = bitset_op(jnp.asarray(a), jnp.asarray(b), op, interpret=True)
    assert np.array_equal(np.asarray(rw), want_w)
    assert np.array_equal(np.asarray(rc), want_c)
    rc2 = bitset_op_card(jnp.asarray(a), jnp.asarray(b), op, interpret=True)
    assert np.array_equal(np.asarray(rc2), want_c)
    # oracle agreement
    ow, oc = ref.bitset_op(jnp.asarray(a), jnp.asarray(b), op)
    assert np.array_equal(np.asarray(ow), want_w)
    assert np.array_equal(np.asarray(oc), want_c)


@pytest.mark.parametrize("cards", [[0, 1, 4096], [100, 2048, 4000]])
def test_array_to_bitset_kernel(rng, cards):
    n = len(cards)
    vals = np.zeros((n, 4096), np.int32)
    for i, c in enumerate(cards):
        vals[i, :c] = np.sort(rng.choice(65536, c, replace=False))
    got = np.asarray(array_to_bitset(jnp.asarray(vals),
                                     jnp.asarray(cards), interpret=True))
    oracle = np.asarray(ref.array_to_bitset(jnp.asarray(vals),
                                            jnp.asarray(cards)))
    assert np.array_equal(got, oracle)
    for i, c in enumerate(cards):
        bits = np.unpackbits(got[i].view(np.uint8), bitorder="little")
        want = np.zeros(65536, np.uint8)
        want[vals[i, :c]] = 1
        assert np.array_equal(bits, want)


def test_bitset_set_many_kernel(rng):
    n = 3
    init = rng.integers(0, 1 << 32, (n, 2048), dtype=np.uint32)
    cards = [10, 1000, 4096]
    vals = np.zeros((n, 4096), np.int32)
    for i, c in enumerate(cards):
        vals[i, :c] = np.sort(rng.choice(65536, c, replace=False))
    nw, delta = bitset_set_many(jnp.asarray(init), jnp.asarray(vals),
                                jnp.asarray(cards), interpret=True)
    onw, od = ref.bitset_set_many(jnp.asarray(init), jnp.asarray(vals),
                                  jnp.asarray(cards))
    assert np.array_equal(np.asarray(nw), np.asarray(onw))
    assert np.array_equal(np.asarray(delta), np.asarray(od))
    # cardinality delta == popcount(new) - popcount(old)
    want_delta = (np.bitwise_count(np.asarray(nw)).sum(1)
                  - np.bitwise_count(init).sum(1))
    assert np.array_equal(np.asarray(delta), want_delta)


def test_bitset_to_array_roundtrip(rng):
    cards = [0, 1, 2000, 4096]
    vals = np.full((4, 4096), 0, np.int32)
    for i, c in enumerate(cards):
        vals[i, :c] = np.sort(rng.choice(65536, c, replace=False))
    words = ref.array_to_bitset(jnp.asarray(vals), jnp.asarray(cards))
    out_vals, out_cards = ref.bitset_to_array(words)
    assert np.array_equal(np.asarray(out_cards), cards)
    for i, c in enumerate(cards):
        assert np.array_equal(np.asarray(out_vals)[i, :c], vals[i, :c])


@pytest.mark.parametrize("ca,cb", [(10, 4000), (3000, 3000), (4096, 1)])
def test_array_intersect_kernel(rng, ca, cb):
    av = np.sort(rng.choice(65536, ca, replace=False)).astype(np.int32)
    bv = np.sort(rng.choice(65536, cb, replace=False)).astype(np.int32)
    A = np.zeros((1, 4096), np.int32)
    A[0, :ca] = av
    B = np.zeros((1, 4096), np.int32)
    B[0, :cb] = bv
    mask, cnt = array_intersect(jnp.asarray(A), jnp.asarray([ca]),
                                jnp.asarray(B), jnp.asarray([cb]),
                                interpret=True)
    want = np.intersect1d(av, bv)
    assert int(cnt[0]) == want.size
    assert np.array_equal(A[0][np.asarray(mask[0]).astype(bool)], want)
    keep, dcnt = array_difference(jnp.asarray(A), jnp.asarray([ca]),
                                  jnp.asarray(B), jnp.asarray([cb]),
                                  interpret=True)
    wantd = np.setdiff1d(av, bv)
    assert int(dcnt[0]) == wantd.size
    assert np.array_equal(A[0][np.asarray(keep[0]).astype(bool)], wantd)


def test_merge_dedup_oracles(rng):
    ca, cb = 2500, 3000
    av = np.sort(rng.choice(65536, ca, replace=False)).astype(np.int32)
    bv = np.sort(rng.choice(65536, cb, replace=False)).astype(np.int32)
    A = np.zeros((1, 4096), np.int32)
    A[0, :ca] = av
    B = np.zeros((1, 4096), np.int32)
    B[0, :cb] = bv
    m, _ = ref.merge_sorted(jnp.asarray(A), jnp.asarray([ca]),
                            jnp.asarray(B), jnp.asarray([cb]))
    u, uc = ref.dedup_sorted(m)
    wantu = np.union1d(av, bv)
    assert int(uc[0]) == wantu.size
    assert np.array_equal(np.asarray(u)[0, :wantu.size], wantu)
    x, xc = ref.xor_dedup_sorted(m)
    wantx = np.setxor1d(av, bv)
    assert int(xc[0]) == wantx.size
    assert np.array_equal(np.asarray(x)[0, :wantx.size], wantx)
