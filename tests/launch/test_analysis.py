"""HLO analyzer + sharding rules + roofline plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_text
from repro.launch.roofline import (model_flops_decode, model_flops_train,
                                   roofline_terms_from_analysis)


def test_scan_trip_count_multiplies_flops():
    def f(xs, w):
        def body(c, x):
            return jnp.tanh(c @ w) + x, ()
        out, _ = jax.lax.scan(body, xs[0], xs)
        return out

    xs = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(xs, w).compile()
    res = analyze_text(comp.as_text())
    assert res["flops"] == 7 * 2 * 64 ** 3
    assert res["collective_total"] == 0


def test_inplace_dus_accounting():
    # a scan that writes one row per step must not be charged the whole
    # buffer each step
    def f(buf, rows):
        def body(b, args):
            i, r = args
            return jax.lax.dynamic_update_index_in_dim(b, r, i, 0), ()
        out, _ = jax.lax.scan(body, buf,
                              (jnp.arange(1024), rows))
        return out

    buf = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    rows = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    comp = jax.jit(f).lower(buf, rows).compile()
    res = analyze_text(comp.as_text())
    full_buffer_per_step = 1024 * 1024 * 256 * 4
    assert res["bytes"] < full_buffer_per_step / 10


def test_roofline_terms_shape():
    ana = {"flops": 197e12, "bytes": 819e9, "collective_total": 50e9}
    t = roofline_terms_from_analysis(ana, model_flops=197e12 * 256,
                                     chips=256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    assert t["model_to_hlo_flops"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(1.0)


def test_model_flops_moe_uses_active():
    import repro.configs as C
    dense = C.get_config("qwen3_14b")
    moe = C.get_config("mixtral_8x7b")
    assert model_flops_train(moe, 4096, 256) < \
        6 * moe.params_count() * 4096 * 256
    assert model_flops_train(dense, 4096, 256) == \
        6 * dense.params_count() * 4096 * 256
    assert model_flops_decode(dense, 8) == 2 * dense.params_count() * 8


def test_sharding_rules_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import spec_for_param
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)
    m = FakeMesh()
    # attention: heads sharded when divisible
    s = spec_for_param("prefix_0.mixer.wq", (4096, 32, 128), m)
    assert s == P("data", "model", None)
    # stacked pattern params get a leading replicated dim
    s = spec_for_param("pattern.0.mixer.wq", (40, 4096, 32, 128), m)
    assert s == P(None, "data", "model", None)
    # non-divisible head count drops the axis
    s = spec_for_param("prefix_0.mixer.wk", (4096, 2, 128), m)
    assert s == P("data", None, None)
    # MoE fallback: 8 experts can't shard 16-way -> ff-dim TP
    s = spec_for_param("pattern.0.ffn.wg", (32, 8, 4096, 14336), m)
    assert s == P(None, None, "data", "model")
    # 160 experts shard fine
    s = spec_for_param("pattern.0.ffn.wg", (59, 160, 5120, 1536), m)
    assert s == P(None, "model", "data", None)


def test_grid_covers_40_cells():
    import repro.configs as C
    cells = C.grid()
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    # hubert decode x2 + long_500k for the 5 pure-full-attention archs
    skip_pairs = {(a, s) for a, s, ok, _ in cells if not ok}
    assert ("hubert_xlarge", "decode_32k") in skip_pairs
    assert ("hubert_xlarge", "long_500k") in skip_pairs
    assert ("qwen2_vl_72b", "long_500k") in skip_pairs
    assert ("gemma2_27b", "long_500k") not in skip_pairs  # roaring-sparse
    assert ("mixtral_8x7b", "long_500k") not in skip_pairs  # SWA
    assert ("xlstm_350m", "long_500k") not in skip_pairs
    assert ("jamba_v01_52b", "long_500k") not in skip_pairs
