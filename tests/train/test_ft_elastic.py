"""Failure detection, straggler policy, elastic replanning."""

import numpy as np
import pytest

from repro.train.elastic import plan_mesh, rebatch_plan
from repro.train.ft import HeartbeatMonitor, StragglerPolicy


def test_heartbeat():
    hb = HeartbeatMonitor(timeout_s=10)
    hb.beat("h0", 0.0)
    hb.beat("h1", 0.0)
    hb.beat("h0", 8.0)
    assert hb.failed_hosts(now=12.0) == ["h1"]
    assert hb.alive_hosts(now=12.0) == ["h0"]


def test_straggler_flagging():
    sp = StragglerPolicy(ratio=1.5, patience=2)
    for step in range(4):
        for h in ["h0", "h1", "h2", "h3"]:
            sp.observe(h, 1.0 if h != "h3" else 5.0)
        flagged = sp.stragglers()
    assert flagged == ["h3"]
    # recovery clears strikes
    for _ in range(3):
        for h in ["h0", "h1", "h2", "h3"]:
            sp.observe(h, 1.0)
        flagged = sp.stragglers()
    assert flagged == []


def test_skip_rescale_unbiased():
    s = StragglerPolicy.scale_for_skipped(16, 2)
    assert abs(s * 14 - 16) < 1e-9


def test_plan_mesh_shapes():
    p = plan_mesh(512, model_parallel=16, chips_per_pod=256)
    assert p.shape == (2, 16, 16) and p.axis_names == ("pod", "data", "model")
    p = plan_mesh(256, 16, 256)
    assert p.shape == (16, 16)
    # lose 3 chips from a pod: mesh shrinks, some chips idle
    p = plan_mesh(253, 16, 256)
    assert p.shape == (15, 16)
    assert p.idle_chips == 13
    with pytest.raises(ValueError):
        plan_mesh(8, 16)


def test_rebatch_keeps_global_batch():
    r = rebatch_plan(global_batch=256, old_dp=16, new_dp=15)
    assert r["effective_batch"] >= 256
    assert r["per_replica_batch"] <= 16       # memory-safe
    r = rebatch_plan(256, 16, 8)
    assert r == {"per_replica_batch": 16, "grad_accum": 2,
                 "effective_batch": 256}
    r = rebatch_plan(256, 16, 16)
    assert r == {"per_replica_batch": 16, "grad_accum": 1,
                 "effective_batch": 256}


def test_reshard_roundtrip():
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train.elastic import make_mesh_from_plan, reshard
    plan = plan_mesh(len(jax.devices()), model_parallel=1, chips_per_pod=1024)
    mesh = make_mesh_from_plan(plan)
    tree = {"w": np.arange(32.0).reshape(8, 4)}
    shardings = {"w": NamedSharding(mesh, P(None, None))}
    placed = reshard(tree, shardings)
    assert np.array_equal(np.asarray(placed["w"]), tree["w"])
