"""Checkpointing: atomicity, checksums, async, corrupt-fallback."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.standard_normal((8, 16)), jnp.float32),
            "nested": {"b": jnp.asarray(r.integers(0, 9, (4,)), jnp.int32),
                       "c": (jnp.ones((3,)), jnp.zeros((2, 2)))}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = tree(1)
    mgr.save(7, t, extra={"foo": 1})
    got, extra = mgr.restore(7, t)
    assert extra == {"foo": 1}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402


def test_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, tree(s), async_=True)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_corrupt_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = tree(1)
    mgr.save(1, t)
    mgr.save(2, t)
    # corrupt the newest checkpoint's arrays
    path = os.path.join(str(tmp_path), "step_0000000002", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef" * 8)
    found = mgr.restore_with_retry(t)
    assert found is not None
    step, got, _ = found
    assert step == 1  # fell back past the corrupt one


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree(1))
    bad = {"a": jnp.zeros((9, 16)),
           "nested": {"b": jnp.zeros((4,), jnp.int32),
                      "c": (jnp.ones((3,)), jnp.zeros((2, 2)))}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


def test_no_tmp_dirs_after_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree(0))
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
