"""Top-k gradient compression with Roaring coordinate sets."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import compress as GC


def test_topk_sparsify_and_densify(rng):
    g = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    vals, idx, res = GC.topk_sparsify(g, k=128)
    dense = GC.densify(vals, idx, g.shape)
    # kept + residual == original
    np.testing.assert_allclose(np.asarray(dense + res), np.asarray(g),
                               atol=1e-6)
    # kept entries are the largest magnitudes
    flat = np.abs(np.asarray(g).reshape(-1))
    thresh = np.sort(flat)[-128]
    assert np.abs(np.asarray(vals)).min() >= thresh - 1e-6


def test_sparse_allreduce_under_shard_map(rng):
    mesh = jax.make_mesh((1,), ("dp",))
    g = jnp.asarray(rng.standard_normal((256,)), jnp.float32)

    from jax.sharding import PartitionSpec as P

    def f(gl):
        red, res = GC.sparse_allreduce(gl, "dp", k=64)
        return red, res

    if hasattr(jax, "shard_map"):
        smap = jax.shard_map(f, mesh=mesh, in_specs=P(),
                             out_specs=(P(), P()), check_vma=False)
    else:  # jax < 0.6 ships it under experimental with check_rep
        from jax.experimental.shard_map import shard_map
        smap = shard_map(f, mesh=mesh, in_specs=P(),
                         out_specs=(P(), P()), check_rep=False)
    red, res = jax.jit(smap)(g)
    # single replica: reduction == top-64 of g, residual == the rest
    np.testing.assert_allclose(np.asarray(red + res), np.asarray(g),
                               atol=1e-6)
    assert int(np.count_nonzero(np.asarray(red))) == 64


def test_wire_bytes_accounting(rng):
    idx = np.sort(rng.choice(1 << 20, 4096, replace=False))
    sparse = GC.wire_bytes_sparse(idx)
    dense = GC.wire_bytes_dense(1 << 20)
    assert sparse < dense / 50
    bm = GC.coordinate_bitmap(idx)
    assert bm.cardinality == 4096
