"""End-to-end trainer: loss goes down; kill/restore resumes exactly."""

import dataclasses

import numpy as np
import pytest

import repro.configs as C
from repro.data.pipeline import RoaringDataPipeline
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer


def make_trainer(tmp_path, tag="a", ckpt_every=5):
    cfg = C.get_config("qwen2_5_3b", reduced=True)
    cfg = dataclasses.replace(cfg, remat="none")
    pipe = RoaringDataPipeline(n_docs=512, seq_len=32, batch_size=4,
                               vocab=cfg.vocab, seed=7)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                      weight_decay=0.0)
    return Trainer(cfg, opt, pipe, str(tmp_path / tag),
                   ckpt_every=ckpt_every, async_ckpt=False)


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path)
    hist = tr.train(30, log_every=100)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.05, (first, last)


@pytest.mark.slow
def test_kill_and_resume_bitexact(tmp_path):
    # run 1: 10 steps, checkpoint at 5 and 10, "crash"
    tr1 = make_trainer(tmp_path, "run")
    tr1.train(10, log_every=100)
    # run 2 (same dir): resume from step 10, do 5 more
    tr2 = make_trainer(tmp_path, "run")
    assert tr2.maybe_resume()
    assert tr2.step == 10
    # pipeline must not replay: its step advanced with the checkpoint
    assert tr2.pipeline.step == tr1.pipeline.step
    h2 = tr2.train(5, log_every=100)
    # reference: train 15 uninterrupted with identical seeds
    tr3 = make_trainer(tmp_path, "ref")
    h3 = tr3.train(15, log_every=100)
    np.testing.assert_allclose(
        [h["loss"] for h in h2],
        [h["loss"] for h in h3[-5:]], rtol=2e-4, atol=2e-4)
