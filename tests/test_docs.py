"""Docs rot guard: every file path and module reference in
docs/ARCHITECTURE.md (and the README's tree sketch) must exist, so the
paper -> module map can never drift from the tree.  Runnable standalone
(CI lint job: ``python tests/test_docs.py``) or under pytest."""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _referenced_paths(text: str) -> set[str]:
    """File-ish references inside backticks or links: src/..., tests/...,
    benchmarks/..., examples/..., docs/..., *.md / *.py / *.yml."""
    pat = re.compile(
        r"`?((?:src|tests|benchmarks|examples|docs|\.github)"
        r"/[\w./-]+\.(?:py|md|yml|json))`?")
    return set(pat.findall(text))


def _referenced_modules(text: str) -> set[str]:
    """Dotted repro.* module references (``repro.core.aggregate`` etc.)."""
    return set(re.findall(r"`(repro(?:\.\w+)+)`", text))


def check() -> list[str]:
    errors = []
    for doc in ("docs/ARCHITECTURE.md", "README.md",
                "benchmarks/README.md"):
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: missing")
            continue
        text = path.read_text()
        for ref in sorted(_referenced_paths(text)):
            if not (ROOT / ref).exists():
                errors.append(f"{doc}: references missing file {ref}")
        for mod in sorted(_referenced_modules(text)):
            rel = mod.replace(".", "/")
            if not ((ROOT / "src" / f"{rel}.py").exists()
                    or (ROOT / "src" / rel / "__init__.py").exists()):
                errors.append(f"{doc}: references missing module {mod}")
    return errors


def test_architecture_references_exist():
    errors = check()
    assert not errors, "\n".join(errors)


def test_architecture_is_linked_and_nontrivial():
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    # the map must actually cover the paper's core sections
    for needle in ("4.1.1", "4.2", "5.8", "5.9", "one-dispatch",
                   "similarity_topk", "segment_reduce"):
        assert needle in arch, needle
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme, \
        "README must link the architecture guide"


if __name__ == "__main__":
    errs = check()
    for e in errs:
        print(f"FAIL {e}", file=sys.stderr)
    if not errs:
        print("docs references OK")
    sys.exit(1 if errs else 0)
