"""Docs rot guard: every file path and module reference in
docs/ARCHITECTURE.md / docs/MEMORY.md (and the READMEs) must exist, so
the paper -> module map can never drift from the tree, and the arena's
public memory-lifecycle surface must stay documented (docstrings are
checked via ``ast``, so this runs in the dependency-free CI lint job).
Runnable standalone (``python tests/test_docs.py``) or under pytest."""

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _referenced_paths(text: str) -> set[str]:
    """File-ish references inside backticks or links: src/..., tests/...,
    benchmarks/..., examples/..., docs/..., *.md / *.py / *.yml."""
    pat = re.compile(
        r"`?((?:src|tests|benchmarks|examples|docs|\.github)"
        r"/[\w./-]+\.(?:py|md|yml|json))`?")
    return set(pat.findall(text))


def _referenced_modules(text: str) -> set[str]:
    """Dotted repro.* module references (``repro.core.aggregate`` etc.)."""
    return set(re.findall(r"`(repro(?:\.\w+)+)`", text))


def _docstring_errors() -> list[str]:
    """The arena documentation pass, enforced: ``BitmapArena`` (and its
    public methods), ``SimilarityEngine``, and every public API that
    grew an ``arena=`` parameter must document it."""
    errors = []

    def doc_of(node) -> str:
        return ast.get_docstring(node) or ""

    def classes(tree):
        return {n.name: n for n in tree.body
                if isinstance(n, ast.ClassDef)}

    arena_tree = ast.parse((ROOT / "src/repro/core/arena.py").read_text())
    if "docs/MEMORY.md" not in doc_of(arena_tree):
        errors.append("core/arena.py module docstring must point at "
                      "docs/MEMORY.md")
    bmcls = classes(arena_tree).get("BitmapArena")
    if bmcls is None or not doc_of(bmcls):
        errors.append("BitmapArena needs a class docstring")
    else:
        for m in bmcls.body:
            if (isinstance(m, ast.FunctionDef)
                    and not m.name.startswith("_")
                    and not doc_of(m)):
                errors.append(f"BitmapArena.{m.name} needs a docstring")

    pw_tree = ast.parse(
        (ROOT / "src/repro/core/pairwise.py").read_text())
    eng = classes(pw_tree).get("SimilarityEngine")
    if eng is None or "arena" not in doc_of(eng).lower():
        errors.append("SimilarityEngine class docstring must document "
                      "the arena view")

    # PR 8: the whole serde/ingest surface is documented -- every
    # public function in core/serde.py carries a docstring, and the
    # format-bearing entry points point at docs/FORMAT.md
    serde_tree = ast.parse((ROOT / "src/repro/core/serde.py").read_text())
    if "docs/FORMAT.md" not in doc_of(serde_tree):
        errors.append("core/serde.py module docstring must point at "
                      "docs/FORMAT.md")
    for node in serde_tree.body:
        if isinstance(node, ast.FunctionDef) and \
                not node.name.startswith("_") and not doc_of(node):
            errors.append(f"serde.{node.name} needs a docstring")
    snapcls = classes(serde_tree).get("FrozenSnapshot")
    if snapcls is None or not doc_of(snapcls):
        errors.append("serde.FrozenSnapshot needs a class docstring")

    pipe_tree = ast.parse(
        (ROOT / "src/repro/data/pipeline.py").read_text())
    sib = classes(pipe_tree).get("StreamingIndexBuilder")
    if sib is None or "spill" not in doc_of(sib).lower():
        errors.append("StreamingIndexBuilder class docstring must "
                      "describe segment spilling")
    else:
        for m in sib.body:
            if (isinstance(m, ast.FunctionDef)
                    and not m.name.startswith("_") and not doc_of(m)):
                errors.append(
                    f"StreamingIndexBuilder.{m.name} needs a docstring")

    bitmap_tree = ast.parse(
        (ROOT / "src/repro/core/bitmap.py").read_text())
    bm_cls = classes(bitmap_tree).get("RoaringBitmap")
    for want in ("serialize", "deserialize"):
        fn = next((m for m in bm_cls.body
                   if isinstance(m, ast.FunctionDef) and m.name == want),
                  None)
        if fn is None or "docs/FORMAT.md" not in doc_of(fn):
            errors.append(f"RoaringBitmap.{want} must exist and point "
                          "at docs/FORMAT.md")

    # every public function/method with an ``arena`` parameter documents
    # it (the class docstring may carry it for __init__)
    for rel in ("src/repro/core/aggregate.py", "src/repro/core/bitmap.py",
                "src/repro/core/pairwise.py", "src/repro/core/tensor.py",
                "src/repro/data/index.py",
                "src/repro/serve/query_server.py"):
        tree = ast.parse((ROOT / rel).read_text())
        for parent in ast.walk(tree):
            body = getattr(parent, "body", None)
            if not isinstance(body, list):
                continue
            for node in body:
                if not isinstance(node, ast.FunctionDef) or \
                        node.name.startswith("_") and \
                        node.name != "__init__":
                    continue
                args = node.args
                names = [a.arg for a in
                         args.args + args.kwonlyargs]
                if "arena" not in names:
                    continue
                doc = doc_of(node)
                if node.name == "__init__" and isinstance(
                        parent, ast.ClassDef):
                    doc += doc_of(parent)
                if "arena" not in doc.lower():
                    errors.append(
                        f"{rel}: {node.name} takes arena= but does "
                        "not document it")
    return errors


def check() -> list[str]:
    errors = []
    for doc in ("docs/ARCHITECTURE.md", "docs/MEMORY.md",
                "docs/FORMAT.md", "README.md", "benchmarks/README.md"):
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: missing")
            continue
        text = path.read_text()
        for ref in sorted(_referenced_paths(text)):
            if not (ROOT / ref).exists():
                errors.append(f"{doc}: references missing file {ref}")
        for mod in sorted(_referenced_modules(text)):
            rel = mod.replace(".", "/")
            if not ((ROOT / "src" / f"{rel}.py").exists()
                    or (ROOT / "src" / rel / "__init__.py").exists()):
                errors.append(f"{doc}: references missing module {mod}")
    errors += _docstring_errors()
    return errors


def test_architecture_references_exist():
    errors = check()
    assert not errors, "\n".join(errors)


def test_architecture_is_linked_and_nontrivial():
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    # the map must actually cover the paper's core sections
    for needle in ("4.1.1", "4.2", "5.8", "5.9", "one-dispatch",
                   "similarity_topk", "segment_reduce"):
        assert needle in arch, needle
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme, \
        "README must link the architecture guide"
    assert "docs/MEMORY.md" in readme, \
        "README must link the memory-lifecycle guide"
    assert "docs/MEMORY.md" in arch, \
        "ARCHITECTURE.md must link the memory-lifecycle guide"
    mem = (ROOT / "docs" / "MEMORY.md").read_text()
    # the lifecycle guide must actually cover the lifecycle
    for needle in ("state machine", "opy-on-write", "PCIe", "VMEM",
                   "ArenaStats", "row 0"):
        assert needle in mem, needle
    assert "docs/FORMAT.md" in readme, \
        "README must link the on-disk format spec"
    assert "docs/FORMAT.md" in arch, \
        "ARCHITECTURE.md must link the on-disk format spec"
    fmt = (ROOT / "docs" / "FORMAT.md").read_text()
    # the format spec must actually be byte-exact and honest
    for needle in ("RJ02", "12346", "12347", "RJFZ0001", "RJSN0001",
                   "CRC-32", "little-endian", "Worked hex",
                   "honest table", "align("):
        assert needle in fmt, needle


if __name__ == "__main__":
    errs = check()
    for e in errs:
        print(f"FAIL {e}", file=sys.stderr)
    if not errs:
        print("docs references OK")
    sys.exit(1 if errs else 0)
