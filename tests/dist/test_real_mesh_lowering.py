"""The param-sharding RULES on a REAL multi-device mesh.

``tests/dist/test_sharding.py`` pins the rules' *specs* against a
FakeMesh at production axis sizes; until now nothing lowered a step
function under an actual >1-device mesh outside the dry-run driver's own
process.  This suite runs in the ``tests-multidevice`` CI job (4 forced
host devices): it builds a real ``(2, 2) = ("data", "model")`` mesh and
drives ``launch.dryrun.lower_cell`` -- the exact production entry point,
with real ``NamedSharding``s from ``dist.sharding`` -- for one dense and
one MoE config over the train / prefill / decode shape cells.  The train
cell additionally COMPILES, so XLA's SPMD partitioner validates every
param/batch/optimizer spec and the optimized HLO must contain the
cross-device gradient sync the data axis implies.

Under the tier-1 single-device run these tests skip (the process sees
one CPU device; forcing more here would perturb every other suite).
"""

import numpy as np
import pytest

import jax

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (tests-multidevice job forces them)")


@pytest.fixture(scope="module")
def mesh22():
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    return Mesh(mesh_utils.create_device_mesh((2, 2)),
                ("data", "model"))


CELLS = ["train_4k", "prefill_32k", "decode_32k"]


@pytest.mark.parametrize("arch", ["qwen3_14b", "mixtral_8x7b"])
@pytest.mark.parametrize("shape", CELLS)
def test_lower_cell_real_mesh(arch, shape, mesh22):
    """Every (config, step) cell lowers under the real 4-device mesh:
    the sharding rules resolve to committed NamedShardings and tracing
    under ``in_shardings`` validates divisibility of every annotated
    axis (a bad spec raises here, not on a TPU pod)."""
    import repro.configs as C
    from repro.launch import dryrun
    cfg = C.get_config(arch, reduced=True)
    ok, why = C.applicable(cfg, shape)
    assert ok, why
    res = dryrun.lower_cell(cfg, shape, mesh22, compile_=False)
    assert res["chips"] == 4
    assert res["mesh"] == "2x2"
    assert res["step"] == C.SHAPES[shape].step


@pytest.mark.parametrize("arch", ["qwen3_14b", "mixtral_8x7b"])
def test_compile_train_real_mesh(arch, mesh22):
    """The train cell compiles end-to-end under the real mesh and the
    optimized HLO carries cross-device collectives: the data axis forces
    a gradient all-reduce (or reduce-scatter), proof the rules actually
    shard rather than replicate-and-hope."""
    import repro.configs as C
    from repro.launch import dryrun
    cfg = C.get_config(arch, reduced=True)
    res = dryrun.lower_cell(cfg, "train_4k", mesh22, compile_=True)
    coll = res["collectives"]
    assert coll["total"] > 0, coll
    assert coll["all-reduce"] + coll["reduce-scatter"] > 0, coll
    assert res["memory"]["argument_bytes"] is not None


def test_param_shardings_committed_on_device(mesh22):
    """Materializing params with the rules' shardings really places
    shards on 4 distinct devices, and each sharded leaf's per-device
    shard is smaller than the full value (the rules partition, not
    replicate, the big matrices)."""
    import repro.configs as C
    from repro.dist import sharding as SH
    from repro.models import transformer as T
    cfg = C.get_config("qwen3_14b", reduced=True)
    shapes = T.param_shapes(cfg)
    shard = SH.param_shardings(shapes, mesh22)
    leaves, treedef = jax.tree.flatten(shapes)
    shardings = treedef.flatten_up_to(shard)
    partitioned = 0
    for leaf, s in zip(leaves, shardings):
        arr = jax.device_put(np.zeros(leaf.shape, leaf.dtype), s)
        assert arr.sharding.mesh.devices.shape == (2, 2)
        shard_elems = arr.addressable_shards[0].data.size
        if shard_elems < arr.size:
            partitioned += 1
            assert len({sh.device for sh in arr.addressable_shards}) == 4
    assert partitioned > 0
