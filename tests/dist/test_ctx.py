"""dist.ctx: off-mesh no-op degradation, head plans, and the shared wide
mesh wiring with core.aggregate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate
from repro.core.bitmap import RoaringBitmap
from repro.dist import ctx


class FakeMesh:
    def __init__(self, shape=(16, 16), axes=("data", "model")):
        self.axis_names = axes
        self.devices = np.empty(shape, object)


@pytest.fixture(autouse=True)
def _reset():
    yield
    ctx.set_pure_dp(False)
    ctx.set_wide_mesh(None)


# ---------------------------------------------------------------------------
# off-mesh degradation
# ---------------------------------------------------------------------------

def test_off_mesh_is_noop():
    assert ctx.current_mesh() is None
    assert ctx.axis_sizes() == {}
    assert ctx.dp_axes() == ("data",)
    assert ctx.model_axis_size() == 1
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, {0: ctx.dp_axes(), 1: "model"}) is x


def test_attn_head_plan_off_mesh_is_dp():
    assert ctx.attn_head_plan(8, 4, 128) == "dp"


# ---------------------------------------------------------------------------
# with a mesh
# ---------------------------------------------------------------------------

def test_activate_sets_and_restores():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with ctx.activate(mesh):
        assert ctx.current_mesh() is mesh
        assert ctx.axis_sizes() == {"data": 1, "model": 1}
        assert ctx.dp_axes() == ("data",)
        y = ctx.constrain(jnp.ones((4, 4)), {0: "data"})
        np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))
    assert ctx.current_mesh() is None


def test_constrain_under_jit_traces():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def f(x):
        return ctx.constrain(x, {0: ctx.dp_axes(), 1: "model"}) * 2
    with ctx.activate(mesh):
        out = jax.jit(f)(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((4, 4)))


def test_constrain_drops_absent_and_non_dividing_axes(monkeypatch):
    monkeypatch.setattr(ctx, "_ACTIVE_MESH", FakeMesh())
    x = jnp.ones((7, 5))
    # 7 % 16 and 5 % 16: both constraints drop -> identity (no jax call,
    # which would fail against the fake mesh)
    assert ctx.constrain(x, {0: "data", 1: "model"}) is x
    # an axis the mesh doesn't have drops too
    assert ctx.constrain(x, {0: "wide"}) is x


def test_axis_queries_against_mesh_shape(monkeypatch):
    monkeypatch.setattr(
        ctx, "_ACTIVE_MESH", FakeMesh((2, 4, 8), ("pod", "data", "model")))
    assert ctx.axis_sizes() == {"pod": 2, "data": 4, "model": 8}
    assert ctx.dp_axes() == ("pod", "data")
    assert ctx.model_axis_size() == 8
    ctx.set_pure_dp(True)
    assert ctx.dp_axes() == ("pod", "data", "model")
    assert ctx.model_axis_size() == 1


def test_constrain_pure_dp_no_duplicate_axes():
    # under pure-dp, dp_axes() includes "model"; a call constraining both
    # the batch dim and an explicit "model" dim (models/mlp.py MoE path)
    # must dedupe instead of building an invalid duplicate-axis spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx.set_pure_dp(True)
    with ctx.activate(mesh):
        assert ctx.dp_axes() == ("data", "model")
        out = ctx.constrain(jnp.ones((4, 4)), {0: ctx.dp_axes(), 1: "model"})
    np.testing.assert_array_equal(np.asarray(out), np.ones((4, 4)))


def test_attn_head_plan_divisibility(monkeypatch):
    monkeypatch.setattr(ctx, "_ACTIVE_MESH", FakeMesh((1, 16)))
    assert ctx.attn_head_plan(16, 4, 128) == "hkv"
    assert ctx.attn_head_plan(2, 16, 128) == "g"
    assert ctx.attn_head_plan(8, 2, 128) == "auto"   # joint 16 divides
    assert ctx.attn_head_plan(3, 5, 128) == "qc"
    assert ctx.attn_head_plan(3, 5, 127) == "dp"
    ctx.set_pure_dp(True)
    assert ctx.attn_head_plan(16, 4, 128) == "dp"


# ---------------------------------------------------------------------------
# one wide-mesh source of truth with core.aggregate
# ---------------------------------------------------------------------------

def test_aggregate_default_mesh_is_ctx_state():
    mesh = object()
    aggregate.set_default_mesh(mesh)
    assert ctx.wide_mesh() is mesh
    assert aggregate._resolve_mesh(None) is mesh
    ctx.set_wide_mesh(None)
    assert aggregate._resolve_mesh(None) is None


def test_install_wide_mesh_feeds_aggregates():
    mesh = ctx.install_wide_mesh()
    try:
        assert mesh.axis_names == ("wide",)
        assert aggregate._resolve_mesh(None) is mesh
        # 1-device host: aggregates fall back transparently and stay exact
        bms = [RoaringBitmap.from_values([1, 5, 70000 + i])
               for i in range(4)]
        got = RoaringBitmap.or_many(bms).to_array().tolist()
        assert got == sorted({1, 5} | {70000 + i for i in range(4)})
    finally:
        aggregate.set_default_mesh(None)
